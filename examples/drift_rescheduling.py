"""Online rescheduling walkthrough: watch a placement go stale as the
workload drifts, then adapt with a warm-started reschedule (DESIGN.md §7).

Run:  PYTHONPATH=src python examples/drift_rescheduling.py
"""
from repro.core import (LLAMA2_70B, WORKLOADS, WorkloadMonitor, reschedule,
                        schedule)
from repro.core.cluster import heterogeneous_setting_1
from repro.serving import (TracePhase, drifting_workload, simulate,
                          simulate_online, slo_baselines)

cluster = heterogeneous_setting_1()
profile = LLAMA2_70B
wl0 = WORKLOADS["HPLD"]

print("== offline schedule for the initial (heavy-prefill) mix")
sched0 = schedule(cluster, profile, wl0, max_refine_iters=6)
print(sched0.placement.describe(cluster), "\n")

phases = [TracePhase(150.0, 0.6 * sched0.placement.throughput_rps,
                     {"HPLD": 1.0}),
          TracePhase(450.0, 8.0, {"LPHD": 1.0})]
print("== trace drifts HPLD -> LPHD at t=150s "
      f"({phases[0].rate_rps:.1f} -> {phases[1].rate_rps:.1f} req/s)\n")

static = simulate(cluster, profile, sched0.placement,
                  drifting_workload(phases, seed=3))
slo = slo_baselines(cluster, profile, sched0.placement, static.requests)
print(f"static placement : {static.decode_throughput:7.0f} tok/s, "
      f"slo5x={static.slo_attainment(slo, 5.0):.3f}, "
      f"avg_lat={static.avg_latency:.1f}s")

monitor = WorkloadMonitor(wl0, window=64, threshold=0.3,
                          min_observations=32)
online = simulate_online(
    cluster, profile, sched0.placement, drifting_workload(phases, seed=3),
    monitor=monitor,
    rescheduler=lambda wl: reschedule(cluster, profile, sched0, wl,
                                      max_refine_iters=8).placement,
    min_gap_s=120.0)
slo = slo_baselines(cluster, profile, sched0.placement, online.requests)
print(f"online reschedule: {online.decode_throughput:7.0f} tok/s, "
      f"slo5x={online.slo_attainment(slo, 5.0):.3f}, "
      f"avg_lat={online.avg_latency:.1f}s")
for ev in online.reschedules:
    print(f"  swap @ {ev.time:5.0f}s  drain={ev.drain_s:5.2f}s  "
          f"kv_migrated={ev.migrated:3d}  restarted={ev.restarted:2d}  "
          f"new_flow={ev.max_flow:.0f}/T")
