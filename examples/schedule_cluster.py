"""Scheduler deep-dive: compare the max-flow-guided search against the
truncated (random-swap) variant and the genetic algorithm on every
paper workload class, and show the refinement trace (paper Fig. 10/11).

Run:  PYTHONPATH=src python examples/schedule_cluster.py
"""
from repro.core import (LLAMA2_70B, WORKLOADS, genetic_schedule,
                        random_swap_schedule, schedule)
from repro.core.cluster import heterogeneous_setting_2

cluster = heterogeneous_setting_2()
print(cluster.describe(), "\n")

for wl_name, wl in WORKLOADS.items():
    ours = schedule(cluster, LLAMA2_70B, wl, max_refine_iters=10)
    rand = random_swap_schedule(cluster, LLAMA2_70B, wl)
    gen = genetic_schedule(cluster, LLAMA2_70B, wl, population=8,
                           generations=10)
    print(f"== {wl_name} (s_in={wl.s_in}, s_out={wl.s_out})")
    print(f"  max-flow swap : flow={ours.placement.max_flow:8.0f}/T "
          f"in {ours.elapsed_s:.2f}s")
    for tr in ours.trace:
        print(f"     step {tr.step}: {tr.max_flow:8.0f}  ({tr.action})")
    print(f"  random swap   : flow={rand.placement.max_flow:8.0f}/T "
          f"in {rand.elapsed_s:.2f}s")
    print(f"  genetic       : flow={gen.placement.max_flow:8.0f}/T "
          f"in {gen.elapsed_s:.2f}s")
    print()
