"""Train a ~180M-parameter xLSTM (the smallest assigned arch at FULL
config) for a few hundred steps on the synthetic pipeline, with
checkpointing — the training-side end-to-end driver.

CPU note: the full 12-layer xLSTM at d_model=768 trains slowly on one
CPU; pass --reduced for a fast smoke run (default here) or --full for
the real 125M-class model.

Run:  PYTHONPATH=src python examples/train_small.py [--full] [--steps N]
"""
import argparse
import tempfile

from repro.configs import get_config
from repro.training import checkpoint, train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = get_config("xlstm-125m")
    if not args.full:
        cfg = cfg.reduced()
    ckpt_dir = tempfile.mkdtemp(prefix="xlstm_ckpt_")
    print(f"training {cfg.name}: {cfg.num_layers}L d={cfg.d_model} "
          f"steps={args.steps} ckpt={ckpt_dir}")
    res = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                ckpt_dir=ckpt_dir, ckpt_every=max(args.steps // 2, 1),
                verbose=True, log_every=20)
    print(f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} over "
          f"{res.tokens_seen} tokens in {res.elapsed_s:.1f}s")
    print("latest checkpoint step:", checkpoint.latest_step(ckpt_dir))
    assert res.losses[-1] < res.losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
