"""End-to-end driver: REAL disaggregated serving with JAX engines.

A prefill engine turns prompts into (first token, KV cache); the cache
is resharded/transferred to decode engines running continuous batching
over fixed slots; dispatch is flow-proportional. Output is verified
token-identical to a monolithic generate loop.

Run:  PYTHONPATH=src python examples/disaggregated_serving.py \
          [--arch qwen3-1.7b] [--requests 6]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED, get_config
from repro.models import decode_step, init_params, prefill
from repro.serving import Coordinator, ServeRequest


def monolithic(cfg, params, prompt, n_new, capacity):
    logits, cache = prefill(params, cfg, jnp.asarray(prompt)[None],
                            cache_capacity=capacity)
    toks = [int(jnp.argmax(logits, -1)[0])]
    pos = len(prompt)
    for _ in range(n_new - 1):
        lg, cache = decode_step(params, cfg, cache,
                                jnp.array([[toks[-1]]], jnp.int32),
                                jnp.array([[pos]], jnp.int32))
        toks.append(int(jnp.argmax(lg, -1)[0]))
        pos += 1
    return toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=ASSIGNED)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=6)
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)
               for _ in range(args.requests)]
    capacity = 8 + args.max_new + 4

    coord = Coordinator(cfg, params, num_decode_engines=2,
                        slots_per_engine=2, capacity=capacity,
                        route_weights=[2.0, 1.0])  # flow-proportional
    t0 = time.perf_counter()
    outs = coord.serve([ServeRequest(i, prompts[i], args.max_new)
                        for i in range(args.requests)])
    dt = time.perf_counter() - t0

    ok = 0
    for i, out in enumerate(outs):
        ref = monolithic(cfg, params, list(prompts[i]), args.max_new,
                         capacity)
        match = out.tokens == ref
        ok += match
        print(f"req {i}: disagg={out.tokens} "
              f"{'== monolithic' if match else f'!= {ref}'}")
    print(f"\n{ok}/{len(outs)} token-identical; served in {dt:.1f}s "
          f"(incl. jit) across 1 prefill + 2 decode engines")
    assert ok == len(outs)


if __name__ == "__main__":
    main()
