"""End-to-end driver: REAL disaggregated serving with JAX engines.

Uses the event-driven ``ServeSession`` API (DESIGN.md §8): requests are
submitted non-blocking, prefill runs as bucketed/padded micro-batches,
the KV cache is resharded/transferred to decode engines running
continuous batching over fixed slots, and tokens stream back through
callbacks. Output is verified token-identical to a monolithic generate
loop and to the legacy blocking ``Coordinator.serve`` wrapper, and the
run reports the shared runtime/simulator metrics schema.

Run:  PYTHONPATH=src python examples/disaggregated_serving.py \
          [--arch qwen3-1.7b] [--requests 6]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ASSIGNED, get_config
from repro.models import decode_step, init_params, prefill
from repro.serving import Coordinator, ServeRequest


def monolithic(cfg, params, prompt, n_new, capacity):
    logits, cache = prefill(params, cfg, jnp.asarray(prompt)[None],
                            cache_capacity=capacity)
    toks = [int(jnp.argmax(logits, -1)[0])]
    pos = len(prompt)
    for _ in range(n_new - 1):
        lg, cache = decode_step(params, cfg, cache,
                                jnp.array([[toks[-1]]], jnp.int32),
                                jnp.array([[pos]], jnp.int32))
        toks.append(int(jnp.argmax(lg, -1)[0]))
        pos += 1
    return toks


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=ASSIGNED)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--max-new", type=int, default=6)
    ap.add_argument("--kv-codec", choices=("none", "int8", "int8-chunked"),
                    default="none",
                    help="KV-handoff wire format (DESIGN.md §10); int8 "
                         "variants ship the cache compressed, decode-side "
                         "logits stay within the documented tolerance")
    args = ap.parse_args()

    cfg = get_config(args.arch).reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab, 8).astype(np.int32)
               for _ in range(args.requests)]
    capacity = 8 + args.max_new + 4

    coord = Coordinator(cfg, params, num_decode_engines=2,
                        slots_per_engine=2, capacity=capacity,
                        route_weights=[2.0, 1.0],  # flow-proportional
                        kv_codec=args.kv_codec)

    # -- session API: submit / step / stream ---------------------------
    streamed = {i: [] for i in range(args.requests)}
    sess = coord.session()
    t0 = time.perf_counter()
    for i in range(args.requests):
        sess.submit(ServeRequest(i, prompts[i], args.max_new),
                    on_token=lambda rid, tok, fin: streamed[rid].append(tok))
    while sess.unfinished:
        sess.step()     # prefill | KV handoff | decode — non-blocking
    dt = time.perf_counter() - t0
    outs = sess.results()

    ok = 0
    for i, out in enumerate(outs):
        ref = monolithic(cfg, params, list(prompts[i]), args.max_new,
                         capacity)
        match = out.tokens == ref and streamed[i] == ref
        ok += match
        print(f"req {i}: session={out.tokens} "
              f"{'== monolithic == stream' if match else f'!= {ref}'}")
    m = sess.metrics()
    print(f"\n{ok}/{len(outs)} token-identical; served in {dt:.1f}s "
          f"(incl. jit) across 1 prefill + 2 decode engines")
    print(f"metrics (shared schema): throughput={m.decode_throughput:.1f}"
          f"tok/s avg_ttft={m.avg_ttft * 1e3:.0f}ms "
          f"avg_tpot={m.avg_tpot * 1e3:.0f}ms")
    if args.kv_codec != "none":
        print(f"kv codec {args.kv_codec}: shipped={m.kv_bytes_shipped:.0f}B "
              f"ratio={m.kv_compression_ratio:.2f} "
              f"(token match vs exact handoff: {ok}/{len(outs)})")
    else:
        # exact codec: the handoff is bit-identical, so the session MUST
        # reproduce the monolithic generate loop token for token
        assert ok == len(outs)

    # -- legacy wrapper: byte-for-byte the session output --------------
    legacy = coord.serve([ServeRequest(100 + i, prompts[i], args.max_new)
                          for i in range(args.requests)])
    assert all(lo.tokens == so.tokens for lo, so in zip(legacy, outs))
    print("legacy serve() wrapper == session output")


if __name__ == "__main__":
    main()
