"""Quickstart: schedule a heterogeneous cluster for disaggregated
LLaMA-2-70B serving and simulate the result — the paper's core loop in
~30 lines of API.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import HPHD, LLAMA2_70B, schedule
from repro.core.cluster import heterogeneous_setting_1
from repro.serving import offline_workload, simulate, simulate_colocated

# 1. A heterogeneous GPU pool (paper Figure 4, setting 1):
#    2×H100 + 6×A100 + 4×L40 + 8×A6000 across six nodes.
cluster = heterogeneous_setting_1()
print(cluster.describe())

# 2. Run the HexGen-2 scheduler: graph partition (spectral + KL) →
#    per-replica TP×PP search + preflow-push max-flow → max-flow-guided
#    iterative refinement.
result = schedule(cluster, LLAMA2_70B, HPHD)
print(f"\nscheduled in {result.elapsed_s:.2f}s, "
      f"{len(result.trace)} refinement steps")
print(result.placement.describe(cluster))

# 3. Serve 100 heavy-prefill/heavy-decode requests through the
#    event-driven simulator, disaggregated vs colocated baseline.
#    SimResult reports the shared serving-metrics schema (DESIGN.md §8)
#    — the runtime Coordinator's ServeSession.metrics() has the same
#    fields, so simulated and real runs are directly comparable.
reqs = offline_workload("HPHD", 100, seed=0)
sim = simulate(cluster, LLAMA2_70B, result.placement, reqs)
col = simulate_colocated(cluster, LLAMA2_70B, result.placement.replicas,
                         offline_workload("HPHD", 100, seed=0))
print(f"\nHexGen-2 (disaggregated): {sim.decode_throughput:.0f} tok/s, "
      f"avg latency {sim.avg_latency:.1f}s, avg TTFT {sim.avg_ttft:.1f}s, "
      f"avg TPOT {sim.avg_tpot * 1e3:.0f}ms")
print(f"HexGen  (colocated)     : {col.decode_throughput:.0f} tok/s, "
      f"avg latency {col.avg_latency:.1f}s, avg TTFT {col.avg_ttft:.1f}s, "
      f"avg TPOT {col.avg_tpot * 1e3:.0f}ms")
print(f"speedup: {sim.decode_throughput / col.decode_throughput:.2f}x")
