"""TPU v5e hardware constants for the roofline model."""

PEAK_FLOPS_BF16 = 197e12        # FLOP/s per chip
HBM_BANDWIDTH = 819e9           # bytes/s per chip
ICI_LINK_BANDWIDTH = 50e9       # bytes/s per link (per direction, approx.)
HBM_BYTES = 16 * 2**30          # per chip

CHIPS_PER_POD = 256
