"""HLO-text cost analyzer with while-loop trip expansion.

``compiled.cost_analysis()`` counts a ``while`` body ONCE (verified in
EXPERIMENTS.md §Dry-run): our models scan over layer periods and (for
SSM mixers) over time, so raw cost_analysis undercounts by the trip
count. This analyzer parses the post-SPMD optimized HLO text and:

  * counts dot FLOPs exactly (2 · prod(result_dims) · K) per dot,
  * models HBM traffic as Σ over top-level instructions of
    (operand + result bytes) — post-fusion, each top-level instruction
    materializes its buffers, so this is the first-order traffic model,
  * sums collective result bytes per kind,
  * recursively multiplies ``while`` bodies by their trip counts
    (read from the loop-condition comparison constant).

All numbers are PER DEVICE (the post-SPMD module is per-partition).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s2": 1, "u2": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute", "collective-broadcast")
# instruction: `%name = <shapes> opcode(...)` (names may lack % in new dumps)
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?.*?\)?)\s*"
    r"([a-z][a-z0-9\-]*)\(")
_CALLED_RE = re.compile(r"(?:calls|branch_computations)=\{?%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%?([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_TRIP_RE = re.compile(r'known_trip_count\D+(\d+)')

_NO_TRAFFIC_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "bitcast-convert", "reshape", "after-all", "partition-id", "replica-id",
    "iota",
}


def _shape_info(shape_str: str) -> Tuple[int, List[Tuple[str, List[int]]]]:
    """Total bytes + list of (dtype, dims) for a (possibly tuple) shape."""
    total = 0
    shapes = []
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        dl = [int(d) for d in dims.split(",")] if dims else []
        n = 1
        for d in dl:
            n *= d
        total += n * _DTYPE_BYTES[dtype]
        shapes.append((dtype, dl))
    return total, shapes


@dataclasses.dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})

    def __iadd__(self, other: "Cost") -> "Cost":
        self.flops += other.flops
        self.bytes += other.bytes
        for k in self.coll:
            self.coll[k] += other.coll[k]
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m,
                    {k: v * m for k, v in self.coll.items()})

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


@dataclasses.dataclass
class _Instr:
    name: str
    shape_str: str
    opcode: str
    line: str
    result_bytes: int


class HloModule:
    def __init__(self, text: str):
        self.computations: Dict[str, List[_Instr]] = {}
        self._parse(text)
        self._memo: Dict[str, Cost] = {}
        self.entry = self._find_entry(text)

    # ------------------------------------------------------------------
    def _parse(self, text: str) -> None:
        cur: Optional[str] = None
        for raw in text.splitlines():
            line = raw.rstrip()
            stripped = line.strip()
            # computation header: `%name (args) -> shape {` or `ENTRY %name ...{`
            if stripped.endswith("{") and ("->" in stripped
                                           or stripped.startswith("ENTRY")):
                m = re.match(r"(?:ENTRY\s+)?%?([\w.\-]+)\s*\(", stripped)
                if m:
                    cur = m.group(1)
                    self.computations[cur] = []
                continue
            if stripped == "}" or stripped.startswith("} "):
                cur = None
                continue
            if cur is None:
                continue
            im = _INSTR_RE.match(line)
            if not im:
                continue
            name, shape_str, opcode = im.groups()
            rb, _ = _shape_info(shape_str)
            self.computations[cur].append(
                _Instr(name, shape_str, opcode, line, rb))

    def _find_entry(self, text: str) -> str:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", text)
        if m and m.group(1) in self.computations:
            return m.group(1)
        # fallback: the largest computation
        return max(self.computations, key=lambda k: len(self.computations[k]))

    # ------------------------------------------------------------------
    def _operand_list(self, comp: str, instr: _Instr) -> List[int]:
        """Ordered operand byte sizes (resolved within the computation)."""
        inside = instr.line.split("(", 1)[1]
        inside = inside.split(")", 1)[0]
        shapes = {i.name: i.result_bytes for i in self.computations[comp]}
        return [shapes[tok] for tok in _OPERAND_RE.findall(inside)
                if tok in shapes]

    def _traffic_bytes(self, comp: str, instr: _Instr) -> float:
        """HBM traffic model per instruction — results-only plus dot
        operand reads.

        Rationale: every materializing instruction writes its result once
        (and that buffer is read by consumers, which we charge at the
        consumer only for dots — the heavy readers of weights/caches that
        arrive as loop-carried parameters and would otherwise be
        uncounted). Counting operands of arbitrary fusions double-charges
        whole loop-carried buffers that the fusion only slices.

          dot                   → result + Σ operands (weights/cache reads)
          dynamic-slice         → 2 × result (read + write the slice)
          dynamic-update-slice  → 2 × update operand (in-place)
          gather                → 2 × result + indices
          scatter               → 2 × updates + indices (in-place)
          copy                  → 2 × result
          fusion w/ DUS root    → 2 × inner update bytes
          everything else       → result bytes
        """
        op = instr.opcode
        ops = self._operand_list(comp, instr)
        if op == "dot":
            return float(instr.result_bytes + sum(ops))
        if op == "dynamic-slice":
            return 2.0 * instr.result_bytes
        if op == "dynamic-update-slice":
            upd = ops[1] if len(ops) > 1 else instr.result_bytes
            return 2.0 * upd
        if op == "gather":
            idx = ops[1] if len(ops) > 1 else 0
            return 2.0 * instr.result_bytes + idx
        if op == "scatter":
            idx = ops[1] if len(ops) > 1 else 0
            upd = ops[2] if len(ops) > 2 else instr.result_bytes
            return 2.0 * upd + idx
        if op == "copy":
            return 2.0 * instr.result_bytes
        if op == "fusion":
            dus = self._fusion_dus_update_bytes(instr)
            if dus is not None:
                return 2.0 * dus
            sc = self._fusion_scatter_update_bytes(instr)
            if sc is not None:
                return 2.0 * sc
        return float(instr.result_bytes)

    def _fusion_dus_update_bytes(self, instr: _Instr) -> Optional[int]:
        """If the fused computation is a (possibly convert-wrapped)
        dynamic-update-slice of the fusion's full result, the fusion is
        in-place: traffic is the inner update operand size. (CPU bf16
        emulation wraps the DUS in converts; a real TPU lowering updates
        the slice in place.)"""
        _, res_shapes = _shape_info(instr.shape_str)
        res_elems = 0
        if res_shapes:
            res_elems = 1
            for d in res_shapes[0][1]:
                res_elems *= d
        for called in _CALLED_RE.findall(instr.line):
            instrs = self.computations.get(called, [])
            names = {i.name: i for i in instrs}
            for inner in instrs:
                if inner.opcode != "dynamic-update-slice":
                    continue
                _, inner_shapes = _shape_info(inner.shape_str)
                elems = 1
                for d in (inner_shapes[0][1] if inner_shapes else []):
                    elems *= d
                if res_elems and elems != res_elems:
                    continue
                inside = inner.line.split("(", 1)[1].split(")", 1)[0]
                toks = [t for t in _OPERAND_RE.findall(inside)
                        if t in names]
                if len(toks) > 1:
                    return names[toks[1]].result_bytes
        return None

    def _fusion_scatter_update_bytes(self, instr: _Instr) -> Optional[int]:
        """Scatter-rooted fusions writing a same-size buffer are in-place:
        traffic ≈ updates + indices, not the whole buffer."""
        _, res_shapes = _shape_info(instr.shape_str)
        res_elems = 0
        if res_shapes:
            res_elems = 1
            for d in res_shapes[0][1]:
                res_elems *= d
        for called in _CALLED_RE.findall(instr.line):
            instrs = self.computations.get(called, [])
            names = {i.name: i for i in instrs}
            for inner in instrs:
                if inner.opcode != "scatter":
                    continue
                _, inner_shapes = _shape_info(inner.shape_str)
                elems = 1
                for d in (inner_shapes[0][1] if inner_shapes else []):
                    elems *= d
                if res_elems and elems != res_elems:
                    continue
                inside = inner.line.split("(", 1)[1].split(")", 1)[0]
                toks = [t for t in _OPERAND_RE.findall(inside)
                        if t in names]
                if len(toks) > 2:
                    return (names[toks[2]].result_bytes
                            + names[toks[1]].result_bytes)
        return None

    def _dot_flops(self, instr: _Instr) -> float:
        """2 · prod(result) · K from lhs shape + contracting dims."""
        _, res_shapes = _shape_info(instr.shape_str)
        if not res_shapes:
            return 0.0
        res_elems = 1
        for d in res_shapes[0][1]:
            res_elems *= d
        # lhs shape: first shape inside the parens
        inside = instr.line.split("(", 1)[1]
        m = _SHAPE_RE.search(inside)
        lhs_dims: Optional[List[int]] = None
        if m and m.group(2):
            lhs_dims = [int(d) for d in m.group(2).split(",")]
        else:
            # operands referenced by name: resolve lhs via first operand
            comp = self._comp_of(instr)
            if comp is not None:
                names = {i.name: i for i in self.computations[comp]}
                toks = _OPERAND_RE.findall(inside)
                for tok in toks:
                    if tok in names:
                        _, shp = _shape_info(names[tok].shape_str)
                        if shp:
                            lhs_dims = shp[0][1]
                        break
        cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.line)
        if lhs_dims is None or cm is None:
            return 0.0
        k = 1
        if cm.group(1):
            for idx in cm.group(1).split(","):
                k *= lhs_dims[int(idx)]
        return 2.0 * res_elems * k

    def _comp_of(self, instr: _Instr) -> Optional[str]:
        for cname, instrs in self.computations.items():
            if instr in instrs:
                return cname
        return None  # pragma: no cover

    def _trip_count(self, cond_comp: str) -> int:
        """Largest integer constant in the loop condition ≈ trip count."""
        best = 1
        for i in self.computations.get(cond_comp, []):
            for c in _CONST_RE.findall(i.line):
                best = max(best, int(c))
        return best

    # ------------------------------------------------------------------
    def computation_cost(self, comp: str) -> Cost:
        if comp in self._memo:
            return self._memo[comp]
        total = Cost()
        self._memo[comp] = total  # guard against cycles
        for instr in self.computations.get(comp, []):
            op = instr.opcode
            if op == "while":
                bm = _BODY_RE.search(instr.line)
                if bm:
                    tm = _TRIP_RE.search(instr.line)
                    if tm:
                        trips = int(tm.group(1))
                    else:
                        cm = _COND_RE.search(instr.line)
                        trips = self._trip_count(cm.group(1)) if cm else 1
                    total += self.computation_cost(bm.group(1)).scaled(trips)
                    continue
            if op in ("call", "conditional"):
                for called in _CALLED_RE.findall(instr.line):
                    if called in self.computations:
                        total += self.computation_cost(called)
            if op == "fusion":
                # dots occasionally live inside fusions: count their FLOPs
                # (traffic is already modeled by the fusion's own buffers)
                for called in _CALLED_RE.findall(instr.line):
                    total.flops += self._flops_only(called)
            if op == "dot":
                total.flops += self._dot_flops(instr)
            base = next((c for c in _COLLECTIVES if op.startswith(c)), None)
            if base and not op.endswith("-done"):
                total.coll[base] += instr.result_bytes
            if op in _NO_TRAFFIC_OPS or op.endswith("-done"):
                continue
            total.bytes += self._traffic_bytes(comp, instr)
        self._memo[comp] = total
        return total

    def _flops_only(self, comp: str) -> float:
        flops = 0.0
        for instr in self.computations.get(comp, []):
            if instr.opcode == "dot":
                flops += self._dot_flops(instr)
            elif instr.opcode == "fusion":
                for called in _CALLED_RE.findall(instr.line):
                    if called != comp:
                        flops += self._flops_only(called)
        return flops

    def entry_cost(self) -> Cost:
        return self.computation_cost(self.entry)


def analyze_text(hlo_text: str) -> Cost:
    return HloModule(hlo_text).entry_cost()
