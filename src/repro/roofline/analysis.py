"""Roofline analysis from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds. NOTE (verified
empirically): after SPMD partitioning ``compiled.cost_analysis()``
reports the PER-DEVICE module, so HLO_FLOPs/HLO_bytes are already
per-chip — the global figures divided by the chip count:

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / link_bw

HLO_FLOPs / bytes come from ``compiled.cost_analysis()``. Collective
bytes are NOT in cost_analysis: we parse the post-SPMD HLO text and sum
the result-shape bytes of every all-gather / all-reduce / reduce-scatter
/ all-to-all / collective-permute op (per-device shapes after
partitioning, so the sum is per-device wire traffic to first order).

MODEL_FLOPS = 6·N·D (training) or 2·N·D (inference fwd) with N =
active params; the ratio MODEL_FLOPS / HLO_FLOPs exposes remat /
redundant compute.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# `%op = TYPE[d0,d1]{layout} collective-name(` — also matches tuple results
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[\w\[\],{}\s/#*]+?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-collective-kind result bytes summed over the module.

    ``-start`` ops are counted; their ``-done`` twins are skipped to
    avoid double counting.
    """
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(shape_str)
    return out


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_breakdown: Dict[str, int]
    model_flops: float
    peak_bytes_per_chip: float = 0.0
    raw_flops: float = 0.0     # uncorrected cost_analysis (scan body once)
    raw_bytes: float = 0.0

    @property
    def t_compute(self) -> float:
        # hlo_flops is per-chip (post-SPMD module)
        return self.hlo_flops / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / hw.HBM_BANDWIDTH

    @property
    def t_collective(self) -> float:
        # coll_bytes is per-device wire traffic (post-SPMD shapes)
        return self.coll_bytes / hw.ICI_LINK_BANDWIDTH

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS (global) vs compiled FLOPs (per-chip × chips)."""
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    def row(self) -> str:
        return (f"{self.arch:26s} {self.shape:12s} {self.mesh:9s} "
                f"comp={self.t_compute:9.3e}s mem={self.t_memory:9.3e}s "
                f"coll={self.t_collective:9.3e}s -> {self.bottleneck:10s} "
                f"useful={self.useful_flops_ratio:6.2%}")


def analyze(arch: str, shape_name: str, mesh_name: str, chips: int,
            compiled, lowered_text: Optional[str],
            model_flops: float) -> RooflineReport:
    """Primary costs come from the while-expanding HLO-text analyzer
    (repro.roofline.hlo_cost) — raw cost_analysis() counts scan bodies
    once and would undercount our period/time-scanned models. The raw
    numbers are kept in raw_* fields as a cross-check."""
    from repro.roofline.hlo_cost import analyze_text
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older API returns [dict]
        cost = cost[0]
    raw_flops = float(cost.get("flops", 0.0))
    raw_bytes = float(cost.get("bytes accessed", 0.0))
    text = lowered_text if lowered_text is not None else compiled.as_text()
    parsed = analyze_text(text)
    mem = compiled.memory_analysis()
    peak = getattr(mem, "peak_memory_in_bytes",
                   getattr(mem, "temp_size_in_bytes", 0) or 0)
    return RooflineReport(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=max(parsed.flops, raw_flops),
        hlo_bytes=max(parsed.bytes, raw_bytes),
        coll_bytes=parsed.coll_bytes,
        coll_breakdown={k: int(v) for k, v in parsed.coll.items()},
        model_flops=model_flops, peak_bytes_per_chip=float(peak or 0),
        raw_flops=raw_flops, raw_bytes=raw_bytes)
