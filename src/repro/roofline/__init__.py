"""Roofline analysis from compiled dry-run artifacts."""
from repro.roofline import hw
from repro.roofline.analysis import (RooflineReport, analyze,
                                     collective_bytes)

__all__ = ["hw", "RooflineReport", "analyze", "collective_bytes"]
