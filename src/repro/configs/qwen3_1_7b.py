"""Qwen3-1.7B [hf:Qwen/Qwen3-8B family] — dense GQA with per-head
QK-RMSNorm (qk_norm) and no QKV bias."""
from repro.configs.base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    d_model=2048,
    num_heads=16,
    kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab=151936,
    period=(BlockSpec("attn", "mlp"),),
    num_periods=28,
    activation="swiglu",
    qk_norm=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B (family card)",
)
