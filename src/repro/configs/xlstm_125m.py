"""xLSTM-125M [arXiv:2405.04517] — sLSTM + mLSTM blocks, no FFN (d_ff=0).

Period of 4 (3 mLSTM : 1 sLSTM ≈ the paper's mostly-mLSTM mixes);
12 layers total. The recurrent state is the "KV cache": O(1) in
sequence length, so long_500k runs natively.
"""
from repro.configs.base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="xlstm-125m",
    family="ssm",
    d_model=768,
    num_heads=4,
    kv_heads=4,
    head_dim=192,
    d_ff=0,
    vocab=50304,
    period=(
        BlockSpec("mlstm", "none"),
        BlockSpec("mlstm", "none"),
        BlockSpec("mlstm", "none"),
        BlockSpec("slstm", "none"),
    ),
    num_periods=3,
    xlstm_heads=4,
    source="arXiv:2405.04517 (xLSTM)",
)
