"""Architecture registry: ``get_config(arch_id)`` / ``ARCHS``.

One module per assigned architecture; each config cites its source in
``source=``. The paper's own evaluation models (OPT-30B, LLaMA-2-70B)
are included for the reproduction benchmarks.
"""
from repro.configs.base import (ArchConfig, BlockSpec, InputShape,
                                INPUT_SHAPES, TRAIN_4K, PREFILL_32K,
                                DECODE_32K, LONG_500K, input_specs)

from repro.configs.xlstm_125m import CONFIG as XLSTM_125M
from repro.configs.yi_34b import CONFIG as YI_34B
from repro.configs.whisper_large_v3 import CONFIG as WHISPER_LARGE_V3
from repro.configs.llama_3_2_vision_90b import CONFIG as LLAMA_3_2_VISION_90B
from repro.configs.qwen3_1_7b import CONFIG as QWEN3_1_7B
from repro.configs.jamba_v0_1_52b import CONFIG as JAMBA_V0_1_52B
from repro.configs.nemotron_4_15b import CONFIG as NEMOTRON_4_15B
from repro.configs.qwen2_5_32b import CONFIG as QWEN2_5_32B
from repro.configs.llama4_maverick_400b import CONFIG as LLAMA4_MAVERICK_400B
from repro.configs.qwen3_moe_30b import CONFIG as QWEN3_MOE_30B
from repro.configs.opt_30b import CONFIG as OPT_30B_ARCH
from repro.configs.llama2_70b import CONFIG as LLAMA2_70B_ARCH

ARCHS = {
    c.name: c for c in (
        XLSTM_125M, YI_34B, WHISPER_LARGE_V3, LLAMA_3_2_VISION_90B,
        QWEN3_1_7B, JAMBA_V0_1_52B, NEMOTRON_4_15B, QWEN2_5_32B,
        LLAMA4_MAVERICK_400B, QWEN3_MOE_30B, OPT_30B_ARCH, LLAMA2_70B_ARCH,
    )
}

ASSIGNED = [
    "xlstm-125m", "yi-34b", "whisper-large-v3", "llama-3.2-vision-90b",
    "qwen3-1.7b", "jamba-v0.1-52b", "nemotron-4-15b", "qwen2.5-32b",
    "llama4-maverick-400b-a17b", "qwen3-moe-30b-a3b",
]


def get_config(arch_id: str) -> ArchConfig:
    if arch_id not in ARCHS:
        raise KeyError(f"unknown arch '{arch_id}'; known: {sorted(ARCHS)}")
    return ARCHS[arch_id]


__all__ = ["ArchConfig", "BlockSpec", "InputShape", "INPUT_SHAPES",
           "TRAIN_4K", "PREFILL_32K", "DECODE_32K", "LONG_500K",
           "input_specs", "ARCHS", "ASSIGNED", "get_config"]
