"""Whisper-large-v3 [arXiv:2212.04356] — encoder-decoder audio backbone.

The mel-spectrogram + conv feature extractor is a STUB per the
assignment: ``input_specs()`` provides 1500 precomputed frame embeddings
of width d_model. Decoder layers are (self-attn, no-ffn) + (cross-attn,
mlp) BlockSpec pairs; the encoder is a 32-layer non-causal stack.
MHA (kv_heads == num_heads == 20), GELU 2-matrix FFN.
"""
from repro.configs.base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="whisper-large-v3",
    family="audio",
    d_model=1280,
    num_heads=20,
    kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab=51866,
    period=(
        BlockSpec("attn", "none"),        # decoder self-attention
        BlockSpec("cross_attn", "mlp"),   # decoder cross-attention + FFN
    ),
    num_periods=32,
    activation="gelu",
    encoder_periods=32,
    encoder_frames=1500,
    source="arXiv:2212.04356 (Whisper); conv frontend stubbed per assignment",
)
