"""Llama-3.2-Vision-90B [hf:meta-llama/Llama-3.2-11B-Vision scaled] —
dense GQA decoder with gated cross-attention image layers every 5th
layer (100 layers total = 80 self + 20 cross).

The ViT vision encoder + projector is a STUB per the assignment:
``input_specs()`` provides projected patch embeddings (1601 tokens of
width d_model) directly.
"""
from repro.configs.base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    d_model=8192,
    num_heads=64,
    kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=128256,
    period=(
        BlockSpec("attn", "mlp"),
        BlockSpec("attn", "mlp"),
        BlockSpec("attn", "mlp"),
        BlockSpec("attn", "mlp"),
        BlockSpec("cross_attn", "mlp"),
    ),
    num_periods=20,
    activation="swiglu",
    rope_theta=5e5,
    num_image_tokens=1601,
    source="hf:meta-llama/Llama-3.2-11B-Vision (arch), 90B scale; "
           "vision encoder stubbed per assignment",
)
