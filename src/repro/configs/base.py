"""Architecture configuration system.

Every assigned architecture is an ``ArchConfig`` built from a repeating
*period* of ``BlockSpec``s — the uniform representation that lets the
model builder scan over periods (compile-time O(period), not O(layers))
while still expressing hybrid interleaves (Jamba's 1-attention-in-8,
Llama-3.2-Vision's cross-attention every 5th layer, xLSTM's sLSTM/mLSTM
mix).

``reduced()`` produces the smoke-test variant (≤2 periods, d_model≤512,
≤4 experts) of the same family; ``input_specs()`` produces
ShapeDtypeStruct stand-ins for the dry-run.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Block / arch specs
# ---------------------------------------------------------------------------

MIXERS = ("attn", "swa", "cross_attn", "mamba", "mlstm", "slstm")
FFNS = ("mlp", "moe", "none")


@dataclasses.dataclass(frozen=True)
class BlockSpec:
    """One layer: a sequence mixer + a feed-forward."""
    mixer: str
    ffn: str = "mlp"

    def __post_init__(self):
        assert self.mixer in MIXERS, self.mixer
        assert self.ffn in FFNS, self.ffn


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    d_model: int
    num_heads: int
    kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    period: Tuple[BlockSpec, ...]    # repeating layer pattern
    num_periods: int
    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    shared_expert: bool = False      # Llama-4 style shared expert
    moe_capacity_factor: float = 1.25
    # §Perf: grouped dispatch (one token group per data shard keeps the
    # dispatch scatter shard-local; the E reshard becomes an all-to-all)
    moe_groups: int = 1
    moe_shard_constraints: bool = False  # needs a mesh ctx at trace time
    # §Perf: constrain q/k/v to batch-only sharding inside attention.
    # With kv_heads < model-axis size GSPMD otherwise splits the
    # contracting head_dim and ALL-REDUCES partial scores every chunk
    # (the 33 TB/device pathology on llama4 prefill). Gathering heads
    # once per layer is orders of magnitude cheaper.
    attn_data_local: bool = False        # needs a mesh ctx at trace time
    # attention flavour
    qk_norm: bool = False
    qkv_bias: bool = False
    sliding_window: int = 0          # >0 => swa mixers use this window
    rope_theta: float = 1e6
    activation: str = "swiglu"       # swiglu | relu2 | gelu
    # ssm (mamba)
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # xlstm
    xlstm_heads: int = 4
    # encoder-decoder (audio): encoder layers + #input frames
    encoder_periods: int = 0
    encoder_frames: int = 0
    # vlm: number of image-embedding tokens supplied by the (stubbed) vision
    # encoder + projector
    num_image_tokens: int = 0
    # KV-cache memory layout: "bshd" ([B,S,kv,hd], baseline) or "kmajor"
    # ([B,kv,S,hd] — dot-friendly, §Perf iteration: removes the per-step
    # transpose/copy churn in decode)
    kv_layout: str = "bshd"
    # citation for the config source
    source: str = ""

    # ------------------------------------------------------------------
    @property
    def num_layers(self) -> int:
        return len(self.period) * self.num_periods

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.kv_heads * self.head_dim

    @property
    def is_encdec(self) -> bool:
        return self.encoder_periods > 0

    @property
    def attn_layer_count(self) -> int:
        per = sum(1 for b in self.period if b.mixer in ("attn", "swa", "cross_attn"))
        return per * self.num_periods

    def with_sliding_window(self, window: int = 8192) -> "ArchConfig":
        """Variant where full-attention mixers become sliding-window — the
        sub-quadratic path required for long_500k on dense archs."""
        period = tuple(
            dataclasses.replace(b, mixer="swa") if b.mixer == "attn" else b
            for b in self.period)
        return dataclasses.replace(self, period=period, sliding_window=window,
                                   name=self.name + "+swa")

    def reduced(self) -> "ArchConfig":
        """Smoke-test config: same family/period structure, tiny dims."""
        d_model = min(self.d_model, 256)
        head_dim = 32
        heads = max(2, min(4, self.num_heads))
        kv = max(1, min(2, self.kv_heads))
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            d_model=d_model,
            num_heads=heads,
            kv_heads=kv,
            head_dim=head_dim,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab=min(self.vocab, 512),
            num_periods=max(1, min(2, self.num_periods)),
            num_experts=min(self.num_experts, 4),
            top_k=min(self.top_k, 2),
            moe_d_ff=min(self.moe_d_ff, 128) if self.moe_d_ff else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            encoder_periods=min(self.encoder_periods, 2),
            encoder_frames=min(self.encoder_frames, 16) if self.encoder_frames else 0,
            num_image_tokens=min(self.num_image_tokens, 16) if self.num_image_tokens else 0,
            xlstm_heads=2,
        )

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Analytic parameter count (matches init_params; used for
        MODEL_FLOPS = 6·N·D in the roofline)."""
        from repro.models.transformer import count_params
        return count_params(self)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32768, 128, "decode")
LONG_500K = InputShape("long_500k", 524288, 1, "decode")

INPUT_SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def input_specs(cfg: ArchConfig, shape: InputShape,
                dtype=jnp.int32) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of a step.

    train   → tokens + labels [B, S]
    prefill → tokens [B, S]   (+ modality embeddings for audio/vlm)
    decode  → token [B, 1] + write position (cache specs come from the
              model builder, since they depend on the arch's cache type)
    """
    b, s = shape.global_batch, shape.seq_len
    f32 = jnp.float32
    specs: Dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), dtype)
        specs["labels"] = jax.ShapeDtypeStruct((b, s), dtype)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), dtype)
    else:  # decode
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), dtype)
    if cfg.is_encdec and shape.kind != "train":
        # stubbed conv/mel frontend output: precomputed frame embeddings
        specs["encoder_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
    if cfg.is_encdec and shape.kind == "train":
        specs["encoder_frames"] = jax.ShapeDtypeStruct(
            (b, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
    if cfg.num_image_tokens and shape.kind in ("train", "prefill"):
        # stubbed ViT+projector output: patch embeddings
        specs["image_embeds"] = jax.ShapeDtypeStruct(
            (b, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    return specs
