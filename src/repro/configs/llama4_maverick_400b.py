"""Llama-4-Maverick-400B-A17B [hf:meta-llama/Llama-4-Scout-17B-16E
family] — MoE with 128 routed experts (top-1) + a Llama-4-style shared
expert. "Early fusion" multimodality means image tokens enter the same
token stream; the text backbone built here is what serves them, and the
vision tower is out of scope (dense-token inputs).
"""
from repro.configs.base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    d_model=5120,
    num_heads=40,
    kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab=202048,
    # Maverick interleaves dense and MoE FFNs 1:1 (hf config
    # interleave_moe_layer_step=2): 24 dense + 24 MoE layers = 48.
    period=(BlockSpec("attn", "mlp"), BlockSpec("attn", "moe")),
    num_periods=24,
    num_experts=128,
    top_k=1,
    moe_d_ff=8192,
    shared_expert=True,
    activation="swiglu",
    rope_theta=5e5,
    source="hf:meta-llama/Llama-4-Scout-17B-16E (family card)",
)
