"""Jamba-v0.1-52B [arXiv:2403.19887] — hybrid Mamba + attention (1:7
interleave) with MoE (16 experts, top-2) on every other layer.

Period of 8 = the Jamba block: attention at index 4, Mamba elsewhere,
MoE FFN on odd indices. Only 4 of 32 layers carry a KV cache, so the
KV-transfer volume the scheduler sees is 1/8 of a dense model — and
long_500k runs natively (full KV kept for the 4 attention layers).
"""
from repro.configs.base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    d_model=4096,
    num_heads=32,
    kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab=65536,
    period=(
        BlockSpec("mamba", "mlp"),
        BlockSpec("mamba", "moe"),
        BlockSpec("mamba", "mlp"),
        BlockSpec("mamba", "moe"),
        BlockSpec("attn", "mlp"),
        BlockSpec("mamba", "moe"),
        BlockSpec("mamba", "mlp"),
        BlockSpec("mamba", "moe"),
    ),
    num_periods=4,
    num_experts=16,
    top_k=2,
    moe_d_ff=14336,
    activation="swiglu",
    ssm_state=16,
    ssm_conv=4,
    ssm_expand=2,
    source="arXiv:2403.19887 (Jamba)",
)
