"""LLaMA-2-70B [arXiv:2307.09288] — the paper's larger evaluation model.
GQA kv=8, SwiGLU, 80 layers."""
from repro.configs.base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="llama2-70b",
    family="dense",
    d_model=8192,
    num_heads=64,
    kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab=32000,
    period=(BlockSpec("attn", "mlp"),),
    num_periods=80,
    activation="swiglu",
    rope_theta=1e4,
    source="arXiv:2307.09288 (LLaMA-2); HexGen-2 evaluation model",
)
