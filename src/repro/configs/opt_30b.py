"""OPT-30B [arXiv:2205.01068] — the paper's smaller evaluation model.
MHA, GELU FFN (4×), 48 layers, d_model 7168."""
from repro.configs.base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="opt-30b",
    family="dense",
    d_model=7168,
    num_heads=56,
    kv_heads=56,
    head_dim=128,
    d_ff=28672,
    vocab=50272,
    period=(BlockSpec("attn", "mlp"),),
    num_periods=48,
    activation="gelu",
    qkv_bias=True,
    rope_theta=1e4,
    source="arXiv:2205.01068 (OPT); HexGen-2 evaluation model",
)
