"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B] — fine-grained MoE: 128 experts,
top-8, small expert d_ff=768, GQA kv=4, qk_norm."""
from repro.configs.base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    d_model=2048,
    num_heads=32,
    kv_heads=4,
    head_dim=64,
    d_ff=768,
    vocab=151936,
    period=(BlockSpec("attn", "moe"),),
    num_periods=48,
    num_experts=128,
    top_k=8,
    moe_d_ff=768,
    activation="swiglu",
    qk_norm=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen3-30B-A3B",
)
