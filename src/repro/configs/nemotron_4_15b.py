"""Nemotron-4-15B [arXiv:2402.16819] — dense GQA with squared-ReLU
2-matrix FFN."""
from repro.configs.base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="nemotron-4-15b",
    family="dense",
    d_model=6144,
    num_heads=48,
    kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab=256000,
    period=(BlockSpec("attn", "mlp"),),
    num_periods=32,
    activation="relu2",
    rope_theta=1e4,
    source="arXiv:2402.16819 (Nemotron-4)",
)
