"""Qwen2.5-32B [hf:Qwen/Qwen2.5-0.5B family card] — dense GQA with QKV
bias."""
from repro.configs.base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="qwen2.5-32b",
    family="dense",
    d_model=5120,
    num_heads=40,
    kv_heads=8,
    head_dim=128,
    d_ff=27648,
    vocab=152064,
    period=(BlockSpec("attn", "mlp"),),
    num_periods=64,
    activation="swiglu",
    qkv_bias=True,
    rope_theta=1e6,
    source="hf:Qwen/Qwen2.5-0.5B (family card)",
)
