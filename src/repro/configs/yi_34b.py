"""Yi-34B [arXiv:2403.04652] — llama-architecture dense GQA."""
from repro.configs.base import ArchConfig, BlockSpec

CONFIG = ArchConfig(
    name="yi-34b",
    family="dense",
    d_model=7168,
    num_heads=56,
    kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab=64000,
    period=(BlockSpec("attn", "mlp"),),
    num_periods=60,
    activation="swiglu",
    rope_theta=5e6,
    source="arXiv:2403.04652 (Yi)",
)
