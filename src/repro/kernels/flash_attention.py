"""Pallas TPU flash attention (prefill hot spot).

TPU-native tiling (DESIGN.md §3): MXU-aligned (block_q × block_k) tiles
streamed HBM→VMEM via BlockSpec; online-softmax statistics (m, l) and
the output accumulator live in fp32 VMEM scratch that persists across
the innermost (k-block) grid dimension. GQA is handled by indexing the
shared KV head from the query-head grid coordinate — no KV replication
in HBM.

Grid: (batch, q_heads, num_q_blocks, num_k_blocks) — the last dimension
iterates fastest, so scratch accumulates over k blocks for a fixed
(b, h, iq) and the output tile is written on the final k block.

Causal and sliding-window masking are applied per-tile; fully-masked
tiles still execute (Pallas grids are static) but contribute zero.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_k: int, num_k_blocks: int,
                  causal: bool, window: int, sm_scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)               # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)               # [bk, hd]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                               # [bq, 1]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                            # [bq, bk]
    alpha = jnp.exp(m_prev - m_new)                   # [bq, 1]

    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == num_k_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_bhsd(q: jax.Array, k: jax.Array, v: jax.Array,
                         causal: bool = True, window: int = 0,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K,
                         interpret: bool = False) -> jax.Array:
    """q [B,Hq,S,hd]; k,v [B,Hkv,S,hd] → out [B,Hq,S,hd]."""
    b, hq, s, hd = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, (hq, hkv)
    group = hq // hkv
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq, nk = s // block_q, s // block_k
    sm_scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, num_k_blocks=nk,
        causal=causal, window=window, sm_scale=sm_scale)

    grid = (b, hq, nq, nk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda ib, ih, iq, ik: (ib, ih // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda ib, ih, iq, ik: (ib, ih, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, s, hd), q.dtype),
        scratch_shapes=[
            # fp32 accumulators persisted across the innermost grid dim
            pltpu.VMEM((block_q, hd), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
