"""Pallas TPU int8 KV-cache quantization kernels (DESIGN.md §10).

The KV handoff between prefill and decode replicas is the binding
constraint of disaggregated serving over heterogeneous links; shipping
the cache as symmetric int8 instead of bf16/fp32 cuts the wire bytes
~2-4x at negligible decode-logit error. Two granularities:

  * ``quantize_int8``           — one fp32 scale per head vector (the
    trailing ``head_dim`` axis): the per-head-group symmetric scheme.
    Scales cost 4/head_dim bytes per element on the wire.
  * ``quantize_int8_blockwise`` — one fp32 scale per [block_rows, D]
    tile of the row-flattened array: coarser, cheaper scale traffic,
    slightly larger error. Not wired into a ``KVCodec`` yet — it is
    the scale scheme the ROADMAP's fp8/int4 group-quant codecs build
    on (per-head scales cost 4/head_dim bytes/elem, prohibitive at
    sub-byte payloads).

Both have pure-jnp oracles (``*_ref``) and run the Pallas kernels in
interpret mode off-TPU, mirroring ``kernels.ops``. On TPU, shapes whose
trailing dim is not lane-aligned fall back to the oracle — the codec
never fails on an odd cache layout.

Zero rows round-trip exactly: an all-zero head vector gets the epsilon
scale and quantizes to all-zero int8, which dequantizes to exact zeros
(pad_capacity padding therefore survives the codec bit-identically).
"""
from __future__ import annotations

import functools
import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

#: Smallest representable scale: keeps all-zero rows at scale*127 == 0
#: after rounding while avoiding 0/0 in the quantize divide.
EPS_SCALE = 1e-12
#: Row-block size for the grid (rows per kernel invocation).
DEFAULT_BLOCK_ROWS = 256


def _interpret() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS") == "interpret":
        return True
    return jax.default_backend() != "tpu"


# ---------------------------------------------------------------------------
# Pure-jnp oracles
# ---------------------------------------------------------------------------


def quantize_int8_ref(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Symmetric per-head-vector int8: one fp32 scale per trailing-axis
    vector. Returns (q int8 with x's shape, scale fp32 [..., 1])."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf), axis=-1, keepdims=True) / 127.0,
                        EPS_SCALE)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_ref(q: jax.Array, scale: jax.Array,
                        dtype=jnp.float32) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def quantize_int8_blockwise_ref(x2d: jax.Array, block_rows: int
                                ) -> Tuple[jax.Array, jax.Array]:
    """One fp32 scale per [block_rows, D] tile of a 2-D array (rows must
    be a multiple of ``block_rows``). Returns (q int8, scale [nb, 1])."""
    n, d = x2d.shape
    assert n % block_rows == 0, (n, block_rows)
    xb = x2d.astype(jnp.float32).reshape(n // block_rows, block_rows * d)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=-1, keepdims=True) / 127.0,
                        EPS_SCALE)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q.reshape(n, d), scale


def dequantize_int8_blockwise_ref(q2d: jax.Array, scale: jax.Array,
                                  block_rows: int,
                                  dtype=jnp.float32) -> jax.Array:
    n, d = q2d.shape
    qb = q2d.astype(jnp.float32).reshape(n // block_rows, block_rows * d)
    return (qb * scale).reshape(n, d).astype(dtype)


# ---------------------------------------------------------------------------
# Pallas kernels (grid over row blocks; per-row scales live in the same
# block so no cross-block state is needed)
# ---------------------------------------------------------------------------


def _quant_rows_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                       # [R, D]
    amax = jnp.max(jnp.abs(x), axis=1, keepdims=True)        # [R, 1]
    scale = jnp.maximum(amax / 127.0, EPS_SCALE)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[...] = scale


def _dequant_rows_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32)
                  * s_ref[...]).astype(o_ref.dtype)


def _quant_block_kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)                       # [R, D]
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, EPS_SCALE)
    q_ref[...] = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    s_ref[0, 0] = scale


def _dequant_block_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = (q_ref[...].astype(jnp.float32)
                  * s_ref[0, 0]).astype(o_ref.dtype)


def _pad_rows(x2d: jax.Array, block: int) -> Tuple[jax.Array, int]:
    n = x2d.shape[0]
    rem = n % block
    if rem == 0:
        return x2d, n
    return jnp.pad(x2d, ((0, block - rem), (0, 0))), n


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _quant_rows_call(x2d, block_rows: int, interpret: bool):
    n, d = x2d.shape
    grid = (n // block_rows,)
    return pl.pallas_call(
        _quant_rows_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                   pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, d), jnp.int8),
                   jax.ShapeDtypeStruct((n, 1), jnp.float32)],
        interpret=interpret,
    )(x2d)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret",
                                             "dtype"))
def _dequant_rows_call(q2d, s2d, block_rows: int, interpret: bool, dtype):
    n, d = q2d.shape
    grid = (n // block_rows,)
    return pl.pallas_call(
        _dequant_rows_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((block_rows, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), dtype),
        interpret=interpret,
    )(q2d, s2d)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def _quant_block_call(x2d, block_rows: int, interpret: bool):
    n, d = x2d.shape
    nb = n // block_rows
    return pl.pallas_call(
        _quant_block_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0))],
        out_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_shape=[jax.ShapeDtypeStruct((n, d), jnp.int8),
                   jax.ShapeDtypeStruct((nb, 1), jnp.float32)],
        interpret=interpret,
    )(x2d)


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret",
                                             "dtype"))
def _dequant_block_call(q2d, s2d, block_rows: int, interpret: bool, dtype):
    n, d = q2d.shape
    return pl.pallas_call(
        _dequant_block_kernel,
        grid=(n // block_rows,),
        in_specs=[pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), dtype),
        interpret=interpret,
    )(q2d, s2d)


# ---------------------------------------------------------------------------
# Public wrappers (any-rank arrays; per-head-vector granularity)
# ---------------------------------------------------------------------------


def _tpu_aligned(d: int) -> bool:
    """Lane alignment needed to run compiled (non-interpret) on TPU."""
    return d % 128 == 0


def quantize_int8(x: jax.Array,
                  block_rows: int = DEFAULT_BLOCK_ROWS,
                  interpret: Optional[bool] = None
                  ) -> Tuple[jax.Array, jax.Array]:
    """Symmetric int8 with one fp32 scale per trailing-axis vector
    (per head group for a [..., heads, head_dim] KV slab).

    Returns (q int8, scale fp32) with ``q.shape == x.shape`` and
    ``scale.shape == x.shape[:-1] + (1,)``."""
    interp = _interpret() if interpret is None else interpret
    d = x.shape[-1]
    if not interp and not _tpu_aligned(d):
        return quantize_int8_ref(x)
    x2d = x.reshape(-1, d)
    block = min(block_rows, x2d.shape[0])
    padded, n = _pad_rows(x2d, block)
    q, s = _quant_rows_call(padded, block, interp)
    return (q[:n].reshape(x.shape),
            s[:n].reshape(x.shape[:-1] + (1,)))


def dequantize_int8(q: jax.Array, scale: jax.Array, dtype=jnp.float32,
                    block_rows: int = DEFAULT_BLOCK_ROWS,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Inverse of ``quantize_int8``."""
    interp = _interpret() if interpret is None else interpret
    d = q.shape[-1]
    if not interp and not _tpu_aligned(d):
        return dequantize_int8_ref(q, scale, dtype)
    q2d = q.reshape(-1, d)
    s2d = scale.reshape(-1, 1)
    block = min(block_rows, q2d.shape[0])
    qp, n = _pad_rows(q2d, block)
    sp, _ = _pad_rows(s2d, block)
    out = _dequant_rows_call(qp, sp, block, interp, jnp.dtype(dtype))
    return out[:n].reshape(q.shape)


def quantize_int8_blockwise(x2d: jax.Array, block_rows: int = 32,
                            interpret: Optional[bool] = None
                            ) -> Tuple[jax.Array, jax.Array]:
    """Coarse variant: one fp32 scale per [block_rows, D] tile. Rows are
    zero-padded to a block multiple; the returned scale is [nb, 1]."""
    interp = _interpret() if interpret is None else interpret
    n, d = x2d.shape
    padded, _ = _pad_rows(x2d, block_rows)
    if not interp and not _tpu_aligned(d):
        q, s = quantize_int8_blockwise_ref(padded, block_rows)
    else:
        q, s = _quant_block_call(padded, block_rows, interp)
    return q[:n], s


def dequantize_int8_blockwise(q2d: jax.Array, scale: jax.Array,
                              block_rows: int = 32, dtype=jnp.float32,
                              interpret: Optional[bool] = None) -> jax.Array:
    interp = _interpret() if interpret is None else interpret
    n, d = q2d.shape
    qp, _ = _pad_rows(q2d, block_rows)
    if not interp and not _tpu_aligned(d):
        out = dequantize_int8_blockwise_ref(qp, scale, block_rows, dtype)
    else:
        out = _dequant_block_call(qp, scale, block_rows, interp,
                                  jnp.dtype(dtype))
    return out[:n]


def wire_bytes_per_element(group: int) -> float:
    """Wire bytes per KV element under per-group int8: 1 payload byte
    plus the amortized fp32 scale. ``group`` is elements per scale
    (head_dim for the per-head-vector scheme). The ONE encoding of the
    wire format's size — every byte-accounting path
    (``kv_transfer.transfer_bytes``, ``kv_compression``, the cost
    model's ratio) derives from it."""
    return 1.0 + 4.0 / max(int(group), 1)


def compression_ratio(elem_bytes: float, group: int) -> float:
    """raw/wire bytes ratio of the int8 scheme for ``elem_bytes``-wide
    source elements; clamped at 1.0 (never 'compress' int8 into more
    bytes)."""
    return max(float(elem_bytes) / wire_bytes_per_element(group), 1.0)
