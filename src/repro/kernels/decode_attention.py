"""Pallas TPU GQA decode attention (the disaggregated decode hot spot).

One new query token per sequence attends over a long KV cache. This is
the TPU analogue of a paged decode kernel: the cache is a dense
per-sequence slab (static shapes — TPU has no pointer indirection, see
DESIGN.md §3) blocked over the sequence dimension; validity is a
per-sequence length mask.

Grid: (batch, kv_heads, num_s_blocks) — the s-block dimension iterates
fastest; online-softmax stats for the whole GQA group tile
[group, head_dim] persist in VMEM scratch.

The GQA group is the MXU tile's row dimension: q for one kv head is
[group, hd], each k block is [bk, hd] → scores [group, bk]. For small
groups the MXU is underutilized — that is exactly why decode is
HBM-bound, which the roofline analysis (§Roofline) makes explicit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *,
                   block_s: int, num_s_blocks: int, sm_scale: float):
    isb = pl.program_id(2)

    @pl.when(isb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # [group, hd]
    k = k_ref[0, 0].astype(jnp.float32)               # [bs, hd]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    valid_len = len_ref[pl.program_id(0)]
    kpos = isb * block_s + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where(kpos < valid_len, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(isb == num_s_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def gqa_decode_bhsd(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                    valid_len: jax.Array,
                    block_s: int = DEFAULT_BLOCK_S,
                    interpret: bool = False) -> jax.Array:
    """q [B,Hq,hd] (one token); caches [B,Hkv,S,hd]; valid_len [B] int32
    → out [B,Hq,hd]."""
    b, hq, hd = q.shape
    _, hkv, s, _ = k_cache.shape
    assert hq % hkv == 0
    group = hq // hkv
    assert s % block_s == 0, (s, block_s)
    ns = s // block_s
    sm_scale = 1.0 / (hd ** 0.5)

    # view q as [B, Hkv, group, hd] so one grid step covers a GQA group
    qg = q.reshape(b, hkv, group, hd)
    valid_len = valid_len.astype(jnp.int32)

    kernel = functools.partial(_decode_kernel, block_s=block_s,
                               num_s_blocks=ns, sm_scale=sm_scale)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv, ns),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # valid_len, whole array
            pl.BlockSpec((1, 1, group, hd), lambda ib, ih, isb: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, block_s, hd),
                         lambda ib, ih, isb: (ib, ih, isb, 0)),
            pl.BlockSpec((1, 1, block_s, hd),
                         lambda ib, ih, isb: (ib, ih, isb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd),
                               lambda ib, ih, isb: (ib, ih, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, hd), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
        interpret=interpret,
    )(valid_len, qg, k_cache, v_cache)
    return out.reshape(b, hq, hd)


# ---------------------------------------------------------------------------
# Paged variant: the cache is a shared page pool, each sequence walks its
# block table (DESIGN.md §11)
# ---------------------------------------------------------------------------


def _paged_decode_kernel(bt_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *,
                         page_size: int, num_blocks: int, sm_scale: float):
    """Same online-softmax recurrence as ``_decode_kernel``; the only
    difference is WHERE each s-block comes from — the BlockSpec index
    map resolved this grid step's logical block to a physical page via
    the scalar-prefetched block table, so the body is unchanged except
    for masking by the sequence's valid length."""
    ib, isb = pl.program_id(0), pl.program_id(2)

    @pl.when(isb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)               # [group, hd]
    k = k_ref[0, 0].astype(jnp.float32)               # [page_size, hd]
    v = v_ref[0, 0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    valid_len = len_ref[ib]
    kpos = isb * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < valid_len, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(isb == num_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def gqa_paged_decode_bhsd(q: jax.Array, k_pages: jax.Array,
                          v_pages: jax.Array, block_tables: jax.Array,
                          valid_len: jax.Array,
                          interpret: bool = False) -> jax.Array:
    """Paged GQA decode attention (DESIGN.md §11).

    q [B,Hq,hd] (one token); page pools [N,Hkv,page_size,hd]; block
    tables [B,num_blocks] int32 (physical page per logical s-block —
    unallocated entries must be clamped to a scratch page by the
    caller); valid_len [B] int32 → out [B,Hq,hd].

    TPU-static paging: the pool and table shapes are fixed, and the
    page indirection happens in the BlockSpec index map via scalar
    prefetch — the kernel DMAs exactly the page the table names, no
    pointer chasing (the §3 discipline: indices, not pointers)."""
    b, hq, hd = q.shape
    n_pages, hkv, page_size, _ = k_pages.shape
    _, num_blocks = block_tables.shape
    assert hq % hkv == 0
    group = hq // hkv
    sm_scale = 1.0 / (hd ** 0.5)

    qg = q.reshape(b, hkv, group, hd)
    block_tables = block_tables.astype(jnp.int32)
    valid_len = valid_len.astype(jnp.int32)

    kernel = functools.partial(_paged_decode_kernel, page_size=page_size,
                               num_blocks=num_blocks, sm_scale=sm_scale)

    def page_map(ib, ih, isb, bt_ref):
        return (bt_ref[ib, isb], ih, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,                # block_tables rides in SMEM
        grid=(b, hkv, num_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # valid_len
            pl.BlockSpec((1, 1, group, hd),
                         lambda ib, ih, isb, bt: (ib, ih, 0, 0)),
            pl.BlockSpec((1, 1, page_size, hd), page_map),
            pl.BlockSpec((1, 1, page_size, hd), page_map),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd),
                               lambda ib, ih, isb, bt: (ib, ih, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group, hd), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, hd), q.dtype),
        interpret=interpret,
    )(block_tables, valid_len, qg,
      k_pages.reshape(n_pages, hkv, page_size, hd),
      v_pages.reshape(n_pages, hkv, page_size, hd))
    return out.reshape(b, hq, hd)


# ---------------------------------------------------------------------------
# Int8-resident paged variant: pages stay quantized in the pool; dequant is
# fused into the online-softmax loop (DESIGN.md §16)
# ---------------------------------------------------------------------------


def _paged_decode_quant_kernel(bt_ref, ks_ref, vs_ref, len_ref,
                               q_ref, k_ref, v_ref, o_ref,
                               acc_ref, m_ref, l_ref, *,
                               page_size: int, num_blocks: int,
                               sm_scale: float):
    """Same online-softmax recurrence as ``_paged_decode_kernel``, but the
    k/v page tiles arrive int8 and are dequantized in-register: the
    per-(page, kv-head) fp32 scales ride the scalar-prefetch path
    alongside the block table, so the scale lookup reuses the same
    SMEM-resident physical-page index the BlockSpec index map used."""
    ib, ih, isb = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(isb == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    page = bt_ref[ib, isb]
    ks = ks_ref[page, ih]
    vs = vs_ref[page, ih]
    q = q_ref[0, 0].astype(jnp.float32)               # [group, hd]
    k = k_ref[0, 0].astype(jnp.float32) * ks          # dequant in-register
    v = v_ref[0, 0].astype(jnp.float32) * vs

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale

    valid_len = len_ref[ib]
    kpos = isb * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(kpos < valid_len, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(isb == num_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def gqa_paged_decode_quant_bhsd(q: jax.Array, k_pages: jax.Array,
                                v_pages: jax.Array, k_scales: jax.Array,
                                v_scales: jax.Array,
                                block_tables: jax.Array,
                                valid_len: jax.Array,
                                interpret: bool = False) -> jax.Array:
    """Int8-resident paged GQA decode attention (DESIGN.md §16).

    q [B,Hq,hd] float (one token); page pools [N,Hkv,page_size,hd]
    int8; k_scales/v_scales [N,Hkv] fp32 per-(page, kv-head) symmetric
    scales; block_tables [B,num_blocks] int32 (unallocated entries must
    be clamped to a scratch page by the caller); valid_len [B] int32 →
    out [B,Hq,hd].

    Pages never materialize in bf16: each grid step DMAs one int8 page
    and multiplies by its scale in VMEM registers right before the q·k
    and p·v dots — the HBM traffic is the int8 payload plus a scalar
    pair per (page, kv-head)."""
    b, hq, hd = q.shape
    n_pages, hkv, page_size, _ = k_pages.shape
    _, num_blocks = block_tables.shape
    assert hq % hkv == 0
    assert k_pages.dtype == jnp.int8 and v_pages.dtype == jnp.int8, (
        k_pages.dtype, v_pages.dtype)
    group = hq // hkv
    sm_scale = 1.0 / (hd ** 0.5)

    qg = q.reshape(b, hkv, group, hd)
    block_tables = block_tables.astype(jnp.int32)
    valid_len = valid_len.astype(jnp.int32)
    k_scales = k_scales.astype(jnp.float32)
    v_scales = v_scales.astype(jnp.float32)

    kernel = functools.partial(_paged_decode_quant_kernel,
                               page_size=page_size,
                               num_blocks=num_blocks, sm_scale=sm_scale)

    def page_map(ib, ih, isb, bt_ref, ks_ref, vs_ref):
        return (bt_ref[ib, isb], ih, 0, 0)

    def group_map(ib, ih, isb, bt_ref, ks_ref, vs_ref):
        return (ib, ih, 0, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,    # block_tables + k/v scales ride in SMEM
        grid=(b, hkv, num_blocks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # valid_len
            pl.BlockSpec((1, 1, group, hd), group_map),
            pl.BlockSpec((1, 1, page_size, hd), page_map),
            pl.BlockSpec((1, 1, page_size, hd), page_map),
        ],
        out_specs=pl.BlockSpec((1, 1, group, hd), group_map),
        scratch_shapes=[
            pltpu.VMEM((group, hd), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, group, hd), q.dtype),
        interpret=interpret,
    )(block_tables, k_scales, v_scales, valid_len, qg,
      k_pages.reshape(n_pages, hkv, page_size, hd),
      v_pages.reshape(n_pages, hkv, page_size, hd))
    return out.reshape(b, hq, hd)
