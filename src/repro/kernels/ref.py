"""Pure-jnp oracles for the Pallas kernels (the allclose ground truth)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q [B,Hq,S,hd]; k,v [B,Hkv,S,hd] → [B,Hq,S,hd]. fp32 math."""
    b, hq, s, hd = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, s, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    scores = jnp.einsum("bkgqd,bksd->bkgqs", qf, kf) / jnp.sqrt(float(hd))
    qpos = jnp.arange(s)[:, None]
    kpos = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bksd->bkgqd", probs, vf)
    return out.reshape(b, hq, s, hd).astype(q.dtype)


def gqa_decode_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                   valid_len: jax.Array) -> jax.Array:
    """q [B,Hq,hd]; caches [B,Hkv,S,hd]; valid_len [B] → [B,Hq,hd]."""
    b, hq, hd = q.shape
    hkv, s = k_cache.shape[1], k_cache.shape[2]
    g = hq // hkv
    qf = q.astype(jnp.float32).reshape(b, hkv, g, hd)
    scores = jnp.einsum("bkgd,bksd->bkgs", qf,
                        k_cache.astype(jnp.float32)) / jnp.sqrt(float(hd))
    mask = jnp.arange(s)[None] < valid_len[:, None]       # [B,S]
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgs,bksd->bkgd", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, hd).astype(q.dtype)


def gqa_paged_decode_ref(q: jax.Array, k_pages: jax.Array,
                         v_pages: jax.Array, block_tables: jax.Array,
                         valid_len: jax.Array) -> jax.Array:
    """Paged-decode oracle: gather each sequence's pages into a dense
    [B,Hkv,S,hd] view via the block table, then run the dense reference.
    q [B,Hq,hd]; pools [N,Hkv,page_size,hd]; block_tables [B,nb] int32
    (entries < 0 = unallocated → scratch page 0); valid_len [B]."""
    n, hkv, ps, hd = k_pages.shape
    b, nb = block_tables.shape
    bt = jnp.maximum(block_tables, 0)
    # [B,nb,Hkv,ps,hd] -> [B,Hkv,nb*ps,hd]
    kd = jnp.moveaxis(k_pages[bt], 2, 1).reshape(b, hkv, nb * ps, hd)
    vd = jnp.moveaxis(v_pages[bt], 2, 1).reshape(b, hkv, nb * ps, hd)
    return gqa_decode_ref(q, kd, vd, valid_len)


def gqa_paged_decode_quant_ref(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, k_scales: jax.Array,
                               v_scales: jax.Array,
                               block_tables: jax.Array,
                               valid_len: jax.Array) -> jax.Array:
    """Int8-resident paged-decode oracle (DESIGN.md §16): dequantize the
    int8 pools with their per-(page, kv-head) fp32 scales, then run the
    paged reference. q [B,Hq,hd]; pools [N,Hkv,page_size,hd] int8;
    scales [N,Hkv] fp32; block_tables [B,nb] int32; valid_len [B]."""
    kd = k_pages.astype(jnp.float32) * k_scales[:, :, None, None]
    vd = v_pages.astype(jnp.float32) * v_scales[:, :, None, None]
    return gqa_paged_decode_ref(q, kd, vd, block_tables, valid_len)
