"""Pallas TPU kernels for the serving hot spots.

flash_attention   — prefill (causal, GQA, optional sliding window)
decode_attention  — one-token GQA decode over a long KV cache
kv_quant          — int8 KV-cache quantize/dequantize for the §10
                    compressed prefill→decode handoff

Each kernel has a pure-jnp oracle (``ref.py`` / the ``*_ref`` functions
in ``kv_quant``); ``ops.py`` holds the jit'd layout-adapting wrappers
the model layer calls.
"""
from repro.kernels import kv_quant, ops, ref
from repro.kernels.decode_attention import gqa_decode_bhsd
from repro.kernels.flash_attention import flash_attention_bhsd

__all__ = ["kv_quant", "ops", "ref", "gqa_decode_bhsd",
           "flash_attention_bhsd"]
