"""Pallas TPU kernels for the serving hot spots.

flash_attention   — prefill (causal, GQA, optional sliding window)
decode_attention  — one-token GQA decode over a long KV cache

Each kernel has a pure-jnp oracle in ``ref.py``; ``ops.py`` holds the
jit'd layout-adapting wrappers the model layer calls.
"""
from repro.kernels import ops, ref
from repro.kernels.decode_attention import gqa_decode_bhsd
from repro.kernels.flash_attention import flash_attention_bhsd

__all__ = ["ops", "ref", "gqa_decode_bhsd", "flash_attention_bhsd"]
