"""Jit'd public wrappers for the Pallas kernels.

Model code calls these through ``repro.models.attention`` when the
backend is TPU (or when ``REPRO_FORCE_PALLAS=interpret`` forces the
interpret-mode path for validation). Layout adapters translate between
the model's [B,S,H,hd] and the kernels' [B,H,S,hd].
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import (gqa_decode_bhsd,
                                            gqa_paged_decode_bhsd,
                                            gqa_paged_decode_quant_bhsd)
from repro.kernels.flash_attention import flash_attention_bhsd


def _interpret() -> bool:
    if os.environ.get("REPRO_FORCE_PALLAS") == "interpret":
        return True
    return jax.default_backend() != "tpu"


def flash_supported(q: jax.Array, k: jax.Array, v: jax.Array,
                    block: int = 128) -> bool:
    """[B,S,H,hd] layout check: seq divisible by the tile size."""
    s = q.shape[1]
    return s % block == 0 and q.shape[2] % k.shape[2] == 0


def decode_supported(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     block: int = 512) -> bool:
    return k_cache.shape[1] % block == 0 and q.shape[1] == 1


@functools.partial(jax.jit, static_argnames=("causal", "window"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, window: int = 0) -> jax.Array:
    """Model layout: q [B,S,Hq,hd], k/v [B,S,Hkv,hd] → [B,S,Hq,hd]."""
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    out = flash_attention_bhsd(qt, kt, vt, causal=causal, window=window,
                               interpret=_interpret())
    return jnp.swapaxes(out, 1, 2)


@jax.jit
def gqa_decode_attention(q: jax.Array, k_cache: jax.Array,
                         v_cache: jax.Array,
                         valid_len: jax.Array) -> jax.Array:
    """Model layout: q [B,1,Hq,hd], cache [B,S,Hkv,hd], valid_len [] or [B]
    → [B,1,Hq,hd]."""
    b = q.shape[0]
    vl = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))
    qt = q[:, 0]                                       # [B,Hq,hd]
    kt = jnp.swapaxes(k_cache, 1, 2)                   # [B,Hkv,S,hd]
    vt = jnp.swapaxes(v_cache, 1, 2)
    out = gqa_decode_bhsd(qt, kt, vt, vl, interpret=_interpret())
    return out[:, None]


def paged_decode_supported(q: jax.Array, k_pages: jax.Array) -> bool:
    """[B,1,Hq,hd] q over a [N,ps,Hkv,hd] model-layout pool. The page
    IS the kernel's s-block — (1, 1, page_size, hd) — so page_size only
    needs the SUBLANE tile (16 covers bf16; fp32 needs 8), unlike the
    dense kernel's 128-lane s-block gate. The default page_size=16
    therefore takes the kernel path on TPU."""
    return (q.shape[1] == 1 and k_pages.shape[1] % 16 == 0
            and q.shape[2] % k_pages.shape[2] == 0)


@jax.jit
def gqa_paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, block_tables: jax.Array,
                               valid_len: jax.Array) -> jax.Array:
    """Model layout: q [B,1,Hq,hd], pools [N,ps,Hkv,hd], block tables
    [B,nb] int32 (unallocated entries < 0), valid_len [] or [B]
    → [B,1,Hq,hd] (DESIGN.md §11)."""
    b = q.shape[0]
    vl = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))
    bt = jnp.maximum(block_tables.astype(jnp.int32), 0)
    qt = q[:, 0]                                       # [B,Hq,hd]
    kt = jnp.swapaxes(k_pages, 1, 2)                   # [N,Hkv,ps,hd]
    vt = jnp.swapaxes(v_pages, 1, 2)
    out = gqa_paged_decode_bhsd(qt, kt, vt, bt, vl, interpret=_interpret())
    return out[:, None]


def paged_decode_quant_supported(q: jax.Array, k_pages: jax.Array) -> bool:
    """Gate for the int8-resident kernel: same shape discipline as
    ``paged_decode_supported`` (the page is the s-block) plus the pool
    must actually be int8 — int8's native sublane tile is 32, but the
    Mosaic lowering handles page_size=16 via masked tiles, so the gate
    stays at the bf16 granularity."""
    return (q.shape[1] == 1 and k_pages.dtype == jnp.int8
            and k_pages.shape[1] % 16 == 0
            and q.shape[2] % k_pages.shape[2] == 0)


@jax.jit
def gqa_paged_decode_quant_attention(q: jax.Array, k_pages: jax.Array,
                                     v_pages: jax.Array,
                                     k_scales: jax.Array,
                                     v_scales: jax.Array,
                                     block_tables: jax.Array,
                                     valid_len: jax.Array) -> jax.Array:
    """Model layout: q [B,1,Hq,hd], int8 pools [N,ps,Hkv,hd], fp32
    scales [N,Hkv], block tables [B,nb] int32 (unallocated entries < 0),
    valid_len [] or [B] → [B,1,Hq,hd] (DESIGN.md §16)."""
    b = q.shape[0]
    vl = jnp.broadcast_to(jnp.asarray(valid_len, jnp.int32), (b,))
    bt = jnp.maximum(block_tables.astype(jnp.int32), 0)
    qt = q[:, 0]                                       # [B,Hq,hd]
    kt = jnp.swapaxes(k_pages, 1, 2)                   # [N,Hkv,ps,hd]
    vt = jnp.swapaxes(v_pages, 1, 2)
    out = gqa_paged_decode_quant_bhsd(qt, kt, vt, k_scales, v_scales,
                                      bt, vl, interpret=_interpret())
    return out[:, None]
