"""Sharding rules: param / optimizer / cache / input PartitionSpecs.

Two parameter profiles (DESIGN.md §6):

  * ``tp``      — weights sharded over ``model`` only (replicated over
                  data). Inference default for models whose per-chip
                  footprint fits HBM.
  * ``fsdp_tp`` — weights additionally sharded over ``data`` on their
                  first logical dim (ZeRO/FSDP style). Used for training
                  and for the ≥90B inference configs (v5e has 16 GB).

Block parameters are stacked over periods, so every block-param spec is
prefixed with one None (the period dim).

Caches: batch dim over the data axes when divisible; long_500k
(batch=1) shards the attention cache's *sequence* dim over ``data``
instead (context-parallel decode — softmax over the sharded axis
resolves to an all-reduce under GSPMD).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig, InputShape


def _axis_size(mesh, names: Tuple[str, ...]) -> int:
    return int(np.prod([mesh.shape[n] for n in names]))


def _div(n: int, k: int) -> bool:
    return n % k == 0


def param_spec(path: Tuple, leaf, *, fsdp: Optional[Any], mesh) -> P:
    """PartitionSpec for one parameter leaf, by pytree path."""
    keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
    name = keys[-1]
    in_blocks = "blocks" in keys
    model = "model"
    msize = mesh.shape["model"]

    def blockify(*spec):
        return P(None, *spec) if in_blocks else P(*spec)

    # vocab-adjacent
    if name == "embed":
        return P(None, model)
    if name == "lm_head":
        return P(None, model)
    # norms / scalars / small vectors
    if leaf.ndim <= 1 and name not in ("bq", "bk", "bv", "conv_b", "d_skip",
                                       "dt_bias"):
        return blockify() if in_blocks else P()
    if "mlstm" in keys or "slstm" in keys or name == "r":
        # xLSTM blocks are tiny (125M total): replicate within the block
        return blockify(*([None] * (leaf.ndim - (1 if in_blocks else 0))))
    if "moe" in keys and name in ("w_gate", "w_up", "w_down") \
            and "shared" not in keys:
        # [E, D, F] / [E, F, D]: expert parallelism over model
        return blockify(model, fsdp, None)
    if name == "router":
        return blockify(None, None)
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "in_proj", "z_proj",
                "w"):
        return blockify(fsdp, model)
    if name in ("wo", "w_down", "out_proj", "dt_proj"):
        return blockify(model, fsdp) if name != "dt_proj" \
            else blockify(None, model)
    if name in ("bq", "bk", "bv"):
        return blockify(model)
    if name in ("conv_w",):
        return blockify(None, model)
    if name in ("conv_b", "d_skip", "dt_bias"):
        return blockify(model)
    if name in ("x_proj", "a_log"):
        return blockify(model, None)
    if name == "qkv":
        return blockify(None, model)
    # default: replicate
    nd = leaf.ndim - (1 if in_blocks else 0)
    return blockify(*([None] * nd))


def param_shardings(cfg: ArchConfig, params_shape: Any, mesh,
                    profile: str = "tp") -> Any:
    fsdp = "data" if profile == "fsdp_tp" else None

    def rule(path, leaf):
        spec = param_spec(path, leaf, fsdp=fsdp, mesh=mesh)
        # drop sharding on non-divisible dims (GSPMD would pad; we prefer
        # clean layouts and replicate instead)
        fixed = []
        for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                fixed.append(None)
            else:
                axes = ax if isinstance(ax, tuple) else (ax,)
                fixed.append(ax if _div(dim, _axis_size(mesh, axes)) else None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(rule, params_shape)


def opt_shardings(param_sh: Any, mesh, opt_state_shape: Any) -> Any:
    """Adam moments shard like their parameters; step is replicated."""
    rep = NamedSharding(mesh, P())
    return type(opt_state_shape)(
        step=rep,
        mu=jax.tree.map(lambda _, s: s, opt_state_shape.mu, param_sh),
        nu=jax.tree.map(lambda _, s: s, opt_state_shape.nu, param_sh),
    )


def batch_shardings(shape_kind: str, mesh, batch: int,
                    specs: Dict[str, jax.ShapeDtypeStruct]) -> Dict[str, Any]:
    from repro.launch.mesh import data_axes
    da = data_axes(mesh)
    dsz = _axis_size(mesh, da)
    baxis = da if _div(batch, dsz) else (
        ("data",) if _div(batch, mesh.shape["data"]) else None)

    out = {}
    for k, v in specs.items():
        spec = [baxis if isinstance(baxis, tuple) else baxis] \
            + [None] * (v.ndim - 1)
        if k in ("encoder_frames", "image_embeds") and v.ndim == 3:
            pass  # [B, T, D] — batch only
        out[k] = NamedSharding(mesh, P(*spec))
    return out


def cache_shardings(cfg: ArchConfig, cache_shape: Any, mesh,
                    batch: int) -> Any:
    """Decode-cache layout:

    * batch over the data axes (when divisible);
    * attention K/V sequence dim over every axis NOT used for batch —
      sequence-parallel ("flash-decode") layout: with GQA kv_heads <
      mesh model size, head sharding is impossible, and the softmax
      over the sharded seq axis resolves to an all-reduce under GSPMD;
    * SSM channel dims over ``model`` (matching the in_proj TP layout).
    """
    from repro.launch.mesh import data_axes
    da = data_axes(mesh)
    dsz = _axis_size(mesh, da)
    batch_ax: Optional[Tuple[str, ...]] = None
    if _div(batch, dsz):
        batch_ax = da
    elif _div(batch, mesh.shape["data"]):
        batch_ax = ("data",)
    used = set(batch_ax or ())
    seq_axes = tuple(a for a in ("model",) + tuple(da) if a not in used)

    def seq_spec(dim: int):
        axes = seq_axes
        while axes and not _div(dim, _axis_size(mesh, axes)):
            axes = axes[:-1]
        return axes or None

    def rule(path, leaf):
        keys = [str(getattr(p, "key", getattr(p, "idx", p))) for p in path]
        name = keys[-1]
        spec = [None] * leaf.ndim
        spec[1] = batch_ax  # [P, B, ...]
        if name in ("k", "v") and leaf.ndim == 5:
            ax = 3 if cfg.kv_layout == "kmajor" else 2
            spec[ax] = seq_spec(leaf.shape[ax])
        if name == "pos" and leaf.ndim == 3:
            spec[2] = seq_spec(leaf.shape[2])
        if name in ("conv", "ssm") and leaf.ndim >= 4:
            # mamba: channel dim (conv: axis 3, ssm: axis 2) over model
            ax = 3 if name == "conv" else 2
            if _div(leaf.shape[ax], mesh.shape["model"]):
                spec[ax] = "model"
        return NamedSharding(mesh, P(*spec))

    return jax.tree_util.tree_map_with_path(rule, cache_shape)
