"""Step-function factory for the dry-run and launchers.

``build_case(arch, shape, mesh)`` returns everything needed to lower one
(architecture × input-shape) combination: the step callable, the
ShapeDtypeStruct argument tree, and the matching in_shardings tree.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, INPUT_SHAPES, get_config, input_specs
from repro.configs.base import ArchConfig, InputShape
from repro.launch import sharding as shard_rules
from repro.models import transformer
from repro.training import optimizer as opt_lib
from repro.training.train_loop import make_train_step

# v5e has 16 GB HBM; above this per-chip TP-only footprint we go fsdp_tp.
FSDP_THRESHOLD_BYTES = 6e9
SLIDING_WINDOW = 8192


@dataclasses.dataclass
class Case:
    arch: str
    shape: InputShape
    cfg: ArchConfig                 # possibly the +swa variant
    kind: str                       # train | prefill | decode
    step_fn: Callable
    arg_specs: Tuple                # ShapeDtypeStructs (positional)
    in_shardings: Tuple
    profile: str                    # tp | fsdp_tp
    note: str = ""


def pick_config(arch: str, shape: InputShape) -> Tuple[ArchConfig, str]:
    """Resolve the config variant for a shape (long_500k → sub-quadratic)."""
    cfg = get_config(arch)
    if shape.name != "long_500k":
        return cfg, ""
    full_attn = any(b.mixer == "attn" for b in cfg.period)
    native = cfg.family in ("ssm", "hybrid")
    if native and cfg.family == "ssm":
        return cfg, "native O(1)-state long context"
    if native:  # hybrid: keep full KV on the few attention layers
        return cfg, "hybrid: full KV on 1-in-8 attention layers"
    if full_attn:
        return cfg.with_sliding_window(SLIDING_WINDOW), \
            f"sliding-window({SLIDING_WINDOW}) variant for 500k decode"
    return cfg, ""


def pick_profile(cfg: ArchConfig, kind: str, mesh) -> str:
    if kind == "train":
        return "fsdp_tp"
    param_bytes = 2.0 * transformer.count_params(cfg)
    if param_bytes / mesh.shape["model"] > FSDP_THRESHOLD_BYTES:
        return "fsdp_tp"
    return "tp"


def _params_specs_and_shardings(cfg: ArchConfig, mesh, profile: str):
    pshape = jax.eval_shape(
        functools.partial(transformer.init_params, cfg=cfg),
        jax.random.PRNGKey(0))
    psh = shard_rules.param_shardings(cfg, pshape, mesh, profile)
    return pshape, psh


def optimize_config(cfg: ArchConfig, shape: InputShape) -> ArchConfig:
    """Apply the §Perf-validated beyond-paper levers where legal:
    batch-local attention for GQA (kv_heads < 16) and grouped MoE
    dispatch (token count divisible by the data width)."""
    over = {}
    has_attn = any(b.mixer in ("attn", "swa") for b in cfg.period)
    if has_attn and cfg.kv_heads < 16 and shape.global_batch >= 16:
        over["attn_data_local"] = True
    tokens = shape.global_batch * (shape.seq_len
                                   if shape.kind in ("train", "prefill")
                                   else 1)
    if cfg.num_experts and tokens % 16 == 0:
        over["moe_groups"] = 16
        over["moe_shard_constraints"] = True
    return dataclasses.replace(cfg, **over) if over else cfg


def build_case(arch: str, shape_name: str, mesh,
               optimized: bool = False) -> Case:
    shape = INPUT_SHAPES[shape_name]
    cfg, note = pick_config(arch, shape)
    if optimized:
        cfg = optimize_config(cfg, shape)
        note = (note + "; " if note else "") + "optimized flags"
    kind = shape.kind
    profile = pick_profile(cfg, kind, mesh)
    pshape, psh = _params_specs_and_shardings(cfg, mesh, profile)
    ins = input_specs(cfg, shape)
    insh = shard_rules.batch_shardings(kind, mesh, shape.global_batch, ins)

    if kind == "train":
        opt_cfg = opt_lib.AdamWConfig()
        oshape = jax.eval_shape(opt_lib.init, pshape)
        osh = shard_rules.opt_shardings(psh, mesh, oshape)
        step = make_train_step(cfg, opt_cfg)

        def train_step(params, opt_state, batch):
            return step(params, opt_state, batch)

        batch_specs = dict(ins)
        return Case(arch, shape, cfg, kind, train_step,
                    (pshape, oshape, batch_specs),
                    (psh, osh, insh), profile, note)

    if kind == "prefill":
        def prefill_step(params, **inputs):
            tokens = inputs.pop("tokens")
            return transformer.prefill(params, cfg, tokens, **inputs)

        def prefill_pos(params, inputs):
            return prefill_step(params, **inputs)

        return Case(arch, shape, cfg, kind, prefill_pos,
                    (pshape, dict(ins)), (psh, insh), profile, note)

    # decode: one token against a full cache
    cshape = transformer.cache_specs(cfg, shape.global_batch, shape.seq_len)
    csh = shard_rules.cache_shardings(cfg, cshape, mesh, shape.global_batch)
    tok = ins["tokens"]
    pos = jax.ShapeDtypeStruct(tok.shape, jnp.int32)
    tok_sh = insh["tokens"]
    pos_sh = insh["tokens"]

    def serve_step(params, cache, tokens, positions):
        return transformer.decode_step(params, cfg, cache, tokens, positions)

    return Case(arch, shape, cfg, kind, serve_step,
                (pshape, cshape, tok, pos),
                (psh, csh, tok_sh, pos_sh), profile, note)


def lower_case(case: Case, mesh, donate: bool = False):
    """jit + lower; returns the Lowered object."""
    jitted = jax.jit(case.step_fn, in_shardings=case.in_shardings)
    with mesh:
        return jitted.lower(*case.arg_specs)
