"""Production meshes.

Functions (not module-level constants) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
initialization; everything else sees the real device count.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e: 16×16 (256 chips) per pod; 2 pods for multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh():
    """1×1 mesh over the real local device (CPU tests)."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple:
    """Batch-sharding axes for this mesh ((pod,data) when multi-pod)."""
    names = mesh.axis_names
    return ("pod", "data") if "pod" in names else ("data",)
