"""Serving launcher: run the disaggregated runtime on a selectable arch.

Drives the event-driven ``ServeSession`` API (DESIGN.md §8): requests
are submitted with (optionally Poisson-paced) arrival times, tokens
stream via callbacks, and the run reports the shared runtime/simulator
``ServeMetrics`` schema — TTFT/TPOT/throughput directly comparable to
``repro.serving.simulate`` output.

On CPU this serves the REDUCED variant of the requested architecture
(the full configs are exercised via the dry-run); on a real TPU mesh the
same code path serves the full config with the Pallas kernels engaged.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --requests 8 --prompt-len 16 --max-new 12 --decode-engines 2 \
        [--rate-rps 4.0] [--stream] \
        [--prefix-trace multiturn --prefill-engines 2] \
        [--kv-codec int8-chunked]

``--prefix-trace`` swaps the random prompts for a shared-prefix
workload (DESIGN.md §9), enables the per-engine radix prefix caches,
and reports hit-rate metrics alongside the usual schema.

``--kv-codec`` selects the §10 KV-handoff wire format (none / int8 /
int8-chunked) and reports shipped bytes + compression ratio.

``--autoscale`` (optionally with ``--surge-trace``) serves behind the
§13 elastic ``FleetController``: the fleet starts at one replica and
provisions/warms/joins more as the burst builds, reporting scale
events and per-state replica-steps; exits non-zero if no scale-up
fires.
"""
from __future__ import annotations

import argparse
import collections
import time

import jax
import numpy as np

from repro.configs import ASSIGNED, get_config
from repro.models import init_params
from repro.serving import Coordinator, ServeRequest, TraceRecorder
from repro.serving.telemetry import (MetricsEndpoint, chrome_trace,
                                     dump_chrome_trace, prometheus_text,
                                     validate_chrome_trace)
from repro.serving.workload import PREFIX_TRACES, prefix_trace


def _maybe_recorder(args):
    """One shared §14 event bus when any observability output is
    requested; None otherwise (telemetry stays zero-cost)."""
    wanted = args.trace_out or args.metrics_out or args.metrics_port
    return TraceRecorder() if wanted else None


def _maybe_endpoint(args, render):
    """Start the §15 scrape endpoint when ``--metrics-port`` is set:
    ``/metrics`` renders a live Prometheus snapshot via ``render``,
    ``/healthz`` answers ``ok``. Returns the started endpoint or None."""
    if not args.metrics_port:
        return None
    ep = MetricsEndpoint(render, port=args.metrics_port).start()
    print(f"[serve] metrics endpoint: {ep.url} (+ /healthz)")
    return ep


def _scrape_endpoint(ep) -> None:
    """One-shot self-scrape before shutdown — the smoke contract for
    ``--metrics-port``, mirroring ``--trace-out``'s schema check: the
    launcher exits non-zero unless ``/healthz`` answers ``ok`` and
    ``/metrics`` serves a non-empty exposition body."""
    if ep is None:
        return
    import urllib.request
    base = f"http://{ep.host}:{ep.port}"
    try:
        with urllib.request.urlopen(f"{base}/healthz", timeout=10) as r:
            healthy = r.status == 200 and r.read().strip() == b"ok"
        with urllib.request.urlopen(f"{base}/metrics", timeout=10) as r:
            body = r.read().decode()
            served = r.status == 200 and "repro_" in body
    except Exception as e:  # noqa: BLE001 — report, then fail the smoke
        raise SystemExit(f"[serve] --metrics-port scrape failed: {e}")
    if not (healthy and served):
        raise SystemExit("[serve] --metrics-port scrape returned an "
                         "unhealthy or empty exposition")
    print(f"[serve] scraped {base}/metrics: "
          f"{len(body.splitlines())} exposition lines, /healthz ok")


def _write_observability(args, m, recorder, *, dispatch_log=(),
                         scale_events=(), gauges=None, dt=0.05,
                         label="repro-serve") -> None:
    """Export the run's telemetry: ``--trace-out`` writes Chrome
    trace-event JSON and VALIDATES it against the schema (the launcher
    exits non-zero on a malformed or empty trace — the CI smoke leg's
    contract); ``--metrics-out`` writes a Prometheus text-exposition
    snapshot of the shared metrics schema + live-window gauges."""
    if args.trace_out:
        trace = chrome_trace(m.requests, dispatch_log=dispatch_log,
                             scale_events=scale_events, recorder=recorder,
                             dt=dt, label=label)
        errors = validate_chrome_trace(trace)
        if errors:
            raise SystemExit("[serve] --trace-out produced an invalid "
                             "Chrome trace: " + "; ".join(errors[:5]))
        dump_chrome_trace(args.trace_out, trace)
        print(f"[serve] trace: {len(trace['traceEvents'])} events -> "
              f"{args.trace_out} (load in Perfetto / chrome://tracing)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(prometheus_text(m, gauges))
        print(f"[serve] metrics snapshot -> {args.metrics_out}")


def _print_breakdown(m) -> None:
    """The §14 TTFT attribution report, one line per priority class."""
    for cls, frac in sorted(m.ttft_breakdown.items()):
        print(f"[serve] ttft breakdown class{cls}: "
              + " ".join(f"{k}={v:.3f}" for k, v in frac.items()))


def _serve_fleet(cfg, params, args) -> None:
    """Multi-replica serving behind the §12 ``Router``: a mixed-
    priority trace (interactive/standard/batch with per-class SLOs and
    shared system prompts) dispatched across ``--replicas`` runtime
    coordinators with priority/aging admission and sticky prefix-aware
    routing; ``--kill-replica`` kills the last replica mid-trace and
    the in-flight requests complete elsewhere via failover
    re-dispatch. ``--autoscale`` puts the §13 ``FleetController`` on
    top — the fleet starts at one replica and provisions/warms/joins
    more as demand builds (pair with ``--surge-trace`` for a quiet →
    burst → quiet arrival pattern); the launcher exits non-zero if the
    burst triggers no scale-up."""
    from repro.serving import (Coordinator, CoordinatorReplica,
                               FleetController, FleetSpec, RequestState,
                               Router, StepClock, mixed_priority_workload,
                               surge_workload)

    out_lens = tuple(min(o, args.max_new) for o in (3, 5, 8))
    rate = args.rate_rps if args.rate_rps > 0 else 20.0
    trace_kw = dict(rate_rps=rate, seed=args.seed,
                    vocab=min(cfg.vocab, 512), system_lens=(12, 8, 6),
                    user_lens=(4, 6, 8), out_lens=out_lens)
    if args.surge_trace:
        trace = surge_workload(args.requests, surge=6.0, **trace_kw)
    else:
        trace = mixed_priority_workload(args.requests, **trace_kw)
    capacity = max(r.s_in for r in trace) + args.max_new + 8
    clock = StepClock()    # virtual: lifecycle stamps are step-indexed

    def make_replica(_slot: int) -> "CoordinatorReplica":
        return CoordinatorReplica(
            Coordinator(cfg, params, num_decode_engines=1,
                        slots_per_engine=args.slots, capacity=capacity,
                        num_prefill_engines=1,
                        prefix_cache_bytes=args.prefix_cache_mb * 1e6),
            max_prefill_batch=args.prefill_batch, clock=clock)

    seed_reps = 1 if args.autoscale else args.replicas
    recorder = _maybe_recorder(args)
    router = Router([make_replica(i) for i in range(seed_reps)],
                    queue_capacity=max(16, 2 * args.requests),
                    age_every="auto", policy="slo", clock=clock,
                    telemetry=recorder)
    ctrl = None
    if args.autoscale:
        spec = FleetSpec(min_replicas=1,
                         max_replicas=max(2, args.replicas),
                         provision_steps=2, warmup_steps=3,
                         cold_window_steps=4, queue_high=0.5,
                         sustain_steps=2, cooldown_steps=4,
                         hysteresis_steps=8)
        ctrl = FleetController(router, make_replica, spec, dt=0.05)
    endpoint = _maybe_endpoint(
        args, lambda: prometheus_text(router.metrics(), router.gauges,
                                      recorder=recorder))
    # kill replica 0: sticky prefix routing concentrates early work
    # there, so the failover path genuinely has requests to move
    failures = {2: 0} if args.kill_replica else None
    t0 = time.perf_counter()
    if ctrl is not None:
        m = ctrl.run_trace(trace, failures=failures)
    else:
        m = router.run_trace(trace, dt=0.05, failures=failures)
    dt = time.perf_counter() - t0
    c = router.counters
    done = sum(1 for _, _, life in router.results()
               if life.phase is RequestState.DONE)
    print(f"[serve] router fleet: {seed_reps} replicas"
          f"{' (1 killed mid-trace)' if args.kill_replica else ''}"
          f"{' + autoscale' if ctrl is not None else ''}, "
          f"{len(trace)} requests, {done} completed in {dt:.1f}s "
          "incl. compile")
    print(f"[serve] counters: admitted={c['admitted']} "
          f"rejected={c['rejected']} cancelled={c['cancelled']} "
          f"redispatched={c['redispatched']}")
    print("[serve] slo_attainment_stated="
          f"{m.slo_attainment_stated:.3f} "
          + " ".join(f"class{k}={v:.2f}" for k, v in
                     sorted(m.slo_attainment_by_class.items())))
    print("[serve] cache hit by class: "
          + " ".join(f"class{k}={v:.3f}" for k, v in
                     sorted(m.cache_hit_rate_by_class.items())))
    _print_breakdown(m)
    _write_observability(
        args, m, recorder, dispatch_log=router.dispatch_log,
        scale_events=(ctrl.events if ctrl is not None else ()),
        gauges=router.gauges, dt=0.05,
        label=f"repro-serve-fleet-{cfg.name}")
    if ctrl is not None:
        print("[serve] scale events: "
              + (" ".join(f"{e.kind}@{e.step}(r{e.replica})"
                          for e in ctrl.events) or "none"))
        print(f"[serve] replica-steps by state: "
              + " ".join(f"{k}={v}" for k, v in
                         sorted(ctrl.replica_steps_by_state.items()))
              + f" warm_pen={m.warmup_ttft_penalty_s:.2f}s")
    _scrape_endpoint(endpoint)
    if endpoint is not None:
        endpoint.close()
    if args.kill_replica and c["redispatched"] == 0:
        raise SystemExit("[serve] --kill-replica exercised no failover "
                         "re-dispatches (raise --requests or --rate-rps)")
    if ctrl is not None and m.scale_up_events == 0:
        raise SystemExit("[serve] --autoscale fired no scale-up during "
                         "the trace (raise --requests or --rate-rps, or "
                         "pass --surge-trace)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=ASSIGNED)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--decode-engines", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--prefill-batch", type=int, default=4,
                    help="max prompts per bucketed prefill micro-batch")
    ap.add_argument("--rate-rps", type=float, default=0.0,
                    help="Poisson arrival rate; 0 = all at t=0")
    ap.add_argument("--prefix-trace", choices=sorted(PREFIX_TRACES),
                    default=None,
                    help="serve a shared-prefix trace (multi-turn chat / "
                         "common system prompt / few-shot agentic) with "
                         "per-engine radix prefix caches enabled "
                         "(DESIGN.md §9) and report hit-rate metrics")
    ap.add_argument("--prefill-engines", type=int, default=1,
                    help="prefill engines for cache-aware routing")
    ap.add_argument("--kv-codec", choices=("none", "int8", "int8-chunked"),
                    default="none",
                    help="KV-handoff wire format (DESIGN.md §10): int8 "
                         "ships attention KV quantized (per-head-group "
                         "fp32 scales); int8-chunked additionally streams "
                         "per-layer-group chunks the decode engines "
                         "install as they land")
    ap.add_argument("--paged", action="store_true",
                    help="paged KV decode (DESIGN.md §11): block-table "
                         "cache layout over a ref-counted page pool — "
                         "page-aligned handoffs, reclamation on finish, "
                         "recompute preemption on pool exhaustion")
    ap.add_argument("--page-size", type=int, default=16,
                    help="tokens per KV page (with --paged)")
    ap.add_argument("--paged-dtype", choices=("int8",), default=None,
                    help="pool-resident KV dtype (DESIGN.md §16): int8 "
                         "keeps pages quantized in HBM (per-page/kv-head "
                         "fp32 scale sidecar) and decodes with the fused "
                         "quantized paged kernel; requires --paged")
    ap.add_argument("--pages-per-engine", type=int, default=0,
                    help="page-pool size per decode engine (0 = the "
                         "dense engine's HBM budget)")
    ap.add_argument("--prefix-cache-mb", type=float, default=256.0,
                    help="per-engine prefix-cache byte budget (MB); KV "
                         "slabs beyond it are LRU-evicted")
    ap.add_argument("--replicas", type=int, default=1,
                    help="serve a mixed-priority trace behind the §12 "
                         "Router over N replica coordinators (priority/"
                         "aging admission, sticky prefix-aware dispatch)")
    ap.add_argument("--kill-replica", action="store_true",
                    help="with --replicas: kill a replica mid-trace to "
                         "exercise §12 failover re-dispatch")
    ap.add_argument("--autoscale", action="store_true",
                    help="elastic fleet (DESIGN.md §13): start at one "
                         "replica behind the FleetController and "
                         "provision/warm/join more as demand builds; "
                         "exits non-zero if no scale-up fires")
    ap.add_argument("--surge-trace", action="store_true",
                    help="with --autoscale: quiet → 6x burst → quiet "
                         "arrival pattern instead of a flat Poisson "
                         "trace")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="write the run's §14 span trace as Chrome "
                         "trace-event JSON (Perfetto-loadable; one track "
                         "per replica/engine, flow arrows across the "
                         "φ→δ handoff); the launcher validates the "
                         "emitted trace and exits non-zero if it is "
                         "malformed or empty")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="write a Prometheus text-exposition snapshot of "
                         "the shared metrics schema + TTFT attribution + "
                         "live-window gauges")
    ap.add_argument("--metrics-port", type=int, default=0, metavar="PORT",
                    help="serve a live Prometheus scrape endpoint "
                         "(/metrics + /healthz, stdlib http.server) on "
                         "this port for the duration of the run "
                         "(DESIGN.md §15); 0 = off")
    ap.add_argument("--stream", action="store_true",
                    help="print each token as it is generated")
    ap.add_argument("--full", action="store_true",
                    help="use the full config (TPU-scale; default reduced)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    print(f"[serve] arch={cfg.name} layers={cfg.num_layers} "
          f"d_model={cfg.d_model} backend={jax.default_backend()}")
    params = init_params(jax.random.PRNGKey(args.seed), cfg)

    if args.replicas > 1 or args.autoscale:
        _serve_fleet(cfg, params, args)
        return

    rng = np.random.default_rng(args.seed)
    extra = {}
    if cfg.is_encdec:
        extra["encoder_frames"] = np.zeros(
            (1, cfg.encoder_frames, cfg.d_model), np.float32)
    if cfg.num_image_tokens:
        extra["image_embeds"] = np.zeros(
            (1, cfg.num_image_tokens, cfg.d_model), np.float32)
    if args.prefix_trace is not None:
        # shared-prefix workload (DESIGN.md §9): prompts carry real
        # token content; prefix caching + cache-aware routing are on.
        # --rate-rps 0 keeps its contract: generate at a nominal pace
        # for ordering, then collapse every arrival to t=0.
        trace = prefix_trace(args.prefix_trace, args.requests,
                             args.rate_rps if args.rate_rps > 0 else 8.0,
                             seed=args.seed, vocab=cfg.vocab,
                             think_time_s=0.25)
        reqs = [ServeRequest(r.rid, np.asarray(r.tokens, np.int32),
                             min(r.s_out, args.max_new), dict(extra))
                for r in trace]
        arrivals = np.array([r.arrival for r in trace])
        if args.rate_rps <= 0:
            arrivals[:] = 0.0
        capacity = max(len(r.prompt) for r in reqs) + args.max_new + 4
        prefix_bytes = args.prefix_cache_mb * 1e6
    else:
        reqs = [ServeRequest(i, rng.integers(0, cfg.vocab, args.prompt_len)
                             .astype(np.int32), args.max_new, dict(extra))
                for i in range(args.requests)]
        if args.rate_rps > 0:
            arrivals = np.cumsum(rng.exponential(1.0 / args.rate_rps,
                                                 size=args.requests))
        else:
            arrivals = np.zeros(args.requests)
        capacity = args.prompt_len + args.max_new + 4
        prefix_bytes = None

    coord = Coordinator(cfg, params, num_decode_engines=args.decode_engines,
                        slots_per_engine=args.slots, capacity=capacity,
                        num_prefill_engines=args.prefill_engines,
                        prefix_cache_bytes=prefix_bytes,
                        kv_codec=args.kv_codec,
                        paged=args.paged, page_size=args.page_size,
                        pages_per_engine=args.pages_per_engine or None,
                        paged_dtype=args.paged_dtype)

    def on_token(rid: int, tok: int, fin: bool) -> None:
        if args.stream:
            print(f"  [stream] req {rid}: {tok}{' <done>' if fin else ''}")

    recorder = _maybe_recorder(args)
    sess = coord.session(max_prefill_batch=args.prefill_batch,
                         telemetry=recorder)
    endpoint = _maybe_endpoint(
        args, lambda: prometheus_text(sess.metrics(), recorder=recorder))
    pending = collections.deque(
        (float(arrivals[i]), r) for i, r in enumerate(reqs))
    t0 = time.perf_counter()
    # event loop: submit at arrival time, step the pipeline otherwise
    while pending or sess.unfinished:
        while pending and pending[0][0] <= sess.now():
            arr, r = pending.popleft()
            sess.submit(r, arrival_time=arr, on_token=on_token)
        if not sess.step():
            if pending:
                time.sleep(max(0.0, min(pending[0][0] - sess.now(), 0.005)))
            elif sess.unfinished:
                raise RuntimeError("serve stalled with requests in flight")
    dt = time.perf_counter() - t0

    outs = sess.results()
    total = sum(len(o.tokens) for o in outs)
    for o in outs[:4]:
        print(f"  req {o.rid}: {o.tokens}")
    m = sess.metrics()
    print(f"[serve] {len(outs)} requests, {total} tokens in {dt:.1f}s "
          f"({total / dt:.1f} tok/s incl. compile)")
    print(f"[serve] metrics: throughput={m.decode_throughput:.1f}tok/s "
          f"avg_ttft={m.avg_ttft * 1e3:.0f}ms avg_tpot={m.avg_tpot * 1e3:.0f}ms "
          f"avg_latency={m.avg_latency:.2f}s p99={m.p99_latency:.2f}s")
    if args.prefix_trace is not None:
        print(f"[serve] prefix cache ({args.prefix_trace}): "
              f"hit_rate={m.cache_hit_rate:.3f} "
              f"reused_tokens={m.reused_tokens} "
              f"prefill_tokens_computed={m.prefill_tokens_computed}")
    if args.paged:
        pre = sum(r.preemptions for r in m.requests)
        pools = [e.pool for e in coord.decode_engines]
        print(f"[serve] paged kv (page_size={args.page_size}, "
              f"dtype={m.kv_cache_dtype or 'bf16'}): "
              f"pages_allocated={m.kv_pages_allocated} "
              f"utilization={m.page_utilization:.3f} "
              f"fragmentation={m.page_fragmentation:.3f} "
              f"preemptions={pre} "
              f"cow_copies={sum(p.stats.cow_copies for p in pools)}")
    if args.kv_codec != "none":
        slab_ratio = (sess.kv_physical_bytes_raw
                      / max(sess.kv_physical_bytes_wire, 1))
        print(f"[serve] kv codec ({args.kv_codec}): "
              f"shipped={m.kv_bytes_shipped:.0f}B "
              f"ratio={m.kv_compression_ratio:.2f} "
              f"measured_slab_ratio={slab_ratio:.2f}")
    _print_breakdown(m)
    _write_observability(args, m, recorder,
                         label=f"repro-serve-{cfg.name}")
    _scrape_endpoint(endpoint)
    if endpoint is not None:
        endpoint.close()


if __name__ == "__main__":
    main()
