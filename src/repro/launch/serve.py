"""Serving launcher: run the disaggregated runtime on a selectable arch.

On CPU this serves the REDUCED variant of the requested architecture
(the full configs are exercised via the dry-run); on a real TPU mesh the
same code path serves the full config with the Pallas kernels engaged.

Usage:
    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b \
        --requests 8 --prompt-len 16 --max-new 12 --decode-engines 2
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ASSIGNED, get_config
from repro.models import init_params
from repro.serving import Coordinator, ServeRequest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b", choices=ASSIGNED)
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--decode-engines", type=int, default=2)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--full", action="store_true",
                    help="use the full config (TPU-scale; default reduced)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    print(f"[serve] arch={cfg.name} layers={cfg.num_layers} "
          f"d_model={cfg.d_model} backend={jax.default_backend()}")
    params = init_params(jax.random.PRNGKey(args.seed), cfg)

    rng = np.random.default_rng(args.seed)
    extra = {}
    if cfg.is_encdec:
        extra["encoder_frames"] = np.zeros(
            (1, cfg.encoder_frames, cfg.d_model), np.float32)
    if cfg.num_image_tokens:
        extra["image_embeds"] = np.zeros(
            (1, cfg.num_image_tokens, cfg.d_model), np.float32)
    reqs = [ServeRequest(i, rng.integers(0, cfg.vocab, args.prompt_len)
                         .astype(np.int32), args.max_new, dict(extra))
            for i in range(args.requests)]

    capacity = args.prompt_len + args.max_new + 4
    coord = Coordinator(cfg, params, num_decode_engines=args.decode_engines,
                        slots_per_engine=args.slots, capacity=capacity)
    t0 = time.perf_counter()
    outs = coord.serve(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(o.tokens) for o in outs)
    for o in outs[:4]:
        print(f"  req {o.rid}: {o.tokens}")
    print(f"[serve] {len(outs)} requests, {total} tokens in {dt:.1f}s "
          f"({total / dt:.1f} tok/s incl. compile)")


if __name__ == "__main__":
    main()
