import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""§Perf hillclimbing driver (process entry point, like dryrun).

Lowers the three selected (arch × shape) pairs under named optimization
variants, re-derives the roofline terms, and appends the results to
reports/perf_iterations.json. Each variant is a hypothesis from
EXPERIMENTS.md §Perf; the baseline rows come from the dry-run report.

Usage: PYTHONPATH=src python -m repro.launch.perf [pair ...]
       pairs: yi_decode qwen3moe_decode llama4_prefill (default: all)
"""

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import INPUT_SHAPES, get_config       # noqa: E402
from repro.launch.dryrun import model_flops_estimate      # noqa: E402
from repro.launch.mesh import make_production_mesh        # noqa: E402
from repro.launch import steps as steps_mod                # noqa: E402
from repro.roofline import analyze                         # noqa: E402

REPORT = "reports/perf_iterations.json"


def _variants():
    """pair -> [(variant_name, arch, shape, cfg_overrides)]"""
    return {
        "yi_decode": [
            ("kmajor_cache", "yi-34b", "decode_32k",
             {"kv_layout": "kmajor"}),
        ],
        "qwen3moe_decode": [
            ("kmajor_cache", "qwen3-moe-30b-a3b", "decode_32k",
             {"kv_layout": "kmajor"}),
            ("kmajor+grouped_moe", "qwen3-moe-30b-a3b", "decode_32k",
             {"kv_layout": "kmajor", "moe_groups": 16,
              "moe_shard_constraints": True}),
        ],
        "llama4_prefill": [
            ("grouped_moe_dispatch", "llama4-maverick-400b-a17b",
             "prefill_32k",
             {"moe_groups": 16, "moe_shard_constraints": True}),
            ("grouped_moe+cap1.0", "llama4-maverick-400b-a17b",
             "prefill_32k",
             {"moe_groups": 16, "moe_shard_constraints": True,
              "moe_capacity_factor": 1.0}),
            ("attn_data_local", "llama4-maverick-400b-a17b",
             "prefill_32k",
             {"attn_data_local": True}),
            ("attn_local+grouped_moe", "llama4-maverick-400b-a17b",
             "prefill_32k",
             {"attn_data_local": True, "moe_groups": 16,
              "moe_shard_constraints": True}),
        ],
        "yi_decode_extra": [
            ("attn_data_local", "yi-34b", "decode_32k",
             {"attn_data_local": True}),
            ("attn_local+kmajor", "yi-34b", "decode_32k",
             {"attn_data_local": True, "kv_layout": "kmajor"}),
        ],
        "qwen3moe_decode_extra": [
            ("attn_data_local", "qwen3-moe-30b-a3b", "decode_32k",
             {"attn_data_local": True}),
            ("attn_local+grouped_moe", "qwen3-moe-30b-a3b", "decode_32k",
             {"attn_data_local": True, "moe_groups": 16,
              "moe_shard_constraints": True}),
        ],
        # beyond-the-three: HBM-over-budget + collective-bound train pairs
        "vision_prefill": [
            ("attn_data_local", "llama-3.2-vision-90b", "prefill_32k",
             {"attn_data_local": True}),
        ],
        "yi_train": [
            ("attn_data_local", "yi-34b", "train_4k",
             {"attn_data_local": True}),
        ],
        "llama4_train": [
            ("attn_local+grouped_moe", "llama4-maverick-400b-a17b",
             "train_4k",
             {"attn_data_local": True, "moe_groups": 16,
              "moe_shard_constraints": True}),
            ("attn_local+moe+bf16_moments", "llama4-maverick-400b-a17b",
             "train_4k",
             {"attn_data_local": True, "moe_groups": 16,
              "moe_shard_constraints": True,
              "opt.moments_dtype": "bfloat16"}),
        ],
    }


def run_variant(name, arch, shape_name, overrides, multi_pod=False):
    mesh = make_production_mesh(multi_pod=multi_pod)
    shape = INPUT_SHAPES[shape_name]
    rec = {"variant": name, "arch": arch, "shape": shape_name,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "overrides": {k: str(v) for k, v in overrides.items()},
           "status": "ok"}
    t0 = time.perf_counter()
    try:
        opt_overrides = {k[4:]: v for k, v in overrides.items()
                         if k.startswith("opt.")}
        cfg_overrides = {k: v for k, v in overrides.items()
                         if not k.startswith("opt.")}
        case = steps_mod.build_case(arch, shape_name, mesh)
        cfg = dataclasses.replace(case.cfg, **cfg_overrides)
        # rebuild the case pieces that depend on cfg
        case = dataclasses.replace(case, cfg=cfg)
        import functools
        from repro.launch import sharding as sr
        from repro.models import transformer
        from repro.configs import input_specs
        pshape = jax.eval_shape(functools.partial(
            transformer.init_params, cfg=cfg), jax.random.PRNGKey(0))
        psh = sr.param_shardings(cfg, pshape, mesh, case.profile)
        ins = input_specs(cfg, shape)
        insh = sr.batch_shardings(shape.kind, mesh, shape.global_batch, ins)
        if shape.kind == "decode":
            import jax.numpy as jnp
            cshape = transformer.cache_specs(cfg, shape.global_batch,
                                             shape.seq_len)
            csh = sr.cache_shardings(cfg, cshape, mesh, shape.global_batch)
            tok = ins["tokens"]
            pos = jax.ShapeDtypeStruct(tok.shape, jnp.int32)

            def step(params, cache, tokens, positions):
                return transformer.decode_step(params, cfg, cache, tokens,
                                               positions)

            args = (pshape, cshape, tok, pos)
            shardings = (psh, csh, insh["tokens"], insh["tokens"])
        elif shape.kind == "train":
            from repro.training import optimizer as opt_lib
            from repro.training.train_loop import make_train_step
            import jax.numpy as jnp
            opt_cfg = opt_lib.AdamWConfig(**opt_overrides)
            mdt = jnp.dtype(opt_cfg.moments_dtype)
            oshape = jax.eval_shape(
                lambda p: opt_lib.init(p, moments_dtype=mdt), pshape)
            osh = sr.opt_shardings(psh, mesh, oshape)
            inner = make_train_step(cfg, opt_cfg)

            def step(params, opt_state, batch):
                return inner(params, opt_state, batch)

            args = (pshape, oshape, dict(ins))
            shardings = (psh, osh, insh)
        elif shape.kind == "prefill":
            def step(params, inputs):
                tokens = inputs.pop("tokens")
                return transformer.prefill(params, cfg, tokens, **inputs)

            args = (pshape, dict(ins))
            shardings = (psh, insh)
        else:
            raise ValueError(shape.kind)
        with mesh:
            jitted = jax.jit(step, in_shardings=shardings)
            lowered = jitted.lower(*args)
            compiled = lowered.compile()
        rep = analyze(arch, shape_name, rec["mesh"],
                      512 if multi_pod else 256, compiled, None,
                      model_flops_estimate(case, shape))
        rec.update({
            "t_compute_s": rep.t_compute, "t_memory_s": rep.t_memory,
            "t_collective_s": rep.t_collective,
            "bottleneck": rep.bottleneck,
            "useful_flops_ratio": rep.useful_flops_ratio,
            "hlo_flops": rep.hlo_flops, "hlo_bytes": rep.hlo_bytes,
            "collective_bytes": rep.coll_bytes,
            "collective_breakdown": rep.coll_breakdown,
            "peak_bytes_per_chip": rep.peak_bytes_per_chip,
            "elapsed_s": round(time.perf_counter() - t0, 1),
        })
        print(f"[ok]   {name:24s} {rep.row()}", flush=True)
    except Exception as e:  # noqa: BLE001
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=8)
        print(f"[FAIL] {name} {arch} {shape_name}: {rec['error']}",
              flush=True)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("pairs", nargs="*", default=[])
    ap.add_argument("--report", default=REPORT)
    args = ap.parse_args()
    table = _variants()
    pairs = args.pairs or list(table)
    records = []
    if os.path.exists(args.report):
        with open(args.report) as f:
            records = json.load(f)
    rc = 0
    for pair in pairs:
        for name, arch, shape, ov in table[pair]:
            rec = run_variant(name, arch, shape, ov)
            records = [r for r in records if not (
                r.get("variant") == name and r["arch"] == arch
                and r["shape"] == shape)]
            records.append(rec)
            rc |= rec["status"] != "ok"
            with open(args.report, "w") as f:
                json.dump(records, f, indent=1)
    return rc


if __name__ == "__main__":
    raise SystemExit(main())
