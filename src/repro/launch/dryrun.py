import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (arch × input-shape) pair.

MUST be the process entry point (``python -m repro.launch.dryrun``) —
the XLA_FLAGS line above runs before any other import so jax sees 512
placeholder host devices for the production meshes. Do NOT import this
module from code that already initialized jax with one device.

Per case it records compile success, memory_analysis, cost_analysis and
the roofline terms (compute / memory / collective) into a JSON report
consumed by benchmarks/roofline_report.py and EXPERIMENTS.md.
"""

import argparse       # noqa: E402
import json           # noqa: E402
import time           # noqa: E402
import traceback      # noqa: E402

import jax            # noqa: E402

from repro.configs import ARCHS, ASSIGNED, INPUT_SHAPES   # noqa: E402
from repro.launch.mesh import make_production_mesh         # noqa: E402
from repro.launch.steps import build_case                  # noqa: E402
from repro.models import transformer                        # noqa: E402
from repro.roofline import analyze                          # noqa: E402

DEFAULT_REPORT = "dryrun_report.json"


def model_flops_estimate(case, shape) -> float:
    """MODEL_FLOPS: 6·N_active·D for training, 2·N_active·D_active-tokens
    for inference (decode processes one token per sequence)."""
    n_active = transformer.count_active_params(case.cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch  # one new token per sequence
    return 2.0 * n_active * tokens


def run_one(arch: str, shape_name: str, multi_pod: bool,
            want_text: bool = True, optimized: bool = False) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    shape = INPUT_SHAPES[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "ok"}
    t0 = time.perf_counter()
    try:
        case = build_case(arch, shape_name, mesh, optimized=optimized)
        rec["profile"] = case.profile
        rec["note"] = case.note
        with mesh:
            jitted = jax.jit(case.step_fn, in_shardings=case.in_shardings)
            lowered = jitted.lower(*case.arg_specs)
            t_lower = time.perf_counter() - t0
            compiled = lowered.compile()
            t_compile = time.perf_counter() - t0 - t_lower
        memdesc = compiled.memory_analysis()
        rep = analyze(arch, shape_name, mesh_name, chips, compiled,
                      None, model_flops_estimate(case, shape))
        rec.update({
            "t_lower_s": round(t_lower, 2),
            "t_compile_s": round(t_compile, 2),
            "hlo_flops": rep.hlo_flops,
            "hlo_bytes": rep.hlo_bytes,
            "collective_bytes": rep.coll_bytes,
            "collective_breakdown": rep.coll_breakdown,
            "model_flops": rep.model_flops,
            "t_compute_s": rep.t_compute,
            "t_memory_s": rep.t_memory,
            "t_collective_s": rep.t_collective,
            "bottleneck": rep.bottleneck,
            "useful_flops_ratio": rep.useful_flops_ratio,
            "memory_analysis": str(memdesc),
            "peak_bytes_per_chip": rep.peak_bytes_per_chip,
        })
        print(f"[ok]   {rep.row()}  (lower {t_lower:.0f}s "
              f"compile {t_compile:.0f}s)", flush=True)
    except Exception as e:  # noqa: BLE001 — report and continue
        rec["status"] = "fail"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc(limit=10)
        print(f"[FAIL] {arch} {shape_name} {mesh_name}: {rec['error']}",
              flush=True)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser(description="HexGen-2 repro multi-pod dry-run")
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (assigned pool)")
    ap.add_argument("--shape", default="all",
                    help="input shape name or 'all'")
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--report", default=DEFAULT_REPORT)
    ap.add_argument("--append", action="store_true",
                    help="merge into an existing report file")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the Perf-validated config levers")
    args = ap.parse_args()

    archs = ASSIGNED if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    records = []
    if args.append and os.path.exists(args.report):
        with open(args.report) as f:
            records = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in records
            if r.get("status") == "ok"}

    failures = 0
    for arch in archs:
        for shape in shapes:
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                if (arch, shape, mesh_name) in done:
                    continue
                rec = run_one(arch, shape, multi, optimized=args.optimized)
                records = [r for r in records
                           if not (r["arch"] == arch and r["shape"] == shape
                                   and r["mesh"] == mesh_name)]
                records.append(rec)
                failures += rec["status"] != "ok"
                with open(args.report, "w") as f:
                    json.dump(records, f, indent=1)
    print(f"dry-run complete: {len(records)} records, {failures} failures "
          f"-> {args.report}")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
