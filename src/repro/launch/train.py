"""Training launcher: train a selectable architecture on the synthetic
token pipeline (the train_4k assigned shape uses this step function via
the dry-run; on CPU run the reduced variant at small batch/seq).

Usage:
    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --steps 200 --batch 8 --seq 64
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import ASSIGNED, get_config
from repro.training import train


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m", choices=ASSIGNED)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="full config (TPU-scale; default reduced)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    print(f"[train] arch={cfg.name} layers={cfg.num_layers} "
          f"d_model={cfg.d_model} backend={jax.default_backend()}")
    res = train(cfg, steps=args.steps, batch=args.batch, seq=args.seq,
                seed=args.seed, ckpt_dir=args.ckpt_dir,
                ckpt_every=args.ckpt_every, verbose=True)
    print(f"[train] {res.steps} steps, {res.tokens_seen} tokens, "
          f"loss {res.losses[0]:.3f} -> {res.losses[-1]:.3f} "
          f"in {res.elapsed_s:.1f}s")


if __name__ == "__main__":
    main()
