"""Generative-inference cost model (paper Table 1, Appendix A).

Estimates, for a model replica served by a (possibly heterogeneous)
device group with an asymmetric TP×PP plan:

  * prefill latency            (compute-bound; includes TP/PP comm)
  * decode   latency           (HBM-scan-bound; includes TP/PP comm)
  * per-stage memory footprint (params + KV cache + activations)
  * KV-cache transfer cost between a prefill and a decode replica

The paper's Table 1 covers dense MHA transformers. The assigned
architecture pool forces three faithful extensions, each reducing to the
paper's formula in the dense-MHA limit:

  * GQA       — KV bytes/token use kv_heads·head_dim, not H.
  * MoE       — compute uses *active* expert params; memory/scan use
                *resident* expert params (the decode phase must stream
                every resident expert touched by the batch).
  * SSM/hybrid — recurrent layers carry a constant-size state instead of
                a KV cache: transfer cost is O(1) in sequence length and
                the decode scan term covers params only.

All units SI (seconds, bytes, FLOP). ``B_TYPE`` = 2 (fp16/bf16).
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Sequence

import numpy as np

from repro.core.cluster import ClusterSpec

B_TYPE = 2.0  # bytes per parameter / activation element (fp16)

#: Bytes per element for the KV-cache dtypes a profile can declare.
#: ``kv_bytes_token_layer`` derives from this instead of assuming fp16,
#: so bf16/fp8/int8-KV deployments price their transfers correctly.
DTYPE_BYTES = {"fp32": 4.0, "float32": 4.0, "tf32": 4.0,
               "fp16": 2.0, "float16": 2.0, "bf16": 2.0, "bfloat16": 2.0,
               "fp8": 1.0, "float8_e4m3fn": 1.0, "float8_e5m2": 1.0,
               "int8": 1.0}


def dtype_bytes(dtype) -> float:
    """Bytes per element for a dtype name or numpy/jax dtype object."""
    name = dtype if isinstance(dtype, str) else np.dtype(dtype).name
    if name not in DTYPE_BYTES:
        raise KeyError(f"unknown KV dtype '{name}'; "
                       f"known: {sorted(DTYPE_BYTES)}")
    return DTYPE_BYTES[name]

# MFU-style derating: achievable fraction of peak FLOPS / HBM bandwidth for
# transformer inference kernels. Single scalars — the *relative* ordering
# across heterogeneous devices is what the scheduler consumes.
COMPUTE_EFFICIENCY = 0.45
MEMORY_EFFICIENCY = 0.75
NET_EFFICIENCY = 0.80


@dataclasses.dataclass(frozen=True)
class ModelProfile:
    """Shape-level description of one served model, as the cost model sees it.

    ``flops_per_token_layer``  — weight-matmul FLOPs per token per layer
                                 (active path for MoE).
    ``param_bytes_layer``      — resident parameter bytes per layer
                                 (all experts for MoE).
    ``scan_bytes_layer``       — bytes the decode phase must stream from HBM
                                 per layer per step (≤ param_bytes_layer;
                                 for MoE top-k ≈ min(resident, batch·k·expert)).
    ``kv_bytes_token_layer``   — KV-cache bytes per token per *attention*
                                 layer (0 for pure-SSM layers).
    ``state_bytes_layer``      — constant recurrent-state bytes per sequence
                                 per *SSM* layer (0 for attention layers).
    ``attn_layer_fraction``    — fraction of layers that carry KV cache
                                 (1.0 dense; 4/32 for Jamba-style hybrids).
    """

    name: str
    num_layers: int
    hidden: int
    flops_per_token_layer: float
    param_bytes_layer: float
    scan_bytes_layer: float
    kv_bytes_token_layer: float
    state_bytes_layer: float = 0.0
    attn_layer_fraction: float = 1.0
    embed_param_bytes: float = 0.0
    # Quadratic attention FLOPs coefficient: per token at context length s,
    # attention adds attn_flops_coeff * s FLOPs per attention layer.
    attn_flops_coeff: float = 0.0
    #: Bytes per stored KV element (already folded into
    #: ``kv_bytes_token_layer`` by the constructors) — the KV codec's
    #: compression-ratio math needs it separately (DESIGN.md §10).
    kv_elem_bytes: float = B_TYPE
    #: Elements sharing one fp32 scale under per-head-group int8
    #: quantization (head_dim for the per-head-vector scheme).
    kv_quant_group: int = 128
    #: Contiguous layer groups a chunked KV stream can split into — the
    #: period-stack extent of the runtime cache pytree
    #: (``ChunkedTransferPlan`` slices that axis). None (paper-profile
    #: default) means every layer is its own group.
    layer_groups: Optional[int] = None

    # ------------------------------------------------------------------
    @property
    def total_param_bytes(self) -> float:
        return self.param_bytes_layer * self.num_layers + self.embed_param_bytes

    def kv_state_bytes_split(self, seq: float) -> tuple:
        """(attention KV bytes, recurrent-state bytes) one request owns
        across all layers at context ``seq`` — the ONE decomposition of
        per-request cache bytes; the §10 codec accounting compresses
        the KV term and ships the state term raw."""
        attn_layers = self.num_layers * self.attn_layer_fraction
        ssm_layers = self.num_layers - attn_layers
        return (self.kv_bytes_token_layer * seq * attn_layers,
                self.state_bytes_layer * ssm_layers)

    def kv_bytes_per_request(self, seq: float) -> float:
        """KV/state bytes one request owns across all layers at context ``seq``."""
        kv, state = self.kv_state_bytes_split(seq)
        return kv + state

    # -- constructors ---------------------------------------------------
    @staticmethod
    def dense(name: str, num_layers: int, hidden: int, ffn: int,
              num_heads: int, kv_heads: int, vocab: int,
              head_dim: Optional[int] = None,
              kv_dtype: str = "fp16") -> "ModelProfile":
        hd = head_dim or hidden // num_heads
        q_dim, kv_dim = num_heads * hd, kv_heads * hd
        kv_b = dtype_bytes(kv_dtype)
        # attn: Wq(H→q_dim) Wk,Wv(H→kv_dim) Wo(q_dim→H); ffn: gated 3 mats
        attn_params = hidden * (q_dim + 2 * kv_dim) + q_dim * hidden
        ffn_params = 3 * hidden * ffn
        params = attn_params + ffn_params
        return ModelProfile(
            name=name, num_layers=num_layers, hidden=hidden,
            flops_per_token_layer=2.0 * params,
            param_bytes_layer=params * B_TYPE,
            scan_bytes_layer=params * B_TYPE,
            kv_bytes_token_layer=2.0 * kv_dim * kv_b,
            embed_param_bytes=2.0 * vocab * hidden * B_TYPE,
            attn_flops_coeff=4.0 * q_dim,
            kv_elem_bytes=kv_b, kv_quant_group=hd,
        )

    @staticmethod
    def moe(name: str, num_layers: int, hidden: int, ffn: int,
            num_heads: int, kv_heads: int, vocab: int,
            num_experts: int, top_k: int,
            head_dim: Optional[int] = None,
            kv_dtype: str = "fp16") -> "ModelProfile":
        hd = head_dim or hidden // num_heads
        q_dim, kv_dim = num_heads * hd, kv_heads * hd
        kv_b = dtype_bytes(kv_dtype)
        attn_params = hidden * (q_dim + 2 * kv_dim) + q_dim * hidden
        expert_params = 3 * hidden * ffn
        router_params = hidden * num_experts
        resident = attn_params + num_experts * expert_params + router_params
        active = attn_params + top_k * expert_params + router_params
        return ModelProfile(
            name=name, num_layers=num_layers, hidden=hidden,
            flops_per_token_layer=2.0 * active,
            param_bytes_layer=resident * B_TYPE,
            # decode scan: attention weights + the experts the batch touches;
            # with moderate batches top-k routing touches most experts, so we
            # charge the resident expert bytes (the paper-era worst case).
            scan_bytes_layer=resident * B_TYPE,
            kv_bytes_token_layer=2.0 * kv_dim * kv_b,
            embed_param_bytes=2.0 * vocab * hidden * B_TYPE,
            attn_flops_coeff=4.0 * q_dim,
            kv_elem_bytes=kv_b, kv_quant_group=hd,
        )

    @staticmethod
    def ssm(name: str, num_layers: int, hidden: int, vocab: int,
            state_bytes_layer: float,
            params_per_layer: Optional[float] = None) -> "ModelProfile":
        params = params_per_layer if params_per_layer is not None else 12.0 * hidden * hidden
        return ModelProfile(
            name=name, num_layers=num_layers, hidden=hidden,
            flops_per_token_layer=2.0 * params,
            param_bytes_layer=params * B_TYPE,
            scan_bytes_layer=params * B_TYPE,
            kv_bytes_token_layer=0.0,
            state_bytes_layer=state_bytes_layer,
            attn_layer_fraction=0.0,
            embed_param_bytes=2.0 * vocab * hidden * B_TYPE,
        )

    @staticmethod
    def from_arch(cfg, kv_dtype=None) -> "ModelProfile":
        """Profile an ``ArchConfig`` (runtime-domain model description)
        so both serving domains account KV traffic with the same math
        — the sim-vs-runtime parity contract for ``kv_bytes_shipped``
        (DESIGN.md §10). ``kv_dtype`` defaults to the runtime cache
        dtype (``models.common.DEFAULT_DTYPE``), resolved lazily so the
        scheduling domain stays importable without JAX."""
        if kv_dtype is None:
            try:
                from repro.models.common import DEFAULT_DTYPE as kv_dtype
            except ImportError:  # pragma: no cover — jax-less install
                kv_dtype = "bf16"
        kv_b = dtype_bytes(kv_dtype)
        hd = cfg.head_dim
        q_dim, kv_dim = cfg.num_heads * hd, cfg.kv_heads * hd
        attn_params = cfg.d_model * (q_dim + 2 * kv_dim) + q_dim * cfg.d_model
        ffn_params = 3.0 * cfg.d_model * max(cfg.d_ff, 1)
        params = attn_params + ffn_params
        frac = cfg.attn_layer_count / max(cfg.num_layers, 1)
        # constant-size recurrent state per non-attention layer: mamba
        # conv ring + fp32 SSM state (xLSTM states are the same order)
        state = 0.0
        if frac < 1.0:
            di = cfg.d_model * max(cfg.ssm_expand, 1)
            state = ((cfg.ssm_conv - 1) * di * kv_b
                     + di * cfg.ssm_state * 4.0)
        return ModelProfile(
            name=cfg.name, num_layers=cfg.num_layers, hidden=cfg.d_model,
            flops_per_token_layer=2.0 * params,
            param_bytes_layer=params * B_TYPE,
            scan_bytes_layer=params * B_TYPE,
            kv_bytes_token_layer=2.0 * kv_dim * kv_b,
            state_bytes_layer=state,
            attn_layer_fraction=frac,
            embed_param_bytes=2.0 * cfg.vocab * cfg.d_model * B_TYPE,
            attn_flops_coeff=4.0 * q_dim,
            kv_elem_bytes=kv_b, kv_quant_group=hd,
            layer_groups=cfg.num_periods,
        )


# Paper evaluation models -----------------------------------------------------

OPT_30B = ModelProfile.dense("opt-30b", num_layers=48, hidden=7168,
                             ffn=4 * 7168, num_heads=56, kv_heads=56,
                             vocab=50272)
LLAMA2_70B = ModelProfile.dense("llama2-70b", num_layers=80, hidden=8192,
                                ffn=28672, num_heads=64, kv_heads=8,
                                vocab=32000)


# ---------------------------------------------------------------------------
# Parallel plan over a heterogeneous device group
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    """Asymmetric TP×PP plan: one device list per pipeline stage.

    ``stages[j]`` is the (cluster-level) device indices doing TP for stage j;
    ``layers[j]`` is the number of transformer layers stage j hosts.
    """

    stages: tuple  # Tuple[Tuple[int, ...], ...]
    layers: tuple  # Tuple[int, ...]

    def __post_init__(self):
        assert len(self.stages) == len(self.layers)
        assert all(l > 0 for l in self.layers)

    @property
    def pp(self) -> int:
        return len(self.stages)

    @property
    def devices(self) -> List[int]:
        return [d for st in self.stages for d in st]

    @property
    def tp_degrees(self) -> List[int]:
        return [len(st) for st in self.stages]

    def describe(self) -> str:
        tps = self.tp_degrees
        if len(set(tps)) == 1:
            return f"TP={tps[0]},PP={self.pp}"
        return f"PP={self.pp},TP={tps}"


def make_plan(stages: Sequence[Sequence[int]], num_layers: int,
              cluster: Optional[ClusterSpec] = None) -> ParallelPlan:
    """Build a plan, splitting layers across stages ∝ stage compute power."""
    if cluster is None:
        weights = [len(s) for s in stages]
    else:
        weights = [sum(cluster.devices[d].gpu.flops for d in s) for s in stages]
    total_w = sum(weights)
    raw = [num_layers * w / total_w for w in weights]
    layers = [max(1, int(round(x))) for x in raw]
    # fix rounding so Σ layers == num_layers
    while sum(layers) > num_layers:
        i = int(np.argmax(layers))
        if layers[i] > 1:
            layers[i] -= 1
        else:  # degenerate: more stages than layers
            break
    while sum(layers) < num_layers:
        layers[int(np.argmin(layers))] += 1
    return ParallelPlan(tuple(tuple(s) for s in stages), tuple(layers))


# ---------------------------------------------------------------------------
# Latency / memory / capacity estimation (Table 1)
# ---------------------------------------------------------------------------


def _stage_compute_time(cluster: ClusterSpec, stage: Sequence[int],
                        flops: float) -> float:
    """max_d flops/(|d|·c_d): TP splits work evenly; slowest member dominates."""
    tp = len(stage)
    return max(flops / (tp * cluster.devices[d].gpu.flops * COMPUTE_EFFICIENCY)
               for d in stage)


def _stage_scan_time(cluster: ClusterSpec, stage: Sequence[int],
                     bytes_: float) -> float:
    tp = len(stage)
    return max(bytes_ / (tp * cluster.devices[d].gpu.hbm_bandwidth * MEMORY_EFFICIENCY)
               for d in stage)


def _tp_comm_time(cluster: ClusterSpec, stage: Sequence[int],
                  msg_bytes: float) -> float:
    """One AllReduce over the stage, ring-modelled as in Table 1:
    max_d Σ_{d'≠d} (α_{dd'} + msg/(|d|·β_{dd'}))."""
    tp = len(stage)
    if tp == 1:
        return 0.0
    worst = 0.0
    for d in stage:
        t = 0.0
        for e in stage:
            if e == d:
                continue
            t += (cluster.latency[d, e]
                  + msg_bytes / (tp * cluster.bandwidth[d, e] * NET_EFFICIENCY))
        worst = max(worst, t)
    return worst


def _pp_comm_time(cluster: ClusterSpec, src: Sequence[int], dst: Sequence[int],
                  msg_bytes: float) -> float:
    """min over cross-stage device pair (α + msg/β)."""
    best = np.inf
    for d in src:
        for e in dst:
            t = cluster.latency[d, e] + msg_bytes / (cluster.bandwidth[d, e] * NET_EFFICIENCY)
            best = min(best, t)
    return float(best)


def prefill_latency(cluster: ClusterSpec, profile: ModelProfile,
                    plan: ParallelPlan, batch: int, s_in: int,
                    cached_len: int = 0) -> float:
    """End-to-end prefill latency of one batch through the pipeline.

    ``cached_len`` prompt tokens are already held in a prefix cache
    (DESIGN.md §9): only the ``s_in - cached_len`` suffix pays linear
    FLOPs and TP/PP traffic, while each suffix token's attention still
    spans the full (cached + new) context — the mean attended context
    is ``(cached_len + s_in) / 2``. ``cached_len=0`` reduces to the
    paper's Table-1 formula."""
    cached_len = min(max(int(cached_len), 0), max(s_in - 1, 0))
    total = 0.0
    ntok = batch * (s_in - cached_len)
    for j, (stage, l) in enumerate(zip(plan.stages, plan.layers)):
        flops = (profile.flops_per_token_layer * ntok
                 + profile.attn_flops_coeff * ntok
                 * ((cached_len + s_in) / 2.0)
                 * profile.attn_layer_fraction) * l
        total += _stage_compute_time(cluster, stage, flops)
        # 4 collectives per layer (2 AllReduce fwd ≈ 4 msg volumes, Table 1)
        msg = ntok * profile.hidden * B_TYPE
        total += _tp_comm_time(cluster, stage, msg) * 4 * l
        if j + 1 < plan.pp:
            total += _pp_comm_time(cluster, stage, plan.stages[j + 1], msg)
    return total


def decode_step_latency(cluster: ClusterSpec, profile: ModelProfile,
                        plan: ParallelPlan, batch: int, context: int) -> float:
    """Latency of ONE decode step for a batch at the given context length."""
    total = 0.0
    for j, (stage, l) in enumerate(zip(plan.stages, plan.layers)):
        # HBM scan: weights once per step + this batch's KV cache
        scan = (profile.scan_bytes_layer
                + batch * profile.kv_bytes_token_layer * context
                * profile.attn_layer_fraction
                + batch * profile.state_bytes_layer
                * (1.0 - profile.attn_layer_fraction)) * l
        compute = profile.flops_per_token_layer * batch * l
        total += max(_stage_scan_time(cluster, stage, scan),
                     _stage_compute_time(cluster, stage, compute))
        msg = batch * profile.hidden * B_TYPE
        total += _tp_comm_time(cluster, stage, msg) * 4 * l
        if j + 1 < plan.pp:
            total += _pp_comm_time(cluster, stage, plan.stages[j + 1], msg)
    return total


def decode_latency(cluster: ClusterSpec, profile: ModelProfile,
                   plan: ParallelPlan, batch: int, s_in: int,
                   s_out: int) -> float:
    """Total decode time for s_out tokens (context grows s_in → s_in+s_out)."""
    mid_ctx = s_in + s_out / 2.0
    return decode_step_latency(cluster, profile, plan, batch, int(mid_ctx)) * s_out


def stage_memory_bytes(profile: ModelProfile, plan: ParallelPlan, j: int,
                       batch: int, s_total: int) -> float:
    """Memory per device of stage j: params/TP + KV/TP + activations (Table 1)."""
    tp = len(plan.stages[j])
    l = plan.layers[j]
    params = profile.param_bytes_layer * l / tp
    kv = profile.kv_bytes_per_request(s_total) / profile.num_layers * l * batch / tp
    act = 4.0 * batch * s_total * profile.hidden * B_TYPE / tp
    embed = profile.embed_param_bytes / tp if j in (0, plan.pp - 1) else 0.0
    return params + kv + act + embed


def plan_fits_memory(cluster: ClusterSpec, profile: ModelProfile,
                     plan: ParallelPlan, batch: int, s_total: int) -> bool:
    for j, stage in enumerate(plan.stages):
        need = stage_memory_bytes(profile, plan, j, batch, s_total)
        cap = min(cluster.devices[d].gpu.memory for d in stage) * 0.9
        if need > cap:
            return False
    return True


def max_decode_batch(cluster: ClusterSpec, profile: ModelProfile,
                     plan: ParallelPlan, s_total: int,
                     cap: int = 256) -> int:
    """Largest batch that fits every stage's memory (bisection)."""
    lo, hi = 0, cap
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if plan_fits_memory(cluster, profile, plan, mid, s_total):
            lo = mid
        else:
            hi = mid - 1
    return lo


# ---------------------------------------------------------------------------
# Replica warm-up pricing (DESIGN.md §13): a replica JOINING the fleet
# must stage the model's weights from disk/host storage onto its
# devices before it can serve — bytes-of-params over the device type's
# host link, the elastic controller's WARMING latency.
# ---------------------------------------------------------------------------

#: Achievable fraction of the peak host/disk link while staging weights
#: (filesystem + driver overhead; same spirit as NET_EFFICIENCY).
HOST_LINK_EFFICIENCY = 0.70


def weight_load_time(profile: ModelProfile, gpus,
                     parallel: Optional[int] = None) -> float:
    """Seconds to stage ``profile``'s weights onto one replica.

    ``gpus`` is the replica's device types (a ``cluster.GPUType`` or a
    sequence of them). Each device pulls its own ``1/N`` parameter
    shard concurrently over its host/disk link
    (``GPUType.host_bandwidth`` × HOST_LINK_EFFICIENCY), so the
    SLOWEST host link binds — on heterogeneous fleets an A6000 pod
    warms up ~4x slower than an H100 pod for the same model.
    ``parallel`` overrides the shard count (e.g. a single GPUType
    standing in for a TP×PP pod of that type)."""
    if not isinstance(gpus, (list, tuple)):
        gpus = [gpus]
    assert gpus, "weight_load_time needs at least one device type"
    n = parallel if parallel is not None else len(gpus)
    shard = profile.total_param_bytes / max(1, int(n))
    return max(shard / (g.host_bandwidth * HOST_LINK_EFFICIENCY)
               for g in gpus)


def warmup_steps(profile: ModelProfile, gpus, dt: float,
                 parallel: Optional[int] = None) -> int:
    """``weight_load_time`` quantized to router steps on the shared
    StepClock (DESIGN.md §13) — the number of WARMING steps a joining
    replica pays before it can go LIVE. Always at least 1: a join is
    never free."""
    assert dt > 0
    return max(1, int(math.ceil(
        weight_load_time(profile, gpus, parallel=parallel) / dt)))


# ---------------------------------------------------------------------------
# Paged KV decode accounting (DESIGN.md §11)
# ---------------------------------------------------------------------------

#: Default KV page size in tokens (the §11 block-table granularity).
PAGE_SIZE = 16


def _pages(tokens: float, page_size: int) -> int:
    """ceil(tokens / page_size) — duplicated from ``serving.paging``
    so the scheduling domain stays importable without JAX."""
    return max(0, -(-int(tokens) // int(page_size)))


def dense_slot_capacity(s_total: int, lo: int = 8) -> int:
    """The slab capacity a DENSE decode engine actually allocates per
    slot for requests of total context ``s_total``: the power-of-two
    bucket the runtime compiles for (``serving.engine._bucket``). This
    is what every dense slot pays in HBM regardless of realized length
    — the padding §11 converts into admitted concurrency."""
    b = lo
    while b < s_total:
        b *= 2
    return b


#: Resident cache dtypes that carry a per-(page, kv-head) fp32 scale
#: sidecar in the pool (DESIGN.md §16). Accounting for these prices
#: payload at the quantized element size PLUS the sidecar; other
#: dtypes (and None) price pages at the profile's own element size.
QUANT_RESIDENT_DTYPES = ("int8",)


def kv_page_bytes(profile: ModelProfile,
                  page_size: int = PAGE_SIZE,
                  kv_cache_dtype: Optional[str] = None) -> float:
    """HBM bytes one KV page occupies across all attention layers.

    ``kv_cache_dtype`` names the POOL-resident dtype when it differs
    from the profile's wire/cache dtype (DESIGN.md §16): "int8" pages
    hold 1-byte elements plus one fp32 scale per (page, kv-head) — the
    scale sidecar is charged here so every byte consumer (page budgets,
    utilization, prefix accounting) agrees on what a page costs. None
    (default) reproduces the §11 formula exactly."""
    per_layer = page_size * profile.kv_bytes_token_layer
    if kv_cache_dtype is not None:
        elems_tok = (profile.kv_bytes_token_layer
                     / max(profile.kv_elem_bytes, 1e-9))
        per_layer = page_size * elems_tok * dtype_bytes(kv_cache_dtype)
        if kv_cache_dtype in QUANT_RESIDENT_DTYPES:
            # one fp32 scale per (page, kv-head) for k and for v —
            # elems_tok / kv_quant_group scales per page per layer
            per_layer += elems_tok / max(profile.kv_quant_group, 1) * 4.0
    return per_layer * profile.num_layers * profile.attn_layer_fraction


def decode_page_budget(cluster: ClusterSpec, profile: ModelProfile,
                       plan: ParallelPlan, page_size: int = PAGE_SIZE,
                       batch: int = 1, act_tokens: int = 1,
                       kv_cache_dtype: Optional[str] = None) -> int:
    """KV pages the plan's HBM headroom holds (min over stages).

    Per stage: device capacity (the same 0.9 derate as
    ``plan_fits_memory``) minus params, embeddings, ``batch`` requests'
    recurrent state, and decode-step activations (``act_tokens`` per
    sequence — decode streams one token per step, unlike prefill's
    full-sequence activations), divided by the stage's share of one
    page's bytes. Returns 0 when any stage cannot even hold the
    weights; a huge budget for pure-SSM profiles (no paged KV).
    ``kv_cache_dtype`` prices pages via the §16 quantized-resident
    accounting — int8 pages roughly double the budget."""
    frac = profile.attn_layer_fraction
    page_b_all_layers = kv_page_bytes(profile, page_size,
                                      kv_cache_dtype=kv_cache_dtype)
    budget = float("inf")
    for j, stage in enumerate(plan.stages):
        tp = len(stage)
        l = plan.layers[j]
        cap = min(cluster.devices[d].gpu.memory for d in stage) * 0.9 * tp
        need = profile.param_bytes_layer * l
        if j in (0, plan.pp - 1):
            need += profile.embed_param_bytes
        need += batch * profile.state_bytes_layer * (1.0 - frac) * l
        need += 4.0 * batch * act_tokens * profile.hidden * B_TYPE
        headroom = cap - need
        if headroom <= 0.0:
            return 0
        page_b = page_b_all_layers * l / max(profile.num_layers, 1)
        if page_b <= 0.0:
            continue            # this stage holds no attention KV
        budget = min(budget, headroom / page_b)
    if budget == float("inf"):   # pure-SSM: KV is O(1), pages unbounded
        return 1 << 20
    return int(budget)


def _bisect_page_batch(cluster: ClusterSpec, profile: ModelProfile,
                       plan: ParallelPlan, pages_per_req: int,
                       page_size: int, cap: int,
                       kv_cache_dtype: Optional[str] = None) -> int:
    lo, hi = 0, cap
    while lo < hi:
        mid = (lo + hi + 1) // 2
        if decode_page_budget(cluster, profile, plan, page_size,
                              batch=mid,
                              kv_cache_dtype=kv_cache_dtype
                              ) >= mid * pages_per_req:
            lo = mid
        else:
            hi = mid - 1
    return lo


def max_decode_batch_paged(cluster: ClusterSpec, profile: ModelProfile,
                           plan: ParallelPlan, wl: Workload,
                           page_size: int = PAGE_SIZE,
                           cap: int = 4096,
                           slot_capacity: Optional[int] = None,
                           kv_cache_dtype: Optional[str] = None) -> int:
    """Largest decode batch the PAGE budget admits (bisection): each
    request holds ``ceil(mean_resident / page_size)`` pages at the
    steady-state mean context ``s_in + s_out/2`` — real residency, not
    the dense slab's padded capacity.

    ``slot_capacity`` instead prices each request at a DENSE engine's
    per-slot slab (``dense_slot_capacity`` bucket) under the SAME
    headroom accounting, so dense-vs-paged comparisons isolate exactly
    the padding-vs-residency difference."""
    per_req = _pages(slot_capacity if slot_capacity
                     else wl.s_in + wl.s_out / 2.0, page_size)
    if per_req <= 0:
        return max_decode_batch(cluster, profile, plan,
                                wl.s_in + wl.s_out, cap)
    # dense-slab pricing (slot_capacity) stays at the profile dtype —
    # the dense engine has no quantized-resident mode to compare against
    return _bisect_page_batch(cluster, profile, plan, per_req,
                              page_size, cap,
                              kv_cache_dtype=(None if slot_capacity
                                              else kv_cache_dtype))


def prefix_bytes_per_token(profile: ModelProfile,
                           kv_cache_dtype: Optional[str] = None,
                           page_size: int = PAGE_SIZE) -> float:
    """KV bytes one cached prompt token occupies across all layers —
    what the prefix cache charges per stored radix-edge token
    (DESIGN.md §9). Constant-size recurrent state is excluded: an SSM
    prefix snapshot costs O(1), accounted via the per-entry slab bytes
    on the runtime side. ``kv_cache_dtype="int8"`` prices the token at
    its §16 page share — quantized payload PLUS the per-token slice of
    the page's fp32 scale sidecar — so a byte budget converts to cached
    tokens without under-counting the sidecar."""
    if kv_cache_dtype is not None:
        return (kv_page_bytes(profile, page_size,
                              kv_cache_dtype=kv_cache_dtype)
                / max(page_size, 1))
    return (profile.kv_bytes_token_layer * profile.num_layers
            * profile.attn_layer_fraction)


def prefix_cache_budget(cluster: ClusterSpec, profile: ModelProfile,
                        plan: ParallelPlan, batch: int, s_total: int,
                        fraction: float = 0.5) -> float:
    """Bytes a replica can dedicate to prefix KV (DESIGN.md §9).

    The cost model's memory headroom: per stage, device capacity (the
    same 0.9 derate ``plan_fits_memory`` uses) minus the working set
    (params + the serving batch's KV + activations), times the TP
    degree (each shard holds its slice of cached KV), summed over
    stages and scaled by ``fraction`` — the rest is left for batch
    growth and fragmentation. Clamps at 0 for plans already at the
    memory edge."""
    total = 0.0
    for j, stage in enumerate(plan.stages):
        cap = min(cluster.devices[d].gpu.memory for d in stage) * 0.9
        need = stage_memory_bytes(profile, plan, j, batch, s_total)
        total += max(cap - need, 0.0) * len(stage)
    return fraction * total


def kv_transfer_time(cluster: ClusterSpec, profile: ModelProfile,
                     src_plan: ParallelPlan, dst_plan: ParallelPlan,
                     batch: int, s_in: int,
                     compression_ratio: float = 1.0,
                     chunks: int = 1) -> float:
    """KV-cache shipping time, one request batch, prefill → decode replica.

    Layer-matched routing (paper §3.3 connection type 3): the device
    holding layer j on the prefill side sends that layer's KV slice to
    the device holding layer j on the decode side. Transfers over
    distinct device pairs proceed in parallel; the completion time is
    the max over pairs of their serialized load (plus one link latency).

    KV-handoff pipeline terms (DESIGN.md §10):

    ``compression_ratio`` — raw/wire ratio of the codec on attention KV
    leaves (``kv_compression.profile_kv_ratio``); exempt recurrent
    state ships uncompressed.

    ``chunks`` — layer-group chunks of a rate-matched streaming
    handoff: chunk *i* ships while layer-group *i+1* still prefills, so
    the EXPOSED post-prefill time is the max per-chunk serialized load
    (≈ serialized/chunks + one link latency) instead of the sum.
    ``chunks=1`` is the blocking single-shot handoff and reproduces the
    pre-§10 formula exactly. Callers that need link *occupancy* (flow
    capacities, drain ledgers) must keep ``chunks=1``: chunking hides
    latency behind compute, it does not add bandwidth.
    """
    ratio = max(float(compression_ratio), 1e-9)
    chunks = max(int(chunks), 1)
    per_layer = (profile.kv_bytes_token_layer * s_in * batch
                 * profile.attn_layer_fraction / ratio
                 + profile.state_bytes_layer * batch
                 * (1.0 - profile.attn_layer_fraction))
    if per_layer <= 0.0:
        return 0.0
    # layer -> stage maps
    def layer_owner(plan: ParallelPlan, layer: int) -> int:
        acc = 0
        for j, l in enumerate(plan.layers):
            acc += l
            if layer < acc:
                return j
        return plan.pp - 1

    # accumulate bytes per (src_stage, dst_stage) edge
    load: dict = {}
    for layer in range(profile.num_layers):
        sj = layer_owner(src_plan, layer)
        dj = layer_owner(dst_plan, layer)
        load[(sj, dj)] = load.get((sj, dj), 0.0) + per_layer
    worst = 0.0
    for (sj, dj), bytes_ in load.items():
        src, dst = src_plan.stages[sj], dst_plan.stages[dj]
        # chunked streaming: only the last layer-group chunk is exposed
        # past the end of prefill compute
        bytes_ /= chunks
        # each of the |src| TP shards sends its KV slice; shards go in
        # parallel over their own best link → divide by min(|src|,|dst|)
        lanes = max(1, min(len(src), len(dst)))
        if set(src) == set(dst):
            # identical stage (migration between overlapping plans): an
            # HBM copy on every shard, slowest member finishes last
            best = max(bytes_ / (lanes * cluster.devices[d].gpu.hbm_bandwidth
                                 * MEMORY_EFFICIENCY) for d in src)
        else:
            # a partially-overlapping stage still ships the non-resident
            # shards over the network, which dominates the local copies —
            # so same-device pairs don't shortcut the edge
            best = min(
                cluster.latency[d, e]
                + bytes_ / (lanes * cluster.bandwidth[d, e] * NET_EFFICIENCY)
                for d in src for e in dst if d != e)
        worst = max(worst, best)
    return worst


# ---------------------------------------------------------------------------
# Replica capacities (Appendix A)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Workload:
    """One inference task class t: batch, prompt and output lengths."""
    name: str
    s_in: int
    s_out: int
    prefill_batch: int = 1


# Paper §5.1 workload classes (heavy prefill > 512 tokens, heavy decode > 128)
HPLD = Workload("HPLD", s_in=1024, s_out=64)
HPHD = Workload("HPHD", s_in=1024, s_out=256)
LPHD = Workload("LPHD", s_in=256, s_out=256)
LPLD = Workload("LPLD", s_in=256, s_out=64)
WORKLOADS = {w.name: w for w in (HPLD, HPHD, LPHD, LPLD)}


def prefill_capacity(cluster: ClusterSpec, profile: ModelProfile,
                     plan: ParallelPlan, wl: Workload, period: float) -> float:
    """Requests the prefill replica finishes per ``period`` (batching doesn't
    help a compute-bound phase; Appendix A divides period by latency)."""
    b = wl.prefill_batch
    if not plan_fits_memory(cluster, profile, plan, b, wl.s_in):
        return 0.0
    lat = prefill_latency(cluster, profile, plan, b, wl.s_in)
    return b * period / lat


def decode_capacity(cluster: ClusterSpec, profile: ModelProfile,
                    plan: ParallelPlan, wl: Workload, period: float,
                    paged: bool = False, page_size: int = PAGE_SIZE,
                    slot_capacity: Optional[int] = None,
                    kv_cache_dtype: Optional[str] = None) -> float:
    """Requests the decode replica finishes per ``period`` at its max batch.

    Three memory accountings for the max batch (DESIGN.md §11):

      * default (legacy): dense slabs priced at the request's final
        context ``s_in + s_out`` — the paper's Appendix-A formula;
      * ``slot_capacity``: dense slabs priced at what the runtime
        engine really allocates per slot (the power-of-two bucket,
        ``dense_slot_capacity``) under the page-budget headroom
        accounting — padding included;
      * ``paged=True``: the page-pool budget at mean real residency
        (``max_decode_batch_paged``) — padding converted into
        admitted concurrency; ``kv_cache_dtype="int8"`` further prices
        pages at the §16 quantized-resident size (payload + scale
        sidecar), roughly doubling the admitted batch."""
    s_total = wl.s_in + wl.s_out
    if paged:
        b = max_decode_batch_paged(cluster, profile, plan, wl, page_size,
                                   kv_cache_dtype=kv_cache_dtype)
    elif slot_capacity:
        b = max_decode_batch_paged(cluster, profile, plan, wl, page_size,
                                   slot_capacity=slot_capacity)
    else:
        b = max_decode_batch(cluster, profile, plan, s_total)
    if b == 0:
        return 0.0
    lat = decode_latency(cluster, profile, plan, b, wl.s_in, wl.s_out)
    return b * period / lat


# ---------------------------------------------------------------------------
# Cost-model calibration (DESIGN.md §15)
# ---------------------------------------------------------------------------

#: Clamp range for calibration factors: one bad observation window must
#: never zero out (or infinitely inflate) a flowgraph edge.
CORRECTION_MIN = 0.2
CORRECTION_MAX = 5.0

#: The calibratable scheduling surfaces, in report order. Each maps to
#: one analytical predictor above: ``prefill_latency``,
#: ``decode_step_latency``, ``kv_transfer_time``, ``warmup_steps``.
CALIBRATION_SURFACES = ("prefill", "decode", "transfer", "warmup")


@dataclasses.dataclass(frozen=True)
class CostCorrections:
    """Multiplicative calibration factors on the analytical cost model:
    robust observed/predicted ratios per scheduling surface, learned by
    ``serving.calibration.CalibrationStore`` from span-derived stage
    durations.

    A factor > 1 means reality is SLOWER than the model believed. The
    flow solver applies them by dividing replica edge capacities
    (prefill/decode) and multiplying the per-request φ→δ KV transfer
    time (transfer) — a calibrated re-solve then prices the cluster as
    observed, not as spec'd. ``warmup`` does not enter the flowgraph
    (warm-up is a §13 fleet-level price, not a steady-state edge); it
    rescales the controller's priced cold-window penalty instead.
    """
    prefill: float = 1.0
    decode: float = 1.0
    transfer: float = 1.0
    warmup: float = 1.0

    @classmethod
    def from_factors(cls, factors) -> "CostCorrections":
        """Build from a ``{surface: observed/predicted}`` mapping,
        clamping each factor to [CORRECTION_MIN, CORRECTION_MAX];
        missing surfaces stay 1.0 (uncorrected)."""
        kw = {}
        for name in CALIBRATION_SURFACES:
            f = factors.get(name)
            if f is None or not math.isfinite(f) or f <= 0.0:
                continue
            kw[name] = min(max(float(f), CORRECTION_MIN), CORRECTION_MAX)
        return cls(**kw)

    def as_dict(self) -> dict:
        return {name: getattr(self, name) for name in CALIBRATION_SURFACES}

    @property
    def is_identity(self) -> bool:
        return all(abs(getattr(self, name) - 1.0) < 1e-12
                   for name in CALIBRATION_SURFACES)

    def max_deviation(self) -> float:
        """Largest |factor − 1| over all surfaces — the scalar the
        §15 miscalibration trigger thresholds on."""
        return max(abs(getattr(self, name) - 1.0)
                   for name in CALIBRATION_SURFACES)
