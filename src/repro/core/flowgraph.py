"""Phase 2 — max-flow over the disaggregated serving graph (paper §3.3).

Builds the directed flow network:

    source → φᵢ.in                      (dispatch link capacity)
    φᵢ.in → φᵢ.out                      (prefill replica capacity)
    φᵢ.out → δⱼ.in                      (KV-cache link capacity)
    δⱼ.in → δⱼ.out                      (decode replica capacity)
    δⱼ.out → sink                       (completion link capacity)

and solves it with preflow-push. The flow assignment on φ→δ edges is the
KV-cache communication plan.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.cluster import ClusterSpec
from repro.core.cost_model import (CostCorrections, ModelProfile, PAGE_SIZE,
                                   Workload, kv_transfer_time, B_TYPE)
from repro.core.maxflow import FlowNetwork, FlowResult
from repro.core.parallel_search import best_decode_plan, best_prefill_plan
from repro.core.partition import GroupPartition
from repro.core.placement import Placement, ReplicaPlacement

DEFAULT_PERIOD = 600.0  # T = 10 minutes (paper §3.3)


@dataclasses.dataclass
class FlowGraphResult:
    placement: Placement
    # per-edge (capacity, flow) for refinement diagnostics
    edge_caps: Dict[Tuple[str, str], float]
    edge_flows: Dict[Tuple[str, str], float]


def _dispatch_capacity(cluster: ClusterSpec, devices: List[int],
                       wl: Workload, period: float) -> float:
    """source→φ / δ→sink capacity: request/response bytes over the best
    host link (Appendix A, connection types 1 & 2). Requests are token
    ids (4 B/token) — tiny; this edge is rarely binding."""
    req_bytes = 4.0 * wl.s_in
    best_bw = max(max(cluster.bandwidth[d]) for d in devices)
    return period * best_bw / max(req_bytes, 1.0)


def solve_flow(cluster: ClusterSpec, profile: ModelProfile,
               part: GroupPartition, wl: Workload,
               period: float = DEFAULT_PERIOD,
               kv_compression_ratio: float = 1.0,
               paged_kv: bool = False,
               page_size: int = PAGE_SIZE,
               dense_slot_capacity: Optional[int] = None,
               kv_cache_dtype: Optional[str] = None,
               corrections: Optional[CostCorrections] = None
               ) -> FlowGraphResult:
    """Pick per-replica optimal plans, build the flow network, run
    preflow-push, and assemble a Placement.

    ``kv_compression_ratio`` scales the φ→δ KV-link capacities by the
    serving codec's raw/wire ratio (DESIGN.md §10): compressed KV edges
    carry proportionally more flow, so ``maxflow``/``refine``
    co-optimize placement WITH compression — a bandwidth-starved edge
    that capped the uncompressed solution may stop being the min-cut.
    Chunked overlap deliberately does NOT enter these capacities: it
    hides latency behind prefill compute but leaves link occupancy
    (req/period throughput) unchanged.

    ``paged_kv`` / ``dense_slot_capacity`` (DESIGN.md §11) switch the
    decode-replica capacity accounting between the §11 page-pool budget
    at real residency and the dense engine's bucketed slab: on a
    memory-skewed cluster the two accountings admit different batch
    sizes per group and the max-flow assignment shifts with them.
    ``kv_cache_dtype="int8"`` (with ``paged_kv``) prices pages at the
    §16 quantized-resident size — roughly double the per-group page
    budget, so decode capacities grow and the assignment shifts again.

    ``corrections`` (DESIGN.md §15) rescales the graph by learned
    observed/predicted calibration factors: prefill/decode replica edge
    capacities are divided by their surface's factor (a group observed
    2x slower finishes half the requests per period) and the per-request
    KV transfer time is multiplied by the transfer factor before the
    φ→δ link capacity is derived — so a calibrated re-solve routes flow
    through the cluster as OBSERVED, not as spec'd."""
    if corrections is None:
        corrections = CostCorrections()
    replicas: List[ReplicaPlacement] = []
    for gid, (group, is_pref) in enumerate(zip(part.groups, part.is_prefill)):
        if is_pref:
            plan, cap = best_prefill_plan(cluster, profile, group, wl, period)
        else:
            plan, cap = best_decode_plan(
                cluster, profile, group, wl, period, paged_kv=paged_kv,
                page_size=page_size,
                dense_slot_capacity=dense_slot_capacity,
                kv_cache_dtype=kv_cache_dtype)
        replicas.append(ReplicaPlacement(gid, list(group), is_pref, plan, cap))

    net = FlowNetwork()
    caps: Dict[Tuple[str, str], float] = {}

    def add(u: str, v: str, c: float) -> None:
        if c <= 0.0:
            return
        net.add_edge(u, v, c)
        caps[(u, v)] = caps.get((u, v), 0.0) + c

    for r in replicas:
        if r.plan is None or r.capacity <= 0.0:
            continue
        gin, gout = f"g{r.group_id}.in", f"g{r.group_id}.out"
        factor = corrections.prefill if r.is_prefill else corrections.decode
        add(gin, gout, r.capacity / factor)
        if r.is_prefill:
            add("source", gin, _dispatch_capacity(cluster, r.devices, wl, period))
        else:
            add(gout, "sink", _dispatch_capacity(cluster, r.devices, wl, period))

    # φ.out → δ.in: KV-cache links (connection type 3)
    for p in replicas:
        if not p.is_prefill or p.plan is None or p.capacity <= 0.0:
            continue
        for d in replicas:
            if d.is_prefill or d.plan is None or d.capacity <= 0.0:
                continue
            t_kv = kv_transfer_time(cluster, profile, p.plan, d.plan,
                                    batch=1, s_in=wl.s_in,
                                    compression_ratio=kv_compression_ratio)
            t_kv *= corrections.transfer
            cap = period / t_kv if t_kv > 0 else float(period * 1e6)
            add(f"g{p.group_id}.out", f"g{d.group_id}.in", cap)

    result: FlowResult = net.preflow_push("source", "sink")

    kv_routes: Dict[Tuple[int, int], float] = {}
    for (u, v), f in result.flow.items():
        if isinstance(u, str) and u.endswith(".out") and \
           isinstance(v, str) and v.endswith(".in") and f > 1e-9:
            pid = int(u[1:].split(".")[0])
            did = int(v[1:].split(".")[0])
            kv_routes[(pid, did)] = f

    placement = Placement(replicas=replicas, kv_routes=kv_routes,
                          max_flow=result.max_flow, period=period)
    flows = {e: f for e, f in result.flow.items() if e in caps}
    return FlowGraphResult(placement, caps, flows)
