"""HexGen-2 scheduler entry point: two-phase search + iterative refinement.

``schedule()`` runs the full paper algorithm:
  phase 1  spectral + KL graph partition, coarsen/secondary partition
  phase 2  per-replica TP×PP search + preflow-push max-flow
  phase 3  max-flow-guided edge-swap refinement

A small outer sweep over the number of groups K and the initial
prefill-capacity share seeds refinement from several starts (cheap —
each start converges in a handful of solve_flow calls).

Online rescheduling (DESIGN.md §7): ``WorkloadMonitor`` watches the
observed prompt/output length mix against the workload the current
placement was scheduled for; when it drifts past a threshold,
``reschedule()`` warm-starts phase-3 refinement from the *current*
partition under the new workload instead of re-running the full
two-phase search — a handful of solve_flow calls rather than the K ×
prefill-share sweep.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Callable, List, Optional, Tuple

from repro.core.cluster import ClusterSpec
from repro.core.cost_model import PAGE_SIZE, ModelProfile, Workload
from repro.core.flowgraph import DEFAULT_PERIOD, FlowGraphResult, solve_flow
from repro.core.partition import GroupPartition, initial_partition, num_groups
from repro.core.placement import Placement
from repro.core.refine import RefineTrace, iterative_refinement


@dataclasses.dataclass
class ScheduleResult:
    placement: Placement
    partition: GroupPartition
    flow: FlowGraphResult
    trace: List[RefineTrace]
    elapsed_s: float


def schedule(cluster: ClusterSpec, profile: ModelProfile, wl: Workload,
             period: float = DEFAULT_PERIOD,
             k: Optional[int] = None,
             prefill_shares: Tuple[float, ...] = (0.35, 0.5, 0.65),
             max_refine_iters: int = 30,
             guided: bool = True,
             seed: int = 0,
             on_step: Optional[Callable[[RefineTrace], None]] = None,
             kv_compression_ratio: float = 1.0,
             paged_kv: bool = False,
             page_size: int = PAGE_SIZE,
             ) -> ScheduleResult:
    """``kv_compression_ratio`` > 1 prices the φ→δ KV links at the
    serving codec's compressed bytes (DESIGN.md §10), letting the whole
    search co-optimize placement with compression. ``paged_kv`` prices
    decode-group capacities off the §11 page-pool budget at real
    residency instead of dense slabs, letting the search size decode
    groups for what a paged fleet actually admits."""
    t0 = time.perf_counter()
    k0 = k if k is not None else num_groups(cluster, profile)
    best: Optional[ScheduleResult] = None
    for kk in sorted({max(2, k0 - 1), k0, k0 + 1} if k is None else {k0}):
        if kk > cluster.num_devices:
            continue
        for share in prefill_shares:
            try:
                part = initial_partition(cluster, profile, k=kk,
                                         prefill_share=share)
            except AssertionError:
                continue
            rpart, res, trace = iterative_refinement(
                cluster, profile, part, wl, period,
                max_iters=max_refine_iters, guided=guided, seed=seed,
                on_step=on_step,
                kv_compression_ratio=kv_compression_ratio,
                paged_kv=paged_kv, page_size=page_size)
            cand = ScheduleResult(res.placement, rpart, res, trace,
                                  time.perf_counter() - t0)
            if best is None or cand.placement.max_flow > best.placement.max_flow:
                best = cand
    if best is None:
        raise RuntimeError("scheduler found no feasible placement "
                           f"for {profile.name} on {cluster.name}")
    best = dataclasses.replace(best, elapsed_s=time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# Online rescheduling (DESIGN.md §7)
# ---------------------------------------------------------------------------


class WorkloadMonitor:
    """Sliding-window observer of served request lengths.

    Tracks mean prompt (s_in) and output (s_out) token counts over the
    last ``window`` requests and compares them against the ``baseline``
    Workload the current placement was scheduled for. Drift is the max
    absolute log-ratio of the two means vs. the baseline — symmetric in
    growth/shrink, so a 2x longer prompt and a 2x shorter prompt drift
    equally. ``drifted()`` fires once ``min_observations`` requests have
    been seen and drift exceeds ``threshold`` (0.3 ≈ a 35% shift)."""

    def __init__(self, baseline: Workload, window: int = 64,
                 threshold: float = 0.3, min_observations: int = 32):
        assert window > 0 and min_observations > 0
        self.baseline = baseline
        self.threshold = threshold
        self.min_observations = min_observations
        self._s_in: collections.deque = collections.deque(maxlen=window)
        self._s_out: collections.deque = collections.deque(maxlen=window)

    @property
    def n(self) -> int:
        return len(self._s_in)

    def observe(self, s_in, s_out: Optional[int] = None) -> None:
        """Record one served request.

        Accepts either a lifecycle ``repro.serving.Request`` (the shared
        serving type, DESIGN.md §8) or raw ``(s_in, s_out)`` token
        counts."""
        if s_out is None:
            req = s_in
            s_in, s_out = req.s_in, req.s_out
        self._s_in.append(max(int(s_in), 1))
        self._s_out.append(max(int(s_out), 1))

    def drift(self) -> float:
        """Max |log(observed mean / baseline)| over prompt and output."""
        if not self._s_in:
            return 0.0
        mean_in = sum(self._s_in) / len(self._s_in)
        mean_out = sum(self._s_out) / len(self._s_out)
        return max(abs(math.log(mean_in / max(self.baseline.s_in, 1))),
                   abs(math.log(mean_out / max(self.baseline.s_out, 1))))

    def drifted(self) -> bool:
        return self.n >= self.min_observations and self.drift() > self.threshold

    def snapshot(self, name: str = "observed") -> Workload:
        """Current window as a scheduler Workload."""
        assert self._s_in, "no observations yet"
        mean_in = int(round(sum(self._s_in) / len(self._s_in)))
        mean_out = int(round(sum(self._s_out) / len(self._s_out)))
        return Workload(name, s_in=max(mean_in, 1), s_out=max(mean_out, 1),
                        prefill_batch=self.baseline.prefill_batch)

    def rebase(self, wl: Workload, clear: bool = True) -> None:
        """Adopt ``wl`` as the new baseline after a reschedule."""
        self.baseline = wl
        if clear:
            self._s_in.clear()
            self._s_out.clear()


def reschedule(cluster: ClusterSpec, profile: ModelProfile,
               prev: ScheduleResult, wl: Workload,
               period: Optional[float] = None,
               max_refine_iters: int = 12,
               guided: bool = True,
               seed: int = 0,
               on_step: Optional[Callable[[RefineTrace], None]] = None,
               kv_compression_ratio: float = 1.0,
               paged_kv: bool = False,
               page_size: int = PAGE_SIZE,
               ) -> ScheduleResult:
    """Warm-start rescheduling for a drifted workload.

    Re-runs phase 2 (plan search + max-flow) and phase 3 (guided
    refinement) under the new workload, seeded from the *current*
    partition instead of the full two-phase K/prefill-share sweep.
    Refinement never returns worse than its start, so the result is at
    least the current placement re-planned for ``wl`` — and typically a
    few device moves / type flips toward the new mix."""
    t0 = time.perf_counter()
    if period is None:
        period = prev.placement.period
    part = GroupPartition([list(g) for g in prev.partition.groups],
                          list(prev.partition.is_prefill))
    rpart, res, trace = iterative_refinement(
        cluster, profile, part, wl, period,
        max_iters=max_refine_iters, guided=guided, seed=seed,
        on_step=on_step, kv_compression_ratio=kv_compression_ratio,
        paged_kv=paged_kv, page_size=page_size)
    return ScheduleResult(res.placement, rpart, res, trace,
                          time.perf_counter() - t0)
