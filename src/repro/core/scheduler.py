"""HexGen-2 scheduler entry point: two-phase search + iterative refinement.

``schedule()`` runs the full paper algorithm:
  phase 1  spectral + KL graph partition, coarsen/secondary partition
  phase 2  per-replica TP×PP search + preflow-push max-flow
  phase 3  max-flow-guided edge-swap refinement

A small outer sweep over the number of groups K and the initial
prefill-capacity share seeds refinement from several starts (cheap —
each start converges in a handful of solve_flow calls).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Tuple

from repro.core.cluster import ClusterSpec
from repro.core.cost_model import ModelProfile, Workload
from repro.core.flowgraph import DEFAULT_PERIOD, FlowGraphResult, solve_flow
from repro.core.partition import GroupPartition, initial_partition, num_groups
from repro.core.placement import Placement
from repro.core.refine import RefineTrace, iterative_refinement


@dataclasses.dataclass
class ScheduleResult:
    placement: Placement
    partition: GroupPartition
    flow: FlowGraphResult
    trace: List[RefineTrace]
    elapsed_s: float


def schedule(cluster: ClusterSpec, profile: ModelProfile, wl: Workload,
             period: float = DEFAULT_PERIOD,
             k: Optional[int] = None,
             prefill_shares: Tuple[float, ...] = (0.35, 0.5, 0.65),
             max_refine_iters: int = 30,
             guided: bool = True,
             seed: int = 0,
             on_step: Optional[Callable[[RefineTrace], None]] = None,
             ) -> ScheduleResult:
    t0 = time.perf_counter()
    k0 = k if k is not None else num_groups(cluster, profile)
    best: Optional[ScheduleResult] = None
    for kk in sorted({max(2, k0 - 1), k0, k0 + 1} if k is None else {k0}):
        if kk > cluster.num_devices:
            continue
        for share in prefill_shares:
            try:
                part = initial_partition(cluster, profile, k=kk,
                                         prefill_share=share)
            except AssertionError:
                continue
            rpart, res, trace = iterative_refinement(
                cluster, profile, part, wl, period,
                max_iters=max_refine_iters, guided=guided, seed=seed,
                on_step=on_step)
            cand = ScheduleResult(res.placement, rpart, res, trace,
                                  time.perf_counter() - t0)
            if best is None or cand.placement.max_flow > best.placement.max_flow:
                best = cand
    if best is None:
        raise RuntimeError("scheduler found no feasible placement "
                           f"for {profile.name} on {cluster.name}")
    best = dataclasses.replace(best, elapsed_s=time.perf_counter() - t0)
    return best
