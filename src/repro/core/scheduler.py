"""HexGen-2 scheduler entry point: two-phase search + iterative refinement.

``schedule()`` runs the full paper algorithm:
  phase 1  spectral + KL graph partition, coarsen/secondary partition
  phase 2  per-replica TP×PP search + preflow-push max-flow
  phase 3  max-flow-guided edge-swap refinement

A small outer sweep over the number of groups K and the initial
prefill-capacity share seeds refinement from several starts (cheap —
each start converges in a handful of solve_flow calls).

Online rescheduling (DESIGN.md §7): ``WorkloadMonitor`` watches the
observed prompt/output length mix against the workload the current
placement was scheduled for; when it drifts past a threshold,
``reschedule()`` warm-starts phase-3 refinement from the *current*
partition under the new workload instead of re-running the full
two-phase search — a handful of solve_flow calls rather than the K ×
prefill-share sweep.
"""
from __future__ import annotations

import collections
import dataclasses
import math
import time
from typing import Callable, List, Optional, Tuple

from repro.core.cluster import ClusterSpec
from repro.core.cost_model import (CostCorrections, PAGE_SIZE, ModelProfile,
                                   Workload)
from repro.core.flowgraph import DEFAULT_PERIOD, FlowGraphResult, solve_flow
from repro.core.partition import GroupPartition, initial_partition, num_groups
from repro.core.placement import Placement
from repro.core.refine import RefineTrace, iterative_refinement


@dataclasses.dataclass
class ScheduleResult:
    placement: Placement
    partition: GroupPartition
    flow: FlowGraphResult
    trace: List[RefineTrace]
    elapsed_s: float


def schedule(cluster: ClusterSpec, profile: ModelProfile, wl: Workload,
             period: float = DEFAULT_PERIOD,
             k: Optional[int] = None,
             prefill_shares: Tuple[float, ...] = (0.35, 0.5, 0.65),
             max_refine_iters: int = 30,
             guided: bool = True,
             seed: int = 0,
             on_step: Optional[Callable[[RefineTrace], None]] = None,
             kv_compression_ratio: float = 1.0,
             paged_kv: bool = False,
             page_size: int = PAGE_SIZE,
             kv_cache_dtype: Optional[str] = None,
             corrections: Optional[CostCorrections] = None,
             ) -> ScheduleResult:
    """``kv_compression_ratio`` > 1 prices the φ→δ KV links at the
    serving codec's compressed bytes (DESIGN.md §10), letting the whole
    search co-optimize placement with compression. ``paged_kv`` prices
    decode-group capacities off the §11 page-pool budget at real
    residency instead of dense slabs, letting the search size decode
    groups for what a paged fleet actually admits —
    ``kv_cache_dtype="int8"`` at the §16 quantized-resident page size
    (roughly double the budget). ``corrections`` (DESIGN.md §15)
    rescales every solve by learned observed/predicted calibration
    factors."""
    t0 = time.perf_counter()
    k0 = k if k is not None else num_groups(cluster, profile)
    best: Optional[ScheduleResult] = None
    for kk in sorted({max(2, k0 - 1), k0, k0 + 1} if k is None else {k0}):
        if kk > cluster.num_devices:
            continue
        for share in prefill_shares:
            try:
                part = initial_partition(cluster, profile, k=kk,
                                         prefill_share=share)
            except AssertionError:
                continue
            rpart, res, trace = iterative_refinement(
                cluster, profile, part, wl, period,
                max_iters=max_refine_iters, guided=guided, seed=seed,
                on_step=on_step,
                kv_compression_ratio=kv_compression_ratio,
                paged_kv=paged_kv, page_size=page_size,
                kv_cache_dtype=kv_cache_dtype,
                corrections=corrections)
            cand = ScheduleResult(res.placement, rpart, res, trace,
                                  time.perf_counter() - t0)
            if best is None or cand.placement.max_flow > best.placement.max_flow:
                best = cand
    if best is None:
        raise RuntimeError("scheduler found no feasible placement "
                           f"for {profile.name} on {cluster.name}")
    best = dataclasses.replace(best, elapsed_s=time.perf_counter() - t0)
    return best


# ---------------------------------------------------------------------------
# Online rescheduling (DESIGN.md §7)
# ---------------------------------------------------------------------------


class WorkloadMonitor:
    """Sliding-window observer of served request lengths.

    Tracks mean prompt (s_in) and output (s_out) token counts over the
    last ``window`` requests and compares them against the ``baseline``
    Workload the current placement was scheduled for. Drift is the max
    absolute log-ratio of the two means vs. the baseline — symmetric in
    growth/shrink, so a 2x longer prompt and a 2x shorter prompt drift
    equally. ``drifted()`` fires once ``min_observations`` requests have
    been seen and drift exceeds ``threshold`` (0.3 ≈ a 35% shift).

    Output lengths are not knowable at arrival. ``estimator`` picks what
    the monitor records as s_out when it only gets an arrival:

      * ``"oracle"`` (legacy default) — the request's true ``s_out``,
        the detection-lag-free upper bound the early drift benchmarks
        used;
      * ``"ewma"`` — an exponentially-weighted moving average of the
        output lengths of *completed* requests (fed via
        ``observe_completion``), seeded from the baseline. Detection
        now lags reality by roughly one mean request latency — the
        production-faithful signal (DESIGN.md §13).

    The monitor doubles as the elastic fleet's demand signal: it
    timestamps arrivals per priority class (``arrival_rate`` /
    ``rates_by_class``) and scores completed stated-SLO requests
    (``recent_slo_attainment``) — queue depth, arrival rates, and SLO
    attainment are what the FleetController's scale-to-demand policy
    reads."""

    def __init__(self, baseline: Workload, window: int = 64,
                 threshold: float = 0.3, min_observations: int = 32,
                 estimator: str = "oracle", ewma_alpha: float = 0.25,
                 rate_window: int = 256):
        assert window > 0 and min_observations > 0
        assert estimator in ("oracle", "ewma"), estimator
        assert 0.0 < ewma_alpha <= 1.0
        self.baseline = baseline
        self.threshold = threshold
        self.min_observations = min_observations
        self.estimator = estimator
        self.ewma_alpha = ewma_alpha
        self._s_in: collections.deque = collections.deque(maxlen=window)
        self._s_out: collections.deque = collections.deque(maxlen=window)
        self._ewma_out: Optional[float] = None
        self.completions = 0
        #: (step, priority) per observed arrival — the demand signal
        self._arrivals: collections.deque = collections.deque(
            maxlen=rate_window)
        #: 1/0 per completed stated-SLO request (met/missed)
        self._slo_hits: collections.deque = collections.deque(maxlen=window)
        #: optional §15 ``CalibrationStore`` — lets the monitor double
        #: as the miscalibration signal the FleetController reads
        self.calibration = None

    @property
    def n(self) -> int:
        return len(self._s_in)

    @property
    def estimated_s_out(self) -> float:
        """Current output-length estimate: the completion EWMA, falling
        back to the baseline before any completion has been seen."""
        if self._ewma_out is None:
            return float(self.baseline.s_out)
        return self._ewma_out

    def observe(self, s_in, s_out: Optional[int] = None,
                step: Optional[int] = None) -> None:
        """Record one ARRIVING request.

        Accepts either a lifecycle ``repro.serving.Request`` (the shared
        serving type, DESIGN.md §8) or raw ``(s_in, s_out)`` token
        counts. Under ``estimator="ewma"`` the recorded output length is
        the completion EWMA, not the oracle value — explicit
        ``(s_in, s_out)`` pairs are always taken verbatim (the caller
        measured them). ``step`` timestamps the arrival for the
        per-class rate signal."""
        priority = 0
        if s_out is None:
            req = s_in
            s_in = req.s_in
            priority = getattr(req, "priority", 0)
            s_out = (self.estimated_s_out if self.estimator == "ewma"
                     else req.s_out)
        self._s_in.append(max(int(s_in), 1))
        self._s_out.append(max(int(round(s_out)), 1))
        if step is not None:
            self._arrivals.append((int(step), int(priority)))

    def observe_completion(self, req) -> None:
        """Record one COMPLETED request: fold its realized output length
        into the EWMA estimate and score its stated SLO (if any). This
        is the only place the ``"ewma"`` estimator learns real output
        lengths — wire it to the serving layer's DONE edge."""
        realized = req.s_out if req.tokens_out is None else req.tokens_out
        realized = max(int(realized), 1)
        if self._ewma_out is None:
            self._ewma_out = float(realized)
        else:
            a = self.ewma_alpha
            self._ewma_out = (1.0 - a) * self._ewma_out + a * realized
        self.completions += 1
        if req.slo_target_s is not None:
            met = (req.latency is not None
                   and req.latency <= req.slo_target_s)
            self._slo_hits.append(1 if met else 0)

    # -- demand signal (DESIGN.md §13) ----------------------------------
    def arrival_rate(self, step: int, window_steps: int = 32) -> float:
        """Observed arrivals per router step over the trailing window."""
        lo = step - window_steps
        hits = sum(1 for s, _ in self._arrivals if lo < s <= step)
        return hits / max(1, window_steps)

    def rates_by_class(self, step: int,
                       window_steps: int = 32) -> dict:
        """Per-priority-class arrivals per step over the trailing
        window (the signal the aging-rate derivation reads)."""
        lo = step - window_steps
        by: dict = {}
        for s, p in self._arrivals:
            if lo < s <= step:
                by[p] = by.get(p, 0) + 1
        return {p: c / max(1, window_steps) for p, c in by.items()}

    def recent_slo_attainment(self) -> Optional[float]:
        """Attainment over the trailing window of completed stated-SLO
        requests; None until anything stated has completed."""
        if not self._slo_hits:
            return None
        return sum(self._slo_hits) / len(self._slo_hits)

    # -- calibration signal (DESIGN.md §15) -----------------------------
    def attach_calibration(self, store) -> None:
        """Attach a serving-layer ``CalibrationStore`` so miscalibration
        joins length drift and SLO attainment as a monitor signal."""
        self.calibration = store

    def miscalibration(self) -> float:
        """Worst per-surface |observed/predicted EWMA − 1| from the
        attached store (0.0 when unattached or not yet warmed up)."""
        if self.calibration is None or not self.calibration.warmed_up:
            return 0.0
        return self.calibration.max_error()

    def drift(self) -> float:
        """Max |log(observed mean / baseline)| over prompt and output."""
        if not self._s_in:
            return 0.0
        mean_in = sum(self._s_in) / len(self._s_in)
        mean_out = sum(self._s_out) / len(self._s_out)
        return max(abs(math.log(mean_in / max(self.baseline.s_in, 1))),
                   abs(math.log(mean_out / max(self.baseline.s_out, 1))))

    def drifted(self) -> bool:
        return self.n >= self.min_observations and self.drift() > self.threshold

    def snapshot(self, name: str = "observed") -> Workload:
        """Current window as a scheduler Workload."""
        assert self._s_in, "no observations yet"
        mean_in = int(round(sum(self._s_in) / len(self._s_in)))
        mean_out = int(round(sum(self._s_out) / len(self._s_out)))
        return Workload(name, s_in=max(mean_in, 1), s_out=max(mean_out, 1),
                        prefill_batch=self.baseline.prefill_batch)

    def rebase(self, wl: Workload, clear: bool = True) -> None:
        """Adopt ``wl`` as the new baseline after a reschedule."""
        self.baseline = wl
        if clear:
            self._s_in.clear()
            self._s_out.clear()


def reschedule(cluster: ClusterSpec, profile: ModelProfile,
               prev: ScheduleResult, wl: Workload,
               period: Optional[float] = None,
               max_refine_iters: int = 12,
               guided: bool = True,
               seed: int = 0,
               on_step: Optional[Callable[[RefineTrace], None]] = None,
               kv_compression_ratio: float = 1.0,
               paged_kv: bool = False,
               page_size: int = PAGE_SIZE,
               kv_cache_dtype: Optional[str] = None,
               corrections: Optional[CostCorrections] = None,
               ) -> ScheduleResult:
    """Warm-start rescheduling for a drifted workload.

    Re-runs phase 2 (plan search + max-flow) and phase 3 (guided
    refinement) under the new workload, seeded from the *current*
    partition instead of the full two-phase K/prefill-share sweep.
    Refinement never returns worse than its start, so the result is at
    least the current placement re-planned for ``wl`` — and typically a
    few device moves / type flips toward the new mix.

    ``corrections`` (DESIGN.md §15) makes this a CALIBRATED re-solve:
    every capacity/transfer price in the warm-started search is rescaled
    by the learned observed/predicted factors, so the refreshed flow
    assignment routes around links/groups the spec over-promised. A
    calibration shift can flip which ROLE a group is best at (a group
    placed for prefill throughput may be worth more as decode capacity
    once the real interconnect prices in), and swap-move refinement
    can't cross that ridge from the stale typing — so a corrected
    re-solve additionally seeds refinement from each single-group role
    flip, exactly like ``reschedule_capacity`` types joining devices,
    and keeps the best corrected max-flow."""
    t0 = time.perf_counter()
    if period is None:
        period = prev.placement.period
    seeds = [GroupPartition([list(g) for g in prev.partition.groups],
                            list(prev.partition.is_prefill))]
    if corrections is not None and not corrections.is_identity:
        roles = list(prev.partition.is_prefill)
        for i in range(len(roles)):
            flipped = list(roles)
            flipped[i] = not flipped[i]
            if any(flipped) and not all(flipped):
                seeds.append(GroupPartition(
                    [list(g) for g in prev.partition.groups], flipped))
    best = None
    for part in seeds:
        rpart, res, trace = iterative_refinement(
            cluster, profile, part, wl, period,
            max_iters=max_refine_iters, guided=guided, seed=seed,
            on_step=on_step, kv_compression_ratio=kv_compression_ratio,
            paged_kv=paged_kv, page_size=page_size,
            kv_cache_dtype=kv_cache_dtype, corrections=corrections)
        if best is None or res.placement.max_flow > best[1].placement.max_flow:
            best = (rpart, res, trace)
    rpart, res, trace = best
    return ScheduleResult(res.placement, rpart, res, trace,
                          time.perf_counter() - t0)


def reschedule_capacity(cluster: ClusterSpec, profile: ModelProfile,
                        prev: ScheduleResult, wl: Workload,
                        new_devices: Sequence[int],
                        period: Optional[float] = None,
                        max_refine_iters: int = 12,
                        guided: bool = True,
                        seed: int = 0,
                        on_step: Optional[Callable[[RefineTrace], None]] = None,
                        kv_compression_ratio: float = 1.0,
                        paged_kv: bool = False,
                        page_size: int = PAGE_SIZE,
                        kv_cache_dtype: Optional[str] = None,
                        corrections: Optional[CostCorrections] = None,
                        ) -> ScheduleResult:
    """Warm-start rescheduling for CAPACITY drift (DESIGN.md §13) —
    §7's trigger extended from the workload changing to the FLEET
    changing: devices joined, so the flow network itself grew.

    ``cluster`` is the GROWN spec (e.g. from ``cluster.grow_cluster``)
    and ``new_devices`` its fresh device indices; ``prev`` is the
    schedule solved on the old spec (its partition's device indices are
    preserved by construction). The joining devices are seeded as one
    new group, tried BOTH as a prefill and as a decode group — the new
    capacity gets *typed* by whichever max-flow is larger — and phase-3
    refinement then re-balances the whole φ→δ assignment around them,
    so the ``kv_routes`` of the returned placement genuinely shift, not
    just grow a row."""
    t0 = time.perf_counter()
    if period is None:
        period = prev.placement.period
    new = sorted(int(d) for d in new_devices)
    assert new, "reschedule_capacity needs at least one joining device"
    covered = {d for g in prev.partition.groups for d in g}
    assert covered.isdisjoint(new), \
        "joining devices are already in the previous partition"
    best: Optional[ScheduleResult] = None
    for as_prefill in (True, False):
        part = GroupPartition(
            [list(g) for g in prev.partition.groups] + [list(new)],
            list(prev.partition.is_prefill) + [as_prefill])
        try:
            part.validate(cluster.num_devices)
        except AssertionError:
            continue
        rpart, res, trace = iterative_refinement(
            cluster, profile, part, wl, period,
            max_iters=max_refine_iters, guided=guided, seed=seed,
            on_step=on_step, kv_compression_ratio=kv_compression_ratio,
            paged_kv=paged_kv, page_size=page_size,
            kv_cache_dtype=kv_cache_dtype, corrections=corrections)
        cand = ScheduleResult(res.placement, rpart, res, trace,
                              time.perf_counter() - t0)
        if best is None or cand.placement.max_flow > best.placement.max_flow:
            best = cand
    if best is None:
        raise RuntimeError(
            f"reschedule_capacity: no feasible typing for joining "
            f"devices {new} on {cluster.name}")
    return dataclasses.replace(best, elapsed_s=time.perf_counter() - t0)
