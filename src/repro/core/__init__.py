"""HexGen-2 core: heterogeneity-aware scheduling for disaggregated inference.

Public API:
    ClusterSpec / build_cluster / PAPER_SETTINGS   — device pools
    ModelProfile / Workload / WORKLOADS            — cost-model inputs
    schedule()                                     — the paper's algorithm
    genetic_schedule / random_swap_schedule / distserve_schedule — baselines
    Placement                                      — scheduler output
"""
from repro.core.cluster import (ClusterSpec, Device, GPUType, GPU_TYPES,
                                PAPER_SETTINGS, build_cluster, grow_cluster)
from repro.core.cost_model import (B_TYPE, HPHD, HPLD, LLAMA2_70B, LPHD, LPLD,
                                   OPT_30B, PAGE_SIZE, ModelProfile,
                                   ParallelPlan, Workload, WORKLOADS,
                                   decode_capacity, decode_latency,
                                   decode_page_budget, dense_slot_capacity,
                                   kv_page_bytes, kv_transfer_time,
                                   make_plan, max_decode_batch,
                                   max_decode_batch_paged, plan_fits_memory,
                                   prefill_capacity, prefill_latency,
                                   prefix_bytes_per_token,
                                   prefix_cache_budget, warmup_steps,
                                   weight_load_time)
from repro.core.flowgraph import DEFAULT_PERIOD, solve_flow
from repro.core.maxflow import FlowNetwork, FlowResult
from repro.core.partition import (GroupPartition, initial_partition,
                                  kernighan_lin, num_groups,
                                  spectral_partition)
from repro.core.placement import Placement, ReplicaPlacement
from repro.core.refine import RefineTrace, iterative_refinement
from repro.core.scheduler import (ScheduleResult, WorkloadMonitor,
                                  reschedule, reschedule_capacity, schedule)
from repro.core.baselines import (colocated_throughput, distserve_schedule,
                                  genetic_schedule, random_swap_schedule)

__all__ = [
    "ClusterSpec", "Device", "GPUType", "GPU_TYPES", "PAPER_SETTINGS",
    "build_cluster", "grow_cluster",
    "B_TYPE", "ModelProfile", "ParallelPlan", "Workload",
    "WORKLOADS", "HPLD", "HPHD", "LPHD", "LPLD", "OPT_30B", "LLAMA2_70B",
    "decode_capacity", "decode_latency", "decode_page_budget",
    "dense_slot_capacity", "kv_page_bytes", "kv_transfer_time", "make_plan",
    "max_decode_batch", "max_decode_batch_paged", "PAGE_SIZE",
    "plan_fits_memory", "prefill_capacity",
    "prefill_latency", "prefix_bytes_per_token", "prefix_cache_budget",
    "DEFAULT_PERIOD", "solve_flow", "FlowNetwork",
    "FlowResult", "GroupPartition", "initial_partition", "kernighan_lin",
    "num_groups", "spectral_partition", "Placement", "ReplicaPlacement",
    "RefineTrace", "iterative_refinement", "ScheduleResult", "schedule",
    "WorkloadMonitor", "reschedule", "reschedule_capacity",
    "warmup_steps", "weight_load_time",
    "colocated_throughput", "distserve_schedule", "genetic_schedule",
    "random_swap_schedule",
]
