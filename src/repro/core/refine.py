"""Phase 3 — max-flow-guided iterative refinement (paper §3.4).

Reads the flow assignment from phase 2, classifies replica edges as
*bottleneck* (flow ≈ capacity) or *underutilized* (flow < capacity), and
proposes device moves/swaps between groups that rebalance capacity:

  * move a device from the slackest group into the tightest group of the
    other type (reallocates resources between phases — the LPHD example
    in Appendix E);
  * swap a device pair between a bottleneck and an underutilized group
    (upgrades the bottleneck group's compute while preserving sizes);
  * flip the type of a chronically underutilized group.

Each candidate is re-scored by re-running phase 2 (and the per-replica
plan search); the best improving candidate is applied and the loop
repeats until convergence.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.core.cost_model import (CostCorrections, PAGE_SIZE, ModelProfile,
                                   Workload)
from repro.core.flowgraph import (DEFAULT_PERIOD, FlowGraphResult, solve_flow)
from repro.core.partition import GroupPartition

TIGHT = 0.98  # flow/capacity above this ⇒ bottleneck edge


@dataclasses.dataclass
class RefineTrace:
    """One refinement step for the convergence benchmark (Fig. 10)."""
    step: int
    max_flow: float
    action: str


def _utilization(res: FlowGraphResult) -> dict:
    """group_id -> flow/capacity through its replica edge."""
    util = {}
    for (u, v), cap in res.edge_caps.items():
        if u.endswith(".in") and v.endswith(".out"):
            gid = int(u[1:].split(".")[0])
            util[gid] = res.edge_flows.get((u, v), 0.0) / cap if cap > 0 else 0.0
    return util


def _candidate_partitions(cluster: ClusterSpec, part: GroupPartition,
                          res: FlowGraphResult,
                          rng: np.random.Generator,
                          max_candidates: int = 12,
                          guided: bool = True) -> List[Tuple[str, GroupPartition]]:
    """Generate candidate partitions. ``guided=False`` gives the paper's
    truncated variant: random swaps instead of flow-guided ones."""
    util = _utilization(res)
    gids = list(range(part.num_groups))
    cands: List[Tuple[str, GroupPartition]] = []

    def clone() -> GroupPartition:
        return GroupPartition([list(g) for g in part.groups],
                              list(part.is_prefill))

    if guided and util:
        order_tight = sorted(gids, key=lambda g: -util.get(g, 0.0))
        order_slack = sorted(gids, key=lambda g: util.get(g, 1.0))
        tight = [g for g in order_tight if util.get(g, 0) >= TIGHT]
        slack = [g for g in order_slack if util.get(g, 1.0) < TIGHT]
        pairs = [(s, t) for s in slack[:3] for t in tight[:3] if s != t]
    else:
        pairs = [(int(rng.integers(part.num_groups)),
                  int(rng.integers(part.num_groups))) for _ in range(6)]
        pairs = [(s, t) for s, t in pairs if s != t]

    for s, t in pairs:
        sg, tg = part.groups[s], part.groups[t]
        if len(sg) > 1:
            # move: give the tight group the slack group's best device
            d = max(sg, key=lambda i: cluster.devices[i].gpu.flops)
            c = clone()
            c.groups[s] = [x for x in sg if x != d]
            c.groups[t] = tg + [d]
            cands.append((f"move d{d}: g{s}->g{t}", c))
        # swap: strongest slack device <-> weakest tight device
        d1 = max(sg, key=lambda i: cluster.devices[i].gpu.flops)
        d2 = min(tg, key=lambda i: cluster.devices[i].gpu.flops)
        if cluster.devices[d1].gpu.flops > cluster.devices[d2].gpu.flops:
            c = clone()
            c.groups[s] = [x for x in sg if x != d1] + [d2]
            c.groups[t] = [x for x in tg if x != d2] + [d1]
            cands.append((f"swap d{d1}<->d{d2}: g{s}<->g{t}", c))

    # type flips of the slackest groups (resource reallocation between phases)
    flip_order = sorted(gids, key=lambda g: util.get(g, 1.0))
    for g in flip_order[:2]:
        same_type = [i for i in gids if part.is_prefill[i] == part.is_prefill[g]]
        if len(same_type) > 1:
            c = clone()
            c.is_prefill[g] = not c.is_prefill[g]
            cands.append((f"flip g{g} -> "
                          f"{'prefill' if c.is_prefill[g] else 'decode'}", c))

    # dedupe, keep valid, cap count
    out, seen = [], set()
    for name, c in cands:
        key = (tuple(tuple(sorted(g)) for g in c.groups), tuple(c.is_prefill))
        if key in seen:
            continue
        seen.add(key)
        try:
            c.validate(cluster.num_devices)
        except AssertionError:
            continue
        if any(len(g) == 0 for g in c.groups):
            continue
        out.append((name, c))
        if len(out) >= max_candidates:
            break
    return out


def iterative_refinement(
    cluster: ClusterSpec, profile: ModelProfile, part: GroupPartition,
    wl: Workload, period: float = DEFAULT_PERIOD,
    max_iters: int = 30, guided: bool = True,
    seed: int = 0,
    anneal: float = 0.0,
    on_step: Optional[Callable[[RefineTrace], None]] = None,
    kv_compression_ratio: float = 1.0,
    paged_kv: bool = False,
    page_size: int = PAGE_SIZE,
    kv_cache_dtype: Optional[str] = None,
    corrections: Optional[CostCorrections] = None,
) -> Tuple[GroupPartition, FlowGraphResult, List[RefineTrace]]:
    """Max-flow-guided edge-swap loop. Returns the refined partition, its
    flow result, and the improvement trace.

    ``kv_compression_ratio`` is the serving codec's KV raw/wire ratio
    (DESIGN.md §10): every solve prices the φ→δ links at compressed
    bytes, so refinement chases the bottlenecks that remain AFTER
    compression. ``paged_kv`` likewise prices decode-replica capacities
    off the §11 page-pool budget at real residency, so refinement
    chases what a PAGED fleet can actually admit —
    ``kv_cache_dtype="int8"`` at the §16 quantized-resident page size.

    ``corrections`` (DESIGN.md §15) threads learned calibration factors
    into EVERY solve — the initial one and each candidate's re-score —
    so the whole refinement walk chases bottlenecks in the cluster as
    observed, not just the final solve.

    ``anneal`` > 0 enables simulated-annealing acceptance (beyond-paper
    extension): a worsening candidate is accepted with probability
    exp(Δ/(T·flow)), T = anneal·(1 − step/max_iters), which lets the
    walk escape the local optima the paper's greedy loop stops at. The
    best-seen partition is still returned.
    """
    rng = np.random.default_rng(seed)
    cur_part = part
    cur_res = solve_flow(cluster, profile, part, wl, period,
                         kv_compression_ratio=kv_compression_ratio,
                         paged_kv=paged_kv, page_size=page_size,
                         kv_cache_dtype=kv_cache_dtype,
                         corrections=corrections)
    best_part, best_res = cur_part, cur_res
    trace = [RefineTrace(0, best_res.placement.max_flow, "initial")]
    if on_step:
        on_step(trace[0])
    stall = 0
    for step in range(1, max_iters + 1):
        cands = _candidate_partitions(cluster, cur_part, cur_res, rng,
                                      guided=guided)
        moved = False
        cur_flow = cur_res.placement.max_flow
        scored = [(name, cand,
                   solve_flow(cluster, profile, cand, wl, period,
                              kv_compression_ratio=kv_compression_ratio,
                              paged_kv=paged_kv, page_size=page_size,
                              kv_cache_dtype=kv_cache_dtype,
                              corrections=corrections))
                  for name, cand in cands]
        scored.sort(key=lambda t: -t[2].placement.max_flow)
        pick = None
        if scored and scored[0][2].placement.max_flow > cur_flow * (1 + 1e-6):
            pick = scored[0]          # greedy: best improving candidate
        elif scored and anneal > 0 and cur_flow > 0:
            name, cand, res = scored[0]   # least-bad downhill move
            delta = res.placement.max_flow - cur_flow
            temp = anneal * max(1.0 - step / max_iters, 0.05)
            if rng.random() < float(np.exp(delta / (temp * cur_flow))):
                pick = (f"{name} (anneal)", cand, res)
        if pick is not None:
            name, cand, res = pick
            cur_part, cur_res = cand, res
            tr = RefineTrace(step, res.placement.max_flow, name)
            trace.append(tr)
            if on_step:
                on_step(tr)
            if res.placement.max_flow > best_res.placement.max_flow:
                best_part, best_res = cand, res
            moved = True
        if not moved:
            stall += 1
            if stall >= (2 if anneal > 0 else 1):
                break
        else:
            stall = 0
    return best_part, best_res, trace
