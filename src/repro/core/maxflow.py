"""Preflow-push (push–relabel) max-flow (Cheriyan & Maheshwari 1989).

Own implementation with the highest-label selection rule and the gap
heuristic; tests cross-check against ``networkx.algorithms.flow
.preflow_push``. Capacities are floats (requests per period).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, Hashable, List, Tuple

Node = Hashable
EPS = 1e-9


@dataclasses.dataclass
class FlowResult:
    max_flow: float
    flow: Dict[Tuple[Node, Node], float]  # flow on each original edge

    def edge_flow(self, u: Node, v: Node) -> float:
        return self.flow.get((u, v), 0.0)


class FlowNetwork:
    """Directed graph with capacities; supports parallel-edge-free addition."""

    def __init__(self) -> None:
        self.capacity: Dict[Tuple[Node, Node], float] = defaultdict(float)
        self.adj: Dict[Node, List[Node]] = defaultdict(list)
        self.nodes: List[Node] = []
        self._seen = set()

    def _touch(self, n: Node) -> None:
        if n not in self._seen:
            self._seen.add(n)
            self.nodes.append(n)

    def add_edge(self, u: Node, v: Node, cap: float) -> None:
        assert cap >= 0.0
        self._touch(u)
        self._touch(v)
        if v not in self.adj[u]:
            self.adj[u].append(v)
        if u not in self.adj[v]:  # residual arc
            self.adj[v].append(u)
        self.capacity[(u, v)] += cap
        self.capacity.setdefault((v, u), 0.0)

    # ------------------------------------------------------------------
    def preflow_push(self, s: Node, t: Node) -> FlowResult:
        if s == t or s not in self._seen or t not in self._seen:
            return FlowResult(0.0, {})
        n = len(self.nodes)
        height: Dict[Node, int] = {v: 0 for v in self.nodes}
        excess: Dict[Node, float] = {v: 0.0 for v in self.nodes}
        flow: Dict[Tuple[Node, Node], float] = defaultdict(float)
        height[s] = n

        def residual(u: Node, v: Node) -> float:
            return self.capacity[(u, v)] - flow[(u, v)]

        def push(u: Node, v: Node) -> None:
            amt = min(excess[u], residual(u, v))
            flow[(u, v)] += amt
            flow[(v, u)] -= amt
            excess[u] -= amt
            excess[v] += amt

        # saturate source arcs
        for v in self.adj[s]:
            if self.capacity[(s, v)] > EPS:
                excess[s] += self.capacity[(s, v)]
                push(s, v)

        # highest-label bucket queue
        def active_nodes() -> List[Node]:
            return [v for v in self.nodes
                    if v not in (s, t) and excess[v] > EPS]

        # count per height for the gap heuristic
        hcount: Dict[int, int] = defaultdict(int)
        for v in self.nodes:
            hcount[height[v]] += 1

        work = 0
        limit = 20 * n * n * max(1, len(self.capacity))
        while True:
            act = active_nodes()
            if not act:
                break
            u = max(act, key=lambda v: height[v])
            pushed = False
            for v in self.adj[u]:
                if residual(u, v) > EPS and height[u] == height[v] + 1:
                    push(u, v)
                    pushed = True
                    if excess[u] <= EPS:
                        break
            if not pushed:
                old = height[u]
                nbrs = [height[v] for v in self.adj[u] if residual(u, v) > EPS]
                if not nbrs:
                    break
                height[u] = min(nbrs) + 1
                hcount[old] -= 1
                hcount[height[u]] += 1
                # gap heuristic: no node at height `old` → lift stranded nodes
                if hcount[old] == 0 and old < n:
                    for v in self.nodes:
                        if v not in (s, t) and old < height[v] < n:
                            hcount[height[v]] -= 1
                            height[v] = n + 1
                            hcount[height[v]] += 1
            work += 1
            if work > limit:  # pragma: no cover — safety valve
                raise RuntimeError("preflow_push: iteration limit exceeded")

        out = {e: f for e, f in flow.items()
               if f > EPS and self.capacity[e] > EPS}
        return FlowResult(max(0.0, excess[t]), dict(out))
