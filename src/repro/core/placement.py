"""Model placement strategy — the scheduler's output (paper §3.1)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.cost_model import ParallelPlan


@dataclasses.dataclass
class ReplicaPlacement:
    """One model replica: its devices, type, parallel plan, capacity."""
    group_id: int
    devices: List[int]
    is_prefill: bool
    plan: Optional[ParallelPlan]
    capacity: float  # requests per scheduling period T

    @property
    def kind(self) -> str:
        return "prefill" if self.is_prefill else "decode"

    def describe(self, cluster=None) -> str:
        plan = self.plan.describe() if self.plan else "-"
        if cluster is not None:
            names: Dict[str, int] = {}
            for d in self.devices:
                n = cluster.devices[d].gpu.name
                names[n] = names.get(n, 0) + 1
            devs = "+".join(f"{v}x{k}" for k, v in sorted(names.items()))
        else:
            devs = str(self.devices)
        return (f"[{self.kind} g{self.group_id}] {devs} {plan} "
                f"cap={self.capacity:.1f}")


@dataclasses.dataclass
class Placement:
    """Complete placement: replicas + KV-cache flow routing + value."""
    replicas: List[ReplicaPlacement]
    # (prefill_group_id, decode_group_id) -> requests per period routed
    kv_routes: Dict[Tuple[int, int], float]
    max_flow: float          # end-to-end requests per period
    period: float            # scheduling period T (seconds)

    @property
    def throughput_rps(self) -> float:
        return self.max_flow / self.period

    def prefill_replicas(self) -> List[ReplicaPlacement]:
        return [r for r in self.replicas if r.is_prefill]

    def decode_replicas(self) -> List[ReplicaPlacement]:
        return [r for r in self.replicas if not r.is_prefill]

    def replica_by_group(self, gid: int) -> ReplicaPlacement:
        for r in self.replicas:
            if r.group_id == gid:
                return r
        raise KeyError(gid)

    def describe(self, cluster=None) -> str:
        lines = [f"max_flow={self.max_flow:.1f} req/T (T={self.period:.0f}s, "
                 f"{self.throughput_rps:.3f} req/s)"]
        for r in self.replicas:
            lines.append("  " + r.describe(cluster))
        for (p, d), f in sorted(self.kv_routes.items()):
            lines.append(f"  kv-route g{p}->g{d}: {f:.1f} req/T")
        return "\n".join(lines)
