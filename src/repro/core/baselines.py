"""Scheduling baselines reproduced from the paper's evaluation (§5).

* ``genetic_schedule``      — HexGen's population-based search (merge /
                              split / swap operators), adapted to drive the
                              same flow-network objective (Fig. 10/11).
* ``random_swap_schedule``  — the truncated variant: refinement with the
                              flow-guided swap replaced by random swaps.
* ``distserve_schedule``    — DistServe-style search for HOMOGENEOUS
                              clusters: uniform replica shapes, exhaustive
                              (replicas × TP × PP) sweep per phase.
* ``colocated_throughput``  — HexGen-style colocated (non-disaggregated)
                              serving estimate with prefill/decode
                              interference, used as the HexGen baseline.
"""
from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.core.cost_model import (ModelProfile, ParallelPlan, Workload,
                                   decode_latency, make_plan, max_decode_batch,
                                   plan_fits_memory, prefill_latency)
from repro.core.flowgraph import DEFAULT_PERIOD, solve_flow
from repro.core.partition import GroupPartition, num_groups
from repro.core.refine import RefineTrace, iterative_refinement
from repro.core.scheduler import ScheduleResult


# ---------------------------------------------------------------------------
# Genetic algorithm (HexGen's scheduler, re-targeted at our objective)
# ---------------------------------------------------------------------------


def _random_partition(cluster: ClusterSpec, k: int,
                      rng: np.random.Generator) -> GroupPartition:
    perm = rng.permutation(cluster.num_devices)
    groups: List[List[int]] = [[] for _ in range(k)]
    for i, d in enumerate(perm):
        groups[i % k].append(int(d))
    is_prefill = [i < max(1, k // 2) for i in range(k)]
    rng.shuffle(is_prefill)
    if all(is_prefill):
        is_prefill[0] = False
    if not any(is_prefill):
        is_prefill[0] = True
    return GroupPartition(groups, is_prefill)


def _mutate(cluster: ClusterSpec, part: GroupPartition,
            rng: np.random.Generator) -> GroupPartition:
    groups = [list(g) for g in part.groups]
    is_prefill = list(part.is_prefill)
    op = rng.choice(["swap", "move", "flip", "merge_split"])
    k = len(groups)
    if op == "swap" and k >= 2:
        a, b = rng.choice(k, size=2, replace=False)
        if groups[a] and groups[b]:
            i, j = rng.integers(len(groups[a])), rng.integers(len(groups[b]))
            groups[a][i], groups[b][j] = groups[b][j], groups[a][i]
    elif op == "move" and k >= 2:
        a, b = rng.choice(k, size=2, replace=False)
        if len(groups[a]) > 1:
            i = rng.integers(len(groups[a]))
            groups[b].append(groups[a].pop(i))
    elif op == "flip":
        g = int(rng.integers(k))
        same = [i for i in range(k) if is_prefill[i] == is_prefill[g]]
        if len(same) > 1:
            is_prefill[g] = not is_prefill[g]
    else:  # merge two groups then split a random group in half
        if k >= 3:
            a, b = sorted(rng.choice(k, size=2, replace=False))
            merged = groups[a] + groups[b]
            rest = [groups[i] for i in range(k) if i not in (a, b)]
            rest_types = [is_prefill[i] for i in range(k) if i not in (a, b)]
            big = max(range(len(rest)), key=lambda i: len(rest[i]),
                      default=None)
            if big is not None and len(rest[big]) >= 2:
                half = len(rest[big]) // 2
                s1, s2 = rest[big][:half], rest[big][half:]
                t = rest_types[big]
                groups = rest[:big] + [s1, s2] + rest[big + 1:] + [merged]
                is_prefill = (rest_types[:big] + [t, t] + rest_types[big + 1:]
                              + [is_prefill[a]])
    groups = [g for g_i, g in enumerate(groups) if g]
    is_prefill = is_prefill[:len(groups)]
    while len(is_prefill) < len(groups):
        is_prefill.append(bool(rng.integers(2)))
    if all(is_prefill):
        is_prefill[0] = False
    if not any(is_prefill):
        is_prefill[0] = True
    return GroupPartition(groups, is_prefill)


def genetic_schedule(cluster: ClusterSpec, profile: ModelProfile,
                     wl: Workload, period: float = DEFAULT_PERIOD,
                     population: int = 8, generations: int = 20,
                     seed: int = 0,
                     on_step=None) -> ScheduleResult:
    t0 = time.perf_counter()
    rng = np.random.default_rng(seed)
    k = num_groups(cluster, profile)
    pop = [_random_partition(cluster, k, rng) for _ in range(population)]
    scored = []
    for p in pop:
        try:
            p.validate(cluster.num_devices)
            scored.append((solve_flow(cluster, profile, p, wl, period), p))
        except (AssertionError, RuntimeError):
            continue
    if not scored:
        raise RuntimeError("genetic: no valid initial population")
    scored.sort(key=lambda sp: -sp[0].placement.max_flow)
    trace = [RefineTrace(0, scored[0][0].placement.max_flow, "init")]
    if on_step:
        on_step(trace[0])
    for gen in range(1, generations + 1):
        elite = scored[:max(2, population // 4)]
        children = []
        for _ in range(population - len(elite)):
            parent = elite[int(rng.integers(len(elite)))][1]
            child = _mutate(cluster, parent, rng)
            try:
                child.validate(cluster.num_devices)
            except AssertionError:
                continue
            children.append(
                (solve_flow(cluster, profile, child, wl, period), child))
        scored = sorted(elite + children,
                        key=lambda sp: -sp[0].placement.max_flow)
        tr = RefineTrace(gen, scored[0][0].placement.max_flow, "generation")
        trace.append(tr)
        if on_step:
            on_step(tr)
    res, part = scored[0]
    return ScheduleResult(res.placement, part, res, trace,
                          time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# Truncated variant: random swaps instead of flow-guided swaps
# ---------------------------------------------------------------------------


def random_swap_schedule(cluster: ClusterSpec, profile: ModelProfile,
                         wl: Workload, period: float = DEFAULT_PERIOD,
                         seed: int = 0, on_step=None) -> ScheduleResult:
    from repro.core.scheduler import schedule
    return schedule(cluster, profile, wl, period, guided=False, seed=seed,
                    on_step=on_step)


# ---------------------------------------------------------------------------
# DistServe-style homogeneous search
# ---------------------------------------------------------------------------


def distserve_schedule(cluster: ClusterSpec, profile: ModelProfile,
                       wl: Workload,
                       period: float = DEFAULT_PERIOD) -> ScheduleResult:
    """Uniform-shape sweep: split N devices into prefill/decode pools, each
    pool into identical replicas with uniform TP×PP. Assumes (and asserts)
    a homogeneous cluster."""
    t0 = time.perf_counter()
    names = {d.gpu.name for d in cluster.devices}
    assert len(names) == 1, "distserve baseline expects homogeneous cluster"
    n = cluster.num_devices
    best: Optional[ScheduleResult] = None
    for n_pref in range(1, n):
        n_dec = n - n_pref
        for pref_size in [s for s in (1, 2, 4, 8) if n_pref % s == 0]:
            for dec_size in [s for s in (1, 2, 4, 8) if n_dec % s == 0]:
                groups, is_prefill = [], []
                devs = list(range(n))
                i = 0
                for _ in range(n_pref // pref_size):
                    groups.append(devs[i:i + pref_size]); i += pref_size
                    is_prefill.append(True)
                for _ in range(n_dec // dec_size):
                    groups.append(devs[i:i + dec_size]); i += dec_size
                    is_prefill.append(False)
                part = GroupPartition(groups, is_prefill)
                try:
                    part.validate(n)
                except AssertionError:
                    continue
                res = solve_flow(cluster, profile, part, wl, period)
                cand = ScheduleResult(res.placement, part, res, [],
                                      time.perf_counter() - t0)
                if best is None or \
                   cand.placement.max_flow > best.placement.max_flow:
                    best = cand
    assert best is not None
    return dataclasses.replace(best, elapsed_s=time.perf_counter() - t0)


# ---------------------------------------------------------------------------
# HexGen-style colocated serving estimate (non-disaggregated baseline)
# ---------------------------------------------------------------------------

# Colocation interference (paper Fig. 1 / §2): adding prefill jobs to a
# decode batch slows both; heavier prompts hurt more. Calibrated against
# the paper's reported HexGen-2/HexGen gap (avg 1.4x).
def _interference_factor(wl: Workload) -> float:
    heavy_prefill = wl.s_in > 512
    heavy_decode = wl.s_out > 128
    if heavy_prefill and not heavy_decode:
        return 1.55
    if heavy_prefill and heavy_decode:
        return 1.35
    if not heavy_prefill and heavy_decode:
        return 1.45
    return 1.30


def colocated_throughput(cluster: ClusterSpec, profile: ModelProfile,
                         wl: Workload, groups: List[List[int]],
                         period: float = DEFAULT_PERIOD) -> float:
    """Requests/period for colocated groups under continuous batching with
    prefill-decode interference (the HexGen baseline operating point)."""
    from repro.core.parallel_search import candidate_plans
    total = 0.0
    for g in groups:
        best = 0.0
        for plan in candidate_plans(cluster, profile, g):
            s_total = wl.s_in + wl.s_out
            b = max_decode_batch(cluster, profile, plan, s_total)
            if b == 0:
                continue
            t_pref = prefill_latency(cluster, profile, plan, 1, wl.s_in) * b
            t_dec = decode_latency(cluster, profile, plan, b, wl.s_in, wl.s_out)
            t_req = (t_pref + t_dec) * _interference_factor(wl)
            best = max(best, b * period / t_req)
        total += best
    return total
