"""Heterogeneous cluster specification.

The scheduling domain of HexGen-2: a pool of devices with per-device
compute/memory specs and a pairwise latency/bandwidth matrix. These are
the *inputs* to the scheduler (paper §3.1/§5.1, Figure 4); the runtime
domain (TPU meshes) lives in ``repro.launch``.

All units SI: FLOP/s, bytes, bytes/s, seconds.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

# ---------------------------------------------------------------------------
# Device types (peak specs; fp16/bf16 tensor compute, HBM bandwidth, capacity)
# Prices are RunPod-era on-demand $/h, used for the paper's budget framing.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class GPUType:
    name: str
    flops: float          # peak tensor FLOP/s (fp16, dense)
    hbm_bandwidth: float  # bytes/s
    memory: float         # bytes
    price_per_hour: float
    #: effective disk/host -> device weight-staging bandwidth (bytes/s):
    #: min(NVMe stripe, PCIe link) for the host class this GPU ships in.
    #: Prices replica warm-up (model weights over this link) in the
    #: elastic-fleet cost model — heterogeneous on purpose: an H100 box
    #: stages weights 4x faster than a commodity A6000 box.
    host_bandwidth: float = 16e9

    @property
    def memory_gb(self) -> float:
        return self.memory / 2**30


H100 = GPUType("H100", 989e12, 3.35e12, 80 * 2**30, 3.69,
               host_bandwidth=64e9)    # PCIe5 x16-class host
A100 = GPUType("A100", 312e12, 2.03e12, 80 * 2**30, 1.89,
               host_bandwidth=32e9)    # PCIe4 x16-class host
L40 = GPUType("L40", 181e12, 0.864e12, 48 * 2**30, 1.14,
              host_bandwidth=16e9)     # PCIe4, NVMe-bound commodity host
A6000 = GPUType("A6000", 155e12, 0.768e12, 48 * 2**30, 0.79,
                host_bandwidth=16e9)

GPU_TYPES: Dict[str, GPUType] = {g.name: g for g in (H100, A100, L40, A6000)}

# Link classes (bandwidth bytes/s, latency s). Figure 4 reports NCCL-measured
# bandwidth in Gbps; we reconstruct the same tiers.
_GBPS = 1e9 / 8  # 1 Gbps in bytes/s

LINK_NVLINK_H100 = (600 * _GBPS, 2e-6)    # intra-node NVLink4 (per-direction eff.)
LINK_NVLINK_A100 = (480 * _GBPS, 2e-6)
LINK_PCIE = (200 * _GBPS, 5e-6)           # intra-node PCIe4 x16 eff.
LINK_IB = (100 * _GBPS, 1.5e-5)           # inter-node InfiniBand
LINK_ETH_FAST = (25 * _GBPS, 5e-5)        # inter-node 25GbE
LINK_ETH_SLOW = (5 * _GBPS, 1e-4)         # cross-datacenter / slow TCP


@dataclasses.dataclass(frozen=True)
class Device:
    """One GPU in the pool."""
    index: int
    gpu: GPUType
    node: int  # physical server id; same node => fast intra-node link

    @property
    def name(self) -> str:
        return f"{self.gpu.name}-{self.index}"


@dataclasses.dataclass
class ClusterSpec:
    """Device pool + pairwise (latency, bandwidth) matrices."""

    devices: List[Device]
    bandwidth: np.ndarray  # [N, N] bytes/s, symmetric, 0 on diagonal
    latency: np.ndarray    # [N, N] seconds, symmetric, 0 on diagonal
    name: str = "cluster"

    def __post_init__(self) -> None:
        n = len(self.devices)
        assert self.bandwidth.shape == (n, n)
        assert self.latency.shape == (n, n)
        assert np.allclose(self.bandwidth, self.bandwidth.T)
        assert np.allclose(self.latency, self.latency.T)

    @property
    def num_devices(self) -> int:
        return len(self.devices)

    @property
    def total_memory(self) -> float:
        return float(sum(d.gpu.memory for d in self.devices))

    @property
    def price_per_hour(self) -> float:
        return float(sum(d.gpu.price_per_hour for d in self.devices))

    def memory_of(self, idxs: Sequence[int]) -> float:
        return float(sum(self.devices[i].gpu.memory for i in idxs))

    def subcluster_bandwidth(self, idxs: Sequence[int]) -> np.ndarray:
        ix = np.asarray(idxs)
        return self.bandwidth[np.ix_(ix, ix)]

    def describe(self) -> str:
        counts: Dict[str, int] = {}
        for d in self.devices:
            counts[d.gpu.name] = counts.get(d.gpu.name, 0) + 1
        parts = ", ".join(f"{v}x{k}" for k, v in sorted(counts.items()))
        return f"{self.name}: {parts} (${self.price_per_hour:.2f}/h)"


def _link_for(d: Device, e: Device) -> Tuple[float, float]:
    """Pick the link class connecting two devices."""
    if d.node == e.node:
        if d.gpu.name == "H100" and e.gpu.name == "H100":
            return LINK_NVLINK_H100
        if d.gpu.name == "A100" and e.gpu.name == "A100":
            return LINK_NVLINK_A100
        return LINK_PCIE
    # inter-node: fabric quality keyed by the "slower" node tier
    tier = {"H100": 0, "A100": 0, "L40": 1, "A6000": 1}
    if tier[d.gpu.name] == 0 and tier[e.gpu.name] == 0:
        return LINK_IB
    if tier[d.gpu.name] == 0 or tier[e.gpu.name] == 0:
        return LINK_ETH_FAST
    return LINK_ETH_FAST


def build_cluster(
    node_specs: Sequence[Tuple[str, int]],
    name: str = "cluster",
    slow_pairs: Optional[Sequence[Tuple[int, int]]] = None,
) -> ClusterSpec:
    """Build a ClusterSpec from (gpu_type_name, count) per physical node.

    ``slow_pairs`` marks node pairs connected over cross-datacenter links
    (LINK_ETH_SLOW), reproducing the ultra-low-bandwidth cells of Fig. 4.
    """
    devices: List[Device] = []
    for node_id, (gname, count) in enumerate(node_specs):
        for _ in range(count):
            devices.append(Device(len(devices), GPU_TYPES[gname], node_id))
    n = len(devices)
    bw = np.zeros((n, n))
    lat = np.zeros((n, n))
    slow = {tuple(sorted(p)) for p in (slow_pairs or [])}
    for i in range(n):
        for j in range(i + 1, n):
            di, dj = devices[i], devices[j]
            if tuple(sorted((di.node, dj.node))) in slow and di.node != dj.node:
                b, l = LINK_ETH_SLOW
            else:
                b, l = _link_for(di, dj)
            bw[i, j] = bw[j, i] = b
            lat[i, j] = lat[j, i] = l
    return ClusterSpec(devices, bw, lat, name=name)


def grow_cluster(
    cluster: ClusterSpec,
    node_specs: Sequence[Tuple[str, int]],
    name: Optional[str] = None,
    slow_nodes: Optional[Sequence[int]] = None,
) -> Tuple[ClusterSpec, List[int]]:
    """Capacity drift: return a NEW ClusterSpec with ``node_specs``
    appended as fresh physical nodes, plus the new device indices.

    Existing devices keep their indices and their pairwise link matrix
    verbatim (including any hand-tuned skew, e.g. ``kv_skewed_setting``)
    — only the new rows/columns are filled from the link classes. This
    is the scheduling-domain view of a replica JOINING the fleet: the
    elastic controller re-solves max-flow over the grown graph so the
    new devices get typed as prefill or decode (DESIGN.md §13).

    ``slow_nodes`` lists NEW node ids (``max existing node + 1 + k``)
    reached only over the cross-datacenter tier — late capacity often
    arrives far away.
    """
    m = cluster.num_devices
    devices = list(cluster.devices)
    next_node = max((d.node for d in devices), default=-1) + 1
    new_idx: List[int] = []
    for k, (gname, count) in enumerate(node_specs):
        for _ in range(count):
            d = Device(len(devices), GPU_TYPES[gname], next_node + k)
            devices.append(d)
            new_idx.append(d.index)
    n = len(devices)
    bw = np.zeros((n, n))
    lat = np.zeros((n, n))
    bw[:m, :m] = cluster.bandwidth
    lat[:m, :m] = cluster.latency
    slow = set(slow_nodes or [])
    for i in range(n):
        for j in range(max(i + 1, m), n):
            di, dj = devices[i], devices[j]
            if di.node != dj.node and (di.node in slow or dj.node in slow):
                b, l = LINK_ETH_SLOW
            else:
                b, l = _link_for(di, dj)
            bw[i, j] = bw[j, i] = b
            lat[i, j] = lat[j, i] = l
    grown = ClusterSpec(devices, bw, lat,
                        name=name or f"{cluster.name}+join")
    return grown, new_idx


# ---------------------------------------------------------------------------
# The paper's evaluation settings (Figure 4). Node layout reconstructed from
# the GPU counts; budgets match the figure captions.
# ---------------------------------------------------------------------------


def homogeneous_setting() -> ClusterSpec:
    """8×H100, one node — $29.5/h."""
    return build_cluster([("H100", 8)], name="homogeneous-8xH100")


def heterogeneous_setting_1() -> ClusterSpec:
    """2×H100 + 6×A100 + 4×L40 + 8×A6000 — $28.8/h."""
    return build_cluster(
        [("H100", 2), ("A100", 4), ("A100", 2), ("L40", 4),
         ("A6000", 4), ("A6000", 4)],
        name="hetero-1",
        slow_pairs=[(0, 4), (0, 5), (1, 5)],
    )


def heterogeneous_setting_2() -> ClusterSpec:
    """3×H100 + 3×A100 + 6×L40 + 6×A6000 — $26.9/h."""
    return build_cluster(
        [("H100", 3), ("A100", 3), ("L40", 4), ("L40", 2),
         ("A6000", 4), ("A6000", 2)],
        name="hetero-2",
        slow_pairs=[(0, 4), (1, 5)],
    )


def heterogeneous_setting_3() -> ClusterSpec:
    """6×A100 + 12×L40 + 6×A6000 — $27.1/h."""
    return build_cluster(
        [("A100", 4), ("A100", 2), ("L40", 4), ("L40", 4), ("L40", 4),
         ("A6000", 4), ("A6000", 2)],
        name="hetero-3",
        slow_pairs=[(0, 6), (1, 5)],
    )


def heterogeneous_setting_4() -> ClusterSpec:
    """3×H100 + 9×A100 — $26.3/h (high-end only)."""
    return build_cluster(
        [("H100", 3), ("A100", 4), ("A100", 4), ("A100", 1)],
        name="hetero-4",
    )


def heterogeneous_setting_5() -> ClusterSpec:
    """4×A100 + 6×L40 + 10×A6000 — 70% budget ($20.5/h)."""
    return build_cluster(
        [("A100", 4), ("L40", 4), ("L40", 2), ("A6000", 4),
         ("A6000", 4), ("A6000", 2)],
        name="hetero-5-70pct",
        slow_pairs=[(0, 5), (1, 4)],
    )


def kv_skewed_setting(inter_node_scale: float = 0.05) -> ClusterSpec:
    """Bandwidth-skewed beyond-paper setting (DESIGN.md §10): capable
    compute on every node behind a starved inter-node fabric
    (``inter_node_scale`` × the normal link tiers), so the φ→δ KV-cache
    links — not replica compute — are the binding constraint. This is
    the regime where KV compression changes both serving latency and
    the max-flow scheduler's decisions."""
    cl = build_cluster([("H100", 2), ("A100", 2), ("A6000", 2),
                        ("A6000", 2)],
                       name=f"kv-skewed-{inter_node_scale:g}")
    for i, di in enumerate(cl.devices):
        for j, dj in enumerate(cl.devices):
            if di.node != dj.node:
                cl.bandwidth[i, j] *= inter_node_scale
    return cl


def memory_skewed_setting() -> ClusterSpec:
    """Memory-skewed beyond-paper setting (DESIGN.md §11): ample
    compute on every node behind a UNIFORMLY fast fabric, but sharply
    unequal HBM per node — 80 GB H100/A100 nodes next to 48 GB A6000
    nodes. Decode-group sizing is bound by KV residency, not FLOPs or
    links, so the dense-vs-paged capacity accounting (padding vs real
    residency) is the only lever that moves the max-flow assignment —
    the regime the §11 paged layout targets."""
    cl = build_cluster([("H100", 2), ("A100", 4), ("A6000", 4),
                        ("A6000", 4)],
                       name="memory-skewed")
    # flatten the fabric: every inter-node link at InfiniBand tier so
    # φ→δ KV links never bind (memory is the one skewed resource)
    b, l = LINK_IB
    for i, di in enumerate(cl.devices):
        for j, dj in enumerate(cl.devices):
            if di.node != dj.node:
                cl.bandwidth[i, j] = b
                cl.latency[i, j] = l
    return cl


PAPER_SETTINGS = {
    "homogeneous": homogeneous_setting,
    "hetero1": heterogeneous_setting_1,
    "hetero2": heterogeneous_setting_2,
    "hetero3": heterogeneous_setting_3,
    "hetero4": heterogeneous_setting_4,
    "hetero5": heterogeneous_setting_5,
}
