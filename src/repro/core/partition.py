"""Phase 1 — graph partition (paper §3.2).

Step (i)   spectral partition of the device graph into K groups,
           refined by Kernighan–Lin (minimize inter-group bandwidth cut,
           balance per-group memory).
Step (ii)  coarsen groups to super-nodes; secondary partition of the
           coarsened graph into {prefill, decode} sets — this time
           MAXIMIZING the inter-type cut (KV cache crosses it).
Step (iii) projection back to device sets.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.core.cost_model import ModelProfile, B_TYPE


# ---------------------------------------------------------------------------
# Spectral partition
# ---------------------------------------------------------------------------


def _laplacian(weights: np.ndarray) -> np.ndarray:
    deg = np.diag(weights.sum(axis=1))
    return deg - weights


def spectral_partition(weights: np.ndarray, k: int,
                       node_weights: Optional[np.ndarray] = None,
                       rng: Optional[np.random.Generator] = None) -> List[int]:
    """Partition a weighted graph into k groups via Laplacian eigenvectors.

    Uses the k smallest non-trivial eigenvectors as node embeddings and a
    balanced greedy assignment (k-means-free, deterministic): sort nodes by
    their Fiedler coordinate and cut into k memory-balanced chunks, then
    snap within the spectral embedding. (Alpert & Yao 1995: "the more
    eigenvectors, the better".)
    """
    n = weights.shape[0]
    k = max(1, min(k, n))
    if k == 1:
        return [0] * n
    if node_weights is None:
        node_weights = np.ones(n)
    lap = _laplacian(weights / (weights.max() + 1e-30))
    vals, vecs = np.linalg.eigh(lap)
    embed = vecs[:, 1:min(k + 1, n)]  # skip the trivial constant eigenvector
    order = np.argsort(embed[:, 0], kind="stable")
    # memory-balanced contiguous cut along the Fiedler ordering
    target = node_weights.sum() / k
    labels = [0] * n
    g, acc = 0, 0.0
    for idx in order:
        if acc >= target and g < k - 1:
            g, acc = g + 1, 0.0
        labels[idx] = g
        acc += node_weights[idx]
    return labels


# ---------------------------------------------------------------------------
# Kernighan–Lin refinement
# ---------------------------------------------------------------------------


def _cut_delta(weights: np.ndarray, labels: Sequence[int], a: int, b: int) -> float:
    """Change in total inter-group cut if nodes a and b swap groups."""
    la, lb = labels[a], labels[b]
    delta = 0.0
    for v in range(weights.shape[0]):
        if v in (a, b):
            continue
        lv = labels[v]
        # after the swap, a joins lb and b joins la: an edge (a,v) with
        # lv==lb stops being cut (+w towards improvement), one with
        # lv==la becomes cut (-w); symmetrically for b.
        delta += weights[a, v] * ((1 if lv == lb else 0) - (1 if lv == la else 0))
        delta += weights[b, v] * ((1 if lv == la else 0) - (1 if lv == lb else 0))
    # the a-b edge itself stays cut either way (different groups)
    return delta  # positive == total cut DECREASES by delta


def kernighan_lin(weights: np.ndarray, labels: List[int],
                  node_weights: np.ndarray,
                  balance_tol: float = 0.25,
                  maximize: bool = False,
                  max_passes: int = 8) -> List[int]:
    """Pairwise-swap refinement of a multiway partition.

    Greedily swaps node pairs across groups while the inter-group cut
    improves (decreases, or increases when ``maximize``) and per-group
    node-weight (memory) balance stays within ``balance_tol`` of even.
    """
    labels = list(labels)
    n = weights.shape[0]
    k = max(labels) + 1
    if k <= 1:
        return labels
    target = node_weights.sum() / k

    def group_w(lbls):
        w = np.zeros(k)
        for i, l in enumerate(lbls):
            w[l] += node_weights[i]
        return w

    sign = -1.0 if maximize else 1.0
    for _ in range(max_passes):
        improved = False
        gw = group_w(labels)
        for a in range(n):
            for b in range(a + 1, n):
                if labels[a] == labels[b]:
                    continue
                delta = _cut_delta(weights, labels, a, b)  # >0 => cut shrinks
                if sign * delta <= 1e-12:
                    continue
                la, lb = labels[a], labels[b]
                dw = node_weights[a] - node_weights[b]
                new_a, new_b = gw[la] - dw, gw[lb] + dw
                if (abs(new_a - target) > balance_tol * target + 1e-9 or
                        abs(new_b - target) > balance_tol * target + 1e-9):
                    # allow the swap only if it doesn't worsen balance
                    if abs(new_a - target) + abs(new_b - target) > \
                       abs(gw[la] - target) + abs(gw[lb] - target) + 1e-9:
                        continue
                labels[a], labels[b] = lb, la
                gw[la], gw[lb] = new_a, new_b
                improved = True
        if not improved:
            break
    return labels


# ---------------------------------------------------------------------------
# Group count, coarsening, secondary partition
# ---------------------------------------------------------------------------


def replica_memory_estimate(profile: ModelProfile, batch: int = 32,
                            s_total: int = 1024) -> float:
    """Appendix A: params + 32 concurrent requests' KV cache."""
    return profile.total_param_bytes + batch * profile.kv_bytes_per_request(s_total)


def num_groups(cluster: ClusterSpec, profile: ModelProfile,
               batch: int = 32, s_total: int = 1024) -> int:
    need = replica_memory_estimate(profile, batch, s_total)
    k = int(cluster.total_memory * 0.9 // need)
    return max(2, min(k, cluster.num_devices))  # ≥1 prefill + ≥1 decode


@dataclasses.dataclass
class GroupPartition:
    """Output of phase 1: device groups + type per group."""
    groups: List[List[int]]           # device indices per group
    is_prefill: List[bool]            # type per group

    @property
    def num_groups(self) -> int:
        return len(self.groups)

    def prefill_groups(self) -> List[int]:
        return [i for i, p in enumerate(self.is_prefill) if p]

    def decode_groups(self) -> List[int]:
        return [i for i, p in enumerate(self.is_prefill) if not p]

    def validate(self, n_devices: int) -> None:
        seen = sorted(d for g in self.groups for d in g)
        assert seen == list(range(n_devices)), "partition must cover all devices"
        assert len(self.groups) == len(self.is_prefill)
        assert any(self.is_prefill) and not all(self.is_prefill), \
            "need at least one prefill and one decode group"


def coarsen(weights: np.ndarray, groups: List[List[int]]) -> np.ndarray:
    """Merge device nodes into super-nodes; edge = summed cross-group weight."""
    k = len(groups)
    coarse = np.zeros((k, k))
    for i in range(k):
        for j in range(i + 1, k):
            w = float(weights[np.ix_(groups[i], groups[j])].sum())
            coarse[i, j] = coarse[j, i] = w
    return coarse


def secondary_partition(coarse_weights: np.ndarray,
                        group_capacity: np.ndarray,
                        prefill_share: float = 0.5) -> List[bool]:
    """Split super-nodes into prefill/decode, MAXIMIZING the inter-type cut.

    Greedy + KL(maximize): start from a capacity-balanced split (the
    ``prefill_share`` fraction of total capacity goes to prefill), then
    pairwise-swap while the inter-type edge weight grows.
    """
    k = coarse_weights.shape[0]
    order = np.argsort(-group_capacity, kind="stable")
    total = group_capacity.sum()
    is_prefill = [False] * k
    acc = 0.0
    for idx in order:
        if acc < prefill_share * total:
            is_prefill[idx] = True
            acc += group_capacity[idx]
    if all(is_prefill):
        is_prefill[int(order[-1])] = False
    if not any(is_prefill):
        is_prefill[int(order[0])] = True
    labels = [0 if p else 1 for p in is_prefill]
    labels = kernighan_lin(coarse_weights, labels, group_capacity,
                           balance_tol=0.6, maximize=True)
    out = [l == 0 for l in labels]
    if all(out) or not any(out):
        out[int(np.argmax(group_capacity))] = not out[int(np.argmax(group_capacity))]
    return out


def initial_partition(cluster: ClusterSpec, profile: ModelProfile,
                      k: Optional[int] = None,
                      prefill_share: float = 0.5) -> GroupPartition:
    """Full phase 1: spectral + KL + coarsen + secondary partition + project."""
    node_mem = np.array([d.gpu.memory for d in cluster.devices])
    if k is None:
        k = num_groups(cluster, profile)
    labels = spectral_partition(cluster.bandwidth, k, node_mem)
    labels = kernighan_lin(cluster.bandwidth / cluster.bandwidth.max(),
                           labels, node_mem)
    k = max(labels) + 1
    groups: List[List[int]] = [[] for _ in range(k)]
    for i, l in enumerate(labels):
        groups[l].append(i)
    groups = [g for g in groups if g]
    # step ii: coarsen + secondary partition on aggregate FLOPS as capacity
    coarse = coarsen(cluster.bandwidth, groups)
    cap = np.array([sum(cluster.devices[d].gpu.flops for d in g) for g in groups])
    is_prefill = secondary_partition(coarse, cap, prefill_share)
    # step iii: projection is implicit — groups already hold device indices
    part = GroupPartition(groups, list(is_prefill))
    part.validate(cluster.num_devices)
    return part
