"""Per-replica parallel-strategy search (paper §3.3, step 1 of phase 2).

Enumerates asymmetric TP×PP plans for a heterogeneous device group and
selects the latency-optimal plan for prefill replicas and the
throughput-optimal plan for decode replicas.
"""
from __future__ import annotations

import itertools
from typing import Iterable, List, Optional, Sequence, Tuple

from repro.core.cluster import ClusterSpec
from repro.core.cost_model import (PAGE_SIZE, ModelProfile, ParallelPlan,
                                   Workload, decode_capacity, make_plan,
                                   plan_fits_memory, prefill_capacity,
                                   prefill_latency)


def _ordered(cluster: ClusterSpec, group: Sequence[int]) -> List[int]:
    """Order devices by (node, gpu tier) so contiguous PP stages are
    node-local and TP stays on fast intra-node links."""
    return sorted(group, key=lambda d: (cluster.devices[d].node,
                                        -cluster.devices[d].gpu.flops, d))


def _stage_splits(devs: List[int], cluster: ClusterSpec,
                  max_pp: int) -> Iterable[List[List[int]]]:
    """Candidate stage splits: (a) uniform TP×PP factorizations over the
    ordered device list; (b) the by-node split (asymmetric TP)."""
    n = len(devs)
    seen = set()
    for pp in range(1, min(n, max_pp) + 1):
        if n % pp == 0:
            tp = n // pp
            split = [devs[i * tp:(i + 1) * tp] for i in range(pp)]
            key = tuple(tuple(s) for s in split)
            if key not in seen:
                seen.add(key)
                yield split
    # by-node asymmetric split
    by_node: List[List[int]] = []
    for d in devs:
        if by_node and cluster.devices[by_node[-1][-1]].node == cluster.devices[d].node:
            by_node[-1].append(d)
        else:
            by_node.append([d])
    if 1 < len(by_node) <= max_pp:
        key = tuple(tuple(s) for s in by_node)
        if key not in seen:
            seen.add(key)
            yield by_node


def candidate_plans(cluster: ClusterSpec, profile: ModelProfile,
                    group: Sequence[int],
                    max_pp: Optional[int] = None) -> List[ParallelPlan]:
    devs = _ordered(cluster, group)
    max_pp = max_pp or min(len(devs), profile.num_layers, 8)
    plans = []
    for split in _stage_splits(devs, cluster, max_pp):
        if len(split) > profile.num_layers:
            continue
        plans.append(make_plan(split, profile.num_layers, cluster))
    return plans


def best_prefill_plan(cluster: ClusterSpec, profile: ModelProfile,
                      group: Sequence[int], wl: Workload,
                      period: float) -> Tuple[Optional[ParallelPlan], float]:
    """Latency-optimal plan; returns (plan, capacity req/period)."""
    best, best_lat = None, float("inf")
    for plan in candidate_plans(cluster, profile, group):
        if not plan_fits_memory(cluster, profile, plan, wl.prefill_batch, wl.s_in):
            continue
        lat = prefill_latency(cluster, profile, plan, wl.prefill_batch, wl.s_in)
        if lat < best_lat:
            best, best_lat = plan, lat
    if best is None:
        return None, 0.0
    return best, prefill_capacity(cluster, profile, best, wl, period)


def best_decode_plan(cluster: ClusterSpec, profile: ModelProfile,
                     group: Sequence[int], wl: Workload,
                     period: float, paged_kv: bool = False,
                     page_size: int = PAGE_SIZE,
                     dense_slot_capacity: Optional[int] = None,
                     kv_cache_dtype: Optional[str] = None
                     ) -> Tuple[Optional[ParallelPlan], float]:
    """Throughput-optimal plan; returns (plan, capacity req/period).

    ``paged_kv`` prices the max decode batch off the §11 page-pool
    budget at real residency; ``dense_slot_capacity`` prices dense
    slabs at the engine's bucketed slab (padding included);
    ``kv_cache_dtype`` prices pages at the §16 quantized-resident
    size (payload + scale sidecar)."""
    best, best_cap = None, 0.0
    for plan in candidate_plans(cluster, profile, group):
        cap = decode_capacity(cluster, profile, plan, wl, period,
                              paged=paged_kv, page_size=page_size,
                              slot_capacity=dense_slot_capacity,
                              kv_cache_dtype=kv_cache_dtype)
        if cap > best_cap:
            best, best_cap = plan, cap
    return best, best_cap
