"""repro - HexGen-2 (ICLR 2025) reproduction: disaggregated LLM inference
with heterogeneity-aware scheduling, built as a JAX/TPU framework."""

__version__ = "0.1.0"
