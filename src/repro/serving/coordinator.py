"""Task coordinator: drives disaggregated serving end to end.

The in-process replacement for HexGen-2's libp2p coordinator
(DESIGN.md §3): it owns one PrefillEngine and one-or-more DecodeEngines,
dispatches incoming requests, performs the KV handoff, and runs decode
continuous batching. Dispatch across decode engines follows the
scheduler's flow assignment proportions when given one, and can be
rebalanced mid-serve from a rescheduled Placement's flow assignment
(``apply_flow_assignment`` — the runtime-domain half of the online
rescheduling path, DESIGN.md §7).

This is the runtime-domain path (real JAX execution); the
scheduling-domain evaluation lives in ``simulator.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ArchConfig
from repro.serving import kv_transfer
from repro.serving.engine import DecodeEngine, PrefillEngine


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ServeResult:
    rid: int
    tokens: List[int]             # generated tokens (incl. first)


class Coordinator:
    def __init__(self, cfg: ArchConfig, params: Any,
                 num_decode_engines: int = 1, slots_per_engine: int = 4,
                 capacity: int = 128,
                 route_weights: Optional[Sequence[float]] = None):
        self.cfg = cfg
        self.capacity = capacity
        self.prefill_engine = PrefillEngine(cfg, params, capacity)
        self.decode_engines = [DecodeEngine(cfg, params, slots_per_engine,
                                            capacity)
                               for _ in range(num_decode_engines)]
        w = list(route_weights or [1.0] * num_decode_engines)
        assert len(w) == num_decode_engines
        self._weights = np.asarray(w, float) / sum(w)
        self._routed = np.zeros(num_decode_engines)

    def _pick_engine(self) -> int:
        # flow-proportional, load-corrected (same rule as the simulator)
        load = (self._routed + 1) / np.maximum(self._weights, 1e-9)
        return int(np.argmin(load))

    # -- online rebalance (DESIGN.md §7) --------------------------------
    def update_route_weights(self, weights: Sequence[float],
                             reset_counts: bool = False) -> None:
        """Rebalance decode-engine dispatch proportions mid-serve.

        ``reset_counts`` also zeroes the per-engine routed counters so
        the new proportions take effect immediately instead of first
        paying down the historical imbalance."""
        w = np.asarray(list(weights), float)
        assert len(w) == len(self.decode_engines) and w.sum() > 0
        self._weights = w / w.sum()
        if reset_counts:
            self._routed[:] = 0.0

    def apply_flow_assignment(self, placement: Any,
                              reset_counts: bool = True) -> np.ndarray:
        """Adopt a (re)scheduled Placement's flow assignment.

        Sums the kv_route flow into each decode group (sorted by group
        id) and maps groups onto this coordinator's decode engines in
        order, folding surplus groups round-robin. Engines with no
        mapped flow keep an epsilon weight so they stay schedulable.
        Returns the normalized weights actually installed."""
        per_group: Dict[int, float] = {}
        for (_, did), f in placement.kv_routes.items():
            per_group[did] = per_group.get(did, 0.0) + f
        gids = sorted(r.group_id for r in placement.decode_replicas())
        n = len(self.decode_engines)
        w = np.full(n, 1e-9)
        for i, gid in enumerate(gids):
            w[i % n] += per_group.get(gid, 0.0)
        if w.sum() <= n * 1e-9:   # degenerate flow: fall back to uniform
            w = np.ones(n)
        self.update_route_weights(w, reset_counts=reset_counts)
        return self._weights

    def serve(self, requests: List[ServeRequest]) -> List[ServeResult]:
        results = {r.rid: ServeResult(r.rid, []) for r in requests}
        queue = list(requests)
        inflight = {r.rid: r for r in requests}

        while queue or any(s.active for e in self.decode_engines
                           for s in e.slots):
            # admit as many queued requests as free slots allow
            progressed = False
            while queue:
                eng_idx = self._pick_engine()
                eng = self.decode_engines[eng_idx]
                if not eng.free_slots():
                    # try any engine with space
                    free = [i for i, e in enumerate(self.decode_engines)
                            if e.free_slots()]
                    if not free:
                        break
                    eng_idx = free[0]
                    eng = self.decode_engines[eng_idx]
                req = queue.pop(0)
                self._routed[eng_idx] += 1
                first, cache = self._prefill_one(req)
                results[req.rid].tokens.append(first)
                if req.max_new_tokens <= 1:
                    continue
                cache = kv_transfer.pad_capacity(cache, self.capacity)
                cache = kv_transfer.transfer(cache)
                eng.admit(req.rid, first, len(req.prompt),
                          req.max_new_tokens, cache)
                progressed = True
            # one decode step across engines
            for eng in self.decode_engines:
                for rid, tok, finished in eng.step():
                    results[rid].tokens.append(tok)
                    progressed = True
            if not progressed and queue:
                raise RuntimeError("coordinator stalled: no free slots and "
                                   "no active decode")
        return [results[r.rid] for r in requests]

    def _prefill_one(self, req: ServeRequest) -> Tuple[int, Any]:
        tokens = np.asarray(req.prompt, np.int32)[None]
        next_tok, cache = self.prefill_engine.prefill(tokens, **req.extra)
        return int(next_tok[0]), cache
