"""Task coordinator: drives disaggregated serving end to end.

The in-process replacement for HexGen-2's libp2p coordinator
(DESIGN.md §3): it owns one PrefillEngine and one-or-more DecodeEngines
and exposes the event-driven request lifecycle (DESIGN.md §8) through
``ServeSession``:

    sess = coord.session()
    sess.submit(req, on_token=cb)      # non-blocking, QUEUED
    while sess.step():                 # prefill | KV handoff | decode —
        ...                            #   separate stages, one step()
    sess.metrics()                     # ServeMetrics, same schema as
                                       #   the simulator's SimResult

``step()`` advances the three pipeline stages independently: a bounded
bucketed/padded prefill micro-batch (one jit'd call), KV handoffs into
free decode slots (flow-weighted routing), and one decode step across
all engines — so a prefill burst can no longer starve in-flight decode
the way the old blocking ``serve(requests)`` loop did. ``serve()``
survives as a thin wrapper over a session.

Dispatch across decode engines follows the scheduler's flow assignment
proportions when given one, and can be rebalanced mid-serve from a
rescheduled Placement's flow assignment (``apply_flow_assignment`` —
the runtime-domain half of the online rescheduling path, DESIGN.md §7).

Shared-prefix KV reuse (DESIGN.md §9): with ``prefix_cache_bytes`` set
the coordinator keeps one radix-tree ``PrefixCache`` per prefill
engine, holding real KV slabs keyed by prompt tokens. Dispatch across
prefill engines scores matched-prefix length against flow-weighted
load (mirroring the production-stack KV router), and a hit runs
``PrefillEngine.prefill_suffix`` — only the uncached suffix pays
compute, bit-identically to full prefill on supporting archs.

This is the runtime-domain path (real JAX execution); the
scheduling-domain evaluation lives in ``simulator.py``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple)

import numpy as np

from repro.configs.base import ArchConfig
from repro.core.cost_model import ModelProfile
from repro.models.common import DEFAULT_DTYPE
from repro.serving import kv_compression, kv_transfer
from repro.serving.engine import DecodeEngine, PrefillEngine
from repro.serving.metrics import ServeMetrics
from repro.serving.paging import PagingError
from repro.serving.prefix_cache import MatchResult, PrefixCache, route_score
from repro.serving.request import Request, RequestState


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)
    #: skip prefix-cache lookup AND insertion for this request (§12
    #: failover re-dispatches set it: their folded prompts contain
    #: generated tokens that would pollute the radix trees)
    no_cache: bool = False


@dataclasses.dataclass
class ServeResult:
    rid: int
    tokens: List[int]             # generated tokens (incl. first)
    lifecycle: Optional[Request] = None   # state + timestamps (§8)


@dataclasses.dataclass
class PollStatus:
    rid: int
    state: RequestState
    tokens: List[int]             # snapshot of tokens streamed so far
    done: bool


#: Streaming callback: (rid, token, finished) — invoked in generation
#: order, exactly once per produced token.
TokenCallback = Callable[[int, int, bool], None]


@dataclasses.dataclass
class _Entry:
    req: ServeRequest
    life: Request
    tokens: List[int]
    on_token: Optional[TokenCallback] = None
    cache: Any = None             # prefilled KV awaiting handoff
    first: Optional[int] = None
    # as-submitted prompt/budget: §11 preemption recompute rebuilds
    # req.prompt = orig_prompt + tokens-emitted-so-far from these
    orig_prompt: Any = None
    orig_max: int = 0


class ServeSession:
    """One serving run over the coordinator's engines.

    ``submit`` is non-blocking; ``step`` advances the prefill, KV
    handoff, and decode stages once each and returns whether anything
    progressed; ``poll``/streaming callbacks expose per-request
    progress; ``metrics`` reports the shared runtime/simulator schema.

    ``max_prefill_batch`` bounds prefill work per step — the knob that
    trades first-token latency against decode-step jitter during
    prefill bursts. ``inline_prefill=True`` reproduces the legacy
    blocking behaviour (drain the whole prefill queue, one exact-shape
    call per request, before any decode step) for interference
    benchmarks; it is not meant for serving.
    """

    def __init__(self, coord: "Coordinator",
                 max_prefill_batch: int = 4,
                 inline_prefill: bool = False,
                 clock: Optional[Callable[[], float]] = None,
                 telemetry=None,
                 calibration=None):
        self.coord = coord
        self.max_prefill_batch = max(1, max_prefill_batch)
        self.inline_prefill = inline_prefill
        #: §15 cost-model calibration (``calibration.CalibrationStore``
        #: or None): predictions are stamped at submit and scored at the
        #: DONE edge. When the session is driven through the §12 Router
        #: the router owns stamping instead — don't wire both.
        self.calibration = calibration
        #: §14 event bus (``telemetry.TraceRecorder`` or None): stage
        #: events (prefill micro-batches, per-chunk KV installs,
        #: preemptions) and per-engine utilization series. Optional —
        #: None keeps every path byte-identical to the untraced run.
        self.telemetry = telemetry
        self._clock = clock or time.perf_counter
        # an injected clock (the router's shared StepClock) is already
        # absolute trace time — don't rebase, or a session opened by a
        # mid-trace replica join (§13 spawn) would stamp lifecycles
        # offset by its spawn time and break sim/runtime parity
        self._t0 = 0.0 if clock is not None else self._clock()
        self._entries: Dict[int, _Entry] = {}
        self._order: List[int] = []
        self._queue: collections.deque = collections.deque()    # QUEUED rids
        self._handoff: collections.deque = collections.deque()  # KV_TRANSFER
        #: §12 cancellations requested from inside a streaming callback
        #: while the request was mid-prefill; honoured at the end of the
        #: running micro-batch, before its KV ships
        self._cancel_requested: set = set()
        self._unfinished = 0
        self._decode_tokens = 0
        self._makespan = 0.0
        #: measured (padded-slab) handoff bytes, raw vs on-the-wire —
        #: the physical counterpart of the cost-accounting lifecycle
        #: stamps (DESIGN.md §10); reported by the kvstream benchmark
        self.kv_physical_bytes_raw = 0
        self.kv_physical_bytes_wire = 0

    # -- clock ----------------------------------------------------------
    def now(self) -> float:
        return self._clock() - self._t0

    # -- submission -----------------------------------------------------
    def submit(self, req: ServeRequest, arrival_time: Optional[float] = None,
               on_token: Optional[TokenCallback] = None,
               life: Optional[Request] = None) -> int:
        """Enqueue a request (non-blocking). ``arrival_time`` defaults
        to the session clock's now; TTFT/latency measure from it.
        ``life`` lets the §12 router hand in an EXISTING lifecycle
        record (its arrival/priority/failover stamps preserved) instead
        of creating a fresh one."""
        assert req.rid not in self._entries, f"duplicate rid {req.rid}"
        if life is None:
            arrival = self.now() if arrival_time is None else arrival_time
            life = Request(rid=req.rid, s_in=len(req.prompt),
                           s_out=req.max_new_tokens, arrival=arrival,
                           tokens=tuple(int(t) for t in req.prompt))
        else:
            assert life.phase is RequestState.QUEUED, \
                f"rid {req.rid}: submitted life must be QUEUED"
        self._entries[req.rid] = _Entry(req=req, life=life, tokens=[],
                                        on_token=on_token,
                                        orig_prompt=np.asarray(req.prompt,
                                                               np.int32),
                                        orig_max=req.max_new_tokens)
        self._order.append(req.rid)
        self._queue.append(req.rid)
        self._unfinished += 1
        if self.calibration is not None:
            self.calibration.stamp(life, 0)
        return req.rid

    # -- pipeline stages ------------------------------------------------
    def _emit(self, e: _Entry, token: int, finished: bool) -> None:
        e.tokens.append(token)
        self._decode_tokens += 1
        if e.on_token is not None:
            e.on_token(e.req.rid, token, finished)

    def _finish(self, e: _Entry) -> None:
        e.life.advance(RequestState.DONE, self.now())
        e.life.tokens_out = len(e.tokens)   # may be < s_out at capacity
        e.cache = None
        self._unfinished -= 1
        self._makespan = max(self._makespan, e.life.decode_end)
        if self.calibration is not None:
            self.calibration.observe(e.life, self.now())

    def _step_prefill(self) -> bool:
        """Run one bounded prefill micro-batch (bucketed/padded, one
        jit'd call for pure-attention archs). Inline mode drains the
        whole queue with exact-shape calls — the legacy behaviour.

        The KV-handoff backlog is capped at the fleet's total slot
        count: each backlog entry holds a full-capacity cache pytree,
        so prefilling further ahead than decode can admit would grow
        memory without bound on long queues. Decode keeps draining the
        backlog, so prefill resumes as slots free up."""
        if not self._queue:
            return False
        if self.inline_prefill:
            take = len(self._queue)
        else:
            total_slots = sum(e.num_slots for e in self.coord.decode_engines)
            take = min(self.max_prefill_batch, len(self._queue),
                       total_slots - len(self._handoff))
            if take <= 0:
                return False
        batch = [self._entries[self._queue.popleft()] for _ in range(take)]
        t = t_batch = self.now()
        for e in batch:
            e.life.advance(RequestState.PREFILLING, t)
        if self.inline_prefill:
            # legacy path: one EXACT-shape call per request (no bucket
            # padding, no prefix reuse) on engine 0 — exactly what the
            # old blocking serve() loop did
            outs = {}
            for e in batch:
                tok, cache = self.coord.prefill_engine.prefill(
                    np.asarray(e.req.prompt, np.int32)[None], **e.req.extra)
                outs[e.req.rid] = (int(tok[0]), cache, 0)
        else:
            outs = self._route_and_prefill(batch)
        t = self.now()
        if self.telemetry is not None:
            self.telemetry.emit("prefill_batch", t_batch, track="session",
                                dur=t - t_batch, batch=len(batch))
        for e in batch:
            first, cache, cached = outs[e.req.rid]
            e.life.cached_len = cached
            self._emit(e, first, finished=e.req.max_new_tokens <= 1)
            if e.req.rid in self._cancel_requested:
                self._cancel_requested.discard(e.req.rid)
                self._cancel_entry(e)     # PREFILLING → CANCELLED
                continue
            if e.req.max_new_tokens <= 1:
                self._finish(e)       # PREFILLING → DONE (no KV ships)
                continue
            e.first = first
            e.cache = cache
            e.life.advance(RequestState.KV_TRANSFER, t)
            self._handoff.append(e.req.rid)
        return True

    def _route_and_prefill(self, batch: List[_Entry]
                           ) -> Dict[int, Tuple[int, Any, int]]:
        """Route each request to a prefill engine (§9 cache-aware when
        caches exist), run hits as suffix-only prefills seeded from
        their matched KV slab and misses as one bucketed micro-batch
        per engine, then record every freshly produced slab in the
        winning engine's radix cache. Returns
        {rid: (first_token, cache, cached_len)}."""
        coord = self.coord
        routed: Dict[int, List[_Entry]] = {}
        matches: Dict[int, MatchResult] = {}
        for e in batch:
            idx, m = coord.route_prefill(e.req.prompt)
            routed.setdefault(idx, []).append(e)
            if m is not None:
                matches[e.req.rid] = m
        out: Dict[int, Tuple[int, Any, int]] = {}
        for idx in sorted(routed):
            eng = coord.prefill_engines[idx]
            cache_obj = (coord.prefix_caches[idx]
                         if coord.prefix_caches is not None else None)
            hits, misses = [], []
            for e in routed[idx]:
                m = matches.get(e.req.rid)
                cached = 0
                if (m is not None and m.payload is not None
                        and eng.supports_prefix_reuse and not e.req.extra
                        and not e.req.no_cache):
                    cached = min(m.length, len(e.req.prompt) - 1)
                    if (cached < 1 or kv_transfer.slab_capacity(
                            m.payload, coord.cfg) < len(e.req.prompt)):
                        cached = 0
                (hits if cached else misses).append((e, cached))
            for e, cached in hits:
                tok, cache = eng.prefill_suffix(
                    np.asarray(e.req.prompt, np.int32), cached,
                    matches[e.req.rid].payload)
                out[e.req.rid] = (tok, cache, cached)
            if misses:
                res = eng.prefill_batch(
                    [np.asarray(e.req.prompt, np.int32) for e, _ in misses],
                    [e.req.extra for e, _ in misses])
                for (e, _), (tok, cache) in zip(misses, res):
                    out[e.req.rid] = (tok, cache, 0)
            for e in routed[idx]:
                if (cache_obj is not None and eng.supports_prefix_reuse
                        and not e.req.extra and not e.req.no_cache):
                    slab = out[e.req.rid][1]
                    cache_obj.insert(
                        tuple(int(t) for t in e.req.prompt), payload=slab,
                        payload_bytes=kv_transfer.transfer_bytes(slab))
                m = matches.get(e.req.rid)
                if m is not None and m.node is not None:
                    cache_obj.unlock(m.node)
        return out

    def _step_handoff(self) -> bool:
        """Admit prefilled requests into free decode slots: transfer
        the KV (resharding device_put) through the coordinator's codec
        (DESIGN.md §10) and install it. A chunked codec encodes once,
        splits along the period-stack axis, and the decode engine
        installs each layer-group chunk as it lands; other codecs ship
        one (possibly int8-compressed) pytree. Routing picks the
        least-loaded *flow-weighted* engine among those with free
        slots (and, when paged, enough free-or-reclaimable pages).

        Paged engines (DESIGN.md §11) receive a PAGE-ALIGNED slab —
        trimmed to the prompt's pages instead of padded to the slot
        capacity, so the wire carries residency, not padding — and the
        transfer/chunk plans land directly in pool pages."""
        progressed = False
        codec = self.coord.kv_codec
        cfg = self.coord.cfg
        paged = self.coord.paged
        while self._handoff:
            head = self._entries[self._handoff[0]]
            eng_idx = self.coord.pick_engine_with_free_slot(
                len(head.req.prompt))
            if eng_idx is None:
                break
            e = self._entries[self._handoff.popleft()]
            eng = self.coord.decode_engines[eng_idx]
            resv = None
            if paged:
                tokens = tuple(int(t) for t in e.req.prompt)
                cache = kv_transfer.trim_to_pages(
                    e.cache, len(e.req.prompt), self.coord.page_size,
                    cfg=cfg)
                # §11 pool sharing: pin the engine's shareable prefix
                # and ship ONLY the non-shared blocks — the wire
                # carries residency the pool doesn't already hold
                resv = eng.reserve_shared(tokens, len(e.req.prompt))
                if resv is not None:
                    cache = kv_transfer.drop_leading_blocks(
                        cache, resv.blocks, self.coord.page_size, cfg=cfg)
            else:
                cache = kv_transfer.pad_capacity(e.cache,
                                                 self.coord.capacity,
                                                 cfg=cfg)
                tokens = None
            t0 = self.now()
            encoded = kv_compression.encode(cache, cfg, codec)
            # §16 zero-requant: int8-resident engines install the wire
            # codec's QuantizedLeaf chunks directly (page scale = max of
            # the page's row scales), so the quantization error is paid
            # once end-to-end — never dequant→requant here
            quant_dst = eng.paged_dtype == "int8"
            try:
                if codec.chunked:
                    plan = kv_compression.ChunkedTransferPlan.for_cache(
                        encoded, codec.chunks)
                    if quant_dst:
                        landing = ((p0, kv_transfer.transfer(chunk))
                                   for (p0, _), chunk in zip(
                                       plan.bounds, plan.split(encoded)))
                    else:
                        landing = ((p0, kv_compression.decode(
                            kv_transfer.transfer(chunk)))
                            for (p0, _), chunk in zip(plan.bounds,
                                                      plan.split(encoded)))
                    if self.telemetry is not None:
                        landing = self._traced_landing(landing, e.req.rid,
                                                       eng_idx)
                    eng.admit_chunked(e.req.rid, e.first, len(e.req.prompt),
                                      e.req.max_new_tokens, landing,
                                      tokens=tokens, reservation=resv)
                else:
                    landed = kv_transfer.transfer(encoded)
                    if not quant_dst:
                        landed = kv_compression.decode(landed)
                    eng.admit(e.req.rid, e.first, len(e.req.prompt),
                              e.req.max_new_tokens, landed,
                              tokens=tokens, reservation=resv)
            except PagingError:
                # explicit §11 admission failure (a competing admit
                # claimed the pages first): requeue and retry once
                # decode frees pages (admit consumed the reservation
                # pin on its way out)
                self._handoff.appendleft(e.req.rid)
                break
            if paged:
                e.life.kv_page_size = self.coord.page_size
            # §10 accounting: lifecycle stamps use the shared
            # cost-model math (sim-comparable); the session counters
            # track the measured padded-slab bytes (sized off the
            # already-encoded tree — no extra encode)
            prof = self.coord.acct_profile
            e.life.kv_bytes_raw += kv_compression.profile_raw_bytes(
                prof, e.life.s_in)
            e.life.kv_bytes_wire += kv_compression.profile_wire_bytes(
                prof, e.life.s_in, codec)
            e.life.kv_serialized_s += self.now() - t0
            self.kv_physical_bytes_raw += kv_transfer.transfer_bytes(cache)
            self.kv_physical_bytes_wire += kv_compression.encoded_bytes(
                encoded)
            self.coord.note_routed(eng_idx)
            e.cache = None
            e.life.decode_group = eng_idx
            e.life.advance(RequestState.DECODING, self.now())
            if self.telemetry is not None:
                self.telemetry.emit("handoff", t0,
                                    track=f"engine:{eng_idx}",
                                    rid=e.req.rid, dur=self.now() - t0)
            progressed = True
        return progressed

    def _traced_landing(self, landing, rid: int, eng_idx: int):
        """Wrap a chunked-handoff landing stream so each layer-group
        chunk install lands on the §14 bus as it happens."""
        for ci, (p0, chunk) in enumerate(landing):
            self.telemetry.emit("kv_chunk", self.now(),
                                track=f"engine:{eng_idx}", rid=rid,
                                chunk=ci, pos0=int(p0))
            yield p0, chunk

    def _recompute(self, rid: int, eng: DecodeEngine) -> None:
        """Re-queue a page-preempted request for recompute (§11): its
        decode residency was released, so the already-emitted tokens
        are folded into the prompt and the (deterministic, greedy)
        generation resumes via a fresh prefill — the vLLM recompute
        policy. Emitted tokens stay emitted; §10/§11 stamps survive the
        lifecycle restart (KV genuinely shipped and pages were
        genuinely held)."""
        e = self._entries[rid]
        life = e.life
        life.kv_pages_allocated += eng.pop_page_stamp(rid)
        life.preemptions += 1
        if self.telemetry is not None:
            eng_idx = self.coord.decode_engines.index(eng)
            self.telemetry.emit("preempt", self.now(),
                                track=f"engine:{eng_idx}", rid=rid,
                                preemptions=life.preemptions)
        snap = (life.kv_bytes_raw, life.kv_bytes_wire,
                life.kv_serialized_s, life.kv_overlap_s, life.cached_len)
        life.restart()
        (life.kv_bytes_raw, life.kv_bytes_wire, life.kv_serialized_s,
         life.kv_overlap_s, life.cached_len) = snap
        e.req.prompt = np.concatenate(
            [e.orig_prompt, np.asarray(e.tokens, np.int32)])
        e.req.max_new_tokens = e.orig_max - len(e.tokens)
        e.cache = None
        e.first = None
        self._queue.append(rid)

    def _step_decode(self) -> bool:
        """One decode step across every engine with active slots."""
        progressed = False
        for eng in self.coord.decode_engines:
            for rid, tok, finished in eng.step():
                e = self._entries[rid]
                self._emit(e, tok, finished)
                if finished:
                    e.life.kv_pages_allocated += eng.pop_page_stamp(rid)
                    self._finish(e)
                progressed = True
            while eng.preempted:
                self._recompute(eng.preempted.pop(0), eng)
                progressed = True
        return progressed

    # -- cancellation & failover (DESIGN.md §12) ------------------------
    def _cancel_entry(self, e: _Entry) -> None:
        e.life.advance(RequestState.CANCELLED, self.now())
        e.cache = None
        self._unfinished -= 1

    def cancel(self, rid: int) -> bool:
        """Cancel a request at whatever lifecycle stage it is in,
        reclaiming what that stage holds: QUEUED leaves the prefill
        queue; PREFILLING (only observable from inside a streaming
        callback, mid micro-batch) is honoured at the end of the
        running batch before any KV ships; KV_TRANSFER drops the
        pending handoff slab; DECODING releases the decode slot (paged
        engines return its pages to the pool, and the page stamp folds
        into the lifecycle record). Returns False when the request is
        unknown or already terminal."""
        e = self._entries.get(rid)
        if e is None or e.life.is_terminal:
            return False
        phase = e.life.phase
        if phase is RequestState.QUEUED:
            self._queue.remove(rid)
            self._cancel_entry(e)
            return True
        if phase is RequestState.PREFILLING:
            self._cancel_requested.add(rid)
            return True
        if phase is RequestState.KV_TRANSFER:
            self._handoff.remove(rid)
            self._cancel_entry(e)
            return True
        for eng in self.coord.decode_engines:      # DECODING
            if eng.cancel(rid):
                e.life.kv_pages_allocated += eng.pop_page_stamp(rid)
                self._cancel_entry(e)
                return True
        return False

    def drain_in_flight(self) -> List[Request]:
        """§12 failover: hand every non-terminal request's lifecycle
        record back to the router and abandon the pipeline state. The
        replica is dead — its engines, slots, and any KV they hold are
        unreachable, so nothing is released here; the router restarts
        each request from its (token-folded) prompt elsewhere."""
        out = []
        for rid in self._order:
            e = self._entries[rid]
            if not e.life.is_terminal:
                out.append(e.life)
                e.cache = None
                self._unfinished -= 1
        self._queue.clear()
        self._handoff.clear()
        self._cancel_requested.clear()
        return out

    # -- driving --------------------------------------------------------
    def step(self) -> bool:
        """Advance all three stages once. Returns True while the
        session is making progress; False once idle (all done, or
        nothing can move)."""
        a = self._step_prefill()
        b = self._step_handoff()
        c = self._step_decode()
        if self.telemetry is not None:
            self._sample_gauges()
        return a or b or c

    def _sample_gauges(self) -> None:
        """One §14 utilization sample per session step: admission/
        handoff backlog depths, per-engine slot and page occupancy,
        per-prefill-engine prefix-cache fill."""
        t = self.now()
        rec = self.telemetry
        rec.gauge("prefill_queue", t, len(self._queue), track="session")
        rec.gauge("handoff_backlog", t, len(self._handoff),
                  track="session")
        for j, eng in enumerate(self.coord.decode_engines):
            u = eng.util()
            rec.gauge("active_slots", t, u["active_slots"],
                      track=f"engine:{j}")
            if "free_pages" in u:
                rec.gauge("free_pages", t, u["free_pages"],
                          track=f"engine:{j}")
        for j, cache in enumerate(self.coord.prefix_caches or ()):
            rec.gauge("prefix_cache_occupancy", t, cache.occupancy,
                      track=f"prefill:{j}")

    @property
    def unfinished(self) -> int:
        return self._unfinished

    def run(self) -> "ServeSession":
        """Step until every submitted request is DONE."""
        while self._unfinished:
            if not self.step():
                raise RuntimeError("serve session stalled: "
                                   f"{self._unfinished} unfinished, "
                                   "no stage can progress")
        return self

    # -- results --------------------------------------------------------
    def poll(self, rid: int) -> PollStatus:
        e = self._entries[rid]
        return PollStatus(rid=rid, state=e.life.phase,
                          tokens=list(e.tokens),
                          done=e.life.phase is RequestState.DONE)

    def result(self, rid: int) -> ServeResult:
        e = self._entries[rid]
        return ServeResult(rid=rid, tokens=list(e.tokens), lifecycle=e.life)

    def results(self) -> List[ServeResult]:
        """All results, in submission order."""
        return [self.result(rid) for rid in self._order]

    def metrics(self) -> ServeMetrics:
        """The shared runtime/simulator schema (DESIGN.md §8) over the
        requests served so far."""
        return ServeMetrics(
            requests=[self._entries[rid].life for rid in self._order],
            makespan=self._makespan, decode_tokens=self._decode_tokens,
            kv_cache_dtype=self.coord.paged_dtype)


class Coordinator:
    """``num_prefill_engines``/``prefix_cache_bytes``/``cache_alpha``
    configure the §9 prefix-reuse path: N prefill engines, each with a
    byte-budgeted radix cache of served prompts (``prefix_cache_bytes``
    is the per-engine budget; None disables reuse entirely — the
    pre-§9 behaviour, byte-for-byte).

    ``kv_codec`` names the §10 handoff wire format ("none"/"int8"/
    "int8-chunked", or a ``kv_compression.KVCodec``): attention KV
    leaves ship int8-quantized (recurrent state and cross-attention
    memory always exempt), and the chunked variant streams per-layer-
    group chunks that decode engines install as they land. The default
    ships raw leaves bit-identically.

    ``paged=True`` switches every decode engine to the §11 paged KV
    layout: a ref-counted page pool of ``pages_per_engine`` pages
    (default: the dense HBM budget) cut at ``page_size`` tokens,
    block-table decode, page-aligned (trimmed, not capacity-padded)
    handoffs, page reclamation on finish, and recompute preemption on
    pool exhaustion. With prefix caching also on, each engine shares
    pool pages copy-on-write between its radix prefix slabs and decode
    residency.

    ``paged_dtype="int8"`` (requires ``paged=True``) keeps pool pages
    int8-resident with per-(page, kv-head) fp32 scales (DESIGN.md §16):
    roughly half the bytes per page, and handoffs from an int8 wire
    codec install their quantized chunks directly into pages — one
    quantization error end-to-end, no dequant→requant round-trip."""

    def __init__(self, cfg: ArchConfig, params: Any,
                 num_decode_engines: int = 1, slots_per_engine: int = 4,
                 capacity: int = 128,
                 route_weights: Optional[Sequence[float]] = None,
                 num_prefill_engines: int = 1,
                 prefill_route_weights: Optional[Sequence[float]] = None,
                 prefix_cache_bytes: Optional[float] = None,
                 cache_alpha: float = 2.0,
                 kv_codec=None,
                 paged: bool = False, page_size: int = 16,
                 pages_per_engine: Optional[int] = None,
                 paged_dtype: Optional[str] = None):
        self.cfg = cfg
        self.paged = paged
        self.paged_dtype = paged_dtype if paged else None
        self.page_size = int(page_size)
        if paged:
            capacity = -(-capacity // self.page_size) * self.page_size
        self.capacity = capacity
        self.cache_alpha = cache_alpha
        self.kv_codec = kv_compression.get_codec(kv_codec)
        #: cost-model view of this arch at the runtime cache dtype —
        #: the shared §10 byte-accounting both domains stamp from
        self.acct_profile = ModelProfile.from_arch(cfg,
                                                   kv_dtype=DEFAULT_DTYPE)
        self.prefill_engines = [PrefillEngine(cfg, params, capacity)
                                for _ in range(num_prefill_engines)]
        self.prefix_caches: Optional[List[PrefixCache]] = None
        if prefix_cache_bytes is not None:
            self.prefix_caches = [PrefixCache(prefix_cache_bytes)
                                  for _ in range(num_prefill_engines)]
        pw = list(prefill_route_weights or [1.0] * num_prefill_engines)
        assert len(pw) == num_prefill_engines
        self._prefill_weights = np.asarray(pw, float) / sum(pw)
        self._prefill_routed = np.zeros(num_prefill_engines)
        self.decode_engines = [
            DecodeEngine(cfg, params, slots_per_engine, capacity,
                         paged=paged, page_size=page_size,
                         num_pages=pages_per_engine,
                         share_prefix_pages=(paged and prefix_cache_bytes
                                             is not None),
                         paged_dtype=self.paged_dtype)
            for _ in range(num_decode_engines)]
        w = list(route_weights or [1.0] * num_decode_engines)
        assert len(w) == num_decode_engines
        self._weights = np.asarray(w, float) / sum(w)
        self._routed = np.zeros(num_decode_engines)
        self._active_session: Optional[ServeSession] = None

    @property
    def prefill_engine(self) -> PrefillEngine:
        """Back-compat alias: the first (pre-§9: only) prefill engine."""
        return self.prefill_engines[0]

    # -- routing --------------------------------------------------------
    def route_prefill(self, prompt: np.ndarray
                      ) -> Tuple[int, Optional[MatchResult]]:
        """Pick a prefill engine for ``prompt``: matched-prefix ratio
        blended with normalized flow-weighted load (``route_score``,
        mirroring the production-stack KV router). Returns the engine
        index and — when prefix caching is on — the winner's match,
        with its providing path pinned until the caller unlocks it.
        Cache-less (or single-engine cold) routing reduces to
        least-normalized-load."""
        base = (self._prefill_routed + 1) / np.maximum(
            self._prefill_weights, 1e-9)
        if self.prefix_caches is None:
            idx = int(np.argmin(base))
            self._prefill_routed[idx] += 1
            return idx, None
        tokens = tuple(int(t) for t in prompt)
        lo = float(base.min())
        scores = [route_score(
            self.prefix_caches[i].matched_len(tokens) / max(len(tokens), 1),
            float(base[i]), lo, self.cache_alpha)
            for i in range(len(self.prefill_engines))]
        idx = int(np.argmax(scores))
        self._prefill_routed[idx] += 1
        return idx, self.prefix_caches[idx].match(tokens, lock=True)
    def pick_engine_with_free_slot(self,
                                   prompt_len: int = 0) -> Optional[int]:
        """Least normalized load among flow-weighted engines that have a
        free slot — and, when paged (§11), enough free-or-reclaimable
        pages for ``prompt_len`` — (same rule as the simulator's
        dispatch); None when every engine is full."""
        free = [i for i, e in enumerate(self.decode_engines)
                if e.can_admit(prompt_len)]
        if not free:
            return None
        return min(free, key=lambda i: (self._routed[i] + 1)
                   / max(self._weights[i], 1e-9))

    def note_routed(self, eng_idx: int) -> None:
        self._routed[eng_idx] += 1

    # -- online rebalance (DESIGN.md §7) --------------------------------
    def update_route_weights(self, weights: Sequence[float],
                             reset_counts: bool = False) -> None:
        """Rebalance decode-engine dispatch proportions mid-serve.

        ``reset_counts`` also zeroes the per-engine routed counters so
        the new proportions take effect immediately instead of first
        paying down the historical imbalance."""
        w = np.asarray(list(weights), float)
        assert len(w) == len(self.decode_engines) and w.sum() > 0
        self._weights = w / w.sum()
        if reset_counts:
            self._routed[:] = 0.0

    def apply_flow_assignment(self, placement: Any,
                              reset_counts: bool = True) -> np.ndarray:
        """Adopt a (re)scheduled Placement's flow assignment.

        Sums the kv_route flow into each decode group (sorted by group
        id) and maps groups onto this coordinator's decode engines in
        order, folding surplus groups round-robin. Engines with no
        mapped flow keep an epsilon weight so they stay schedulable.
        Returns the normalized weights actually installed."""
        per_group: Dict[int, float] = {}
        for (_, did), f in placement.kv_routes.items():
            per_group[did] = per_group.get(did, 0.0) + f
        gids = sorted(r.group_id for r in placement.decode_replicas())
        n = len(self.decode_engines)
        w = np.full(n, 1e-9)
        for i, gid in enumerate(gids):
            w[i % n] += per_group.get(gid, 0.0)
        if w.sum() <= n * 1e-9:   # degenerate flow: fall back to uniform
            w = np.ones(n)
        self.update_route_weights(w, reset_counts=reset_counts)
        return self._weights

    # -- sessions -------------------------------------------------------
    def session(self, **kwargs) -> ServeSession:
        """Open an event-driven serving session (DESIGN.md §8).

        Sessions own the coordinator's engines exclusively while they
        have requests in flight: the decode slots and routing counters
        are shared state, so a second concurrent session would consume
        the first one's tokens. Opening a new session is allowed only
        once the previous one has drained."""
        if (self._active_session is not None
                and self._active_session.unfinished):
            raise RuntimeError(
                "coordinator already has an active session with "
                f"{self._active_session.unfinished} requests in flight; "
                "drain it before opening another")
        self._active_session = ServeSession(self, **kwargs)
        return self._active_session

    def serve(self, requests: List[ServeRequest],
              on_token: Optional[TokenCallback] = None) -> List[ServeResult]:
        """Blocking batch entry point — a thin compatibility wrapper
        over the session API: submit everything at t=0, step to
        completion, return results in submission order."""
        sess = self.session()
        for r in requests:
            sess.submit(r, on_token=on_token)
        sess.run()
        return sess.results()
