"""Task coordinator: drives disaggregated serving end to end.

The in-process replacement for HexGen-2's libp2p coordinator
(DESIGN.md §3): it owns one PrefillEngine and one-or-more DecodeEngines
and exposes the event-driven request lifecycle (DESIGN.md §8) through
``ServeSession``:

    sess = coord.session()
    sess.submit(req, on_token=cb)      # non-blocking, QUEUED
    while sess.step():                 # prefill | KV handoff | decode —
        ...                            #   separate stages, one step()
    sess.metrics()                     # ServeMetrics, same schema as
                                       #   the simulator's SimResult

``step()`` advances the three pipeline stages independently: a bounded
bucketed/padded prefill micro-batch (one jit'd call), KV handoffs into
free decode slots (flow-weighted routing), and one decode step across
all engines — so a prefill burst can no longer starve in-flight decode
the way the old blocking ``serve(requests)`` loop did. ``serve()``
survives as a thin wrapper over a session.

Dispatch across decode engines follows the scheduler's flow assignment
proportions when given one, and can be rebalanced mid-serve from a
rescheduled Placement's flow assignment (``apply_flow_assignment`` —
the runtime-domain half of the online rescheduling path, DESIGN.md §7).

This is the runtime-domain path (real JAX execution); the
scheduling-domain evaluation lives in ``simulator.py``.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import (Any, Callable, Dict, List, Optional, Sequence)

import numpy as np

from repro.configs.base import ArchConfig
from repro.serving import kv_transfer
from repro.serving.engine import DecodeEngine, PrefillEngine
from repro.serving.metrics import ServeMetrics
from repro.serving.request import Request, RequestState


@dataclasses.dataclass
class ServeRequest:
    rid: int
    prompt: np.ndarray            # [S] int32
    max_new_tokens: int
    extra: Dict[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class ServeResult:
    rid: int
    tokens: List[int]             # generated tokens (incl. first)
    lifecycle: Optional[Request] = None   # state + timestamps (§8)


@dataclasses.dataclass
class PollStatus:
    rid: int
    state: RequestState
    tokens: List[int]             # snapshot of tokens streamed so far
    done: bool


#: Streaming callback: (rid, token, finished) — invoked in generation
#: order, exactly once per produced token.
TokenCallback = Callable[[int, int, bool], None]


@dataclasses.dataclass
class _Entry:
    req: ServeRequest
    life: Request
    tokens: List[int]
    on_token: Optional[TokenCallback] = None
    cache: Any = None             # prefilled KV awaiting handoff
    first: Optional[int] = None


class ServeSession:
    """One serving run over the coordinator's engines.

    ``submit`` is non-blocking; ``step`` advances the prefill, KV
    handoff, and decode stages once each and returns whether anything
    progressed; ``poll``/streaming callbacks expose per-request
    progress; ``metrics`` reports the shared runtime/simulator schema.

    ``max_prefill_batch`` bounds prefill work per step — the knob that
    trades first-token latency against decode-step jitter during
    prefill bursts. ``inline_prefill=True`` reproduces the legacy
    blocking behaviour (drain the whole prefill queue, one exact-shape
    call per request, before any decode step) for interference
    benchmarks; it is not meant for serving.
    """

    def __init__(self, coord: "Coordinator",
                 max_prefill_batch: int = 4,
                 inline_prefill: bool = False,
                 clock: Optional[Callable[[], float]] = None):
        self.coord = coord
        self.max_prefill_batch = max(1, max_prefill_batch)
        self.inline_prefill = inline_prefill
        self._clock = clock or time.perf_counter
        self._t0 = self._clock()
        self._entries: Dict[int, _Entry] = {}
        self._order: List[int] = []
        self._queue: collections.deque = collections.deque()    # QUEUED rids
        self._handoff: collections.deque = collections.deque()  # KV_TRANSFER
        self._unfinished = 0
        self._decode_tokens = 0
        self._makespan = 0.0

    # -- clock ----------------------------------------------------------
    def now(self) -> float:
        return self._clock() - self._t0

    # -- submission -----------------------------------------------------
    def submit(self, req: ServeRequest, arrival_time: Optional[float] = None,
               on_token: Optional[TokenCallback] = None) -> int:
        """Enqueue a request (non-blocking). ``arrival_time`` defaults
        to the session clock's now; TTFT/latency measure from it."""
        assert req.rid not in self._entries, f"duplicate rid {req.rid}"
        arrival = self.now() if arrival_time is None else arrival_time
        life = Request(rid=req.rid, s_in=len(req.prompt),
                       s_out=req.max_new_tokens, arrival=arrival)
        self._entries[req.rid] = _Entry(req=req, life=life, tokens=[],
                                        on_token=on_token)
        self._order.append(req.rid)
        self._queue.append(req.rid)
        self._unfinished += 1
        return req.rid

    # -- pipeline stages ------------------------------------------------
    def _emit(self, e: _Entry, token: int, finished: bool) -> None:
        e.tokens.append(token)
        self._decode_tokens += 1
        if e.on_token is not None:
            e.on_token(e.req.rid, token, finished)

    def _finish(self, e: _Entry) -> None:
        e.life.advance(RequestState.DONE, self.now())
        e.life.tokens_out = len(e.tokens)   # may be < s_out at capacity
        e.cache = None
        self._unfinished -= 1
        self._makespan = max(self._makespan, e.life.decode_end)

    def _step_prefill(self) -> bool:
        """Run one bounded prefill micro-batch (bucketed/padded, one
        jit'd call for pure-attention archs). Inline mode drains the
        whole queue with exact-shape calls — the legacy behaviour.

        The KV-handoff backlog is capped at the fleet's total slot
        count: each backlog entry holds a full-capacity cache pytree,
        so prefilling further ahead than decode can admit would grow
        memory without bound on long queues. Decode keeps draining the
        backlog, so prefill resumes as slots free up."""
        if not self._queue:
            return False
        if self.inline_prefill:
            take = len(self._queue)
        else:
            total_slots = sum(e.num_slots for e in self.coord.decode_engines)
            take = min(self.max_prefill_batch, len(self._queue),
                       total_slots - len(self._handoff))
            if take <= 0:
                return False
        batch = [self._entries[self._queue.popleft()] for _ in range(take)]
        t = self.now()
        for e in batch:
            e.life.advance(RequestState.PREFILLING, t)
        if self.inline_prefill:
            # legacy path: one EXACT-shape call per request (no bucket
            # padding), exactly what the old blocking serve() loop did
            outs = []
            for e in batch:
                tok, cache = self.coord.prefill_engine.prefill(
                    np.asarray(e.req.prompt, np.int32)[None], **e.req.extra)
                outs.append((int(tok[0]), cache))
        else:
            outs = self.coord.prefill_engine.prefill_batch(
                [np.asarray(e.req.prompt, np.int32) for e in batch],
                [e.req.extra for e in batch])
        t = self.now()
        for e, (first, cache) in zip(batch, outs):
            self._emit(e, first, finished=e.req.max_new_tokens <= 1)
            if e.req.max_new_tokens <= 1:
                self._finish(e)       # PREFILLING → DONE (no KV ships)
                continue
            e.first = first
            e.cache = cache
            e.life.advance(RequestState.KV_TRANSFER, t)
            self._handoff.append(e.req.rid)
        return True

    def _step_handoff(self) -> bool:
        """Admit prefilled requests into free decode slots: transfer
        the KV (resharding device_put) and install it. Routing picks
        the least-loaded *flow-weighted* engine among those with free
        slots."""
        progressed = False
        while self._handoff:
            eng_idx = self.coord.pick_engine_with_free_slot()
            if eng_idx is None:
                break
            e = self._entries[self._handoff.popleft()]
            cache = kv_transfer.pad_capacity(e.cache, self.coord.capacity)
            cache = kv_transfer.transfer(cache)
            self.coord.decode_engines[eng_idx].admit(
                e.req.rid, e.first, len(e.req.prompt),
                e.req.max_new_tokens, cache)
            self.coord.note_routed(eng_idx)
            e.cache = None
            e.life.decode_group = eng_idx
            e.life.advance(RequestState.DECODING, self.now())
            progressed = True
        return progressed

    def _step_decode(self) -> bool:
        """One decode step across every engine with active slots."""
        progressed = False
        for eng in self.coord.decode_engines:
            for rid, tok, finished in eng.step():
                e = self._entries[rid]
                self._emit(e, tok, finished)
                if finished:
                    self._finish(e)
                progressed = True
        return progressed

    # -- driving --------------------------------------------------------
    def step(self) -> bool:
        """Advance all three stages once. Returns True while the
        session is making progress; False once idle (all done, or
        nothing can move)."""
        a = self._step_prefill()
        b = self._step_handoff()
        c = self._step_decode()
        return a or b or c

    @property
    def unfinished(self) -> int:
        return self._unfinished

    def run(self) -> "ServeSession":
        """Step until every submitted request is DONE."""
        while self._unfinished:
            if not self.step():
                raise RuntimeError("serve session stalled: "
                                   f"{self._unfinished} unfinished, "
                                   "no stage can progress")
        return self

    # -- results --------------------------------------------------------
    def poll(self, rid: int) -> PollStatus:
        e = self._entries[rid]
        return PollStatus(rid=rid, state=e.life.phase,
                          tokens=list(e.tokens),
                          done=e.life.phase is RequestState.DONE)

    def result(self, rid: int) -> ServeResult:
        e = self._entries[rid]
        return ServeResult(rid=rid, tokens=list(e.tokens), lifecycle=e.life)

    def results(self) -> List[ServeResult]:
        """All results, in submission order."""
        return [self.result(rid) for rid in self._order]

    def metrics(self) -> ServeMetrics:
        """The shared runtime/simulator schema (DESIGN.md §8) over the
        requests served so far."""
        return ServeMetrics(
            requests=[self._entries[rid].life for rid in self._order],
            makespan=self._makespan, decode_tokens=self._decode_tokens)


class Coordinator:
    def __init__(self, cfg: ArchConfig, params: Any,
                 num_decode_engines: int = 1, slots_per_engine: int = 4,
                 capacity: int = 128,
                 route_weights: Optional[Sequence[float]] = None):
        self.cfg = cfg
        self.capacity = capacity
        self.prefill_engine = PrefillEngine(cfg, params, capacity)
        self.decode_engines = [DecodeEngine(cfg, params, slots_per_engine,
                                            capacity)
                               for _ in range(num_decode_engines)]
        w = list(route_weights or [1.0] * num_decode_engines)
        assert len(w) == num_decode_engines
        self._weights = np.asarray(w, float) / sum(w)
        self._routed = np.zeros(num_decode_engines)
        self._active_session: Optional[ServeSession] = None

    # -- routing --------------------------------------------------------
    def pick_engine_with_free_slot(self) -> Optional[int]:
        """Least normalized load among flow-weighted engines that have a
        free slot (same rule as the simulator's dispatch); None when
        every engine is full."""
        free = [i for i, e in enumerate(self.decode_engines)
                if e.free_slots()]
        if not free:
            return None
        return min(free, key=lambda i: (self._routed[i] + 1)
                   / max(self._weights[i], 1e-9))

    def note_routed(self, eng_idx: int) -> None:
        self._routed[eng_idx] += 1

    # -- online rebalance (DESIGN.md §7) --------------------------------
    def update_route_weights(self, weights: Sequence[float],
                             reset_counts: bool = False) -> None:
        """Rebalance decode-engine dispatch proportions mid-serve.

        ``reset_counts`` also zeroes the per-engine routed counters so
        the new proportions take effect immediately instead of first
        paying down the historical imbalance."""
        w = np.asarray(list(weights), float)
        assert len(w) == len(self.decode_engines) and w.sum() > 0
        self._weights = w / w.sum()
        if reset_counts:
            self._routed[:] = 0.0

    def apply_flow_assignment(self, placement: Any,
                              reset_counts: bool = True) -> np.ndarray:
        """Adopt a (re)scheduled Placement's flow assignment.

        Sums the kv_route flow into each decode group (sorted by group
        id) and maps groups onto this coordinator's decode engines in
        order, folding surplus groups round-robin. Engines with no
        mapped flow keep an epsilon weight so they stay schedulable.
        Returns the normalized weights actually installed."""
        per_group: Dict[int, float] = {}
        for (_, did), f in placement.kv_routes.items():
            per_group[did] = per_group.get(did, 0.0) + f
        gids = sorted(r.group_id for r in placement.decode_replicas())
        n = len(self.decode_engines)
        w = np.full(n, 1e-9)
        for i, gid in enumerate(gids):
            w[i % n] += per_group.get(gid, 0.0)
        if w.sum() <= n * 1e-9:   # degenerate flow: fall back to uniform
            w = np.ones(n)
        self.update_route_weights(w, reset_counts=reset_counts)
        return self._weights

    # -- sessions -------------------------------------------------------
    def session(self, **kwargs) -> ServeSession:
        """Open an event-driven serving session (DESIGN.md §8).

        Sessions own the coordinator's engines exclusively while they
        have requests in flight: the decode slots and routing counters
        are shared state, so a second concurrent session would consume
        the first one's tokens. Opening a new session is allowed only
        once the previous one has drained."""
        if (self._active_session is not None
                and self._active_session.unfinished):
            raise RuntimeError(
                "coordinator already has an active session with "
                f"{self._active_session.unfinished} requests in flight; "
                "drain it before opening another")
        self._active_session = ServeSession(self, **kwargs)
        return self._active_session

    def serve(self, requests: List[ServeRequest],
              on_token: Optional[TokenCallback] = None) -> List[ServeResult]:
        """Blocking batch entry point — a thin compatibility wrapper
        over the session API: submit everything at t=0, step to
        completion, return results in submission order."""
        sess = self.session()
        for r in requests:
            sess.submit(r, on_token=on_token)
        sess.run()
        return sess.results()
