"""Cost-model calibration observability (DESIGN.md §15).

The scheduler prices every placement decision off the analytical cost
model (paper Table 1): prefill latency, per-step decode latency, KV wire
time, warm-up. Nothing before this module ever checked those predictions
against what the simulator or runtime actually observed — a miscalibrated
cluster spec (links slower than spec'd, a throttled GPU) silently
degrades every max-flow solve and autoscale decision.

``CalibrationStore`` closes the loop:

* **Stamp** (dispatch edge): the cost model's *predicted* per-surface
  costs are written onto the request (``pred_prefill_s`` /
  ``pred_decode_step_s`` / ``pred_transfer_s`` / ``pred_warmup_s``) by a
  pure *predictor* function of (request, routed group). Predictions are
  made once, at the routing decision, from the cluster spec the
  scheduler BELIEVED.
* **Observe** (terminal sweep): observed per-surface costs are derived
  purely from the §8/§14 lifecycle stamps — the same stamps
  ``request_spans`` reads — never measured separately. Per
  (surface, group) the store keeps a robust EWMA of the
  observed/predicted ratio (each observation clamped before folding, so
  one outlier can't swing an edge) and of the residual
  (observed − predicted seconds).
* **Report**: ``cost_error`` events + per-group ``cost_ratio:{surface}``
  gauge series on the ``TraceRecorder`` (chrome-trace counter tracks),
  ``repro_cost_model_error{surface,group}`` in the Prometheus snapshot,
  and ``corrections()`` — a clamped ``CostCorrections`` the §7 re-solve
  path threads into every ``solve_flow`` capacity.

Parity: both the stamp (a pure function of identically-constructed
predictor args) and the observation (a pure function of the
parity-exact lifecycle stamps) are inside the two-domain contract, so
two identically-configured stores driven by the simulator and the
runtime on the same seeded trace end with EXACTLY equal factors — the
new §15 parity surface, pinned by ``tests/test_calibration.py`` and the
calibration benchmark's parity leg.
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, Optional, Tuple

from repro.core.cost_model import (CALIBRATION_SURFACES, CostCorrections,
                                   ModelProfile, decode_step_latency,
                                   kv_transfer_time, prefill_latency)
from repro.serving.request import Request, RequestState
from repro.serving.telemetry import TraceRecorder

__all__ = [
    "CalibrationStore", "plan_predictor", "placement_predictor",
    "CALIBRATION_SURFACES",
]

#: per-observation ratio clamp — the "robust" in robust EWMA: a single
#: pathological request (zero-length stage, clock quantization) folds
#: in as at most this far from the running estimate
_RATIO_LO, _RATIO_HI = 0.05, 20.0
#: predictions/observations below this are treated as "surface absent"
#: (single-token requests have no decode cadence, zero-length transfers
#: no wire time) rather than as a measured zero
_EPS = 1e-12


class _ErrStat:
    """Running robust error estimate for one (surface, group) cell."""

    __slots__ = ("ratio", "residual", "count")

    def __init__(self) -> None:
        self.ratio: Optional[float] = None
        self.residual: Optional[float] = None
        self.count = 0

    def fold(self, ratio: float, residual: float, alpha: float) -> None:
        ratio = min(max(ratio, _RATIO_LO), _RATIO_HI)
        if self.ratio is None:
            self.ratio, self.residual = ratio, residual
        else:
            self.ratio = (1.0 - alpha) * self.ratio + alpha * ratio
            self.residual = (1.0 - alpha) * self.residual + alpha * residual
        self.count += 1


class CalibrationStore:
    """Predicted-vs-observed cost attribution per scheduling surface.

    ``predictor(req, group)`` returns the model's predicted seconds for
    any subset of ``CALIBRATION_SURFACES`` for ``req`` routed to
    ``group`` (missing/zero surfaces are simply never scored). It must
    be a PURE function of its arguments — that, plus observations being
    pure functions of the parity-exact lifecycle stamps, is what makes
    two stores driven by the two domains agree exactly.

    ``bound`` + ``min_observations`` define the miscalibration trigger
    signal: ``miscalibrated()`` is True once some warmed-up surface's
    global |EWMA ratio − 1| exceeds ``bound``. The §13 controller damps
    this signal exactly like ``slo_floor`` (sustain + cooldown) before
    firing a calibrated re-solve.

    ``recorder`` (optional, OUTSIDE the parity surface) receives one
    ``cost_error`` event per scored request plus per-group
    ``cost_ratio:{surface}`` gauge series that ``chrome_trace`` renders
    as counter tracks.
    """

    def __init__(self, predictor: Callable[[Request, int], Dict[str, float]],
                 *, ewma_alpha: float = 0.25, bound: float = 0.5,
                 min_observations: int = 8,
                 recorder: Optional[TraceRecorder] = None):
        assert 0.0 < ewma_alpha <= 1.0
        assert bound > 0.0 and min_observations > 0
        self.predictor = predictor
        self.ewma_alpha = ewma_alpha
        self.bound = bound
        self.min_observations = min_observations
        self.recorder = recorder
        #: (surface, group) -> running error stats (group -1 = global,
        #: the per-surface aggregate ``factors()``/``corrections()`` read)
        self._stats: Dict[Tuple[str, int], _ErrStat] = {}
        #: rid -> routed group of the latest stamp (redispatch restamps)
        self._routed: Dict[int, int] = {}
        self.stamped = 0
        self.observations = 0

    # -- dispatch edge --------------------------------------------------
    def stamp(self, req: Request, group: int) -> None:
        """Write the model's predicted stage costs onto ``req`` for the
        routing decision that just sent it to ``group``. Call AFTER any
        warm-up pricing hook: the predicted warm-up is whatever penalty
        the controller priced at this dispatch."""
        pred = self.predictor(req, group)
        req.pred_prefill_s = float(pred.get("prefill", 0.0))
        req.pred_decode_step_s = float(pred.get("decode", 0.0))
        req.pred_transfer_s = float(pred.get("transfer", 0.0))
        req.pred_warmup_s = float(pred.get("warmup", req.warmup_penalty_s))
        self._routed[req.rid] = int(group)
        self.stamped += 1

    # -- terminal sweep -------------------------------------------------
    def _observed(self, req: Request) -> Dict[str, float]:
        """Observed per-surface seconds, derived purely from the §8
        lifecycle stamps (the same stamps ``request_spans`` renders —
        prefill span, transfer span, decode cadence, warm-up stamp)."""
        obs: Dict[str, float] = {}
        if req.prefill_start is not None and req.prefill_end is not None:
            obs["prefill"] = max(req.prefill_end - req.prefill_start, 0.0)
        if req.prefill_end is not None and req.transfer_end is not None:
            obs["transfer"] = max(req.transfer_end - req.prefill_end, 0.0)
        if req.transfer_end is not None and req.decode_end is not None:
            n = req.s_out if req.tokens_out is None else req.tokens_out
            if n > 1:
                obs["decode"] = max(
                    req.decode_end - req.transfer_end, 0.0) / (n - 1)
        obs["warmup"] = req.warmup_penalty_s
        return obs

    def observe(self, req: Request, ts: float = 0.0) -> None:
        """Score one TERMINAL request: fold observed/predicted ratios
        into the per-(surface, group) and global EWMAs. Non-DONE
        terminals (rejected/cancelled) only clear bookkeeping — they
        have no complete stage timeline to score."""
        group = self._routed.pop(req.rid, None)
        if req.phase is not RequestState.DONE or group is None:
            return
        pred = {"prefill": req.pred_prefill_s,
                "decode": req.pred_decode_step_s,
                "transfer": req.pred_transfer_s,
                "warmup": req.pred_warmup_s}
        obs = self._observed(req)
        scored: Dict[str, Tuple[float, float]] = {}
        for surface in CALIBRATION_SURFACES:
            p, o = pred.get(surface, 0.0), obs.get(surface)
            if o is None or p <= _EPS or o <= _EPS:
                continue            # surface absent for this request
            ratio, residual = o / p, o - p
            for key in ((surface, group), (surface, -1)):
                self._stats.setdefault(key, _ErrStat()).fold(
                    ratio, residual, self.ewma_alpha)
            scored[surface] = (ratio, residual)
        if scored:
            self.observations += 1
        if self.recorder is not None and scored:
            args = {f"{s}_ratio": r for s, (r, _) in scored.items()}
            args.update({f"{s}_residual_s": d
                         for s, (_, d) in scored.items()})
            self.recorder.emit("cost_error", ts,
                               track=f"replica:{group}", rid=req.rid,
                               **args)
            for surface, (ratio, _) in scored.items():
                cell = self._stats[(surface, group)]
                self.recorder.gauge(f"cost_ratio:{surface}", ts,
                                    cell.ratio, track=f"replica:{group}")

    # -- reporting ------------------------------------------------------
    def snapshot(self) -> Dict[Tuple[str, int], Dict[str, float]]:
        """Per-(surface, group) error state for the Prometheus export:
        ``{(surface, group): {"ratio", "residual_s", "count"}}``. The
        global aggregate appears as group ``-1``."""
        return {key: {"ratio": st.ratio, "residual_s": st.residual,
                      "count": float(st.count)}
                for key, st in sorted(self._stats.items())
                if st.ratio is not None}

    def factors(self) -> Dict[str, float]:
        """Global per-surface observed/predicted EWMA ratios, restricted
        to surfaces with at least ``min_observations`` scores (an
        under-sampled surface must not rescale the flowgraph)."""
        out: Dict[str, float] = {}
        for surface in CALIBRATION_SURFACES:
            st = self._stats.get((surface, -1))
            if st is not None and st.count >= self.min_observations \
                    and st.ratio is not None and math.isfinite(st.ratio):
                out[surface] = st.ratio
        return out

    def corrections(self) -> CostCorrections:
        """Clamped multiplicative corrections for a calibrated re-solve
        (identity for every surface not yet warmed up)."""
        return CostCorrections.from_factors(self.factors())

    @property
    def warmed_up(self) -> bool:
        return bool(self.factors())

    def max_error(self) -> float:
        """Largest |EWMA ratio − 1| over warmed-up surfaces — the raw
        miscalibration signal the damped §13 trigger thresholds."""
        f = self.factors()
        if not f:
            return 0.0
        return max(abs(r - 1.0) for r in f.values())

    def miscalibrated(self) -> bool:
        return self.max_error() > self.bound


# ---------------------------------------------------------------------------
# Predictors
# ---------------------------------------------------------------------------


def plan_predictor(cluster: Any, profile: ModelProfile,
                   prefill_plan: Any, decode_plan: Any
                   ) -> Callable[[Request, int], Dict[str, float]]:
    """Predictor for the ROUTER domain, where every replica serves the
    same (prefill plan, decode plan) pair: predicted costs depend only
    on the request's lengths, so two domains constructing this from the
    same arguments stamp bit-identical predictions. ``group`` (the
    replica index) is deliberately unused — it labels the error series,
    not the prediction."""

    def predict(req: Request, group: int) -> Dict[str, float]:
        ctx = req.s_in + max(req.s_out, 1) // 2
        return {
            "prefill": prefill_latency(cluster, profile, prefill_plan,
                                       batch=1, s_in=req.s_in),
            "decode": decode_step_latency(cluster, profile, decode_plan,
                                          batch=1, context=ctx),
            "transfer": kv_transfer_time(cluster, profile, prefill_plan,
                                         decode_plan, batch=1,
                                         s_in=req.s_in),
        }

    return predict


def placement_predictor(cluster: Any, profile: ModelProfile, placement: Any
                        ) -> Callable[[Request, int], Dict[str, float]]:
    """Predictor for the SCHEDULING domain: ``group`` is the placement
    group id the request was routed to for prefill. The decode leg is
    predicted at the group's DOMINANT §4 kv_route destination (largest
    flow share, ties to the lowest id) — a genuine prediction: the
    dispatcher may route the KV elsewhere, and the error series absorbs
    the difference. ``cluster`` here is the spec the scheduler BELIEVED
    when it solved ``placement``; running the fleet on different
    hardware is exactly the miscalibration this store measures."""
    by_gid = {r.group_id: r for r in placement.replicas}
    main_route: Dict[int, int] = {}
    for (pid, did), f in sorted(placement.kv_routes.items()):
        best = main_route.get(pid)
        if best is None or f > placement.kv_routes[(pid, best)]:
            main_route[pid] = did
    decode_gids = sorted(r.group_id for r in placement.replicas
                         if not r.is_prefill and r.plan is not None)

    def predict(req: Request, group: int) -> Dict[str, float]:
        rep = by_gid.get(group)
        if rep is None or rep.plan is None:
            return {}
        out: Dict[str, float] = {
            "prefill": prefill_latency(cluster, profile, rep.plan,
                                       batch=1, s_in=req.s_in)}
        did = main_route.get(group,
                             decode_gids[0] if decode_gids else None)
        dec = by_gid.get(did) if did is not None else None
        if dec is not None and dec.plan is not None:
            ctx = req.s_in + max(req.s_out, 1) // 2
            out["decode"] = decode_step_latency(cluster, profile, dec.plan,
                                                batch=1, context=ctx)
            out["transfer"] = kv_transfer_time(cluster, profile, rep.plan,
                                               dec.plan, batch=1,
                                               s_in=req.s_in)
        return out

    return predict
