"""Shared serving-metrics schema (DESIGN.md §8).

``ServeMetrics`` is computed from a list of lifecycle ``Request``
records plus (makespan, decode_tokens) — nothing domain-specific. The
scheduling-domain ``SimResult`` subclasses it and the runtime
``ServeSession.metrics()`` returns it directly, so simulator and real
JAX runs report the SAME schema (throughput, TTFT, TPOT, SLO
attainment) and are directly comparable; ``METRIC_FIELDS`` is the
parity contract the tests pin.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np

from repro.serving.request import Request, RequestState

#: The shared runtime/simulator metrics schema. Every name is a
#: property (or method, for slo_attainment) on ServeMetrics and on
#: every subclass — tests/test_lifecycle.py asserts parity. The last
#: three are the prefix-cache fields (DESIGN.md §9): both domains stamp
#: ``Request.cached_len`` at prefill dispatch, so sim-vs-runtime hit
#: rates are computed from lifecycle records the same way and are
#: directly comparable.
#: The final three are the KV-handoff fields (DESIGN.md §10): both
#: domains stamp ``Request.kv_bytes_raw``/``kv_bytes_wire`` (and the
#: serialized/overlap transfer seconds) at handoff from the same
#: ``kv_compression`` accounting, so shipped bytes and compression
#: ratios are directly comparable sim-vs-runtime.
#: The final three are the paged-decode fields (DESIGN.md §11): both
#: domains stamp ``Request.kv_pages_allocated`` (sim: the
#: ``paging.pages_for_request`` arithmetic; runtime: the real
#: allocator), so page counts, pool utilization, and internal
#: fragmentation are directly comparable — and must agree EXACTLY on
#: the same trace.
#: The elastic-fleet block (DESIGN.md §13): scale events and per-state
#: replica-step totals are filled by the FleetController (dataclass
#: fields, 0/{} on a static fleet); ``warmup_ttft_penalty_s`` derives
#: from per-request ``warmup_penalty_s`` stamps. ``replica_steps_by_state``
#: is dict-valued and, like ``*_by_class``, NOT in ``summary()``.
#: The final block is the router tier (DESIGN.md §12): admission /
#: cancellation / failover counters and per-priority-class breakdowns.
#: Both domains drive the SAME ``Router`` over replica handles, so the
#: counters are derived from identical lifecycle records and must agree
#: EXACTLY on the same trace. The ``*_by_class`` fields are dicts keyed
#: by priority class — part of the schema contract but deliberately NOT
#: in ``summary()`` (summary values must stay finite scalars).
#: §14 telemetry adds the medians (``p50_*`` — what dashboards alert
#: on; p99-only hides the bimodality cold windows introduce) and
#: ``ttft_breakdown``, the per-priority-class TTFT attribution report
#: (dict-valued, so NOT in ``summary()``; fractions per request sum to
#: exactly 1.0 — see ``Request.ttft_fractions``).
#: §15 calibration adds ``cost_model_error``: per-surface mean
#: |observed/predicted − 1| derived purely from the ``pred_*`` dispatch
#: stamps and span-derived lifecycle timestamps, so sim-vs-runtime
#: reports agree EXACTLY on the same trace (dict-valued, NOT in
#: ``summary()``; {} when nothing was stamped).
#: §16 adds ``kv_cache_dtype``: the pool-resident KV dtype the run
#: served with ("int8" for quantized-resident pools, None for bf16 /
#: dense) — a dataclass field both domains stamp identically.
#: String-valued, so NOT in ``summary()``.
METRIC_FIELDS = ("decode_throughput", "avg_latency", "p50_latency",
                 "p99_latency",
                 "avg_ttft", "p50_ttft", "p99_ttft",
                 "avg_tpot", "slo_attainment",
                 "cache_hit_rate", "reused_tokens",
                 "prefill_tokens_computed",
                 "kv_bytes_shipped", "kv_compression_ratio",
                 "transfer_overlap_frac",
                 "kv_pages_allocated", "page_utilization",
                 "page_fragmentation",
                 "admitted", "rejected", "cancelled", "redispatched",
                 "slo_attainment_stated",
                 "avg_ttft_by_class", "slo_attainment_by_class",
                 "cache_hit_rate_by_class",
                 "scale_up_events", "scale_down_events",
                 "warmup_ttft_penalty_s", "replica_steps_by_state",
                 "ttft_breakdown", "cost_model_error",
                 "kv_cache_dtype")


@dataclasses.dataclass
class ServeMetrics:
    requests: List[Request]
    makespan: float
    decode_tokens: int
    # -- elastic-fleet fields (DESIGN.md §13; 0/{} on static fleets;
    # keyword-only so subclasses keep their positional signatures) -----
    #: scale DECISIONS the controller took (not lifecycle transitions:
    #: a scale-up that is still WARMING at trace end counts)
    scale_up_events: int = dataclasses.field(default=0, kw_only=True)
    scale_down_events: int = dataclasses.field(default=0, kw_only=True)
    #: replica-steps spent in each lifecycle state, keyed by state name
    #: ("provisioning"/"warming"/"live"/"draining") — the fleet's cost
    #: denominator: every non-dead replica-step is a machine you pay for
    replica_steps_by_state: Dict[str, int] = dataclasses.field(
        default_factory=dict, kw_only=True)
    #: §16 pool-resident KV dtype ("int8" when pages are quantized-
    #: resident; None for bf16-paged and dense runs). Stamped by both
    #: domains from their own configuration — parity-tested.
    kv_cache_dtype: Optional[str] = dataclasses.field(default=None,
                                                      kw_only=True)

    @property
    def decode_throughput(self) -> float:
        """tokens/s — the paper's offline metric."""
        return self.decode_tokens / self.makespan if self.makespan > 0 else 0.0

    def _stat(self, attr: str, fn) -> float:
        vals = [getattr(r, attr) for r in self.requests
                if getattr(r, attr) is not None]
        return float(fn(vals)) if vals else float("inf")

    @property
    def avg_latency(self) -> float:
        return self._stat("latency", np.mean)

    @property
    def p50_latency(self) -> float:
        return self._stat("latency", lambda v: np.percentile(v, 50))

    @property
    def p99_latency(self) -> float:
        return self._stat("latency", lambda v: np.percentile(v, 99))

    @property
    def avg_ttft(self) -> float:
        return self._stat("ttft", np.mean)

    @property
    def p50_ttft(self) -> float:
        return self._stat("ttft", lambda v: np.percentile(v, 50))

    @property
    def p99_ttft(self) -> float:
        return self._stat("ttft", lambda v: np.percentile(v, 99))

    @property
    def avg_tpot(self) -> float:
        return self._stat("tpot", np.mean)

    # -- prefix-cache fields (DESIGN.md §9) -----------------------------
    @property
    def reused_tokens(self) -> int:
        """Prompt tokens served from a prefix cache instead of computed."""
        return int(sum(r.cached_len for r in self.requests))

    @property
    def prefill_tokens_computed(self) -> int:
        """Prompt tokens that actually paid prefill compute."""
        return int(sum(r.s_in - r.cached_len for r in self.requests))

    @property
    def cache_hit_rate(self) -> float:
        """Token-level hit rate: reused / total prompt tokens (0.0 on a
        cold or cache-less run)."""
        total = sum(r.s_in for r in self.requests)
        return self.reused_tokens / total if total else 0.0

    # -- KV-handoff fields (DESIGN.md §10) ------------------------------
    @property
    def kv_bytes_shipped(self) -> float:
        """Wire bytes of every φ→δ KV shipment (handoffs + migrations),
        after the codec. Equals the raw bytes when no codec compresses."""
        return float(sum(r.kv_bytes_wire for r in self.requests))

    @property
    def kv_compression_ratio(self) -> float:
        """raw/wire over all shipped KV (1.0 when nothing shipped or
        the codec is exact)."""
        raw = sum(r.kv_bytes_raw for r in self.requests)
        wire = sum(r.kv_bytes_wire for r in self.requests)
        return raw / wire if wire > 0 else 1.0

    @property
    def transfer_overlap_frac(self) -> float:
        """Fraction of serialized KV-transfer seconds hidden behind
        prefill compute by chunked streaming (0.0 for blocking
        handoffs and for the synchronous single-host runtime)."""
        serialized = sum(r.kv_serialized_s for r in self.requests)
        overlap = sum(r.kv_overlap_s for r in self.requests)
        return overlap / serialized if serialized > 0 else 0.0

    # -- paged-decode fields (DESIGN.md §11) ----------------------------
    @property
    def kv_pages_allocated(self) -> int:
        """Distinct decode-residency KV pages across all requests (0 on
        a dense run)."""
        return int(sum(r.kv_pages_allocated for r in self.requests))

    @property
    def page_utilization(self) -> float:
        """Token slots actually resident / token slots allocated, over
        requests that held pages: the complement of internal
        fragmentation. 1.0 when nothing was paged."""
        slots = sum(r.kv_pages_allocated * r.kv_page_size
                    for r in self.requests)
        if slots <= 0:
            return 1.0
        used = sum(min(r.s_in + (r.s_out if r.tokens_out is None
                                 else r.tokens_out) - 1,
                       r.kv_pages_allocated * r.kv_page_size)
                   for r in self.requests if r.kv_pages_allocated)
        return used / slots

    @property
    def page_fragmentation(self) -> float:
        """Allocated-but-unused fraction of paged token slots — the
        padding a dense layout would multiply across its whole
        capacity (0.0 on a dense run)."""
        return 1.0 - self.page_utilization

    # -- router-tier fields (DESIGN.md §12) -----------------------------
    @property
    def rejected(self) -> int:
        """Requests refused at admission (queue overflow)."""
        return sum(1 for r in self.requests
                   if r.phase is RequestState.REJECTED)

    @property
    def cancelled(self) -> int:
        """Requests cancelled by the client at some lifecycle stage."""
        return sum(1 for r in self.requests
                   if r.phase is RequestState.CANCELLED)

    @property
    def admitted(self) -> int:
        """Requests that entered (and stayed in) the pipeline. The three
        counters partition the trace: admitted + rejected + cancelled ==
        submitted — the §12 conservation invariant."""
        return len(self.requests) - self.rejected - self.cancelled

    @property
    def redispatched(self) -> int:
        """Total §12 failover re-dispatches (a request surviving two
        replica deaths counts twice)."""
        return int(sum(r.redispatches for r in self.requests))

    # -- elastic-fleet fields (DESIGN.md §13) ---------------------------
    @property
    def warmup_ttft_penalty_s(self) -> float:
        """Total cold-start TTFT cost across requests dispatched to a
        just-joined replica inside its cold window (0.0 on a static
        fleet or when no dispatch landed cold)."""
        return float(sum(r.warmup_penalty_s for r in self.requests))

    def _classes(self) -> Dict[int, List[Request]]:
        by: Dict[int, List[Request]] = {}
        for r in self.requests:
            by.setdefault(r.priority, []).append(r)
        return by

    @property
    def avg_ttft_by_class(self) -> Dict[int, float]:
        """Mean TTFT per priority class (classes with no finished
        request report inf — they never saw a first token)."""
        out = {}
        for cls, rs in self._classes().items():
            vals = [r.ttft for r in rs if r.ttft is not None]
            out[cls] = float(np.mean(vals)) if vals else float("inf")
        return out

    @property
    def slo_attainment_by_class(self) -> Dict[int, float]:
        """Fraction of each class's stated-SLO requests that finished
        within their own ``slo_target_s``. Rejected/cancelled requests
        count as misses (latency None) — admission control can't buy
        attainment by shedding. Classes with no stated SLO are omitted."""
        out = {}
        for cls, rs in self._classes().items():
            stated = [r for r in rs if r.slo_target_s is not None]
            if not stated:
                continue
            ok = sum(1 for r in stated if r.latency is not None
                     and r.latency <= r.slo_target_s)
            out[cls] = ok / len(stated)
        return out

    @property
    def slo_attainment_stated(self) -> float:
        """Overall attainment over requests with a stated per-request
        SLO (1.0 when the trace states none)."""
        stated = [r for r in self.requests if r.slo_target_s is not None]
        if not stated:
            return 1.0
        ok = sum(1 for r in stated if r.latency is not None
                 and r.latency <= r.slo_target_s)
        return ok / len(stated)

    @property
    def cache_hit_rate_by_class(self) -> Dict[int, float]:
        """Token-level prefix-cache hit rate per priority class."""
        out = {}
        for cls, rs in self._classes().items():
            total = sum(r.s_in for r in rs)
            out[cls] = (sum(r.cached_len for r in rs) / total
                        if total else 0.0)
        return out

    # -- telemetry fields (DESIGN.md §14) -------------------------------
    @property
    def ttft_breakdown(self) -> Dict[int, Dict[str, float]]:
        """The TTFT attribution report: mean fraction of TTFT spent in
        each ``TTFT_BUCKETS`` bucket (queue / prefill / transfer /
        warmup / decode_first), per priority class, over requests that
        produced a first token. Every contributing request's fractions
        sum to exactly 1.0 (``Request.ttft_fractions``), so each
        class's means do too. Classes that never served are omitted."""
        from repro.serving.request import TTFT_BUCKETS
        out: Dict[int, Dict[str, float]] = {}
        for cls, rs in self._classes().items():
            fracs = [f for f in (r.ttft_fractions() for r in rs)
                     if f is not None]
            if not fracs:
                continue
            out[cls] = {k: float(np.mean([f[k] for f in fracs]))
                        for k in TTFT_BUCKETS}
        return out

    # -- calibration fields (DESIGN.md §15) -----------------------------
    @property
    def cost_model_error(self) -> Dict[str, float]:
        """Per-surface mean |observed/predicted − 1| over DONE requests
        carrying §15 dispatch stamps (``pred_prefill_s`` etc.), with
        observations derived from the same span-boundary timestamps the
        ``CalibrationStore`` reads — a pure function of lifecycle
        records, so sim-vs-runtime agrees EXACTLY on the same trace.
        {} when no request was stamped (calibration off)."""
        eps = 1e-12
        errs: Dict[str, List[float]] = {}
        for r in self.requests:
            if r.phase is not RequestState.DONE or r.prefill_start is None:
                continue
            n = r.s_out if r.tokens_out is None else r.tokens_out
            pairs = (
                ("prefill", r.pred_prefill_s,
                 (r.prefill_end or 0.0) - r.prefill_start),
                ("transfer", r.pred_transfer_s,
                 (r.transfer_end or 0.0) - (r.prefill_end or 0.0)),
                ("decode", r.pred_decode_step_s,
                 ((r.decode_end or 0.0) - (r.transfer_end or 0.0))
                 / (n - 1) if n > 1 else 0.0),
                ("warmup", r.pred_warmup_s, r.warmup_penalty_s),
            )
            for surface, pred, obs in pairs:
                if pred > eps and obs > eps:
                    errs.setdefault(surface, []).append(abs(obs / pred - 1.0))
        return {k: float(np.mean(v)) for k, v in sorted(errs.items())}

    def slo_attainment(self, slo_per_request: Dict[int, float],
                       scale: float) -> float:
        ok = sum(1 for r in self.requests
                 if r.latency is not None
                 and r.latency <= scale * slo_per_request[r.rid])
        return ok / max(len(self.requests), 1)

    def summary(self, slo: Optional[Dict[int, float]] = None,
                slo_scale: float = 5.0) -> Dict[str, float]:
        """The schema as one flat dict (benchmark/report rows)."""
        out = {"decode_throughput": self.decode_throughput,
               "avg_latency": self.avg_latency,
               "p50_latency": self.p50_latency,
               "p99_latency": self.p99_latency,
               "avg_ttft": self.avg_ttft,
               "p50_ttft": self.p50_ttft,
               "p99_ttft": self.p99_ttft,
               "avg_tpot": self.avg_tpot,
               "cache_hit_rate": self.cache_hit_rate,
               "reused_tokens": float(self.reused_tokens),
               "prefill_tokens_computed": float(self.prefill_tokens_computed),
               "kv_bytes_shipped": self.kv_bytes_shipped,
               "kv_compression_ratio": self.kv_compression_ratio,
               "transfer_overlap_frac": self.transfer_overlap_frac,
               "kv_pages_allocated": float(self.kv_pages_allocated),
               "page_utilization": self.page_utilization,
               "page_fragmentation": self.page_fragmentation,
               "admitted": float(self.admitted),
               "rejected": float(self.rejected),
               "cancelled": float(self.cancelled),
               "redispatched": float(self.redispatched),
               "slo_attainment_stated": self.slo_attainment_stated,
               "scale_up_events": float(self.scale_up_events),
               "scale_down_events": float(self.scale_down_events),
               "warmup_ttft_penalty_s": self.warmup_ttft_penalty_s}
        if slo is not None:
            out["slo_attainment"] = self.slo_attainment(slo, slo_scale)
        return out
