"""Workload generators (paper §5.1) and time-varying traces.

Four offline classes from the heavy/light prefill-decode taxonomy
(heavy prefill > 512 prompt tokens; heavy decode > 128 output tokens),
sampled with Azure-Conversation-like lognormal length distributions,
plus an online trace with Poisson arrivals scaled to 75% of cluster
peak throughput.

``drifting_workload`` produces phased traces whose arrival rate and
prompt/output mix change over time — the input to the online
rescheduling path (DESIGN.md §7): a placement optimized for the first
phase's mix goes stale once the mix drifts, and the WorkloadMonitor /
``reschedule`` warm-start reacts.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class LengthDist:
    """Lognormal token-length distribution clipped to [lo, hi]."""
    mean_log: float
    sigma_log: float
    lo: int
    hi: int

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        x = rng.lognormal(self.mean_log, self.sigma_log, size=n)
        return np.clip(x.astype(int), self.lo, self.hi)


# heavy prefill: >512 prompt tokens; heavy decode: >128 output tokens
_PREFILL_HEAVY = LengthDist(np.log(1024), 0.4, 513, 4096)
_PREFILL_LIGHT = LengthDist(np.log(256), 0.5, 16, 512)
_DECODE_HEAVY = LengthDist(np.log(256), 0.4, 129, 1024)
_DECODE_LIGHT = LengthDist(np.log(64), 0.5, 8, 128)

WORKLOAD_DISTS = {
    "HPLD": (_PREFILL_HEAVY, _DECODE_LIGHT),
    "HPHD": (_PREFILL_HEAVY, _DECODE_HEAVY),
    "LPHD": (_PREFILL_LIGHT, _DECODE_HEAVY),
    "LPLD": (_PREFILL_LIGHT, _DECODE_LIGHT),
}


def offline_workload(kind: str, n: int, seed: int = 0) -> List[Request]:
    """Offline = all requests available at t=0 (arrival rate saturates)."""
    rng = np.random.default_rng(seed)
    pd, dd = WORKLOAD_DISTS[kind]
    s_in = pd.sample(rng, n)
    s_out = dd.sample(rng, n)
    return [Request(rid=i, s_in=int(s_in[i]), s_out=int(s_out[i]),
                    arrival=0.0) for i in range(n)]


def online_workload(n: int, rate_rps: float, seed: int = 0,
                    mix: Optional[List[str]] = None) -> List[Request]:
    """Online = Poisson arrivals at ``rate_rps``, mixed workload classes
    (the paper's online trace mixes conversation-like lengths)."""
    rng = np.random.default_rng(seed)
    mix = mix or ["HPLD", "HPHD", "LPHD", "LPLD"]
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n):
        kind = mix[int(rng.integers(len(mix)))]
        pd, dd = WORKLOAD_DISTS[kind]
        reqs.append(Request(
            rid=i, s_in=int(pd.sample(rng, 1)[0]),
            s_out=int(dd.sample(rng, 1)[0]), arrival=float(arrivals[i])))
    return reqs


def mean_lengths(kind: str) -> tuple:
    """Representative (s_in, s_out) for the scheduler's Workload input."""
    from repro.core.cost_model import WORKLOADS
    wl = WORKLOADS[kind]
    return wl.s_in, wl.s_out


# ---------------------------------------------------------------------------
# Time-varying traces (workload drift)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TracePhase:
    """One phase of a time-varying trace: Poisson arrivals at
    ``rate_rps`` for ``duration_s`` seconds, classes drawn from ``mix``
    (class name -> probability weight, normalized internally)."""
    duration_s: float
    rate_rps: float
    mix: Dict[str, float]

    def normalized_mix(self) -> Dict[str, float]:
        total = sum(self.mix.values())
        assert total > 0, "phase mix must have positive weight"
        return {k: v / total for k, v in self.mix.items()}


def drifting_workload(phases: Sequence[TracePhase],
                      seed: int = 0) -> List[Request]:
    """Concatenate ``phases`` into one trace with drifting statistics.

    Arrivals are Poisson within each phase; each request's class is
    drawn from the phase mix and its lengths from that class's
    distributions. Phase boundaries are hard (the drift is a step
    function — the worst case for a static placement)."""
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    t = 0.0
    rid = 0
    for phase in phases:
        end = t + phase.duration_s
        if phase.rate_rps <= 0.0:   # idle gap
            t = end
            continue
        mix = phase.normalized_mix()
        names = list(mix)
        probs = np.array([mix[n] for n in names])
        while True:
            t += rng.exponential(1.0 / phase.rate_rps)
            if t >= end:
                break
            kind = names[int(rng.choice(len(names), p=probs))]
            pd, dd = WORKLOAD_DISTS[kind]
            reqs.append(Request(rid=rid, s_in=int(pd.sample(rng, 1)[0]),
                                s_out=int(dd.sample(rng, 1)[0]),
                                arrival=float(t)))
            rid += 1
        t = end
    return reqs


def observed_workload(requests: Sequence[Request],
                      name: str = "observed",
                      prefill_batch: int = 1):
    """Fit a scheduler ``Workload`` to a batch of observed requests
    (mean prompt/output lengths). The offline counterpart of
    ``WorkloadMonitor.snapshot`` (which streams the same fit over a
    sliding window and inherits prefill_batch from its baseline)."""
    from repro.core.cost_model import Workload
    assert requests, "cannot fit a workload to zero requests"
    s_in = int(np.mean([r.s_in for r in requests]))
    s_out = int(np.mean([r.s_out for r in requests]))
    return Workload(name, s_in=max(s_in, 1), s_out=max(s_out, 1),
                    prefill_batch=prefill_batch)
