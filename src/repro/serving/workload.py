"""Workload generators (paper §5.1) and time-varying traces.

Four offline classes from the heavy/light prefill-decode taxonomy
(heavy prefill > 512 prompt tokens; heavy decode > 128 output tokens),
sampled with Azure-Conversation-like lognormal length distributions,
plus an online trace with Poisson arrivals scaled to 75% of cluster
peak throughput.

``drifting_workload`` produces phased traces whose arrival rate and
prompt/output mix change over time — the input to the online
rescheduling path (DESIGN.md §7): a placement optimized for the first
phase's mix goes stale once the mix drifts, and the WorkloadMonitor /
``reschedule`` warm-start reacts.

The shared-prefix generators (``multi_turn_workload`` and friends)
produce traces whose prompts overlap token-for-token — the input to the
prefix-cache subsystem (DESIGN.md §9).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass(frozen=True)
class LengthDist:
    """Lognormal token-length distribution clipped to [lo, hi]."""
    mean_log: float
    sigma_log: float
    lo: int
    hi: int

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        x = rng.lognormal(self.mean_log, self.sigma_log, size=n)
        return np.clip(x.astype(int), self.lo, self.hi)


# heavy prefill: >512 prompt tokens; heavy decode: >128 output tokens
_PREFILL_HEAVY = LengthDist(np.log(1024), 0.4, 513, 4096)
_PREFILL_LIGHT = LengthDist(np.log(256), 0.5, 16, 512)
_DECODE_HEAVY = LengthDist(np.log(256), 0.4, 129, 1024)
_DECODE_LIGHT = LengthDist(np.log(64), 0.5, 8, 128)

WORKLOAD_DISTS = {
    "HPLD": (_PREFILL_HEAVY, _DECODE_LIGHT),
    "HPHD": (_PREFILL_HEAVY, _DECODE_HEAVY),
    "LPHD": (_PREFILL_LIGHT, _DECODE_HEAVY),
    "LPLD": (_PREFILL_LIGHT, _DECODE_LIGHT),
}


def offline_workload(kind: str, n: int, seed: int = 0) -> List[Request]:
    """Offline = all requests available at t=0 (arrival rate saturates)."""
    rng = np.random.default_rng(seed)
    pd, dd = WORKLOAD_DISTS[kind]
    s_in = pd.sample(rng, n)
    s_out = dd.sample(rng, n)
    return [Request(rid=i, s_in=int(s_in[i]), s_out=int(s_out[i]),
                    arrival=0.0) for i in range(n)]


def online_workload(n: int, rate_rps: float, seed: int = 0,
                    mix: Optional[List[str]] = None) -> List[Request]:
    """Online = Poisson arrivals at ``rate_rps``, mixed workload classes
    (the paper's online trace mixes conversation-like lengths)."""
    rng = np.random.default_rng(seed)
    mix = mix or ["HPLD", "HPHD", "LPHD", "LPLD"]
    gaps = rng.exponential(1.0 / rate_rps, size=n)
    arrivals = np.cumsum(gaps)
    reqs = []
    for i in range(n):
        kind = mix[int(rng.integers(len(mix)))]
        pd, dd = WORKLOAD_DISTS[kind]
        reqs.append(Request(
            rid=i, s_in=int(pd.sample(rng, 1)[0]),
            s_out=int(dd.sample(rng, 1)[0]), arrival=float(arrivals[i])))
    return reqs


def mean_lengths(kind: str) -> tuple:
    """Representative (s_in, s_out) for the scheduler's Workload input."""
    from repro.core.cost_model import WORKLOADS
    wl = WORKLOADS[kind]
    return wl.s_in, wl.s_out


# ---------------------------------------------------------------------------
# Time-varying traces (workload drift)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TracePhase:
    """One phase of a time-varying trace: Poisson arrivals at
    ``rate_rps`` for ``duration_s`` seconds, classes drawn from ``mix``
    (class name -> probability weight, normalized internally)."""
    duration_s: float
    rate_rps: float
    mix: Dict[str, float]

    def normalized_mix(self) -> Dict[str, float]:
        total = sum(self.mix.values())
        assert total > 0, "phase mix must have positive weight"
        return {k: v / total for k, v in self.mix.items()}


def drifting_workload(phases: Sequence[TracePhase],
                      seed: int = 0) -> List[Request]:
    """Concatenate ``phases`` into one trace with drifting statistics.

    Arrivals are Poisson within each phase; each request's class is
    drawn from the phase mix and its lengths from that class's
    distributions. Phase boundaries are hard (the drift is a step
    function — the worst case for a static placement)."""
    rng = np.random.default_rng(seed)
    reqs: List[Request] = []
    t = 0.0
    rid = 0
    for phase in phases:
        end = t + phase.duration_s
        if phase.rate_rps <= 0.0:   # idle gap
            t = end
            continue
        mix = phase.normalized_mix()
        names = list(mix)
        probs = np.array([mix[n] for n in names])
        while True:
            t += rng.exponential(1.0 / phase.rate_rps)
            if t >= end:
                break
            kind = names[int(rng.choice(len(names), p=probs))]
            pd, dd = WORKLOAD_DISTS[kind]
            reqs.append(Request(rid=rid, s_in=int(pd.sample(rng, 1)[0]),
                                s_out=int(dd.sample(rng, 1)[0]),
                                arrival=float(t)))
            rid += 1
        t = end
    return reqs


# ---------------------------------------------------------------------------
# Shared-prefix traces (DESIGN.md §9)
# ---------------------------------------------------------------------------
#
# The prefix-cache subsystem only matters if the workload actually shares
# prefixes. These generators emit requests WITH prompt-token content
# (``Request.tokens``) plus the scheduling-domain descriptor
# (``prefix_id``, ``shared_len``), so the same trace drives the real
# runtime (tokens feed the engines) and the simulator (the radix state
# is keyed on the same tokens). Three production shapes:
#
#   * multi-turn conversations — turn k's prompt extends turn k-1's
#     full context (prompt + that turn's response), the dominant chat
#     pattern;
#   * common system prompt — every request opens with one shared
#     instruction block;
#   * few-shot agentic templates — a small set of long exemplar
#     prefixes, each reused by many calls.


def _tok(rng: np.random.Generator, n: int, vocab: int) -> List[int]:
    return [int(t) for t in rng.integers(0, vocab, size=n)]


def multi_turn_workload(conversations: int, turns: int, rate_rps: float,
                        seed: int = 0, vocab: int = 512,
                        system_len: int = 48, user_len: int = 24,
                        out_len: int = 16,
                        think_time_s: float = 2.0) -> List[Request]:
    """Multi-turn chat: each conversation's turn k prompt is the full
    history (previous prompt + previous response) plus a fresh user
    message, so consecutive turns share an ever-growing prefix.

    Conversations open with Poisson arrivals at ``rate_rps``; turns
    within a conversation are spaced by exponential think time. The
    trace fixes the "response" tokens (the runtime's actual generations
    differ, but the *prompt* content — which is what prefix caching
    keys on — is what the trace pins)."""
    rng = np.random.default_rng(seed)
    opens = np.cumsum(rng.exponential(1.0 / max(rate_rps, 1e-9),
                                      size=conversations))
    reqs: List[Request] = []
    for c in range(conversations):
        history = _tok(rng, system_len, vocab)
        t = float(opens[c])
        for k in range(turns):
            ulen = max(1, int(rng.poisson(user_len)))
            olen = max(1, int(rng.poisson(out_len)))
            prompt = history + _tok(rng, ulen, vocab)
            reqs.append(Request(
                rid=len(reqs), s_in=len(prompt), s_out=olen, arrival=t,
                tokens=tuple(prompt), prefix_id=c,
                shared_len=len(history) if k else 0))
            # next turn extends this prompt + this turn's (trace) response
            history = prompt + _tok(rng, olen, vocab)
            t += float(rng.exponential(think_time_s))
    reqs.sort(key=lambda r: r.arrival)
    for i, r in enumerate(reqs):
        r.rid = i
    return reqs


def shared_system_prompt_workload(n: int, rate_rps: float, seed: int = 0,
                                  vocab: int = 512, system_len: int = 96,
                                  user_len: int = 32,
                                  out_len: int = 24) -> List[Request]:
    """Every request opens with ONE shared system prompt followed by a
    unique user tail — the ceiling case for prefix reuse."""
    rng = np.random.default_rng(seed)
    system = _tok(rng, system_len, vocab)
    arrivals = np.cumsum(rng.exponential(1.0 / max(rate_rps, 1e-9), size=n))
    reqs = []
    for i in range(n):
        ulen = max(1, int(rng.poisson(user_len)))
        olen = max(1, int(rng.poisson(out_len)))
        prompt = system + _tok(rng, ulen, vocab)
        reqs.append(Request(rid=i, s_in=len(prompt), s_out=olen,
                            arrival=float(arrivals[i]),
                            tokens=tuple(prompt), prefix_id=0,
                            shared_len=system_len if i else 0))
    return reqs


def fewshot_agentic_workload(n: int, rate_rps: float, templates: int = 4,
                             seed: int = 0, vocab: int = 512,
                             template_len: int = 128, task_len: int = 24,
                             out_len: int = 32) -> List[Request]:
    """Agentic / few-shot traffic: a small pool of long exemplar
    templates; each call picks one and appends a short task."""
    rng = np.random.default_rng(seed)
    pool = [_tok(rng, template_len, vocab) for _ in range(templates)]
    seen = [False] * templates
    arrivals = np.cumsum(rng.exponential(1.0 / max(rate_rps, 1e-9), size=n))
    reqs = []
    for i in range(n):
        tid = int(rng.integers(templates))
        tlen = max(1, int(rng.poisson(task_len)))
        olen = max(1, int(rng.poisson(out_len)))
        prompt = pool[tid] + _tok(rng, tlen, vocab)
        reqs.append(Request(rid=i, s_in=len(prompt), s_out=olen,
                            arrival=float(arrivals[i]),
                            tokens=tuple(prompt), prefix_id=tid,
                            shared_len=template_len if seen[tid] else 0))
        seen[tid] = True
    return reqs


PREFIX_TRACES = {
    "multiturn": multi_turn_workload,
    "sysprompt": shared_system_prompt_workload,
    "fewshot": fewshot_agentic_workload,
}


def prefix_trace(kind: str, n: int, rate_rps: float, seed: int = 0,
                 vocab: int = 512,
                 think_time_s: Optional[float] = None) -> List[Request]:
    """Uniform entry point over the shared-prefix generators: ``n`` is
    the (approximate) request count whatever the trace shape.
    ``think_time_s`` (multiturn only) overrides the between-turn gap —
    smoke runs pass a small value so a wall-clock driver doesn't sleep
    through real conversation pauses."""
    if kind == "multiturn":
        turns = 4
        kw = {} if think_time_s is None else {"think_time_s": think_time_s}
        return multi_turn_workload(max(1, n // turns), turns, rate_rps,
                                   seed=seed, vocab=vocab, **kw)
    if kind == "sysprompt":
        return shared_system_prompt_workload(n, rate_rps, seed=seed,
                                             vocab=vocab)
    if kind == "fewshot":
        return fewshot_agentic_workload(n, rate_rps, seed=seed, vocab=vocab)
    raise KeyError(f"unknown prefix trace {kind!r}; "
                   f"options: {sorted(PREFIX_TRACES)}")


def observed_workload(requests: Sequence[Request],
                      name: str = "observed",
                      prefill_batch: int = 1):
    """Fit a scheduler ``Workload`` to a batch of observed requests
    (mean prompt/output lengths). The offline counterpart of
    ``WorkloadMonitor.snapshot`` (which streams the same fit over a
    sliding window and inherits prefill_batch from its baseline)."""
    from repro.core.cost_model import Workload
    assert requests, "cannot fit a workload to zero requests"
    s_in = int(np.mean([r.s_in for r in requests]))
    s_out = int(np.mean([r.s_out for r in requests]))
    return Workload(name, s_in=max(s_in, 1), s_out=max(s_out, 1),
                    prefill_batch=prefill_batch)


# ---------------------------------------------------------------------------
# Mixed-priority traffic (DESIGN.md §12): the router tier's input
# ---------------------------------------------------------------------------

#: (name, slo multiplier of the interactive target, default class mix)
PRIORITY_CLASS_NAMES = {0: "interactive", 1: "standard", 2: "batch"}


def mixed_priority_workload(n: int, rate_rps: float, seed: int = 0,
                            vocab: int = 512,
                            class_weights: Sequence[float] = (0.5, 0.3, 0.2),
                            system_lens: Sequence[int] = (24, 16, 8),
                            user_lens: Sequence[int] = (6, 10, 18),
                            out_lens: Sequence[int] = (6, 12, 40),
                            slo_s: Sequence[float] = (2.0, 8.0, 30.0)
                            ) -> List[Request]:
    """Three-class mixed traffic for the §12 router: interactive
    (priority 0 — frequent, short, tight SLO), standard, and batch
    (long outputs, loose SLO). Each class opens with its OWN shared
    system prompt (so prefix reuse and sticky routing have something to
    bite on, and per-class hit rates are meaningful) followed by a
    unique tail. Poisson arrivals at ``rate_rps`` overall."""
    rng = np.random.default_rng(seed)
    ncls = len(class_weights)
    w = np.asarray(class_weights, float)
    w = w / w.sum()
    systems = [_tok(rng, system_lens[c], vocab) for c in range(ncls)]
    seen = [False] * ncls
    arrivals = np.cumsum(rng.exponential(1.0 / max(rate_rps, 1e-9), size=n))
    reqs = []
    for i in range(n):
        c = int(rng.choice(ncls, p=w))
        ulen = max(1, int(rng.poisson(user_lens[c])))
        olen = max(1, int(rng.poisson(out_lens[c])))
        prompt = systems[c] + _tok(rng, ulen, vocab)
        reqs.append(Request(rid=i, s_in=len(prompt), s_out=olen,
                            arrival=float(arrivals[i]),
                            tokens=tuple(prompt), prefix_id=c,
                            shared_len=system_lens[c] if seen[c] else 0,
                            priority=c, slo_target_s=float(slo_s[c])))
        seen[c] = True
    return reqs


def surge_workload(n: int, rate_rps: float, seed: int = 0,
                   surge: float = 4.0,
                   phases: Sequence[float] = (0.30, 0.40, 0.30),
                   vocab: int = 512,
                   class_weights: Sequence[float] = (0.5, 0.3, 0.2),
                   system_lens: Sequence[int] = (24, 16, 8),
                   user_lens: Sequence[int] = (6, 10, 18),
                   out_lens: Sequence[int] = (6, 12, 40),
                   slo_s: Sequence[float] = (2.0, 8.0, 30.0)
                   ) -> List[Request]:
    """Quiet → burst → quiet traffic for the §13 elastic fleet: the
    same three priority classes as ``mixed_priority_workload``, but the
    Poisson arrival rate steps ``rate_rps`` → ``surge * rate_rps`` →
    ``rate_rps`` across the three ``phases`` (fractions of ``n``).
    A static fleet sized for the quiet phases drowns in the burst; one
    sized for the burst idles ~60% of its replica-steps — the gap
    scale-to-demand closes."""
    rng = np.random.default_rng(seed)
    ncls = len(class_weights)
    w = np.asarray(class_weights, float)
    w = w / w.sum()
    ph = np.asarray(phases, float)
    ph = ph / ph.sum()
    counts = [int(round(p * n)) for p in ph]
    counts[-1] = n - sum(counts[:-1])
    rates = (rate_rps, surge * rate_rps, rate_rps)
    systems = [_tok(rng, system_lens[c], vocab) for c in range(ncls)]
    seen = [False] * ncls
    reqs: List[Request] = []
    t = 0.0
    i = 0
    for cnt, rate in zip(counts, rates):
        for _ in range(cnt):
            t += float(rng.exponential(1.0 / max(rate, 1e-9)))
            c = int(rng.choice(ncls, p=w))
            ulen = max(1, int(rng.poisson(user_lens[c])))
            olen = max(1, int(rng.poisson(out_lens[c])))
            prompt = systems[c] + _tok(rng, ulen, vocab)
            reqs.append(Request(rid=i, s_in=len(prompt), s_out=olen,
                                arrival=t,
                                tokens=tuple(prompt), prefix_id=c,
                                shared_len=system_lens[c] if seen[c] else 0,
                                priority=c, slo_target_s=float(slo_s[c])))
            seen[c] = True
            i += 1
    return reqs


def calibration_workload(n: int, rate_rps: float, seed: int = 0,
                         s_in_mean: int = 768, s_out_mean: int = 24,
                         slo_s: float = 6.0) -> List[Request]:
    """Transfer-heavy steady traffic for §15 calibration runs: long
    prompts (big φ→δ KV shipments, so a mis-believed interconnect
    bandwidth dominates TTFT) with short outputs and one stated SLO
    across the trace. Poisson arrivals; every request states the same
    ``slo_target_s`` so stated-SLO attainment is a single clean series
    for the predicted-vs-observed comparison."""
    rng = np.random.default_rng(seed)
    arrivals = np.cumsum(rng.exponential(1.0 / max(rate_rps, 1e-9), size=n))
    s_in = np.maximum(16, rng.poisson(s_in_mean, size=n))
    s_out = np.maximum(2, rng.poisson(s_out_mean, size=n))
    return [Request(rid=i, s_in=int(s_in[i]), s_out=int(s_out[i]),
                    arrival=float(arrivals[i]), slo_target_s=float(slo_s))
            for i in range(n)]
