"""Event-driven cluster simulator for disaggregated serving.

Executes a scheduler ``Placement`` against a request trace using the
Table-1 cost model for service times — this is the scheduling-domain
evaluation harness that reproduces the paper's throughput/latency/SLO
numbers (Figures 6–9) without renting heterogeneous GPUs.

Faithful mechanics:
  * prefill replicas serve one request at a time (compute-bound; paper
    Appendix A), FIFO;
  * dispatch follows the max-flow assignment — requests are routed to
    prefill replicas (and their KV targets) proportionally to flow,
    load-corrected;
  * KV transfers serialize per (prefill, decode) route at the cost
    model's transfer time;
  * decode replicas run continuous batching in rounds of
    ``chunk_tokens`` steps at the cost model's step latency for the
    current batch size and mean context.

Shared-prefix KV reuse (DESIGN.md §9): with ``prefix_caching=True``
every prefill replica carries a token-level radix tree of the prompts
it has served (budgeted by the cost model's memory headroom, LRU leaf
eviction). Dispatch becomes cache-aware — replicas are scored by
matched-prefix length blended with flow weight and load — and prefill
charges the cost model only for the uncached suffix. A §7 placement
swap invalidates every tree: the cached KV lives on the old replicas'
devices.

Compressed/chunked KV handoff (DESIGN.md §10): with ``kv_codec`` set
(including the explicit ``"none"``) the handoff runs the staged/
blocking pipeline model — the prefill replica holds each request's KV
until its stream drains, int8 codecs shrink the stream by the shared
``kv_compression`` accounting ratio, and chunked codecs start
streaming mid-prefill so only the last layer-group chunk is exposed
past prefill end. ``kv_codec=None`` keeps the legacy detached-handoff
abstraction (one §8-alignment change applies to every path: requests
with ``s_out <= 1`` finish at prefill and never ship KV, like the
runtime).

Online rescheduling (DESIGN.md §7): ``simulate_online`` additionally
feeds every arrival to a ``WorkloadMonitor`` and, when the observed mix
drifts, asks a rescheduler callback for a new placement and applies it
mid-trace. The swap is not free:

  * requests queued or mid-prefill restart on the new prefill replicas
    (prefill is stateless — only queueing time is lost);
  * requests holding decode-resident KV migrate: each re-ships its KV
    cache old-plan → new-plan at the cost model's transfer time,
    serialized per (old replica, new replica) route, and the receiving
    decode replica is blocked until its last migrated cache lands
    (the KV-drain cost);
  * in-flight decode rounds are abandoned — their partial chunk
    produces nothing (the migrated request keeps its pre-round
    remaining-token count).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.core.cost_model import (PAGE_SIZE, ModelProfile,
                                   decode_page_budget, decode_step_latency,
                                   kv_page_bytes, kv_transfer_time,
                                   max_decode_batch, prefill_latency,
                                   prefix_bytes_per_token,
                                   prefix_cache_budget)
from repro.core.placement import Placement, ReplicaPlacement
from repro.serving import kv_compression
from repro.serving.metrics import ServeMetrics
from repro.serving.paging import OutOfPagesError, PagePool, pages_for
from repro.serving.prefix_cache import PrefixCache, route_score
from repro.serving.request import Request, RequestState


@dataclasses.dataclass
class SimResult(ServeMetrics):
    """Scheduling-domain result: the shared metrics schema
    (``ServeMetrics``, DESIGN.md §8) computed over simulated requests.
    Runtime ``ServeSession.metrics()`` returns the same schema, so the
    two domains are directly comparable."""


@dataclasses.dataclass
class RescheduleEvent:
    """One mid-trace placement swap (for the drift benchmark's report)."""
    time: float
    drain_s: float            # KV-drain window: last migrated cache lands
    migrated: int             # decode-resident requests whose KV moved
    restarted: int            # queued / mid-prefill requests restarted
    max_flow: float           # new placement's solved flow
    #: cached prefix tokens dropped with the old prefill replicas
    #: (their KV lives on devices the new placement reassigned, §9)
    prefix_tokens_invalidated: int = 0


@dataclasses.dataclass
class OnlineSimResult(SimResult):
    reschedules: List[RescheduleEvent] = dataclasses.field(
        default_factory=list)


class _PrefillServer:
    def __init__(self, replica: ReplicaPlacement,
                 cache: Optional[PrefixCache] = None):
        self.replica = replica
        self.queue: List[Request] = []
        self.busy = False
        self.current: Optional[Request] = None
        self.cache = cache               # per-replica radix state (§9)


class _DecodeServer:
    def __init__(self, replica: ReplicaPlacement, max_batch: int,
                 pool: Optional[PagePool] = None, page_size: int = 0):
        self.replica = replica
        self.max_batch = max(1, max_batch)
        self.active: List[Tuple[Request, int]] = []   # (req, remaining)
        self.pending: List[Tuple[Request, int]] = []  # (req, remaining)
        self.in_round = False
        self.blocked_until = 0.0   # KV-drain: no rounds before this time
        # §11 paged admission: the SAME allocator the runtime engine
        # drives, against the cost model's page budget. None = dense.
        self.pool = pool
        self.page_size = page_size
        self.held: Dict[int, List[int]] = {}   # rid -> pages (grows only)


class _DisaggSim:
    """The event engine shared by ``simulate`` and ``simulate_online``.

    Placement-dependent state (server objects, dispatch tables) is
    rebuilt by ``_install``; events are epoch-tagged so a swap
    invalidates in-flight prefill/round events without touching the
    heap."""

    def __init__(self, cluster: ClusterSpec, profile: ModelProfile,
                 placement: Placement, chunk_tokens: int,
                 typical_context: int, prefix_caching: bool = False,
                 cache_alpha: float = 2.0,
                 prefix_budget_fraction: float = 0.5,
                 kv_codec=None, paged_kv: bool = False,
                 page_size: int = PAGE_SIZE,
                 kv_cache_dtype: Optional[str] = None,
                 telemetry=None, calibration=None):
        self.cluster = cluster
        self.profile = profile
        #: §14 event bus (``telemetry.TraceRecorder`` or None): the
        #: scheduling domain's stage events and utilization series —
        #: per-group queue depth / decode batch / page occupancy
        self.telemetry = telemetry
        #: §15 cost-model calibration (``calibration.CalibrationStore``
        #: or None): stamps predicted stage costs at the prefill routing
        #: decision and scores observed-vs-predicted at every DONE edge
        self.calibration = calibration
        self.chunk_tokens = chunk_tokens
        self.typical_context = typical_context
        self.prefix_caching = prefix_caching
        self.cache_alpha = cache_alpha
        self.prefix_budget_fraction = prefix_budget_fraction
        # §11 paged decode: admission/growth against the cost model's
        # page budget instead of the dense max batch; page-exhaustion
        # preempts the youngest resident request for recompute
        self.paged_kv = paged_kv
        self.page_size = int(page_size)
        # §16 int8-resident pools: the page budget (and so admitted
        # concurrency) is priced at quantized payload + scale sidecar
        self.kv_cache_dtype = kv_cache_dtype if paged_kv else None
        self.recompute_tokens: Dict[int, int] = {}   # rid -> tokens redone
        # §10 KV-handoff pipeline: None keeps the legacy abstraction
        # (handoff detached from the prefill server, uncompressed); a
        # codec — including the explicit "none" — switches to the
        # staged/blocking model where the prefill replica holds the KV
        # until its stream drains, so compression and chunked overlap
        # shorten the hold and feed straight into TTFT under load.
        self.kv_pipeline = kv_codec is not None
        self.codec = kv_compression.get_codec(kv_codec)
        self.kv_ratio = kv_compression.profile_kv_ratio(profile, self.codec)
        self.kv_chunks = kv_compression.sim_chunks(profile, self.codec)
        self._pins: Dict[int, Tuple[PrefixCache, object]] = {}
        self.epoch = 0
        self.events: List[Tuple[float, int, str, object]] = []
        self.seq = 0
        self.decode_tokens = 0
        self.makespan = 0.0
        self.reschedules: List[RescheduleEvent] = []
        # decode replicas per epoch, for re-shipping KV that was
        # mid-transfer when a swap (possibly several) landed: a stale
        # transfer resolves its source plan via its own epoch's map
        self.decode_reps_by_epoch: Dict[int, Dict[int, ReplicaPlacement]] = {}
        self.migrate_link: Dict[Tuple[int, int], float] = {}
        #: optional completion tap: called as ``on_done(t, req)`` at
        #: every DONE edge — how ``simulate_online`` feeds realized
        #: output lengths to a WorkloadMonitor's EWMA estimator with
        #: detection-lag-faithful timing (§13)
        self.on_done: Optional[Callable[[float, Request], None]] = None
        self.feasible = self._install(placement)
        if self.feasible:
            self._record_epoch_reps()

    # -- placement installation -----------------------------------------
    def _new_cache(self, replica: ReplicaPlacement) -> Optional[PrefixCache]:
        if not self.prefix_caching:
            return None
        budget = prefix_cache_budget(self.cluster, self.profile, replica.plan,
                                     batch=1, s_total=self.typical_context,
                                     fraction=self.prefix_budget_fraction)
        return PrefixCache(capacity_bytes=budget,
                           bytes_per_token=prefix_bytes_per_token(
                               self.profile))

    def _install(self, placement: Placement) -> bool:
        self.placement = placement
        self.prefill = {r.group_id: _PrefillServer(r, self._new_cache(r))
                        for r in placement.prefill_replicas()
                        if r.plan is not None}
        self.decode = {}
        for r in placement.decode_replicas():
            if r.plan is None:
                continue
            mb = max_decode_batch(self.cluster, self.profile, r.plan,
                                  self.typical_context)
            if self.paged_kv:
                budget = decode_page_budget(
                    self.cluster, self.profile, r.plan, self.page_size,
                    kv_cache_dtype=self.kv_cache_dtype)
                pool = PagePool(max(budget, 1) + 1, self.page_size,
                                page_bytes=kv_page_bytes(
                                    self.profile, self.page_size,
                                    kv_cache_dtype=self.kv_cache_dtype),
                                dtype=self.kv_cache_dtype)
                # pool-bound, not slot-bound: each request holds >= 1
                # page, so the pool itself caps concurrency
                self.decode[r.group_id] = _DecodeServer(
                    r, pool.num_allocatable, pool, self.page_size)
            else:
                self.decode[r.group_id] = _DecodeServer(r, mb)
        if not self.prefill or not self.decode:
            return False

        # flow-proportional dispatch tables
        self.pref_weight = {gid: 0.0 for gid in self.prefill}
        self.route_weight: Dict[int, List[Tuple[int, float]]] = {
            g: [] for g in self.prefill}
        for (p, d), f in placement.kv_routes.items():
            if p in self.prefill and d in self.decode:
                self.pref_weight[p] += f
                self.route_weight[p].append((d, f))
        # fall back to capacity weights if flow is degenerate
        if sum(self.pref_weight.values()) <= 0:
            for gid, srv in self.prefill.items():
                self.pref_weight[gid] = max(srv.replica.capacity, 1e-9)
                self.route_weight[gid] = [(d, self.decode[d].replica.capacity)
                                          for d in self.decode]
        for gid in self.prefill:
            if not self.route_weight[gid]:
                self.route_weight[gid] = [(d, self.decode[d].replica.capacity)
                                          for d in self.decode]
        self.dispatched = {gid: 0.0 for gid in self.prefill}
        self.routed: Dict[Tuple[int, int], float] = {}
        self.link_free: Dict[Tuple[int, int], float] = {}
        return True

    def _record_epoch_reps(self) -> None:
        self.decode_reps_by_epoch[self.epoch] = {
            gid: srv.replica for gid, srv in self.decode.items()}

    # -- event plumbing ---------------------------------------------------
    def push(self, t: float, kind: str, payload) -> None:
        heapq.heappush(self.events, (t, self.seq, kind, payload))
        self.seq += 1

    # -- dispatch rules ---------------------------------------------------
    def pick_prefill(self, req: Optional[Request] = None) -> int:
        """Cache-aware when §9 is on and the request carries tokens:
        replicas are scored by matched-prefix ratio blended with the
        normalized flow-weighted load (``route_score``); with no hits
        anywhere this reduces exactly to least-normalized-load."""
        if (not self.prefix_caching or req is None or req.tokens is None):
            # least normalized load among flow-weighted replicas
            return min(self.prefill,
                       key=lambda g: (self.dispatched[g] + 1)
                       / max(self.pref_weight[g], 1e-9))
        base = {g: (self.dispatched[g] + 1) / max(self.pref_weight[g], 1e-9)
                for g in self.prefill}
        lo = min(base.values())

        def score(g: int) -> float:
            cache = self.prefill[g].cache
            hit = (cache.matched_len(req.tokens) / max(req.s_in, 1)
                   if cache is not None else 0.0)
            return route_score(hit, base[g], lo, self.cache_alpha)

        # exact score ties break to the LOWEST group id (stable replica-
        # index order), matching the §12 router's rule — routing is
        # seed-reproducible and identical across domains
        return min(self.prefill, key=lambda g: (-score(g), g))

    def pick_decode(self, p: int) -> int:
        opts = self.route_weight[p]
        return min(opts, key=lambda df: (self.routed.get((p, df[0]), 0.0) + 1)
                   / max(df[1], 1e-9))[0]

    def any_decode(self) -> int:
        """Least-loaded decode server (fallback for stale transfers)."""
        return min(self.decode,
                   key=lambda g: (len(self.decode[g].active)
                                  + len(self.decode[g].pending) + 1)
                   / max(self.decode[g].replica.capacity, 1e-9))

    # -- server actions ---------------------------------------------------
    def start_prefill(self, t: float, srv: _PrefillServer) -> None:
        if srv.busy or not srv.queue:
            return
        req = srv.queue.pop(0)
        srv.busy = True
        srv.current = req
        req.advance(RequestState.PREFILLING, t)
        # §9: match at service start (the tree may have grown since
        # dispatch), pin the providing path for the prefill's duration,
        # and charge the cost model only for the uncached suffix
        req.cached_len = 0
        if srv.cache is not None and req.tokens is not None:
            m = srv.cache.match(req.tokens, lock=True)
            req.cached_len = min(m.length, req.s_in - 1)
            srv.cache.stats.reused_tokens += req.cached_len
            if m.node is not None:
                self._pins[req.rid] = (srv.cache, m.node)
        # §11 recompute: a preempted request re-prefills its original
        # prompt PLUS the tokens it had already generated
        redo = self.recompute_tokens.get(req.rid, 0)
        lat = prefill_latency(self.cluster, self.profile, srv.replica.plan,
                              1, req.s_in + redo, cached_len=req.cached_len)
        if self.telemetry is not None:
            gid = srv.replica.group_id
            self.telemetry.emit("prefill", t, track=f"prefill:{gid}",
                                rid=req.rid, dur=lat)
            self.telemetry.gauge("prefill_queue", t, len(srv.queue),
                                 track=f"prefill:{gid}")
        self.push(t + lat, "prefill_done",
                  (self.epoch, srv.replica.group_id, req))

    # -- §11 paged decode residency ---------------------------------------
    def _admit_paged(self, srv: _DecodeServer) -> None:
        """FIFO-admit pending requests while the pool can hold their
        current context — the same ``pages_for`` arithmetic the runtime
        allocator runs, so page counts match exactly."""
        while srv.pending:
            req, rem = srv.pending[0]
            produced = req.s_out - rem
            need = pages_for(req.s_in + produced, srv.page_size)
            try:
                pages = srv.pool.alloc(max(need, 1))
            except OutOfPagesError:
                break
            srv.held[req.rid] = pages
            req.kv_page_size = srv.page_size
            srv.active.append(srv.pending.pop(0))

    def _preempt_paged(self, t: float, srv: _DecodeServer,
                       entry: Tuple[Request, int]) -> None:
        """Page-exhaustion preemption (youngest resident first, the
        runtime engine's policy): release the request's pages and send
        it back through prefill for recompute. §10/§11 stamps survive
        the lifecycle restart — KV genuinely shipped, pages were
        genuinely held."""
        req, rem = entry
        srv.active.remove(entry)
        pages = srv.held.pop(req.rid)
        srv.pool.release(pages)
        req.kv_pages_allocated += len(pages)
        req.preemptions += 1
        if self.telemetry is not None:
            self.telemetry.emit(
                "preempt", t, track=f"decode:{srv.replica.group_id}",
                rid=req.rid, preemptions=req.preemptions)
        self.recompute_tokens[req.rid] = req.s_out - rem
        pin = self._pins.pop(req.rid, None)
        if pin is not None:
            pin[0].unlock(pin[1])
        snap = (req.kv_bytes_raw, req.kv_bytes_wire, req.kv_serialized_s,
                req.kv_overlap_s)
        req.restart()
        (req.kv_bytes_raw, req.kv_bytes_wire, req.kv_serialized_s,
         req.kv_overlap_s) = snap
        gid = self.pick_prefill(req)
        self.dispatched[gid] += 1
        req.prefill_group = gid
        self.prefill[gid].queue.append(req)
        self.start_prefill(t, self.prefill[gid])

    def _grow_paged(self, t: float, srv: _DecodeServer) -> None:
        """Grow every resident request to the pages this round's tokens
        will write (the runtime grows per step; per round is the same
        total). Exhaustion preempts the youngest resident — possibly
        the grower itself."""
        for entry in list(srv.active):
            if entry not in srv.active:
                continue                      # preempted by an earlier grow
            req, rem = entry
            produced_after = (req.s_out - rem) + min(self.chunk_tokens, rem)
            need = pages_for(req.s_in + produced_after - 1, srv.page_size)
            while len(srv.held[req.rid]) < need:
                try:
                    srv.held[req.rid].extend(srv.pool.alloc(1))
                except OutOfPagesError:
                    victim = srv.active[-1]   # youngest resident
                    self._preempt_paged(t, srv, victim)
                    if victim is entry:
                        break

    def start_round(self, t: float, srv: _DecodeServer) -> None:
        if srv.in_round:
            return
        if t < srv.blocked_until:
            # KV-drain window: wake up when the last migrated cache lands
            self.push(srv.blocked_until, "kick",
                      (self.epoch, srv.replica.group_id))
            return
        if srv.pool is not None:
            self._admit_paged(srv)
            self._grow_paged(t, srv)
        else:
            free = srv.max_batch - len(srv.active)
            if free > 0 and srv.pending:
                srv.active.extend(srv.pending[:free])
                srv.pending = srv.pending[free:]
        if not srv.active:
            return
        srv.in_round = True
        batch = len(srv.active)
        ctx = int(np.mean([r.s_in + (r.s_out - rem)
                           for r, rem in srv.active]))
        step = decode_step_latency(self.cluster, self.profile,
                                   srv.replica.plan, batch, max(ctx, 1))
        if self.telemetry is not None:
            gid = srv.replica.group_id
            self.telemetry.gauge("decode_batch", t, batch,
                                 track=f"decode:{gid}")
            if srv.pool is not None:
                self.telemetry.gauge("free_pages", t, srv.pool.free_pages,
                                     track=f"decode:{gid}")
        self.push(t + self.chunk_tokens * step, "round_done",
                  (self.epoch, srv.replica.group_id))

    # -- placement swap ---------------------------------------------------
    def swap(self, t: float, new_placement: Placement) -> bool:
        """Apply ``new_placement`` mid-trace. Returns False (and keeps
        the current placement) if the new one has no usable replicas."""
        if not (any(r.plan is not None
                    for r in new_placement.prefill_replicas())
                and any(r.plan is not None
                        for r in new_placement.decode_replicas())):
            return False
        old_prefill = self.prefill
        old_decode = self.decode
        # §9: the swap moves prefill groups onto different devices — the
        # cached prefix KV stays behind and every radix tree dies with
        # its server (fresh caches are built by _install)
        invalidated = sum(srv.cache.num_tokens
                          for srv in old_prefill.values()
                          if srv.cache is not None)
        self._pins.clear()

        # gather in-system work before tearing the tables down
        restart: List[Request] = []
        for srv in old_prefill.values():
            restart.extend(srv.queue)
            if srv.current is not None:
                restart.append(srv.current)   # mid-prefill: start over
        migrate: List[Tuple[Request, int, ReplicaPlacement]] = []
        for srv in old_decode.values():
            for req, rem in srv.active:
                migrate.append((req, rem, srv.replica))
                if srv.pool is not None:
                    # §11: the old pool dissolves with its replica —
                    # stamp the pages this residency held; the new
                    # server re-admits (and re-allocates) from pending
                    req.kv_pages_allocated += len(
                        srv.held.pop(req.rid, []))
            for req, rem in srv.pending:
                migrate.append((req, rem, srv.replica))

        self._install(new_placement)
        self.epoch += 1   # invalidate in-flight prefill_done / round_done
        self._record_epoch_reps()
        self.migrate_link = {}

        # KV drain: each decode-resident request re-ships its cache at
        # the cost model's transfer time — codec-compressed bytes when a
        # §10 codec is active — serialized per (old, new) route
        # (mid-flight transfers that land later share the same ledger)
        drain_end = t
        for req, rem, old_rep in migrate:
            did = self.any_decode()
            dst = self.decode[did]
            ctx = req.s_in + (req.s_out - rem)
            tt = kv_transfer_time(self.cluster, self.profile, old_rep.plan,
                                  dst.replica.plan, 1, max(ctx, 1),
                                  compression_ratio=self.kv_ratio)
            self._stamp_kv(req, max(ctx, 1), tt, 0.0)
            key = (old_rep.group_id, did)
            begin = max(t, self.migrate_link.get(key, t))
            self.migrate_link[key] = begin + tt
            dst.pending.append((req, rem))
            req.decode_group = did
            dst.blocked_until = max(dst.blocked_until, begin + tt)
            drain_end = max(drain_end, begin + tt)

        # queued / mid-prefill requests restart on the new prefill tables
        for req in sorted(restart, key=lambda r: r.arrival):
            gid = self.pick_prefill(req)
            self.dispatched[gid] += 1
            req.restart()
            req.prefill_group = gid
            self.prefill[gid].queue.append(req)
        for srv in self.prefill.values():
            self.start_prefill(t, srv)
        for srv in self.decode.values():
            self.start_round(t, srv)

        self.reschedules.append(RescheduleEvent(
            time=t, drain_s=drain_end - t, migrated=len(migrate),
            restarted=len(restart), max_flow=new_placement.max_flow,
            prefix_tokens_invalidated=invalidated))
        return True

    # -- event handlers ---------------------------------------------------
    def on_arrival(self, t: float, req: Request) -> None:
        gid = self.pick_prefill(req)
        self.dispatched[gid] += 1
        req.prefill_group = gid
        if self.calibration is not None:
            self.calibration.stamp(req, gid)
        self.prefill[gid].queue.append(req)
        self.start_prefill(t, self.prefill[gid])

    def _stamp_kv(self, req: Request, ctx: int, serialized: float,
                  overlap: float) -> None:
        """Stamp one KV shipment's cost accounting on the lifecycle
        record — the same ``kv_compression`` math the runtime stamps,
        which is what makes the §10 metrics comparable across domains."""
        req.kv_bytes_raw += kv_compression.profile_raw_bytes(
            self.profile, ctx)
        req.kv_bytes_wire += kv_compression.profile_wire_bytes(
            self.profile, ctx, self.codec)
        req.kv_serialized_s += serialized
        req.kv_overlap_s += overlap

    def on_prefill_done(self, t: float, epoch: int, gid: int,
                        req: Request) -> None:
        if epoch != self.epoch:
            return   # stale: the request was requeued at swap time
        srv = self.prefill[gid]
        srv.current = None
        # §9: record this prompt's KV in the replica's radix state
        # (budget-evicting LRU leaves) BEFORE releasing the pinned
        # provider path — the insert extends that very path, so it must
        # stay ineligible for eviction until the extension lands
        if srv.cache is not None and req.tokens is not None:
            srv.cache.insert(req.tokens)
        pin = self._pins.pop(req.rid, None)
        if pin is not None:
            pin[0].unlock(pin[1])
        if req.s_out <= 1:
            # single-token request: prefill itself produced the only
            # token — PREFILLING → DONE, no KV ever ships (§8), exactly
            # like the runtime session
            srv.busy = False
            self.decode_tokens += req.s_out
            req.advance(RequestState.DONE, t)
            if self.calibration is not None:
                self.calibration.observe(req, t)
            if self.on_done is not None:
                self.on_done(t, req)
            self.start_prefill(t, srv)
            return
        req.advance(RequestState.KV_TRANSFER, t)
        did = self.pick_decode(gid)
        self.routed[(gid, did)] = self.routed.get((gid, did), 0.0) + 1
        req.decode_group = did
        key = (gid, did)
        serial = kv_transfer_time(self.cluster, self.profile,
                                  srv.replica.plan,
                                  self.decode[did].replica.plan, 1, req.s_in,
                                  compression_ratio=self.kv_ratio)
        if not self.kv_pipeline:
            # legacy abstraction: the handoff detaches from the prefill
            # server immediately; only the route ledger serializes it
            srv.busy = False
            begin = max(t, self.link_free.get(key, t))
            self.link_free[key] = begin + serial
            self._stamp_kv(req, req.s_in, serial, 0.0)
            self.push(begin + serial, "transfer_done", (self.epoch, req))
            self.start_prefill(t, srv)
            return
        # §10 staged/blocking handoff: the prefill replica holds the KV
        # until its stream drains. A chunked codec began streaming
        # rate-matched layer groups DURING prefill, so on an idle route
        # only the last chunk (serial/chunks + link latency) is exposed
        # past t; the blocking single-shot codec exposes all of it.
        # Rate-matching bounds what prefill compute can hide: the first
        # chunk exists only once its layer group finished computing, so
        # the stream can start no earlier than 1/chunks into this
        # request's own prefill — on links slower than compute the full
        # serialized load past that point stays exposed.
        exposed = serial if self.kv_chunks <= 1 else kv_transfer_time(
            self.cluster, self.profile, srv.replica.plan,
            self.decode[did].replica.plan, 1, req.s_in,
            compression_ratio=self.kv_ratio, chunks=self.kv_chunks)
        stream_earliest = t - (serial - exposed)
        if req.prefill_start is not None and self.kv_chunks > 1:
            first_chunk_ready = (req.prefill_start
                                 + (t - req.prefill_start) / self.kv_chunks)
            stream_earliest = max(stream_earliest, first_chunk_ready)
        start = max(stream_earliest, self.link_free.get(key, 0.0))
        done = start + serial
        self.link_free[key] = done
        # overlap realized = stream time hidden before prefill end;
        # clamp float residue so unchunked handoffs report exactly 0
        overlap = serial - (done - t)
        self._stamp_kv(req, req.s_in, serial,
                       overlap if overlap > 1e-9 * serial else 0.0)
        self.push(done, "transfer_done", (self.epoch, req))
        # srv.busy stays True: the staging slot frees when the stream ends
        self.push(done, "handoff_free", (self.epoch, gid))

    def on_transfer_done(self, t: float, epoch: int, req: Request) -> None:
        if epoch != self.epoch or req.decode_group not in self.decode:
            # the target replica dissolved mid-flight: the cache landed on
            # the old group's devices, so re-ship it old-plan → new-plan
            # (serialized per route, like the drain migrations) before it
            # can be admitted
            old_rep = self.decode_reps_by_epoch.get(
                epoch, {}).get(req.decode_group)
            did = self.any_decode()
            dst = self.decode[did]
            if old_rep is not None and old_rep.plan is not None:
                tt = kv_transfer_time(self.cluster, self.profile,
                                      old_rep.plan, dst.replica.plan,
                                      1, req.s_in,
                                      compression_ratio=self.kv_ratio)
                key = (old_rep.group_id, did)
                begin = max(t, self.migrate_link.get(key, t))
                self.migrate_link[key] = begin + tt
                req.decode_group = did
                self._stamp_kv(req, req.s_in, tt, 0.0)
                self.push(begin + tt, "transfer_done", (self.epoch, req))
                return
            req.decode_group = did
        # DECODING = KV resident on the decode replica (it may still
        # wait in ``pending`` for a continuous-batch slot). A §11
        # recompute arrives here with its redone tokens already charged
        # to the prefill (and re-emitted there, like the runtime's
        # recompute), so only the REMAINDER decodes — re-decoding the
        # redo tokens would inflate decode_tokens and makespan vs the
        # runtime on the same trace.
        req.advance(RequestState.DECODING, t)
        srv = self.decode[req.decode_group]
        srv.pending.append((req, req.s_out
                            - self.recompute_tokens.get(req.rid, 0)))
        self.start_round(t, srv)

    def on_round_done(self, t: float, epoch: int, gid: int) -> None:
        if epoch != self.epoch:
            return   # abandoned round: its requests migrated at swap time
        srv = self.decode[gid]
        srv.in_round = False
        still = []
        for req, rem in srv.active:
            produced = min(self.chunk_tokens, rem)
            self.decode_tokens += produced
            rem -= produced
            if rem <= 0:
                if srv.pool is not None:
                    # §11 reclamation: pages return to the pool at
                    # finish; the lifecycle stamps the allocator count
                    pages = srv.held.pop(req.rid)
                    srv.pool.release(pages)
                    req.kv_pages_allocated += len(pages)
                req.advance(RequestState.DONE, t)
                if self.calibration is not None:
                    self.calibration.observe(req, t)
                if self.on_done is not None:
                    self.on_done(t, req)
            else:
                still.append((req, rem))
        srv.active = still
        self.start_round(t, srv)

    # -- main loop --------------------------------------------------------
    def run(self, requests: List[Request],
            on_arrival_hook: Optional[Callable[[float, Request], None]] = None
            ) -> None:
        for req in requests:
            self.push(req.arrival, "arrival", req)
        while self.events:
            t, _, kind, payload = heapq.heappop(self.events)
            self.makespan = max(self.makespan, t)
            if kind == "arrival":
                if on_arrival_hook is not None:
                    on_arrival_hook(t, payload)
                self.on_arrival(t, payload)
            elif kind == "prefill_done":
                epoch, gid, req = payload
                self.on_prefill_done(t, epoch, gid, req)
            elif kind == "transfer_done":
                epoch, req = payload
                self.on_transfer_done(t, epoch, req)
            elif kind == "round_done":
                epoch, gid = payload
                self.on_round_done(t, epoch, gid)
            elif kind == "kick":
                epoch, gid = payload
                if epoch == self.epoch and gid in self.decode:
                    self.start_round(t, self.decode[gid])
            elif kind == "handoff_free":
                # §10 staged handoff: the prefill replica's KV stream
                # drained — release the staging slot, resume prefilling
                epoch, gid = payload
                if epoch == self.epoch and gid in self.prefill:
                    self.prefill[gid].busy = False
                    self.start_prefill(t, self.prefill[gid])


def simulate(cluster: ClusterSpec, profile: ModelProfile,
             placement: Placement, requests: List[Request],
             chunk_tokens: int = 16,
             typical_context: int = 1024,
             prefix_caching: bool = False,
             cache_alpha: float = 2.0,
             prefix_budget_fraction: float = 0.5,
             kv_codec=None, paged_kv: bool = False,
             page_size: int = PAGE_SIZE,
             kv_cache_dtype: Optional[str] = None,
             telemetry=None, calibration=None) -> SimResult:
    """Deterministic: dispatch is load-corrected flow-proportional, so
    the same placement and trace always produce the same result.

    ``prefix_caching`` turns on per-prefill-replica radix caches and
    cache-aware dispatch (DESIGN.md §9); requests without token content
    are served cold either way.

    ``kv_codec`` (DESIGN.md §10) activates the staged/blocking KV
    handoff model under the named wire format ("none", "int8",
    "int8-chunked" or a ``KVCodec``): the prefill replica holds each
    request's KV until its stream drains, compressed edges drain
    faster, and chunked codecs expose only the last layer-group chunk
    past prefill end. ``None`` keeps the legacy detached-handoff
    abstraction (modulo the §8 alignment: single-token requests finish
    at prefill and ship no KV on every path).

    ``paged_kv`` (DESIGN.md §11) replaces each decode replica's dense
    max-batch admission with the paged model: a ref-counted page pool
    sized by the cost model's ``decode_page_budget``, FIFO admission
    while pages fit, per-round growth, reclamation at finish, and
    youngest-first recompute preemption on exhaustion — the same
    allocator arithmetic the runtime engine runs, so page counts agree
    exactly on the same trace. ``kv_cache_dtype="int8"`` (DESIGN.md
    §16) sizes each pool at the quantized-resident page bytes (payload
    + scale sidecar) — roughly double the pages, matching a runtime
    fleet running ``paged_dtype="int8"``.

    ``calibration`` (DESIGN.md §15) wires a ``CalibrationStore``:
    predicted stage costs are stamped at each prefill routing decision
    and observed-vs-predicted errors scored at every DONE edge."""
    sim = _DisaggSim(cluster, profile, placement, chunk_tokens,
                     typical_context, prefix_caching=prefix_caching,
                     cache_alpha=cache_alpha,
                     prefix_budget_fraction=prefix_budget_fraction,
                     kv_codec=kv_codec, paged_kv=paged_kv,
                     page_size=page_size, kv_cache_dtype=kv_cache_dtype,
                     telemetry=telemetry, calibration=calibration)
    if not sim.feasible:
        return SimResult(requests, float("inf"), 0,
                         kv_cache_dtype=sim.kv_cache_dtype)
    sim.run(requests)
    return SimResult(requests, sim.makespan, sim.decode_tokens,
                     kv_cache_dtype=sim.kv_cache_dtype)


def simulate_online(cluster: ClusterSpec, profile: ModelProfile,
                    placement: Placement, requests: List[Request],
                    monitor=None,
                    rescheduler: Optional[Callable] = None,
                    min_gap_s: float = 0.0,
                    max_reschedules: int = 4,
                    chunk_tokens: int = 16,
                    typical_context: int = 1024,
                    prefix_caching: bool = False,
                    cache_alpha: float = 2.0,
                    prefix_budget_fraction: float = 0.5,
                    kv_codec=None, paged_kv: bool = False,
                    page_size: int = PAGE_SIZE,
                    kv_cache_dtype: Optional[str] = None,
                    telemetry=None, calibration=None) -> OnlineSimResult:
    """Simulate with online workload-drift rescheduling.

    ``monitor`` is a ``repro.core.scheduler.WorkloadMonitor`` (or any
    object with observe/drifted/snapshot/rebase); ``rescheduler`` maps a
    drifted ``Workload`` to a new ``Placement`` (typically a closure
    over ``repro.core.scheduler.reschedule``). At most
    ``max_reschedules`` swaps, spaced ``min_gap_s`` apart, are applied;
    each pays the KV-drain cost described in the module docstring.

    What the monitor sees depends on its estimator (DESIGN.md §13): the
    legacy ``estimator="oracle"`` observes each request's true output
    length at arrival (the detection-lag-free upper bound), while
    ``estimator="ewma"`` observes only the prompt at arrival and learns
    output lengths from the simulator's DONE edges — realized
    completions, with the same detection lag a production monitor
    pays."""
    sim = _DisaggSim(cluster, profile, placement, chunk_tokens,
                     typical_context, prefix_caching=prefix_caching,
                     cache_alpha=cache_alpha,
                     prefix_budget_fraction=prefix_budget_fraction,
                     kv_codec=kv_codec, paged_kv=paged_kv,
                     page_size=page_size, kv_cache_dtype=kv_cache_dtype,
                     telemetry=telemetry, calibration=calibration)
    if not sim.feasible:
        return OnlineSimResult(requests, float("inf"), 0, [],
                               kv_cache_dtype=sim.kv_cache_dtype)
    state = {"last": -float("inf")}
    if monitor is not None and hasattr(monitor, "observe_completion"):
        sim.on_done = lambda t, req: monitor.observe_completion(req)

    def hook(t: float, req: Request) -> None:
        if monitor is None or rescheduler is None:
            return
        monitor.observe(req)   # lifecycle-typed observation (DESIGN.md §8)
        if (len(sim.reschedules) >= max_reschedules
                or t - state["last"] < min_gap_s
                or not monitor.drifted()):
            return
        new_wl = monitor.snapshot()
        new_placement = rescheduler(new_wl)
        state["last"] = t
        if new_placement is not None and sim.swap(t, new_placement):
            monitor.rebase(new_wl)

    sim.run(requests, on_arrival_hook=hook)
    return OnlineSimResult(requests, sim.makespan, sim.decode_tokens,
                           sim.reschedules,
                           kv_cache_dtype=sim.kv_cache_dtype)


def slo_baselines(cluster: ClusterSpec, profile: ModelProfile,
                  placement: Placement,
                  requests: List[Request]) -> Dict[int, float]:
    """Per-request SLO base: unloaded best-replica latency (the paper's
    'single device execution latency' scaled by SLO-scale)."""
    best_p = min((r.plan for r in placement.prefill_replicas()
                  if r.plan is not None),
                 key=lambda pl: prefill_latency(cluster, profile, pl, 1, 512))
    best_d = min((r.plan for r in placement.decode_replicas()
                  if r.plan is not None),
                 key=lambda pl: decode_step_latency(cluster, profile, pl,
                                                    1, 1024))
    out = {}
    for req in requests:
        p = prefill_latency(cluster, profile, best_p, 1, req.s_in)
        d = decode_step_latency(cluster, profile, best_d, 1,
                                req.s_in + req.s_out // 2) * req.s_out
        out[req.rid] = p + d
    return out


# ---------------------------------------------------------------------------
# Colocated (HexGen-style, non-disaggregated) simulator — the baseline.
# Prefill and decode share each replica; a prefill job serializes against
# decode rounds and both pay the interference penalty (paper Fig. 1).
# ---------------------------------------------------------------------------


def simulate_colocated(cluster: ClusterSpec, profile: ModelProfile,
                       replicas: List[ReplicaPlacement],
                       requests: List[Request],
                       interference: float = 1.35,
                       chunk_tokens: int = 16,
                       typical_context: int = 1024) -> SimResult:
    class _Srv:
        def __init__(self, rep: ReplicaPlacement):
            self.rep = rep
            self.prefill_q: List[Request] = []
            self.active: List[Tuple[Request, int]] = []
            self.busy = False
            self.max_batch = max(1, max_decode_batch(
                cluster, profile, rep.plan, typical_context))

    servers = [_Srv(r) for r in replicas if r.plan is not None]
    if not servers:
        return SimResult(requests, float("inf"), 0)
    events: List[Tuple[float, int, str, object]] = []
    seq = 0

    def push(t, kind, payload):
        nonlocal seq
        heapq.heappush(events, (t, seq, kind, payload))
        seq += 1

    for req in requests:
        push(req.arrival, "arrival", req)

    rr = 0
    decode_tokens = 0
    makespan = 0.0

    def kick(t: float, si: int) -> None:
        srv = servers[si]
        if srv.busy:
            return
        # prefill first when a slot is free (continuous batching admits)
        if srv.prefill_q and len(srv.active) < srv.max_batch:
            req = srv.prefill_q.pop(0)
            req.advance(RequestState.PREFILLING, t)
            dur = prefill_latency(cluster, profile, srv.rep.plan, 1,
                                  req.s_in) * interference
            srv.busy = True
            push(t + dur, "prefill_done", (si, req))
            return
        if srv.active:
            batch = len(srv.active)
            ctx = int(np.mean([r.s_in + (r.s_out - rem)
                               for r, rem in srv.active]))
            step = decode_step_latency(cluster, profile, srv.rep.plan,
                                       batch, max(ctx, 1)) * interference
            srv.busy = True
            push(t + chunk_tokens * step, "round_done", si)

    while events:
        t, _, kind, payload = heapq.heappop(events)
        makespan = max(makespan, t)
        if kind == "arrival":
            req = payload
            si = rr % len(servers)
            rr += 1
            servers[si].prefill_q.append(req)
            req.prefill_group = servers[si].rep.group_id
            kick(t, si)
        elif kind == "prefill_done":
            si, req = payload
            srv = servers[si]
            srv.busy = False
            # colocated: KV stays in place — zero-cost handoff at t
            req.advance(RequestState.KV_TRANSFER, t)
            req.advance(RequestState.DECODING, t)
            req.decode_group = srv.rep.group_id
            srv.active.append((req, req.s_out))
            kick(t, si)
        elif kind == "round_done":
            si = payload
            srv = servers[si]
            srv.busy = False
            still = []
            for req, rem in srv.active:
                produced = min(chunk_tokens, rem)
                decode_tokens += produced
                rem -= produced
                if rem <= 0:
                    req.advance(RequestState.DONE, t)
                else:
                    still.append((req, rem))
            srv.active = still
            kick(t, si)
    return SimResult(requests, makespan, decode_tokens)


# ---------------------------------------------------------------------------
# Fleet tier (DESIGN.md §12): N replicas behind the shared Router
# ---------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class _SimSlot:
    life: Request
    prompt_len: int
    max_new: int
    on_token: Optional[Callable[[int, int, bool], None]]
    start: int                # token index of the next emission
    emitted: int = 0
    length: int = 0           # KV positions held (prompt + emitted - ...)
    #: freshly admitted this step (async-handoff engines skip one
    #: decode tick before their deferred first emission)
    fresh: bool = False


class SimReplica:
    """Scheduling-domain replica handle for the §12 ``Router``.

    Mirrors ``ServeSession``'s three-stage step pipeline EXACTLY in
    step structure — prefill micro-batch (bounded by free decode
    slots), handoff admission, one decode token per active slot per
    step — and mirrors the runtime's prefix-cache discipline on the
    same radix tree (payloads are the slab CAPACITY ints the runtime's
    real slabs report, so the hit-gating arithmetic is identical).
    Driving the same trace through ``Router`` over N of these or N
    ``CoordinatorReplica``s therefore produces the same admission/
    dispatch/failover decisions at the same step indices: the parity
    contract ``simulate_fleet`` vs the runtime router is tested under.

    Lifecycle timestamps come from the router's virtual ``StepClock``;
    emitted tokens are synthetic sequential indices (``start_index``
    onward) so stream conservation is testable across failover."""

    def __init__(self, num_slots: int = 4, max_prefill_batch: int = 4,
                 capacity: int = 128, prefix_caching: bool = True,
                 cache_bytes: Optional[float] = None,
                 clock: Optional[Callable[[], float]] = None,
                 defer_first_token: bool = False):
        self.alive = True
        #: async-handoff mode (DESIGN.md §14 ``decode_first``): prefill
        #: does NOT emit the first token; the decode stage emits it one
        #: step after KV admission, stamping ``decode_first_s`` — the
        #: engine shape the ``decode_first`` TTFT bucket attributes.
        self.defer_first_token = bool(defer_first_token)
        self.num_slots = int(num_slots)
        self.max_prefill_batch = max(1, int(max_prefill_batch))
        self.capacity = int(capacity)
        self.cache = (PrefixCache(cache_bytes) if prefix_caching else None)
        self._clock = clock or (lambda: 0.0)
        self._queue: List[int] = []
        self._handoff: List[int] = []
        self._active: List[_SimSlot] = []
        self._slots: Dict[int, _SimSlot] = {}
        self._no_cache: Dict[int, bool] = {}
        self._prompts: Dict[int, Optional[Tuple[int, ...]]] = {}

    # -- router protocol -------------------------------------------------
    @property
    def max_inflight(self) -> int:
        return self.num_slots + self.max_prefill_batch

    def now(self) -> float:
        return self._clock()

    def matched_len(self, tokens) -> int:
        if self.cache is None or tokens is None:
            return 0
        return self.cache.matched_len(tokens)

    def submit(self, life: Request, prompt, max_new: int, *,
               on_token=None, no_cache: bool = False,
               start_index: int = 0) -> None:
        assert life.phase is RequestState.QUEUED
        prompt = tuple(int(t) for t in prompt) if prompt is not None else None
        plen = len(prompt) if prompt is not None else life.s_in + start_index
        self._slots[life.rid] = _SimSlot(life, plen, max_new, on_token,
                                         start_index)
        self._prompts[life.rid] = prompt
        self._no_cache[life.rid] = no_cache
        self._queue.append(life.rid)

    def step(self) -> bool:
        a = self._step_prefill()
        b = self._step_handoff()
        c = self._step_decode()
        return a or b or c

    def cancel(self, rid: int) -> bool:
        s = self._slots.get(rid)
        if s is None or s.life.is_terminal:
            return False
        if rid in self._queue:
            self._queue.remove(rid)
        elif rid in self._handoff:
            self._handoff.remove(rid)
        elif s in self._active:
            self._active.remove(s)
        else:
            return False
        s.life.advance(RequestState.CANCELLED, self.now())
        return True

    def drain_in_flight(self) -> List[Request]:
        out = [s.life for s in self._slots.values()
               if not s.life.is_terminal]
        self._queue.clear()
        self._handoff.clear()
        self._active.clear()
        return out

    # -- pipeline stages (mirror ServeSession's) -------------------------
    def _emit(self, s: _SimSlot, finished: bool) -> None:
        tok = s.start + s.emitted        # synthetic, sequential
        s.emitted += 1
        if s.on_token is not None:
            s.on_token(s.life.rid, tok, finished)

    def _finish(self, s: _SimSlot) -> None:
        s.life.advance(RequestState.DONE, self.now())
        s.life.tokens_out = s.start + s.emitted

    def _step_prefill(self) -> bool:
        if not self._queue:
            return False
        take = min(self.max_prefill_batch, len(self._queue),
                   self.num_slots - len(self._handoff))
        if take <= 0:
            return False
        batch = [self._slots[self._queue.pop(0)] for _ in range(take)]
        t = self.now()
        for s in batch:
            s.life.advance(RequestState.PREFILLING, t)
        # match all BEFORE any insert — exactly _route_and_prefill's
        # order, so in-batch prompts never hit each other's fresh slabs
        for s in batch:
            cached = 0
            prompt = self._prompts[s.life.rid]
            if (self.cache is not None and prompt is not None
                    and not self._no_cache[s.life.rid]):
                m = self.cache.match(prompt)
                if m.payload is not None:
                    cached = min(m.length, len(prompt) - 1)
                    if cached < 1 or m.payload < len(prompt):
                        cached = 0     # slab can't seat the full prompt
            s.life.cached_len = cached
        for s in batch:
            prompt = self._prompts[s.life.rid]
            if (self.cache is not None and prompt is not None
                    and not self._no_cache[s.life.rid]):
                # payload = slab capacity (what the runtime's real slab
                # reports via kv_transfer.slab_capacity)
                self.cache.insert(prompt, payload=self.capacity)
        for s in batch:
            if s.max_new <= 1:
                # single-token request: no handoff exists to defer past,
                # so even async-handoff engines emit at prefill
                self._emit(s, finished=True)
                self._finish(s)
                continue
            if not self.defer_first_token:
                self._emit(s, finished=False)
            s.life.advance(RequestState.KV_TRANSFER, t)
            self._handoff.append(s.life.rid)
        return True

    def _step_handoff(self) -> bool:
        progressed = False
        while self._handoff and len(self._active) < self.num_slots:
            s = self._slots[self._handoff.pop(0)]
            s.length = s.prompt_len + 1
            s.life.decode_group = 0
            s.life.advance(RequestState.DECODING, self.now())
            s.fresh = True
            self._active.append(s)
            progressed = True
        return progressed

    def _step_decode(self) -> bool:
        progressed = False
        for s in list(self._active):
            if self.defer_first_token and s.fresh:
                # async handoff: KV finished installing this step; the
                # deferred first emission happens on the NEXT tick
                s.fresh = False
                progressed = True
                continue
            if self.defer_first_token and s.emitted == 0:
                # the deferred first token: attribute the lag past the
                # handoff to the §14 ``decode_first`` TTFT bucket
                s.life.decode_first_s = self.now() - (s.life.transfer_end
                                                      or self.now())
            s.length += 1
            finished = (s.emitted + 1 >= s.max_new
                        or s.length >= self.capacity)
            self._emit(s, finished)
            if finished:
                self._active.remove(s)
                self._finish(s)
            progressed = True
        return progressed


@dataclasses.dataclass
class FleetResult(SimResult):
    """``simulate_fleet`` result: the shared schema plus the router's
    §12 conservation counters and dispatch log (for the property
    tests' ordering/aging assertions)."""
    counters: Dict[str, int] = dataclasses.field(default_factory=dict)
    dispatch_log: List[Dict[str, int]] = dataclasses.field(
        default_factory=list)
    #: §13 elastic runs: the controller's full (step, kind, replica)
    #: event stream — the parity benchmark asserts it matches the
    #: runtime's exactly on the same seeded trace
    scale_events: List[Tuple[int, str, int]] = dataclasses.field(
        default_factory=list)


def simulate_fleet(requests: List[Request], num_replicas: int = 2,
                   slots_per_replica: int = 4, max_prefill_batch: int = 4,
                   capacity: int = 128, dt: float = 0.05,
                   queue_capacity: int = 64, age_every=8,
                   policy: str = "slo", prefix_caching: bool = True,
                   cache_alpha: float = 2.0,
                   route_weights=None,
                   failures: Optional[Dict[int, int]] = None,
                   cancels: Optional[Dict[int, List[int]]] = None,
                   autoscale=None, monitor=None, resolver=None,
                   telemetry=None, calibration=None,
                   defer_first_token: bool = False) -> FleetResult:
    """Scheduling-domain fleet serve (DESIGN.md §12): the SAME
    ``Router`` the runtime uses, over ``SimReplica`` handles on a
    virtual step clock. ``failures`` maps router step -> replica index
    to kill; ``cancels`` maps router step -> rids to cancel.

    ``autoscale`` (DESIGN.md §13) is a ``fleet.FleetSpec``: the run is
    driven through a ``FleetController`` instead of the bare router —
    ``num_replicas`` becomes the warm seed fleet and the controller
    provisions/warms/drains ``SimReplica``s to track demand. Scale
    events and per-state replica-steps land on the result; an optional
    ``monitor`` (WorkloadMonitor) feeds the demand signal and a
    ``resolver`` re-solves max-flow on joins/leaves. Static runs fill
    ``replica_steps_by_state`` too (alive replicas per step), so
    replica-step cost is comparable across policies.

    ``calibration`` (DESIGN.md §15) wires a ``CalibrationStore``
    through the router: predicted costs stamped at dispatch, errors
    scored at the terminal sweep. ``defer_first_token`` builds
    async-handoff ``SimReplica``s (first emission one step past KV
    admission), populating the ``decode_first`` TTFT bucket."""
    from repro.serving.router import Router, StepClock
    clock = StepClock()

    def make_replica(_slot: int) -> SimReplica:
        return SimReplica(num_slots=slots_per_replica,
                          max_prefill_batch=max_prefill_batch,
                          capacity=capacity, prefix_caching=prefix_caching,
                          clock=clock, defer_first_token=defer_first_token)

    reps = [make_replica(i) for i in range(num_replicas)]
    router = Router(reps, queue_capacity=queue_capacity,
                    age_every=age_every, policy=policy,
                    cache_alpha=cache_alpha, route_weights=route_weights,
                    clock=clock, telemetry=telemetry,
                    calibration=calibration)
    if autoscale is not None:
        from repro.serving.fleet import FleetController
        ctrl = FleetController(router, make_replica, autoscale, dt=dt,
                               monitor=monitor, resolver=resolver)
        em = ctrl.run_trace(requests, failures=failures, cancels=cancels)
        return FleetResult(em.requests, em.makespan, em.decode_tokens,
                           counters=dict(router.counters),
                           dispatch_log=list(router.dispatch_log),
                           scale_events=[(e.step, e.kind, e.replica)
                                         for e in ctrl.events],
                           scale_up_events=em.scale_up_events,
                           scale_down_events=em.scale_down_events,
                           replica_steps_by_state=dict(
                               em.replica_steps_by_state))
    live_steps = {"live": 0}

    def _tick(_step: int) -> None:
        live_steps["live"] += sum(1 for r in router.replicas if r.alive)

    m = router.run_trace(requests, dt=dt, failures=failures,
                         cancels=cancels, on_step=_tick)
    return FleetResult(m.requests, m.makespan, m.decode_tokens,
                       counters=dict(router.counters),
                       dispatch_log=list(router.dispatch_log),
                       replica_steps_by_state=dict(live_steps))
