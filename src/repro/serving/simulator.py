"""Event-driven cluster simulator for disaggregated serving.

Executes a scheduler ``Placement`` against a request trace using the
Table-1 cost model for service times — this is the scheduling-domain
evaluation harness that reproduces the paper's throughput/latency/SLO
numbers (Figures 6–9) without renting heterogeneous GPUs.

Faithful mechanics:
  * prefill replicas serve one request at a time (compute-bound; paper
    Appendix A), FIFO;
  * dispatch follows the max-flow assignment — requests are routed to
    prefill replicas (and their KV targets) proportionally to flow,
    load-corrected;
  * KV transfers serialize per (prefill, decode) route at the cost
    model's transfer time;
  * decode replicas run continuous batching in rounds of
    ``chunk_tokens`` steps at the cost model's step latency for the
    current batch size and mean context.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.cluster import ClusterSpec
from repro.core.cost_model import (ModelProfile, decode_step_latency,
                                   kv_transfer_time, max_decode_batch,
                                   prefill_latency)
from repro.core.placement import Placement, ReplicaPlacement
from repro.serving.request import Phase, Request


@dataclasses.dataclass
class SimResult:
    requests: List[Request]
    makespan: float
    decode_tokens: int

    @property
    def decode_throughput(self) -> float:
        """tokens/s — the paper's offline metric."""
        return self.decode_tokens / self.makespan if self.makespan > 0 else 0.0

    @property
    def avg_latency(self) -> float:
        lats = [r.latency for r in self.requests if r.latency is not None]
        return float(np.mean(lats)) if lats else float("inf")

    @property
    def p99_latency(self) -> float:
        lats = [r.latency for r in self.requests if r.latency is not None]
        return float(np.percentile(lats, 99)) if lats else float("inf")

    def slo_attainment(self, slo_per_request: Dict[int, float],
                       scale: float) -> float:
        ok = sum(1 for r in self.requests
                 if r.latency is not None
                 and r.latency <= scale * slo_per_request[r.rid])
        return ok / max(len(self.requests), 1)


class _PrefillServer:
    def __init__(self, replica: ReplicaPlacement):
        self.replica = replica
        self.queue: List[Request] = []
        self.busy = False


class _DecodeServer:
    def __init__(self, replica: ReplicaPlacement, max_batch: int):
        self.replica = replica
        self.max_batch = max(1, max_batch)
        self.active: List[Tuple[Request, int]] = []   # (req, remaining)
        self.pending: List[Request] = []
        self.in_round = False


def simulate(cluster: ClusterSpec, profile: ModelProfile,
             placement: Placement, requests: List[Request],
             chunk_tokens: int = 16, seed: int = 0,
             typical_context: int = 1024) -> SimResult:
    rng = np.random.default_rng(seed)
    prefill = {r.group_id: _PrefillServer(r)
               for r in placement.prefill_replicas() if r.plan is not None}
    decode = {}
    for r in placement.decode_replicas():
        if r.plan is None:
            continue
        mb = max_decode_batch(cluster, profile, r.plan, typical_context)
        decode[r.group_id] = _DecodeServer(r, mb)
    if not prefill or not decode:
        return SimResult(requests, float("inf"), 0)

    # flow-proportional dispatch tables
    pref_weight = {gid: 0.0 for gid in prefill}
    route_weight: Dict[int, List[Tuple[int, float]]] = {g: [] for g in prefill}
    for (p, d), f in placement.kv_routes.items():
        if p in prefill and d in decode:
            pref_weight[p] += f
            route_weight[p].append((d, f))
    # fall back to capacity weights if flow is degenerate
    if sum(pref_weight.values()) <= 0:
        for gid, srv in prefill.items():
            pref_weight[gid] = max(srv.replica.capacity, 1e-9)
            route_weight[gid] = [(d, decode[d].replica.capacity)
                                 for d in decode]
    for gid in prefill:
        if not route_weight[gid]:
            route_weight[gid] = [(d, decode[d].replica.capacity)
                                 for d in decode]

    dispatched = {gid: 0.0 for gid in prefill}
    routed: Dict[Tuple[int, int], float] = {}
    link_free: Dict[Tuple[int, int], float] = {}

    events: List[Tuple[float, int, str, object]] = []
    seq = 0

    def push(t: float, kind: str, payload) -> None:
        nonlocal seq
        heapq.heappush(events, (t, seq, kind, payload))
        seq += 1

    for req in requests:
        push(req.arrival, "arrival", req)

    def pick_prefill() -> int:
        # least normalized load among flow-weighted replicas
        return min(prefill,
                   key=lambda g: (dispatched[g] + 1) / max(pref_weight[g], 1e-9))

    def pick_decode(p: int) -> int:
        opts = route_weight[p]
        return min(opts, key=lambda df: (routed.get((p, df[0]), 0.0) + 1)
                   / max(df[1], 1e-9))[0]

    def start_prefill(t: float, srv: _PrefillServer) -> None:
        if srv.busy or not srv.queue:
            return
        req = srv.queue.pop(0)
        srv.busy = True
        req.phase = Phase.PREFILLING
        req.prefill_start = t
        lat = prefill_latency(cluster, profile, srv.replica.plan, 1, req.s_in)
        push(t + lat, "prefill_done", (srv.replica.group_id, req))

    def start_round(t: float, srv: _DecodeServer) -> None:
        if srv.in_round:
            return
        free = srv.max_batch - len(srv.active)
        if free > 0 and srv.pending:
            for req in srv.pending[:free]:
                srv.active.append((req, req.s_out))
                req.phase = Phase.DECODING
            srv.pending = srv.pending[free:]
        if not srv.active:
            return
        srv.in_round = True
        batch = len(srv.active)
        ctx = int(np.mean([r.s_in + (r.s_out - rem) for r, rem in srv.active]))
        step = decode_step_latency(cluster, profile, srv.replica.plan,
                                   batch, max(ctx, 1))
        push(t + chunk_tokens * step, "round_done",
             srv.replica.group_id)

    decode_tokens = 0
    makespan = 0.0
    while events:
        t, _, kind, payload = heapq.heappop(events)
        makespan = max(makespan, t)
        if kind == "arrival":
            req = payload
            gid = pick_prefill()
            dispatched[gid] += 1
            req.prefill_group = gid
            prefill[gid].queue.append(req)
            start_prefill(t, prefill[gid])
        elif kind == "prefill_done":
            gid, req = payload
            srv = prefill[gid]
            srv.busy = False
            req.prefill_end = t
            req.phase = Phase.KV_TRANSFER
            did = pick_decode(gid)
            routed[(gid, did)] = routed.get((gid, did), 0.0) + 1
            req.decode_group = did
            tt = kv_transfer_time(cluster, profile, srv.replica.plan,
                                  decode[did].replica.plan, 1, req.s_in)
            begin = max(t, link_free.get((gid, did), t))
            link_free[(gid, did)] = begin + tt
            push(begin + tt, "transfer_done", req)
            start_prefill(t, srv)
        elif kind == "transfer_done":
            req = payload
            req.transfer_end = t
            srv = decode[req.decode_group]
            srv.pending.append(req)
            start_round(t, srv)
        elif kind == "round_done":
            gid = payload
            srv = decode[gid]
            srv.in_round = False
            still = []
            for req, rem in srv.active:
                produced = min(chunk_tokens, rem)
                decode_tokens += produced
                rem -= produced
                if rem <= 0:
                    req.decode_end = t
                    req.phase = Phase.DONE
                else:
                    still.append((req, rem))
            srv.active = still
            start_round(t, srv)
    return SimResult(requests, makespan, decode_tokens)


def slo_baselines(cluster: ClusterSpec, profile: ModelProfile,
                  placement: Placement,
                  requests: List[Request]) -> Dict[int, float]:
    """Per-request SLO base: unloaded best-replica latency (the paper's
    'single device execution latency' scaled by SLO-scale)."""
    best_p = min((r.plan for r in placement.prefill_replicas()
                  if r.plan is not None),
                 key=lambda pl: prefill_latency(cluster, profile, pl, 1, 512))
    best_d = min((r.plan for r in placement.decode_replicas()
                  if r.plan is not None),
                 key=lambda pl: decode_step_latency(cluster, profile, pl,
                                                    1, 1024))
    out = {}
    for req in requests:
        p = prefill_latency(cluster, profile, best_p, 1, req.s_in)
        d = decode_step_latency(cluster, profile, best_d, 1,
                                req.s_in + req.s_out // 2) * req.s_out
        out[req.rid] = p + d
    return out


# ---------------------------------------------------------------------------
# Colocated (HexGen-style, non-disaggregated) simulator — the baseline.
# Prefill and decode share each replica; a prefill job serializes against
# decode rounds and both pay the interference penalty (paper Fig. 1).
# ---------------------------------------------------------------------------


def simulate_colocated(cluster: ClusterSpec, profile: ModelProfile,
                       replicas: List[ReplicaPlacement],
                       requests: List[Request],
                       interference: float = 1.35,
                       chunk_tokens: int = 16,
                       typical_context: int = 1024) -> SimResult:
    class _Srv:
        def __init__(self, rep: ReplicaPlacement):
            self.rep = rep
            self.prefill_q: List[Request] = []
            self.active: List[Tuple[Request, int]] = []
            self.busy = False
            self.max_batch = max(1, max_decode_batch(
                cluster, profile, rep.plan, typical_context))

    servers = [_Srv(r) for r in replicas if r.plan is not None]
    if not servers:
        return SimResult(requests, float("inf"), 0)
    events: List[Tuple[float, int, str, object]] = []
    seq = 0

    def push(t, kind, payload):
        nonlocal seq
        heapq.heappush(events, (t, seq, kind, payload))
        seq += 1

    for req in requests:
        push(req.arrival, "arrival", req)

    rr = 0
    decode_tokens = 0
    makespan = 0.0

    def kick(t: float, si: int) -> None:
        srv = servers[si]
        if srv.busy:
            return
        # prefill first when a slot is free (continuous batching admits)
        if srv.prefill_q and len(srv.active) < srv.max_batch:
            req = srv.prefill_q.pop(0)
            req.prefill_start = t
            dur = prefill_latency(cluster, profile, srv.rep.plan, 1,
                                  req.s_in) * interference
            srv.busy = True
            push(t + dur, "prefill_done", (si, req))
            return
        if srv.active:
            batch = len(srv.active)
            ctx = int(np.mean([r.s_in + (r.s_out - rem)
                               for r, rem in srv.active]))
            step = decode_step_latency(cluster, profile, srv.rep.plan,
                                       batch, max(ctx, 1)) * interference
            srv.busy = True
            push(t + chunk_tokens * step, "round_done", si)

    while events:
        t, _, kind, payload = heapq.heappop(events)
        makespan = max(makespan, t)
        if kind == "arrival":
            req = payload
            si = rr % len(servers)
            rr += 1
            servers[si].prefill_q.append(req)
            req.prefill_group = servers[si].rep.group_id
            kick(t, si)
        elif kind == "prefill_done":
            si, req = payload
            srv = servers[si]
            srv.busy = False
            req.prefill_end = req.transfer_end = t
            req.decode_group = srv.rep.group_id
            srv.active.append((req, req.s_out))
            kick(t, si)
        elif kind == "round_done":
            si = payload
            srv = servers[si]
            srv.busy = False
            still = []
            for req, rem in srv.active:
                produced = min(chunk_tokens, rem)
                decode_tokens += produced
                rem -= produced
                if rem <= 0:
                    req.decode_end = t
                else:
                    still.append((req, rem))
            srv.active = still
            kick(t, si)
    return SimResult(requests, makespan, decode_tokens)
