"""Elastic fleet controller (DESIGN.md §13): capacity ARRIVING.

The §12 ``Router`` survives replicas dying; this subsystem is the other
half — replicas joining, warming up, and the fleet growing/shrinking to
track demand. One ``FleetController`` sits above the Router and owns
replica LIFECYCLE::

    PROVISIONING -> WARMING -> LIVE -> DRAINING -> DEAD

* PROVISIONING — a machine is being acquired (fixed step count).
* WARMING — the model's weights stage from disk/host storage onto the
  replica's devices: ``cost_model.weight_load_time`` prices it as
  bytes-of-params over the device type's host link
  (``GPUType.host_bandwidth``), quantized to router steps by
  ``cost_model.warmup_steps``. Heterogeneity is real here: an A6000
  pod warms ~4x slower than an H100 pod for the same model.
* LIVE — the replica joined the router (``Router.spawn``) and takes
  dispatches. For the first ``cold_window_steps`` it is cold (compile /
  empty caches): requests dispatched into the window get a
  ``warmup_penalty_s`` stamp — the TTFT cost of serving from a
  just-joined replica, surfaced as ``ServeMetrics.warmup_ttft_penalty_s``.
* DRAINING — graceful retirement via ``Router.drain``: no new work,
  in-flight completes, the router marks it dead.

Scale-to-demand reads the ``WorkloadMonitor`` demand signal — queue
depth against live dispatch capacity, per-class arrival rates, and
recent stated-SLO attainment — with THREE dampers so the fleet doesn't
flap: a signal must SUSTAIN for ``sustain_steps`` consecutive steps, any
two scale decisions are ``cooldown_steps`` apart, and no scale-up fires
within ``hysteresis_steps`` of a scale-down (the bound the property
tests pin).

Capacity drift re-solves max-flow (§7's workload-drift trigger extended):
when a replica joins or leaves, the optional ``resolver`` callback runs
— typically a closure over ``core.scheduler.reschedule_capacity``, which
seeds the joining devices as a new group, tries them as prefill AND as
decode, and lets refinement shift the whole φ→δ assignment. Whatever
per-replica weights the resolver returns feed straight back into
dispatch via ``Router.set_route_weights``.

Parity is by construction, exactly as in §12: every controller decision
is a pure function of router step indices and router/monitor state that
is itself step-deterministic. Driving the same seeded surge trace over
``SimReplica``s or real ``CoordinatorReplica``s yields EXACTLY the same
scale events, per-state replica-step totals, and counters.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Any, Callable, Dict, List, Optional, Sequence

from repro.serving.request import Request, RequestState


class ReplicaState(enum.Enum):
    PROVISIONING = "provisioning"
    WARMING = "warming"
    LIVE = "live"
    DRAINING = "draining"
    DEAD = "dead"


@dataclasses.dataclass(frozen=True)
class ScaleEvent:
    """One controller decision or lifecycle transition, step-stamped."""
    step: int
    kind: str        # scale_up | scale_down | live | dead | resolve
    replica: int     # fleet slot id (stable across the replica's life)
    reason: str = ""


@dataclasses.dataclass(frozen=True)
class FleetSpec:
    """Elastic policy knobs. All step counts are router steps on the
    shared clock — the same numbers mean the same thing in both
    domains. Price ``warmup_steps`` with ``cost_model.warmup_steps``
    (weight bytes over the device type's host link) rather than
    guessing."""
    min_replicas: int = 1
    max_replicas: int = 4
    #: machine-acquisition steps before weight staging starts
    provision_steps: int = 4
    #: WARMING steps (weight load priced by the cost model)
    warmup_steps: int = 8
    #: post-LIVE steps during which dispatches pay a cold-start stamp
    cold_window_steps: int = 0
    #: scale up when queue depth exceeds this multiple of live capacity
    queue_high: float = 1.0
    #: scale down when in-flight fits this fraction of the SHRUNK fleet
    queue_low: float = 0.25
    #: optional second up-trigger: recent stated-SLO attainment floor
    slo_floor: Optional[float] = None
    #: a pressure signal must hold this many consecutive steps
    sustain_steps: int = 4
    #: minimum steps between any two scale decisions
    cooldown_steps: int = 16
    #: no scale-up within this many steps of a scale-down (anti-flap)
    hysteresis_steps: int = 32
    #: optional §15 miscalibration trigger: once a warmed-up surface's
    #: |observed/predicted EWMA − 1| exceeds this bound for
    #: ``sustain_steps`` consecutive steps (damped exactly like
    #: ``slo_floor``), the controller emits ``recalibrate`` and runs
    #: the resolver — typically a calibrated ``reschedule`` closing the
    #: §15 loop. None disables the trigger.
    miscal_bound: Optional[float] = None
    #: minimum steps between calibrated re-solves
    recal_cooldown_steps: int = 64


@dataclasses.dataclass
class _ReplicaRecord:
    slot: int
    state: ReplicaState
    state_since: int
    router_idx: Optional[int] = None
    #: step the replica went LIVE via spawn; None for the seed fleet
    #: (already warm at step 0 — no cold window)
    live_step: Optional[int] = None


#: resolver(controller, event) -> optional per-replica route weights
Resolver = Callable[["FleetController", ScaleEvent],
                    Optional[Sequence[float]]]


class FleetController:
    """Drives replica lifecycle and scale-to-demand above a ``Router``.

    ``replica_factory(slot)`` builds a fresh replica handle when slot
    ``slot`` goes LIVE — a ``SimReplica`` closure in the scheduling
    domain, a ``CoordinatorReplica`` closure in the runtime. That
    factory is the ONLY domain-specific part; everything the controller
    decides is step arithmetic, so both domains agree exactly.

    The controller registers itself on the router: ``capacity_hook``
    (a kill while capacity is joining parks instead of raising
    ``FleetExhausted``), ``on_submit`` (feeds the monitor's demand
    signal), and ``on_dispatch`` (stamps cold-window penalties).
    """

    def __init__(self, router: Any,
                 replica_factory: Callable[[int], Any],
                 spec: FleetSpec = FleetSpec(), *,
                 dt: float = 0.05,
                 monitor: Optional[Any] = None,
                 resolver: Optional[Resolver] = None,
                 calibration: Optional[Any] = None):
        assert spec.min_replicas >= 1
        assert spec.max_replicas >= spec.min_replicas
        self.router = router
        self.factory = replica_factory
        self.spec = spec
        self.dt = float(dt)
        self.monitor = monitor
        self.resolver = resolver
        #: §15 calibration store the miscalibration trigger reads;
        #: falls back to one attached to the WorkloadMonitor, then to
        #: the router's own store
        self.calibration = calibration
        self.events: List[ScaleEvent] = []
        self.resolves = 0
        self.recalibrations = 0
        self.replica_steps_by_state: Dict[str, int] = {}
        self.records: List[_ReplicaRecord] = [
            _ReplicaRecord(slot=i, state=ReplicaState.LIVE, state_since=0,
                           router_idx=i)
            for i in range(len(router.replicas))]
        self._by_router_idx: Dict[int, _ReplicaRecord] = {
            r.router_idx: r for r in self.records}
        self._up_pressure = 0
        self._down_pressure = 0
        self._miscal_pressure = 0
        self._last_scale = -10 ** 9
        self._last_down = -10 ** 9
        self._last_recal = -10 ** 9
        self._completed: set = set()
        router.capacity_hook = self._capacity_pending
        router.on_dispatch = self._on_dispatch
        if monitor is not None:
            router.on_submit = self._on_submit

    # -- router hooks ---------------------------------------------------
    def _capacity_pending(self) -> bool:
        return any(r.state in (ReplicaState.PROVISIONING,
                               ReplicaState.WARMING)
                   for r in self.records)

    def _on_submit(self, life: Request, step: int) -> None:
        self.monitor.observe(life, step=step)

    def _on_dispatch(self, life: Request, idx: int, step: int) -> None:
        rec = self._by_router_idx.get(idx)
        if rec is None or rec.live_step is None:
            return
        cold_until = rec.live_step + self.spec.cold_window_steps
        if step < cold_until:
            # remaining cold steps, in shared-clock seconds: a pure
            # function of step indices — identical in both domains
            life.warmup_penalty_s += (cold_until - step) * self.dt
            if self.router.telemetry is not None:
                self.router.telemetry.emit(
                    "cold_window", step * self.dt,
                    track=f"replica:{rec.router_idx}", rid=life.rid,
                    penalty_s=(cold_until - step) * self.dt)

    # -- event helpers --------------------------------------------------
    def _emit(self, step: int, kind: str, slot: int,
              reason: str = "") -> None:
        self.events.append(ScaleEvent(step, kind, slot, reason))
        if self.router.telemetry is not None:
            self.router.telemetry.emit(kind, step * self.dt, slot=slot,
                                       reason=reason)

    def _resolve(self, step: int, event: ScaleEvent) -> None:
        """Capacity drift: re-solve max-flow over the changed fleet
        graph and feed the solved flow shares back into dispatch."""
        if self.resolver is None:
            return
        weights = self.resolver(self, event)
        self.resolves += 1
        self._emit(step, "resolve", event.replica, reason=event.kind)
        if weights is not None:
            self.router.set_route_weights(weights)

    # -- lifecycle ------------------------------------------------------
    def _advance(self, step: int) -> None:
        for rec in self.records:
            if (rec.state is ReplicaState.PROVISIONING
                    and step - rec.state_since >= self.spec.provision_steps):
                rec.state = ReplicaState.WARMING
                rec.state_since = step
            if (rec.state is ReplicaState.WARMING
                    and step - rec.state_since >= self.spec.warmup_steps):
                handle = self.factory(rec.slot)
                rec.router_idx = self.router.spawn(handle)
                self._by_router_idx[rec.router_idx] = rec
                rec.state = ReplicaState.LIVE
                rec.state_since = step
                rec.live_step = step
                self._emit(step, "live", rec.slot)
                self._resolve(step, self.events[-1])
            if (rec.state in (ReplicaState.LIVE, ReplicaState.DRAINING)
                    and rec.router_idx is not None
                    and not self.router.replicas[rec.router_idx].alive):
                # drain completed — or an external kill took it down
                rec.state = ReplicaState.DEAD
                rec.state_since = step
                self._emit(step, "dead", rec.slot)
                self._resolve(step, self.events[-1])

    # -- scale-to-demand policy -----------------------------------------
    def _live(self) -> List[_ReplicaRecord]:
        return [r for r in self.records if r.state is ReplicaState.LIVE]

    def _policy(self, step: int) -> None:
        spec = self.spec
        live = self._live()
        joining = sum(1 for r in self.records
                      if r.state in (ReplicaState.PROVISIONING,
                                     ReplicaState.WARMING))
        non_dead = sum(1 for r in self.records
                       if r.state is not ReplicaState.DEAD)
        cap = sum(self.router.replicas[r.router_idx].max_inflight
                  for r in live)
        q = len(self.router.queue)
        infl = sum(self.router._inflight[r.router_idx] for r in live)

        # fleet repair: below the floor (external kills), join capacity
        # immediately — dampers exist to stop flapping, not healing
        if (len(live) + joining < spec.min_replicas
                and non_dead < spec.max_replicas):
            self._scale_up(step, reason="repair")
            return

        up = q > spec.queue_high * max(cap, 1)
        if not up and spec.slo_floor is not None:
            # demand signal for the SLO trigger: a WorkloadMonitor when
            # wired, else the router's §14 rolling window — the gauges
            # are fed at the shared terminal sweep, so this fallback
            # stays a pure function of step indices (parity-exact)
            att = (self.monitor.recent_slo_attainment()
                   if self.monitor is not None
                   else self.router.gauges.slo_attainment())
            up = att is not None and att < spec.slo_floor
        self._up_pressure = self._up_pressure + 1 if up else 0

        down = False
        cand = self._drain_candidate(live)
        if cand is not None and q == 0 and len(live) + joining > spec.min_replicas:
            rest = cap - self.router.replicas[cand.router_idx].max_inflight
            down = rest > 0 and infl <= spec.queue_low * rest
        self._down_pressure = self._down_pressure + 1 if down else 0

        settled = step - self._last_scale >= spec.cooldown_steps
        if (self._up_pressure >= spec.sustain_steps and settled
                and joining == 0 and non_dead < spec.max_replicas
                and step - self._last_down >= spec.hysteresis_steps):
            self._scale_up(step, reason=f"queue={q} cap={cap}")
        elif self._down_pressure >= spec.sustain_steps and settled:
            self._scale_down(step, cand,
                             reason=f"inflight={infl} cap={cap}")

    def _calibration_store(self) -> Optional[Any]:
        if self.calibration is not None:
            return self.calibration
        if self.monitor is not None:
            store = getattr(self.monitor, "calibration", None)
            if store is not None:
                return store
        return getattr(self.router, "calibration", None)

    def _calibration_policy(self, step: int) -> None:
        """§15 miscalibration trigger, damped like ``slo_floor``: the
        cost-model error must exceed ``miscal_bound`` for
        ``sustain_steps`` consecutive steps, with its own cooldown so a
        re-solve is not re-fired while the same error persists.  A pure
        function of the store's EWMA state — parity-exact across the
        simulator and runtime domains."""
        spec = self.spec
        if spec.miscal_bound is None:
            return
        store = self._calibration_store()
        if store is None:
            return
        hot = store.warmed_up and store.max_error() > spec.miscal_bound
        self._miscal_pressure = self._miscal_pressure + 1 if hot else 0
        if (self._miscal_pressure >= spec.sustain_steps
                and step - self._last_recal >= spec.recal_cooldown_steps):
            self._miscal_pressure = 0
            self._last_recal = step
            self.recalibrations += 1
            self._emit(step, "recalibrate", -1,
                       reason=f"max_error={store.max_error():.3f}")
            self._resolve(step, self.events[-1])

    def _drain_candidate(self,
                         live: List[_ReplicaRecord]
                         ) -> Optional[_ReplicaRecord]:
        """Least-loaded live replica; exact ties retire the NEWEST slot
        (deterministic, and the seed fleet outlives the surge capacity)."""
        if not live:
            return None
        return min(live, key=lambda r: (self.router._inflight[r.router_idx],
                                        -r.slot))

    def _scale_up(self, step: int, reason: str = "") -> None:
        rec = _ReplicaRecord(slot=len(self.records),
                             state=ReplicaState.PROVISIONING,
                             state_since=step)
        self.records.append(rec)
        self._emit(step, "scale_up", rec.slot, reason=reason)
        self._last_scale = step
        self._up_pressure = 0
        self._down_pressure = 0

    def _scale_down(self, step: int, rec: _ReplicaRecord,
                    reason: str = "") -> None:
        self.router.drain(rec.router_idx)
        rec.state = ReplicaState.DRAINING
        rec.state_since = step
        self._emit(step, "scale_down", rec.slot, reason=reason)
        self._last_scale = step
        self._last_down = step
        self._up_pressure = 0
        self._down_pressure = 0

    # -- accounting -----------------------------------------------------
    def _account(self, step: int) -> None:
        for rec in self.records:
            if rec.state is not ReplicaState.DEAD:
                key = rec.state.value
                self.replica_steps_by_state[key] = (
                    self.replica_steps_by_state.get(key, 0) + 1)
        if self.monitor is None:
            return
        for rid, entry in self.router._entries.items():
            life = entry.life
            if life.phase is RequestState.DONE and rid not in self._completed:
                self._completed.add(rid)
                self.monitor.observe_completion(life)

    # -- control point (Router.run_trace's on_step) ---------------------
    def on_step(self, step: int) -> None:
        """One control tick, called after this step's arrivals land and
        before the router dispatches: advance lifecycles (a WARMING
        replica may go LIVE and join dispatch THIS step), evaluate
        scale-to-demand, accumulate per-state replica-steps."""
        self._advance(step)
        self._policy(step)
        self._calibration_policy(step)
        self._account(step)

    # -- driving / results ----------------------------------------------
    @property
    def scale_up_events(self) -> int:
        return sum(1 for e in self.events if e.kind == "scale_up")

    @property
    def scale_down_events(self) -> int:
        return sum(1 for e in self.events if e.kind == "scale_down")

    @property
    def replica_steps_total(self) -> int:
        """The fleet-cost denominator: every step a replica existed in
        any non-dead state is a machine you were paying for."""
        return sum(self.replica_steps_by_state.values())

    def states(self) -> Dict[int, str]:
        return {r.slot: r.state.value for r in self.records}

    def run_trace(self, trace: Sequence[Request],
                  failures: Optional[Dict[int, Any]] = None,
                  cancels: Optional[Dict[int, Sequence[int]]] = None,
                  on_token: Optional[Callable] = None,
                  max_steps: int = 200_000):
        """Drive a full trace through the router with this controller's
        control tick wired in; returns elastic ``ServeMetrics``."""
        self.router.run_trace(trace, dt=self.dt, failures=failures,
                              cancels=cancels, on_token=on_token,
                              on_step=self.on_step, max_steps=max_steps)
        return self.metrics()

    def metrics(self):
        from repro.serving.metrics import ServeMetrics
        base = self.router.metrics()
        return ServeMetrics(
            requests=base.requests, makespan=base.makespan,
            decode_tokens=base.decode_tokens,
            scale_up_events=self.scale_up_events,
            scale_down_events=self.scale_down_events,
            replica_steps_by_state=dict(self.replica_steps_by_state))
