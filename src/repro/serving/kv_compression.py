"""KV-cache compression & chunked streaming for the prefill→decode
handoff (DESIGN.md §10).

``KVCodec`` names a wire format for the cache pytree crossing the φ→δ
boundary:

  * ``none``          — raw leaves, one blocking transfer (bit-exact);
  * ``int8``          — role-"kv"/"window_kv" float leaves ship as
    symmetric int8 with one fp32 scale per head vector
    (``kernels.kv_quant``); everything the codec cannot round-trip —
    mamba/xLSTM recurrent state, conv rings, cross-attention memory,
    int32 position rings — passes through untouched, classified by
    ``kv_transfer.leaf_role``;
  * ``int8-chunked``  — int8 plus a ``ChunkedTransferPlan``: the cache
    splits into per-layer-group chunks along the period-stack axis so
    chunk *i* can ship while layer-group *i+1* still prefills, and the
    decode engine installs chunks as they land.

Both serving domains consume the same object. The runtime encodes real
arrays (``encode``/``decode``/``encoded_bytes``); the scheduling domain
prices the identical scheme analytically (``profile_raw_bytes`` /
``profile_wire_bytes`` / ``profile_kv_ratio``) — the shared math is what
makes ``kv_bytes_shipped``/``kv_compression_ratio`` directly comparable
across simulator and runtime under the METRIC_FIELDS parity contract.
"""
from __future__ import annotations

import dataclasses
from typing import Any, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from repro.kernels import kv_quant
from repro.serving import kv_transfer

#: Leaf roles the int8 codec may quantize: growable full-attention KV
#: and sliding-window KV rings — float slabs whose values feed dot
#: products that tolerate ~0.4% relative error. Every other role
#: (recurrent state, conv rings, cross-attention memory, position
#: buffers) is exempt: the codec cannot guarantee a faithful round-trip
#: through their downstream recurrences / integer semantics.
QUANT_ROLES = frozenset({"kv", "window_kv"})


@jax.tree_util.register_pytree_node_class
class QuantizedLeaf:
    """One compressed cache leaf: int8 payload + fp32 per-head-vector
    scales + the original dtype (restored on decode). Registered as a
    pytree node so ``jax.device_put`` / chunk slicing map straight over
    the payload arrays."""

    def __init__(self, q: jax.Array, scale: jax.Array, dtype: Any):
        self.q = q
        self.scale = scale
        self.dtype = jnp.dtype(dtype)

    def tree_flatten(self):
        return (self.q, self.scale), (str(self.dtype),)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], children[1], aux[0])

    @property
    def nbytes(self) -> int:
        return int(self.q.size * self.q.dtype.itemsize
                   + self.scale.size * self.scale.dtype.itemsize)

    def __repr__(self) -> str:  # pragma: no cover
        return (f"QuantizedLeaf(q={tuple(self.q.shape)}, "
                f"scale={tuple(self.scale.shape)}, dtype={self.dtype})")


@dataclasses.dataclass(frozen=True)
class KVCodec:
    """Named wire format for the KV handoff.

    ``chunks`` is the layer-group count of the streaming plan (clamped
    to the cache's period-stack extent at split time); it only applies
    when ``chunked``."""

    name: str
    quantize: bool
    chunked: bool
    chunks: int = 1

    @property
    def is_exact(self) -> bool:
        return not self.quantize


CODECS = {
    "none": KVCodec("none", quantize=False, chunked=False),
    "int8": KVCodec("int8", quantize=True, chunked=False),
    "int8-chunked": KVCodec("int8-chunked", quantize=True, chunked=True,
                            chunks=8),
}


def get_codec(codec: Union[None, str, KVCodec]) -> KVCodec:
    """Resolve None (→ "none"), a codec name, or a KVCodec instance."""
    if codec is None:
        return CODECS["none"]
    if isinstance(codec, KVCodec):
        return codec
    if codec not in CODECS:
        raise KeyError(f"unknown KV codec '{codec}'; known: {sorted(CODECS)}")
    return CODECS[codec]


def _quantizable(role: str, leaf: Any) -> bool:
    return (role in QUANT_ROLES and hasattr(leaf, "dtype")
            and jnp.issubdtype(leaf.dtype, jnp.floating))


def quantizes(codec: Union[None, str, KVCodec], path: Sequence[Any],
              leaf: Any, cfg: Any = None) -> bool:
    """Would ``codec`` quantize this cache leaf? (The byte-accounting
    predicate ``kv_transfer.transfer_bytes`` shares with ``encode``.)"""
    codec = get_codec(codec)
    return codec.quantize and _quantizable(
        kv_transfer.leaf_role(path, leaf, cfg), leaf)


# ---------------------------------------------------------------------------
# Runtime-domain: encode / decode real cache pytrees
# ---------------------------------------------------------------------------


def require_cfg_for(codec: Union[None, str, KVCodec], cfg: Any) -> None:
    """Quantizing codecs refuse to run on the cfg-less name heuristic:
    cross-attention K/V share the bare ``k``/``v`` name+ndim with
    self-attention slabs, so without declared roles the codec would
    silently quantize the very leaves the exemption contract protects
    (the §9 pad_capacity hazard, §10 edition)."""
    if not get_codec(codec).is_exact and cfg is None:
        raise ValueError(
            "a quantizing KV codec requires the ArchConfig (cfg): the "
            "cfg-less leaf-role heuristic cannot distinguish "
            "cross-attention memory from self-attention KV "
            "(DESIGN.md §10 exemption contract)")


def encode(cache: Any, cfg: Any = None,
           codec: Union[None, str, KVCodec] = None) -> Any:
    """Compress a cache pytree leaf-by-leaf. Exact codecs return the
    cache unchanged; int8 codecs replace each quantizable leaf (by
    ``kv_transfer.leaf_role``) with a ``QuantizedLeaf``. ``cfg`` is
    REQUIRED for quantizing codecs (``require_cfg_for``) so SWA rings /
    cross-attention memory are classified declaratively."""
    codec = get_codec(codec)
    if codec.is_exact:
        return cache
    require_cfg_for(codec, cfg)

    def enc(path, leaf):
        if quantizes(codec, path, leaf, cfg):
            q, scale = kv_quant.quantize_int8(leaf)
            return QuantizedLeaf(q, scale, leaf.dtype)
        return leaf

    return jax.tree_util.tree_map_with_path(enc, cache)


def decode(encoded: Any) -> Any:
    """Invert ``encode``: dequantize every ``QuantizedLeaf`` back to its
    original dtype; raw leaves pass through."""

    def dec(leaf):
        if isinstance(leaf, QuantizedLeaf):
            return kv_quant.dequantize_int8(leaf.q, leaf.scale, leaf.dtype)
        return leaf

    return jax.tree.map(dec, encoded, is_leaf=lambda x:
                        isinstance(x, QuantizedLeaf))


def encoded_bytes(tree: Any) -> int:
    """Wire size of an encoded (or raw) cache pytree."""
    total = 0
    for leaf in jax.tree.leaves(
            tree, is_leaf=lambda x: isinstance(x, QuantizedLeaf)):
        if isinstance(leaf, QuantizedLeaf):
            total += leaf.nbytes
        elif hasattr(leaf, "size"):
            total += int(leaf.size * leaf.dtype.itemsize)
    return total


# ---------------------------------------------------------------------------
# Chunked streaming plan (per-layer-group handoff)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ChunkedTransferPlan:
    """Splits a (possibly encoded) cache along the period-stack axis
    (axis 0 of every leaf — layer ``l`` lives in period ``l // len(
    cfg.period)``) into contiguous layer groups. The coordinator ships
    chunk *i* while group *i+1* is still prefilling; the decode engine
    installs each chunk as it lands (``DecodeEngine.admit_chunked``)."""

    bounds: Tuple[Tuple[int, int], ...]   # [p0, p1) per chunk

    @property
    def num_chunks(self) -> int:
        return len(self.bounds)

    @staticmethod
    def for_cache(cache: Any, num_chunks: int) -> "ChunkedTransferPlan":
        leaves = [l for l in jax.tree.leaves(cache) if hasattr(l, "shape")]
        assert leaves, "empty cache pytree"
        periods = int(leaves[0].shape[0])
        n = max(1, min(int(num_chunks), periods))
        edges = [round(i * periods / n) for i in range(n + 1)]
        bounds = tuple((edges[i], edges[i + 1]) for i in range(n)
                       if edges[i + 1] > edges[i])
        return ChunkedTransferPlan(bounds)

    def split(self, cache: Any) -> List[Any]:
        """Chunk pytrees in layer order (leaf axis 0 sliced to each
        period group). Works transparently through ``QuantizedLeaf``."""
        return [jax.tree.map(
            lambda leaf, p0=p0, p1=p1: jax.lax.slice_in_dim(
                leaf, p0, p1, axis=0), cache)
            for p0, p1 in self.bounds]

    def join(self, chunks: Sequence[Any]) -> Any:
        """Reassemble ``split`` output into the full cache pytree."""
        assert len(chunks) == self.num_chunks
        return jax.tree.map(
            lambda *leaves: jnp.concatenate(leaves, axis=0), *chunks)


# ---------------------------------------------------------------------------
# Scheduling-domain accounting (shared with the runtime's lifecycle
# stamping — the sim-vs-runtime parity contract)
# ---------------------------------------------------------------------------


def profile_kv_ratio(profile: Any, codec: Union[None, str, KVCodec]) -> float:
    """raw/wire ratio of the codec on the profile's *attention KV*
    leaves (state/cross leaves are exempt and handled separately by
    ``profile_wire_bytes``). This is the ratio fed to
    ``cost_model.kv_transfer_time`` and the flowgraph's φ→δ edge
    capacities."""
    codec = get_codec(codec)
    if not codec.quantize:
        return 1.0
    return kv_quant.compression_ratio(profile.kv_elem_bytes,
                                      profile.kv_quant_group)


def profile_raw_bytes(profile: Any, s_in: int) -> float:
    """Uncompressed KV/state bytes one request ships at context
    ``s_in`` — the cost model's accounting, identical in both domains."""
    return float(profile.kv_bytes_per_request(s_in))


def profile_wire_bytes(profile: Any, s_in: int,
                       codec: Union[None, str, KVCodec]) -> float:
    """Bytes actually crossing the wire for one request: attention KV
    divided by the codec ratio, exempt state bytes unchanged (the
    KV/state split comes from ``ModelProfile.kv_state_bytes_split`` —
    the same decomposition ``profile_raw_bytes`` sums)."""
    codec = get_codec(codec)
    kv, state = profile.kv_state_bytes_split(s_in)
    return kv / profile_kv_ratio(profile, codec) + state


def sim_chunks(profile: Any, codec: Union[None, str, KVCodec]) -> int:
    """Layer-group chunk count the simulator models for this codec
    (1 = blocking single-shot handoff). Clamped to the profile's
    ``layer_groups`` — the period-stack extent the runtime's
    ``ChunkedTransferPlan`` can physically split — so both domains
    model the same stream shape."""
    codec = get_codec(codec)
    if not codec.chunked:
        return 1
    groups = getattr(profile, "layer_groups", None) or int(
        profile.num_layers)
    return max(1, min(codec.chunks, groups))
