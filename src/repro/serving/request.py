"""Request lifecycle for disaggregated serving."""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class Phase(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    KV_TRANSFER = "kv_transfer"
    DECODING = "decoding"
    DONE = "done"


@dataclasses.dataclass
class Request:
    rid: int
    s_in: int                     # prompt tokens
    s_out: int                    # tokens to generate
    arrival: float                # seconds
    phase: Phase = Phase.QUEUED
    # timeline (filled by simulator / coordinator)
    prefill_start: Optional[float] = None
    prefill_end: Optional[float] = None
    transfer_end: Optional[float] = None
    decode_end: Optional[float] = None
    prefill_group: Optional[int] = None
    decode_group: Optional[int] = None

    @property
    def latency(self) -> Optional[float]:
        if self.decode_end is None:
            return None
        return self.decode_end - self.arrival

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token (prefill completion)."""
        if self.prefill_end is None:
            return None
        return self.prefill_end - self.arrival

    @property
    def is_heavy_prefill(self) -> bool:
        return self.s_in > 512

    @property
    def is_heavy_decode(self) -> bool:
        return self.s_out > 128
