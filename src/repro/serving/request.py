"""Request lifecycle for disaggregated serving.

One state machine shared by BOTH domains (DESIGN.md §8): the
scheduling-domain simulator and the runtime Coordinator's ServeSession
drive the same ``RequestState`` transitions and stamp the same
timestamps, so TTFT/TPOT/latency are computed identically on both
sides.

    QUEUED → PREFILLING → KV_TRANSFER → DECODING → DONE

``advance`` enforces the legal edges (a request can never be DECODING
before its KV handoff, etc.); ``restart`` is the one sanctioned
back-edge — online rescheduling requeues queued/mid-prefill requests on
the new placement (DESIGN.md §7).
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional, Sequence


class RequestState(enum.Enum):
    QUEUED = "queued"
    PREFILLING = "prefilling"
    KV_TRANSFER = "kv_transfer"
    DECODING = "decoding"
    DONE = "done"
    #: refused at admission (router queue overflow, DESIGN.md §12) —
    #: the request never entered the pipeline
    REJECTED = "rejected"
    #: cancelled by the client at some lifecycle stage (§12); resources
    #: it held (decode pages, prefix pins) were reclaimed on the edge
    CANCELLED = "cancelled"


# Backwards-compatible alias (pre-PR-2 name).
Phase = RequestState


#: Legal lifecycle edges. PREFILLING → DONE covers single-token requests
#: (the first token is produced by prefill itself; no KV ever ships).
#: REJECTED is reachable only from QUEUED (admission happens before any
#: work); CANCELLED is reachable from every non-terminal state.
TRANSITIONS = {
    RequestState.QUEUED: (RequestState.PREFILLING, RequestState.REJECTED,
                          RequestState.CANCELLED),
    RequestState.PREFILLING: (RequestState.KV_TRANSFER, RequestState.DONE,
                              RequestState.CANCELLED),
    RequestState.KV_TRANSFER: (RequestState.DECODING,
                               RequestState.CANCELLED),
    RequestState.DECODING: (RequestState.DONE, RequestState.CANCELLED),
    RequestState.DONE: (),
    RequestState.REJECTED: (),
    RequestState.CANCELLED: (),
}

#: States a request can never leave. ``restart`` (the §7/§11/§12
#: requeue back-edge) refuses all of them.
TERMINAL_STATES = frozenset(
    (RequestState.DONE, RequestState.REJECTED, RequestState.CANCELLED))


class IllegalTransition(RuntimeError):
    pass


#: TTFT attribution buckets (DESIGN.md §14), in report order. Each
#: served request's time-to-first-token partitions EXACTLY into these:
#: ``queue`` (admission wait, the remainder after everything
#: accountable), ``prefill`` (compute between prefill_start and
#: prefill_end), ``transfer`` (redo-exposed serialized KV shipping a
#: preempted/redispatched request paid before its final prefill),
#: ``warmup`` (§13 cold-window penalty), and ``decode_first`` (first
#: emission deferred past the φ→δ handoff — carved from the
#: ``decode_first_s`` stamp, which only async-handoff engines set;
#: 0.0 in the standard pipeline, where prefill itself emits the
#: first token).
TTFT_BUCKETS = ("queue", "prefill", "transfer", "warmup", "decode_first")


@dataclasses.dataclass
class Request:
    rid: int
    s_in: int                     # prompt tokens
    s_out: int                    # tokens to generate
    arrival: float                # seconds
    phase: RequestState = RequestState.QUEUED
    # timeline (filled by simulator / coordinator)
    prefill_start: Optional[float] = None
    prefill_end: Optional[float] = None
    transfer_end: Optional[float] = None
    decode_end: Optional[float] = None
    prefill_group: Optional[int] = None
    decode_group: Optional[int] = None
    #: tokens actually produced — differs from s_out when the runtime
    #: truncates at slot capacity; None means "all s_out produced"
    tokens_out: Optional[int] = None
    # -- shared-prefix descriptors (DESIGN.md §9) -----------------------
    #: prompt token ids (length s_in). The runtime prefix cache keys on
    #: these; trace generators fill them for shared-prefix workloads.
    #: None means "content-free request" (legacy traces): no KV reuse.
    tokens: Optional[Sequence[int]] = None
    #: which prefix group (conversation / template) this prompt extends,
    #: and how many leading tokens it shares with the group's
    #: ACCUMULATED context (prompt + trace response for multi-turn) —
    #: a descriptor of trace structure for analysis, NOT a cache
    #: oracle: the reusable length is bounded by what a replica
    #: actually prefilled, and only ``cached_len`` (stamped at
    #: dispatch) reports realized reuse
    prefix_id: Optional[int] = None
    shared_len: int = 0
    #: prompt tokens served from a prefix cache at prefill dispatch
    #: (stamped by whichever domain ran the prefill; 0 = cold)
    cached_len: int = 0
    # -- KV-handoff accounting (DESIGN.md §10) --------------------------
    #: cost-accounting bytes of this request's φ→δ KV shipments:
    #: ``kv_bytes_raw`` uncompressed, ``kv_bytes_wire`` after the codec.
    #: Both domains stamp them from the SAME ``kv_compression`` profile
    #: math at handoff (and again on §7 migrations), which is what makes
    #: ``kv_bytes_shipped``/``kv_compression_ratio`` directly comparable
    #: sim-vs-runtime. 0 = nothing shipped yet.
    kv_bytes_raw: float = 0.0
    kv_bytes_wire: float = 0.0
    #: serialized (no-overlap) transfer seconds, and the portion hidden
    #: behind prefill compute by chunked streaming — the runtime stamps
    #: overlap 0 (its single-host device_put is synchronous)
    kv_serialized_s: float = 0.0
    kv_overlap_s: float = 0.0
    # -- paged-decode accounting (DESIGN.md §11) ------------------------
    #: distinct KV pages this request's decode residency ever held, and
    #: the page size they were cut at. The simulator stamps them from
    #: ``paging.pages_for_request`` arithmetic; the runtime stamps the
    #: REAL allocator count — the two must agree exactly on the same
    #: trace (the §11 parity contract). 0 = dense slabs / never decoded.
    kv_pages_allocated: int = 0
    kv_page_size: int = 0
    #: §11 preemptions this request survived (page-exhaustion recompute)
    preemptions: int = 0
    # -- router-tier descriptors (DESIGN.md §12) ------------------------
    #: priority class: 0 = interactive (most urgent), larger = less
    #: urgent. The router's admission queue orders on this (with aging).
    priority: int = 0
    #: end-to-end latency target in seconds; None = no stated SLO.
    #: ``ServeMetrics.slo_attainment_stated`` scores only stated SLOs.
    slo_target_s: Optional[float] = None
    #: §12 failovers this request survived (replica died mid-flight and
    #: the router re-dispatched it elsewhere, emitted tokens folded
    #: into the prompt)
    redispatches: int = 0
    # -- elastic-fleet accounting (DESIGN.md §13) -----------------------
    #: cold-start TTFT cost attributed to this request: it was
    #: dispatched to a replica inside its post-LIVE cold window, so its
    #: first token paid compile/cache warm-up the steady-state fleet
    #: doesn't. Stamped by the FleetController's dispatch hook as a
    #: pure function of step indices — identical in both domains.
    warmup_penalty_s: float = 0.0
    # -- cost-model calibration stamps (DESIGN.md §15) ------------------
    #: the analytical cost model's PREDICTED per-surface costs for this
    #: request at the placement it was dispatched to: prefill latency at
    #: the routed group's plan, per-decode-step latency, serialized KV
    #: wire time, and the priced warm-up penalty. Stamped by
    #: ``CalibrationStore.stamp`` at dispatch; 0.0 = never stamped (no
    #: calibration wired, or the surface doesn't apply). Observed
    #: counterparts are derived from the lifecycle stamps above, never
    #: recorded separately.
    pred_prefill_s: float = 0.0
    pred_decode_step_s: float = 0.0
    pred_transfer_s: float = 0.0
    pred_warmup_s: float = 0.0
    #: first-token emission deferred past the φ→δ handoff: seconds
    #: between handoff completion and the engine's first decode
    #: emission, stamped by async-handoff engines (the deferred
    #: first-emission fixtures). Feeds the ``decode_first`` TTFT
    #: bucket; 0.0 in the standard pipeline, where prefill itself
    #: emits the first token.
    decode_first_s: float = 0.0

    # -- lifecycle ------------------------------------------------------
    def advance(self, state: RequestState, t: float) -> "Request":
        """Move to ``state`` at time ``t``, stamping the timestamp that
        edge owns. Raises IllegalTransition on a bad edge."""
        if state not in TRANSITIONS[self.phase]:
            raise IllegalTransition(
                f"req {self.rid}: {self.phase.value} -> {state.value}")
        if state is RequestState.PREFILLING:
            self.prefill_start = t
        elif state in (RequestState.REJECTED, RequestState.CANCELLED):
            pass    # no timestamp: latency/ttft stay None (never served)
        elif state is RequestState.KV_TRANSFER:
            self.prefill_end = t
        elif state is RequestState.DECODING:
            self.transfer_end = t
        elif state is RequestState.DONE:
            if self.phase is RequestState.PREFILLING:   # single-token
                self.prefill_end = t
                self.transfer_end = t
            self.decode_end = t
        self.phase = state
        return self

    def restart(self) -> "Request":
        """Requeue after a placement swap: queued/mid-prefill work starts
        over on the new prefill replicas (prefill is stateless)."""
        if self.phase in TERMINAL_STATES:
            raise IllegalTransition(
                f"req {self.rid}: restart after {self.phase.value}")
        self.phase = RequestState.QUEUED
        self.prefill_start = None
        self.prefill_end = None
        self.transfer_end = None
        self.cached_len = 0      # re-stamped when the new replica prefills
        # restart happens strictly pre-handoff, so no KV ever shipped
        # and no deferred first emission ever happened
        self.kv_bytes_raw = 0.0
        self.kv_bytes_wire = 0.0
        self.kv_serialized_s = 0.0
        self.kv_overlap_s = 0.0
        self.decode_first_s = 0.0
        return self

    @property
    def is_terminal(self) -> bool:
        return self.phase in TERMINAL_STATES

    # -- derived metrics ------------------------------------------------
    @property
    def latency(self) -> Optional[float]:
        if self.decode_end is None:
            return None
        return self.decode_end - self.arrival

    @property
    def ttft(self) -> Optional[float]:
        """Time to first token: prefill completion, plus any deferred
        first-emission lag (``decode_first_s``, 0 in the standard
        pipeline where prefill itself emits the first token)."""
        if self.prefill_end is None:
            return None
        return self.prefill_end - self.arrival + self.decode_first_s

    @property
    def tpot(self) -> Optional[float]:
        """Time per output token after the first (decode cadence)."""
        if self.decode_end is None or self.prefill_end is None:
            return None
        n = self.s_out if self.tokens_out is None else self.tokens_out
        if n <= 1:
            return 0.0
        return (self.decode_end - self.prefill_end) / (n - 1)

    def ttft_attribution(self) -> Optional[dict]:
        """Where this request's TTFT went, in seconds per
        ``TTFT_BUCKETS`` bucket — an EXACT partition (buckets sum to
        ``ttft`` to the float) derived purely from lifecycle stamps,
        so both domains report identical attributions on a shared
        clock. None until the first token exists.

        The final attempt's prefill compute is read off the stamps;
        warm-up and redo-exposed transfer are carved out of the
        remaining wait (clamped — they can never exceed what was
        actually waited); queue takes the exact remainder, which is
        what makes the fractions sum to 1.0 without epsilon games."""
        if self.prefill_end is None or self.prefill_start is None:
            return None
        total = self.ttft
        prefill = min(max(self.prefill_end - self.prefill_start, 0.0), total)
        rest = total - prefill
        decode_first = min(max(self.decode_first_s, 0.0), rest)
        rest -= decode_first
        warmup = min(self.warmup_penalty_s, rest)
        rest -= warmup
        transfer = 0.0
        if self.preemptions or self.redispatches:
            # KV this request shipped before a preemption was thrown
            # away and re-done — serialized (non-overlapped) stream
            # time it paid inside its pre-first-token wait
            transfer = min(max(self.kv_serialized_s - self.kv_overlap_s,
                               0.0), rest)
            rest -= transfer
        return {"queue": rest, "prefill": prefill, "transfer": transfer,
                "warmup": warmup, "decode_first": decode_first}

    def ttft_fractions(self) -> Optional[dict]:
        """``ttft_attribution`` normalized to fractions summing to
        exactly 1.0; a zero-TTFT request (arrival and first token on
        the same virtual step) attributes fully to ``queue``."""
        att = self.ttft_attribution()
        if att is None:
            return None
        total = sum(att.values())
        if total <= 0.0:
            return {k: (1.0 if k == "queue" else 0.0)
                    for k in TTFT_BUCKETS}
        return {k: v / total for k, v in att.items()}

    @property
    def is_heavy_prefill(self) -> bool:
        return self.s_in > 512

    @property
    def is_heavy_decode(self) -> bool:
        return self.s_out > 128
