"""End-to-end serving telemetry (DESIGN.md §14).

Three layers, all optional and zero-cost when unused:

1. **Span timelines** — ``request_spans``/``span_stream`` derive a
   per-request stage timeline (queue → prefill → KV transfer → decode,
   plus §10 serialized/overlap sub-spans and §12 dispatch/redispatch
   markers) as a *pure function* of the §8 lifecycle stamps and the
   router's dispatch log. Because both domains stamp those records
   identically on a shared ``StepClock`` (the §12/§13 parity
   contracts), the derived span streams are bitwise-identical across
   simulator and runtime on the same seeded trace — the new parity
   surface this module adds.

2. **Live event bus** — ``TraceRecorder`` collects domain-flavored
   stage events (prefill micro-batches, per-chunk KV installs,
   preemptions, scale transitions) and utilization time series
   (admission-queue depth, active decode slots, page-pool occupancy)
   emitted by the Router / ServeSession / SimReplica paths as they
   run. These enrich the exported trace but are deliberately *outside*
   the parity surface: each domain reports its own machinery.

3. **Rolling-window gauges** — ``WindowedGauges`` maintains windowed
   TTFT/TPOT/SLO-attainment/hit-rate over recent completions so the
   Router and FleetController can consume *observed* windows (the §13
   ``slo_floor`` trigger falls back to these when no WorkloadMonitor
   is wired) instead of end-of-run aggregates.

Exports: ``chrome_trace`` renders everything as Chrome trace-event
JSON (Perfetto-loadable: one track per replica/engine, flow arrows
following each request across the φ→δ handoff), ``prometheus_text``
renders a text-exposition snapshot, and ``validate_chrome_trace``
checks an emitted trace against the trace-event schema (the serve
smoke's ``--trace-out`` leg exits non-zero on violations).
"""
from __future__ import annotations

import dataclasses
import json
import math
from collections import deque
from typing import (Any, Deque, Dict, Iterable, List, Optional, Sequence,
                    Tuple)

from repro.serving.request import Request, RequestState, TTFT_BUCKETS

__all__ = [
    "Span", "TelemetryEvent", "TraceRecorder", "WindowedGauges",
    "request_spans", "span_stream", "chrome_trace", "prometheus_text",
    "validate_chrome_trace", "TTFT_BUCKETS",
]


# ---------------------------------------------------------------------------
# Span derivation (the parity surface)
# ---------------------------------------------------------------------------

#: canonical pipeline order; also the Perfetto lane (tid) per stage
SPAN_LANES: Dict[str, int] = {
    "queue": 0, "prefill": 1, "transfer": 2, "transfer:wire": 2,
    "transfer:overlap": 2, "decode": 3, "rejected": 4, "cancelled": 4,
    "dispatch": 4, "redispatch": 4,
}


@dataclasses.dataclass(frozen=True)
class Span:
    """One stage interval of one request, in trace seconds."""
    rid: int
    name: str
    start: float
    end: float
    args: Tuple[Tuple[str, Any], ...] = ()

    @property
    def dur(self) -> float:
        return self.end - self.start


#: open-interval stage name per non-terminal state (DESIGN.md §14):
#: the span a request was inside when the trace ended.
_OPEN_STAGE = {
    RequestState.QUEUED: "queue",
    RequestState.PREFILLING: "prefill",
    RequestState.KV_TRANSFER: "transfer",
    RequestState.DECODING: "decode",
}


def request_spans(req: Request,
                  trace_end: Optional[float] = None) -> List[Span]:
    """Derive the stage timeline of one request from its §8 lifecycle
    stamps. Pure: same stamps → same spans, which is what makes the
    sim-vs-runtime span streams comparable bit-for-bit.

    A DONE multi-token request yields exactly
    ``queue → prefill → transfer → decode`` (plus §10
    ``transfer:wire``/``transfer:overlap`` sub-spans when KV actually
    shipped); a single-token request collapses transfer/decode to
    zero-length spans at prefill end (§8's PREFILLING→DONE shortcut
    stamps all three ends at the same instant). REJECTED and CANCELLED
    requests yield a terminal marker after whatever stages they
    completed.

    ``trace_end`` closes OPEN intervals: a request still in flight when
    the trace ended emits the stage it was inside as a span closed at
    ``trace_end`` carrying an ``incomplete`` arg, instead of being
    silently truncated at its last completed stage. Omitting it (the
    parity default) keeps in-flight tails out of the stream."""
    out: List[Span] = []
    if req.phase is RequestState.REJECTED:
        return [Span(req.rid, "rejected", req.arrival, req.arrival)]
    if req.prefill_start is None:
        if req.phase is RequestState.CANCELLED:
            return [Span(req.rid, "cancelled", req.arrival, req.arrival)]
        if trace_end is not None and not req.is_terminal:
            return [Span(req.rid, "queue", req.arrival,
                         max(float(trace_end), req.arrival),
                         args=(("incomplete", True),))]
        return out                       # still QUEUED at trace end
    out.append(Span(req.rid, "queue", req.arrival, req.prefill_start))
    last = req.prefill_start
    if req.prefill_end is not None:
        out.append(Span(req.rid, "prefill", req.prefill_start,
                        req.prefill_end,
                        args=(("cached_len", req.cached_len),)))
        last = req.prefill_end
    if req.transfer_end is not None and req.prefill_end is not None:
        args: Tuple[Tuple[str, Any], ...] = ()
        if req.kv_bytes_wire:
            args = (("kv_bytes_wire", req.kv_bytes_wire),)
        out.append(Span(req.rid, "transfer", req.prefill_end,
                        req.transfer_end, args=args))
        # §10 sub-spans: serialized stream vs the part hidden under
        # prefill compute — derived from the same stamps both domains
        # accumulate via kv_compression, so they agree exactly too
        if req.kv_serialized_s > 0.0:
            out.append(Span(req.rid, "transfer:wire", req.prefill_end,
                            req.prefill_end + req.kv_serialized_s))
        if req.kv_overlap_s > 0.0:
            out.append(Span(req.rid, "transfer:overlap", req.prefill_end,
                            req.prefill_end + req.kv_overlap_s))
        last = req.transfer_end
    if req.decode_end is not None and req.transfer_end is not None:
        out.append(Span(req.rid, "decode", req.transfer_end,
                        req.decode_end,
                        args=(("tokens_out", req.tokens_out),)))
        last = req.decode_end
    if req.phase is RequestState.CANCELLED:
        out.append(Span(req.rid, "cancelled", last, last))
    if trace_end is not None and not req.is_terminal:
        stage = _OPEN_STAGE[req.phase]
        out.append(Span(req.rid, stage, last,
                        max(float(trace_end), last),
                        args=(("incomplete", True),)))
    return out


def span_stream(requests: Iterable[Request],
                dispatch_log: Sequence[Dict[str, int]] = (),
                ndigits: int = 9,
                trace_end: Optional[float] = None
                ) -> List[Tuple[int, str, float, float]]:
    """Canonical ordered span stream for parity comparison:
    ``(rid, name, start, dur)`` rounded to ``ndigits``, grouped by rid
    in rid order — lifecycle spans in pipeline order, then §12
    dispatch/redispatch markers in dispatch-step order (marker times
    are *step indices*, already integral in both domains). Two runs
    that made identical decisions at identical steps produce equal
    streams; any divergence shows up as a first differing tuple.
    ``trace_end`` (optional) closes in-flight requests' open intervals
    at the final step instead of dropping them — see
    ``request_spans``; both domains passing the same end time keeps
    the stream comparable."""
    markers: Dict[int, List[Tuple[int, str, float, float]]] = {}
    for row in dispatch_log:
        kind = "redispatch" if row.get("redispatch") else "dispatch"
        markers.setdefault(int(row["rid"]), []).append(
            (int(row["rid"]), kind, float(row["dispatch_step"]), 0.0))
    out: List[Tuple[int, str, float, float]] = []
    for req in sorted(requests, key=lambda r: r.rid):
        for sp in request_spans(req, trace_end=trace_end):
            out.append((sp.rid, sp.name, round(sp.start, ndigits),
                        round(sp.dur, ndigits)))
        out.extend(sorted(markers.get(req.rid, ()), key=lambda m: m[2]))
    return out


# ---------------------------------------------------------------------------
# Live event bus
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TelemetryEvent:
    """One bus event: an instant (``dur == 0``) or a stage interval."""
    ts: float
    kind: str
    track: str
    rid: Optional[int] = None
    dur: float = 0.0
    args: Tuple[Tuple[str, Any], ...] = ()


#: default event-bus ring size: generous for CI traces, bounded for
#: long-lived serving (the §14 unbounded-growth follow-up)
DEFAULT_BUS_EVENTS = 65536


class TraceRecorder:
    """Structured event bus both domains drive.

    ``emit`` records stage events (kv chunk installs, preemptions,
    scale transitions); ``gauge`` appends to a named per-track time
    series (queue depth, active slots, free pages). The event bus is a
    bounded ring (``max_events``; ``None`` = unbounded): once full, the
    oldest event is dropped per emit and ``dropped`` counts the
    evictions — exposed as ``repro_trace_events_dropped`` in the
    Prometheus snapshot so a truncated trace is visible, never silent.
    ``chrome_trace`` turns everything retained into counter tracks and
    instant events."""

    def __init__(self, max_events: Optional[int] = DEFAULT_BUS_EVENTS) -> None:
        self.events: Deque[TelemetryEvent] = deque(maxlen=max_events)
        #: events evicted from the ring since construction (or clear())
        self.dropped = 0
        #: (track, name) -> [(ts, value)]
        self.series: Dict[Tuple[str, str], List[Tuple[float, float]]] = {}

    def emit(self, kind: str, ts: float, *, track: str = "router",
             rid: Optional[int] = None, dur: float = 0.0,
             **args: Any) -> None:
        if (self.events.maxlen is not None
                and len(self.events) == self.events.maxlen):
            self.dropped += 1
        self.events.append(TelemetryEvent(
            ts=float(ts), kind=kind, track=track, rid=rid, dur=float(dur),
            args=tuple(sorted(args.items()))))

    def gauge(self, name: str, ts: float, value: float,
              track: str = "router") -> None:
        self.series.setdefault((track, name), []).append(
            (float(ts), float(value)))

    def clear(self) -> None:
        self.events.clear()
        self.dropped = 0
        self.series.clear()


# ---------------------------------------------------------------------------
# Rolling-window live gauges
# ---------------------------------------------------------------------------


class WindowedGauges:
    """Windowed TTFT/TPOT/SLO-attainment/hit-rate over the last
    ``window_steps`` router steps' completions — the *observed* signal
    scale/route policies consume (§13 ``slo_floor`` reads
    ``slo_attainment()`` when no WorkloadMonitor is wired). Driven at
    the router's terminal sweep, so both domains observe identical
    sequences on the same seeded trace."""

    def __init__(self, window_steps: int = 64) -> None:
        self.window_steps = int(window_steps)
        #: (step, ttft, tpot, slo_ok, s_in, cached_len)
        self._done: Deque[Tuple[int, float, float, Optional[bool],
                                int, int]] = deque()
        self._step = 0

    def observe(self, life: Request, step: int) -> None:
        self._step = max(self._step, int(step))
        if life.phase is not RequestState.DONE:
            return
        slo_ok: Optional[bool] = None
        if life.slo_target_s is not None and life.latency is not None:
            # judged on end-to-end latency, same as the §8 schema's
            # slo_attainment_stated — the floor trigger and the final
            # report must not disagree about what an SLO miss is
            slo_ok = life.latency <= life.slo_target_s
        self._done.append((int(step), life.ttft or 0.0, life.tpot or 0.0,
                           slo_ok, life.s_in, life.cached_len))
        self._trim()

    def advance(self, step: int) -> None:
        self._step = max(self._step, int(step))
        self._trim()

    def _trim(self) -> None:
        lo = self._step - self.window_steps
        while self._done and self._done[0][0] < lo:
            self._done.popleft()

    def count(self) -> int:
        return len(self._done)

    def ttft(self) -> Optional[float]:
        if not self._done:
            return None
        return sum(d[1] for d in self._done) / len(self._done)

    def tpot(self) -> Optional[float]:
        if not self._done:
            return None
        return sum(d[2] for d in self._done) / len(self._done)

    def slo_attainment(self) -> Optional[float]:
        judged = [d[3] for d in self._done if d[3] is not None]
        if not judged:
            return None
        return sum(1 for ok in judged if ok) / len(judged)

    def hit_rate(self) -> Optional[float]:
        toks = sum(d[4] for d in self._done)
        if toks <= 0:
            return None
        return sum(d[5] for d in self._done) / toks

    def snapshot(self) -> Dict[str, float]:
        out: Dict[str, float] = {"window_completions": float(len(self._done))}
        for name, fn in (("window_ttft", self.ttft),
                         ("window_tpot", self.tpot),
                         ("window_slo_attainment", self.slo_attainment),
                         ("window_hit_rate", self.hit_rate)):
            v = fn()
            if v is not None:
                out[name] = float(v)
        return out


# ---------------------------------------------------------------------------
# Chrome trace-event export (Perfetto-loadable)
# ---------------------------------------------------------------------------

_US = 1e6          # trace seconds -> trace-event microseconds
_ROUTER_PID = 0


def _track_pid(track: str) -> int:
    """Map a bus track name onto a trace process id: the router is pid
    0; ``replica:i`` tracks are pid i+1; session-local tracks
    (``engine:j``, ``prefill:j``, ``session``) live under pid 1 (the
    single-coordinator case)."""
    if track.startswith("replica:"):
        return int(track.split(":", 1)[1]) + 1
    if track == "router":
        return _ROUTER_PID
    return 1


def _span_events(req: Request, pid: int,
                 trace_end: Optional[float] = None) -> List[Dict[str, Any]]:
    evs: List[Dict[str, Any]] = []
    for sp in request_spans(req, trace_end=trace_end):
        args = dict(sp.args)
        args["rid"] = sp.rid
        evs.append({"name": sp.name, "cat": "lifecycle", "ph": "X",
                    "ts": sp.start * _US, "dur": max(sp.dur, 0.0) * _US,
                    "pid": pid, "tid": SPAN_LANES.get(sp.name, 4),
                    "args": args})
    return evs


def chrome_trace(requests: Iterable[Request], *,
                 dispatch_log: Sequence[Dict[str, int]] = (),
                 scale_events: Sequence[Any] = (),
                 recorder: Optional[TraceRecorder] = None,
                 dt: float = 0.05,
                 label: str = "repro-serve",
                 trace_end: Optional[float] = None) -> Dict[str, Any]:
    """Render lifecycle spans + bus events as a Chrome trace-event
    JSON object (load in Perfetto / chrome://tracing).

    Layout: one trace *process* per replica (pid = replica index + 1)
    with the router on pid 0; within a process, one *thread* lane per
    pipeline stage (queue/prefill/transfer/decode). Each multi-token
    request carries a flow arrow (``s``/``f`` pair keyed by rid) from
    its prefill end to its decode start — the φ→δ KV handoff — so
    selecting a request in Perfetto walks it across engines.
    ``scale_events`` accepts §13 ``(step, kind, replica)`` tuples or
    ``ScaleEvent`` objects; their instants land on the router track.
    ``trace_end`` closes open intervals of still-in-flight requests at
    that time with an ``incomplete`` arg (see ``request_spans``)."""
    reqs = sorted(requests, key=lambda r: r.rid)
    home: Dict[int, int] = {}
    for row in dispatch_log:
        home[int(row["rid"])] = int(row["replica"])

    events: List[Dict[str, Any]] = []
    pids = {_ROUTER_PID}
    for req in reqs:
        pid = home.get(req.rid, (req.decode_group or 0)) + 1
        pids.add(pid)
        events.extend(_span_events(req, pid, trace_end=trace_end))
        if (req.phase is RequestState.DONE and req.prefill_end is not None
                and req.transfer_end is not None and req.s_out > 1):
            flow = {"name": "kv_handoff", "cat": "flow", "id": req.rid,
                    "pid": pid}
            events.append(dict(flow, ph="s", tid=SPAN_LANES["prefill"],
                               ts=req.prefill_end * _US))
            events.append(dict(flow, ph="f", bp="e",
                               tid=SPAN_LANES["decode"],
                               ts=req.transfer_end * _US))
    for ev in scale_events:
        step, kind, replica = (
            (ev.step, ev.kind, ev.replica) if hasattr(ev, "step") else ev)
        events.append({"name": kind, "cat": "fleet", "ph": "i", "s": "p",
                       "ts": step * dt * _US, "pid": _ROUTER_PID, "tid": 5,
                       "args": {"replica": replica, "step": step}})
    if recorder is not None:
        for tev in recorder.events:
            pid = _track_pid(tev.track)
            pids.add(pid)
            args = dict(tev.args)
            if tev.rid is not None:
                args["rid"] = tev.rid
            base = {"name": tev.kind, "cat": "bus", "ts": tev.ts * _US,
                    "pid": pid, "tid": 6, "args": args}
            if tev.dur > 0.0:
                events.append(dict(base, ph="X", dur=tev.dur * _US))
            else:
                events.append(dict(base, ph="i", s="t"))
        for (track, name), pts in sorted(recorder.series.items()):
            pid = _track_pid(track)
            pids.add(pid)
            for ts, val in pts:
                events.append({"name": f"{track}/{name}", "cat": "util",
                               "ph": "C", "ts": ts * _US, "pid": pid,
                               "tid": 0, "args": {name: val}})
    meta: List[Dict[str, Any]] = []
    for pid in sorted(pids):
        pname = "router" if pid == _ROUTER_PID else f"replica:{pid - 1}"
        meta.append({"name": "process_name", "ph": "M", "pid": pid,
                     "tid": 0, "args": {"name": pname}})
        for lane, tid in (("queue", 0), ("prefill", 1), ("transfer", 2),
                          ("decode", 3), ("events", 4), ("fleet", 5),
                          ("bus", 6)):
            meta.append({"name": "thread_name", "ph": "M", "pid": pid,
                         "tid": tid, "args": {"name": lane}})
    events.sort(key=lambda e: (e.get("ts", 0.0), e["pid"], e.get("tid", 0)))
    return {"traceEvents": meta + events, "displayTimeUnit": "ms",
            "otherData": {"label": label}}


_KNOWN_PH = {"B", "E", "X", "i", "I", "C", "s", "t", "f", "M", "b", "e",
             "n", "P"}


def validate_chrome_trace(obj: Any) -> List[str]:
    """Validate an object against the Chrome trace-event schema (the
    subset ``chrome_trace`` emits plus the common phases). Returns a
    list of human-readable violations — empty means loadable. The
    serve launcher exits non-zero on any violation (or an empty
    trace), which is what the CI smoke leg asserts."""
    errs: List[str] = []
    if isinstance(obj, dict):
        events = obj.get("traceEvents")
        if not isinstance(events, list):
            return ["traceEvents: missing or not a list"]
    elif isinstance(obj, list):
        events = obj
    else:
        return ["trace must be a JSON object or array"]
    if not events:
        return ["trace is empty"]
    flows: Dict[Any, List[str]] = {}
    n_real = 0
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errs.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if not isinstance(ph, str) or ph not in _KNOWN_PH:
            errs.append(f"{where}: bad phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str):
            errs.append(f"{where}: missing name")
        if not isinstance(ev.get("pid"), int):
            errs.append(f"{where}: missing integer pid")
        if ph == "M":
            continue
        n_real += 1
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or not math.isfinite(ts):
            errs.append(f"{where}: missing finite ts")
        if ph == "X":
            dur = ev.get("dur")
            if (not isinstance(dur, (int, float))
                    or not math.isfinite(dur) or dur < 0):
                errs.append(f"{where}: X event needs dur >= 0")
        if ph == "C":
            args = ev.get("args")
            if (not isinstance(args, dict) or not args or not all(
                    isinstance(v, (int, float)) and math.isfinite(v)
                    for v in args.values())):
                errs.append(f"{where}: C event needs numeric args")
        if ph in ("s", "t", "f"):
            if "id" not in ev:
                errs.append(f"{where}: flow event needs id")
            else:
                flows.setdefault(ev["id"], []).append(ph)
    for fid, phs in sorted(flows.items(), key=lambda kv: str(kv[0])):
        if ("s" in phs) != ("f" in phs):
            errs.append(f"flow id {fid!r}: unmatched start/finish "
                        f"({''.join(sorted(phs))})")
    if n_real == 0:
        errs.append("trace has only metadata events")
    return errs


# ---------------------------------------------------------------------------
# Prometheus text exposition
# ---------------------------------------------------------------------------


def _prom_value(v: float) -> str:
    if math.isnan(v):
        return "NaN"
    if math.isinf(v):
        return "+Inf" if v > 0 else "-Inf"
    return repr(float(v))


def prometheus_text(metrics: Any, gauges: Optional[WindowedGauges] = None,
                    prefix: str = "repro",
                    calibration: Any = None,
                    recorder: Optional[TraceRecorder] = None) -> str:
    """Render a ``ServeMetrics`` summary (+ optional live-window
    snapshot + per-class TTFT attribution) in Prometheus text
    exposition format. Non-finite aggregates (a class that never
    finished) render as ``+Inf`` — valid in the exposition format,
    unlike JSON.

    ``calibration`` (a §15 ``CalibrationStore``) adds the
    ``{prefix}_cost_model_error{{surface,group}}`` series — the robust
    EWMA observed/predicted ratio per scheduling surface and device
    group (1.0 = perfectly calibrated). ``recorder`` adds
    ``{prefix}_trace_events_dropped``, the event-bus ring's eviction
    count."""
    lines: List[str] = []

    def sample(name: str, value: float, labels: str = "",
               help_: str = "") -> None:
        full = f"{prefix}_{name}"
        if help_:
            lines.append(f"# HELP {full} {help_}")
            lines.append(f"# TYPE {full} gauge")
        lines.append(f"{full}{labels} {_prom_value(float(value))}")

    for key, val in sorted(metrics.summary().items()):
        sample(key, val, help_=f"ServeMetrics.{key}")
    breakdown = getattr(metrics, "ttft_breakdown", None)
    if breakdown:
        first = True
        for cls in sorted(breakdown):
            for bucket in TTFT_BUCKETS:
                sample("ttft_fraction",
                       breakdown[cls].get(bucket, 0.0),
                       labels=f'{{class="{cls}",bucket="{bucket}"}}',
                       help_=("mean TTFT attribution fraction per "
                              "priority class" if first else ""))
                first = False
    if gauges is not None:
        for key, val in sorted(gauges.snapshot().items()):
            sample(key, val, help_=f"rolling window: {key}")
    if calibration is not None:
        first = True
        for (surface, group), stat in sorted(calibration.snapshot().items()):
            sample("cost_model_error", stat["ratio"],
                   labels=f'{{surface="{surface}",group="{group}"}}',
                   help_=("robust EWMA observed/predicted cost ratio "
                          "per surface and device group" if first else ""))
            first = False
    if recorder is not None:
        sample("trace_events_dropped", recorder.dropped,
               help_="events evicted from the TraceRecorder ring buffer")
    return "\n".join(lines) + "\n"


def dump_chrome_trace(path: str, trace: Dict[str, Any]) -> None:
    """Write a trace object as strict JSON (no ``Infinity``/``NaN``)."""
    with open(path, "w") as f:
        json.dump(trace, f, indent=1, allow_nan=False)


class MetricsEndpoint:
    """Stdlib Prometheus scrape endpoint (DESIGN.md §15).

    Serves ``/metrics`` (whatever the ``render`` callable returns —
    wire it to ``prometheus_text`` over the live session/router) and
    ``/healthz`` on a daemon thread; every other path is 404. No
    third-party dependency — ``http.server`` only. ``port=0`` binds an
    ephemeral port, exposed as ``.port`` after ``start()``. A render
    that raises turns into a 500 with the error text, so a scrape
    can't kill the serving loop."""

    def __init__(self, render, host: str = "127.0.0.1", port: int = 0):
        self.render = render
        self.host = host
        self.port = port
        self._httpd = None
        self._thread = None

    def start(self) -> "MetricsEndpoint":
        import http.server
        import threading
        endpoint = self

        class Handler(http.server.BaseHTTPRequestHandler):
            def do_GET(self):
                if self.path.split("?", 1)[0] == "/healthz":
                    body, code = b"ok\n", 200
                    ctype = "text/plain; charset=utf-8"
                elif self.path.split("?", 1)[0] == "/metrics":
                    try:
                        body = endpoint.render().encode()
                        code = 200
                    except Exception as e:  # pragma: no cover - defensive
                        body, code = f"render failed: {e}\n".encode(), 500
                    ctype = "text/plain; version=0.0.4; charset=utf-8"
                else:
                    body, code = b"not found\n", 404
                    ctype = "text/plain; charset=utf-8"
                self.send_response(code)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):   # quiet: no per-scrape stderr spam
                pass

        self._httpd = http.server.ThreadingHTTPServer(
            (self.host, self.port), Handler)
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        name="metrics-endpoint", daemon=True)
        self._thread.start()
        return self

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        if self._httpd is not None:
            self._httpd.shutdown()
            self._httpd.server_close()
            self._httpd = None
