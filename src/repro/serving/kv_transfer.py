"""KV-cache handoff between prefill and decode replicas.

On a real multi-device runtime this is a resharding ``jax.device_put``:
the prefill replica's cache (laid out for its TP degree) is re-laid-out
to the decode replica's sharding; XLA emits the collective-permute /
ICI traffic. That is the TPU-idiomatic analogue of HexGen-2's
layer-matched NCCL SendRecv routing (DESIGN.md §3).

The helpers below also normalize capacity (prefill pads its cache to
the decode engine's slot capacity) and slice out single requests from a
prefill batch.
"""
from __future__ import annotations

from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp

#: Roles a cache leaf can play in the handoff / prefix-slab machinery
#: (DESIGN.md §3, §9). Only "kv" and "pos" leaves have a growable
#: sequence axis; everything else is shape-fixed and must pass through
#: untouched:
#:   kv         — full-attention K/V slab, seq axis grows to capacity
#:   pos        — growable position leaf (legacy heuristic only; the
#:                declared classification never produces it)
#:   window_kv  — sliding-window ring buffer (fixed size = window)
#:   window_pos — ring-buffer absolute positions (fixed size = window)
#:   cross_kv   — cross-attention memory KV (fixed size = image/enc len)
#:   state      — constant-size recurrent state (SSM/xLSTM), O(1) in seq
LEAF_ROLES = ("kv", "pos", "window_kv", "window_pos", "cross_kv", "state")


def leaf_role(path: Sequence[Any], leaf: Any, cfg: Any = None) -> str:
    """Classify one cache-pytree leaf (see ``LEAF_ROLES``).

    With ``cfg`` (an ArchConfig) the role is DECLARED: the leaf's
    top-level index in the period-stacked cache names its BlockSpec, so
    cross-attention and sliding-window K/V — which match the bare
    ``k``/``v`` name+ndim heuristic but must never be grown (their
    "sequence" axis is image-token count / ring-buffer window) — are
    classified correctly. Without ``cfg`` the legacy heuristic applies:
    literal names ``k``/``v`` at ndim 5 are "kv", ``pos`` at ndim 3 is
    a growable "pos", anything else is "state"."""
    keys = [getattr(p, "key", getattr(p, "name", getattr(p, "idx", None)))
            for p in path]
    name = keys[-1] if keys else ""
    if cfg is not None:
        block = next((k for k in keys if isinstance(k, int)), None)
        if block is None or block >= len(cfg.period):
            return "state"
        mixer = cfg.period[block].mixer
        if mixer == "cross_attn":
            return "cross_kv"
        if mixer == "swa":
            return "window_pos" if name == "pos" else "window_kv"
        if mixer == "attn" and name in ("k", "v"):
            return "kv"
        return "state"
    if name in ("k", "v") and getattr(leaf, "ndim", 0) == 5:
        return "kv"
    if name == "pos" and getattr(leaf, "ndim", 0) == 3:
        return "pos"
    return "state"


def kv_seq_axis(cfg: Any = None) -> int:
    """Axis of the growable sequence dim on a role-"kv" leaf (the cache
    layout is [period, batch, seq, kv_heads, hd] for "bshd" and
    [period, batch, kv_heads, seq, hd] for "kmajor")."""
    if cfg is not None and getattr(cfg, "kv_layout", "bshd") == "kmajor":
        return 3
    return 2


def slab_capacity(cache: Any, cfg: Any = None) -> int:
    """Token capacity of a cache slab's attention KV (DESIGN.md §9):
    the sequence extent of its role-"kv" leaves. 0 when the cache has
    none (pure recurrent state — a constant-size prefix snapshot)."""
    axis = kv_seq_axis(cfg)
    caps = set()

    def visit(path, leaf):
        if leaf_role(path, leaf, cfg) == "kv":
            caps.add(int(leaf.shape[axis]))

    jax.tree_util.tree_map_with_path(visit, cache)
    assert len(caps) <= 1, f"inconsistent slab KV capacities: {caps}"
    return caps.pop() if caps else 0


def slice_request(cache: Any, batch_index: int) -> Any:
    """Extract one request's cache (batch dim kept, size 1). Batch is
    axis 1 of every leaf (axis 0 is the period stack)."""

    def pick(leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim < 2:
            return leaf
        return jax.lax.dynamic_slice_in_dim(leaf, batch_index, 1, axis=1)

    return jax.tree.map(pick, cache)


def pad_capacity(cache: Any, target: int, cfg: Any = None) -> Any:
    """Grow full-attention caches' sequence dim to ``target`` slots.

    Leaves are classified by ``leaf_role``: only role-"kv" (and, on the
    cfg-less heuristic path, legacy "pos") leaves grow; sliding-window
    ring buffers, cross-attention memory, and constant-size recurrent
    state pass through untouched — growing a ring buffer or an
    image-token memory would corrupt decode masking. Pass ``cfg`` so
    those leaves are classified declaratively rather than by the bare
    k/v/pos name+ndim heuristic."""
    axis = kv_seq_axis(cfg)

    def pad(path, leaf):
        role = leaf_role(path, leaf, cfg)
        if role == "kv" and leaf.ndim == 5 and leaf.shape[axis] < target:
            cfgpad = [(0, 0)] * leaf.ndim
            cfgpad[axis] = (0, target - leaf.shape[axis])
            return jnp.pad(leaf, cfgpad)
        if role == "pos" and leaf.ndim == 3 and leaf.shape[2] < target:
            cfgpad = [(0, 0), (0, 0), (0, target - leaf.shape[2])]
            return jnp.pad(leaf, cfgpad, constant_values=-1)
        return leaf

    return jax.tree_util.tree_map_with_path(pad, cache)


def trim_to_pages(cache: Any, tokens: int, page_size: int,
                  cfg: Any = None) -> Any:
    """Set role-"kv" leaves' sequence extent to exactly
    ``ceil(tokens / page_size) * page_size`` slots (DESIGN.md §11).

    The paged handoff ships page-aligned slabs instead of
    capacity-padded ones: a prefill cache padded to the engine's slot
    capacity is sliced down to the pages the prompt actually occupies
    (or padded up from an exact-shape slab), so wire bytes track
    residency, not padding. Every non-growable leaf passes through
    untouched, exactly like ``pad_capacity``."""
    target = max(1, -(-int(tokens) // int(page_size))) * int(page_size)
    axis = kv_seq_axis(cfg)

    def trim(path, leaf):
        role = leaf_role(path, leaf, cfg)
        if role != "kv" or getattr(leaf, "ndim", 0) != 5:
            return leaf
        cur = leaf.shape[axis]
        if cur > target:
            return jax.lax.slice_in_dim(leaf, 0, target, axis=axis)
        if cur < target:
            pad = [(0, 0)] * leaf.ndim
            pad[axis] = (0, target - cur)
            return jnp.pad(leaf, pad)
        return leaf

    return jax.tree_util.tree_map_with_path(trim, cache)


def drop_leading_blocks(cache: Any, blocks: int, page_size: int,
                        cfg: Any = None) -> Any:
    """Drop the first ``blocks`` pages from role-"kv" leaves' sequence
    axis (DESIGN.md §11): a handoff whose target engine will alias
    those pages from a shared prefix slab ships only the remainder —
    the wire carries the NON-shared residency. Other leaves (per-slot
    state, rings, memory) pass through whole."""
    if blocks <= 0:
        return cache
    axis = kv_seq_axis(cfg)
    start = int(blocks) * int(page_size)

    def drop(path, leaf):
        if leaf_role(path, leaf, cfg) != "kv" or getattr(
                leaf, "ndim", 0) != 5:
            return leaf
        # a page-aligned prompt fully covered by the shared prefix
        # drops every block: the zero-extent slab ships nothing and
        # the engine installs nothing
        assert leaf.shape[axis] >= start, (leaf.shape, start)
        return jax.lax.slice_in_dim(leaf, start, leaf.shape[axis],
                                    axis=axis)

    return jax.tree_util.tree_map_with_path(drop, cache)


def split_pages(cache: Any, page_size: int, cfg: Any = None) -> list:
    """Split a (possibly encoded) page-aligned single-request slab into
    per-page slabs along the kv sequence axis — the unit the paged
    decode engine installs and the unit the §10 codecs compose over:
    per-head-vector int8 scales are sequence-local, so
    ``encode ∘ split == split ∘ encode`` leaf-for-leaf (tested)."""
    from repro.serving import kv_compression  # circular-safe lazy import
    axis = kv_seq_axis(cfg)
    cap = 0

    def measure(path, leaf):
        nonlocal cap
        if isinstance(leaf, kv_compression.QuantizedLeaf):
            leaf = leaf.q
        if leaf_role(path, leaf, cfg) == "kv" and getattr(
                leaf, "ndim", 0) == 5:
            cap = max(cap, int(leaf.shape[axis]))

    jax.tree_util.tree_map_with_path(
        measure, cache,
        is_leaf=lambda x: isinstance(x, kv_compression.QuantizedLeaf))
    assert cap and cap % page_size == 0, (cap, page_size)

    def page(path, leaf, p0):
        if leaf_role(path, getattr(leaf, "q", leaf), cfg) == "kv" and \
                getattr(getattr(leaf, "q", leaf), "ndim", 0) == 5:
            return jax.tree.map(
                lambda a: jax.lax.slice_in_dim(a, p0, p0 + page_size,
                                               axis=axis), leaf)
        return leaf

    return [jax.tree_util.tree_map_with_path(
        lambda path, leaf, p0=p0: page(path, leaf, p0), cache,
        is_leaf=lambda x: isinstance(x, kv_compression.QuantizedLeaf))
        for p0 in range(0, cap, page_size)]


def transfer(cache: Any, dst_shardings: Optional[Any] = None,
             donate: bool = False, codec: Any = None,
             cfg: Any = None) -> Any:
    """Ship a cache pytree to the decode replica's layout.

    ``dst_shardings``: pytree of NamedSharding (or a single device) —
    None keeps placement (single-device test runtime).

    ``codec``: a ``kv_compression.KVCodec`` (or its name) — the wire
    format of the handoff (DESIGN.md §10). The cache is encoded
    leaf-by-leaf on the source, the COMPRESSED pytree crosses the
    device boundary, and the decode side dequantizes back to the
    original dtypes. ``None``/"none" ships raw leaves bit-identically
    (the pre-§10 behaviour). Quantizing codecs REQUIRE ``cfg`` so leaf
    roles are classified declaratively (the codec never quantizes
    recurrent state or cross-attention memory; the cfg-less heuristic
    cannot tell the latter apart)."""
    from repro.serving import kv_compression  # circular-safe lazy import
    codec_obj = kv_compression.get_codec(codec)
    if codec_obj.is_exact:
        if dst_shardings is None:
            return cache
        return jax.device_put(cache, dst_shardings, donate=donate)
    encoded = kv_compression.encode(cache, cfg, codec_obj)
    if dst_shardings is not None:
        # the wire crossing: only int8 payloads + fp32 scales move
        encoded = jax.device_put(encoded, dst_shardings, donate=donate)
    return kv_compression.decode(encoded)


def transfer_bytes(cache: Any, codec: Any = None, cfg: Any = None) -> int:
    """Wire size of a cache pytree (for logging / cost cross-checks).

    With a ``codec``, the size the encoded pytree occupies on the wire
    (int8 payload + fp32 per-head-vector scales for quantized leaves,
    raw bytes for exempt ones) — computed analytically, without
    materializing the encoding."""
    from repro.kernels import kv_quant       # circular-safe lazy import
    from repro.serving import kv_compression
    codec_obj = kv_compression.get_codec(codec)
    kv_compression.require_cfg_for(codec_obj, cfg)
    total = 0

    def visit(path, leaf):
        nonlocal total
        if not hasattr(leaf, "size"):
            return
        if (not codec_obj.is_exact
                and kv_compression.quantizes(codec_obj, path, leaf, cfg)):
            group = leaf.shape[-1] if getattr(leaf, "ndim", 0) else 1
            total += int(leaf.size * kv_quant.wire_bytes_per_element(group))
        else:
            total += int(leaf.size * leaf.dtype.itemsize)

    jax.tree_util.tree_map_with_path(visit, cache)
    return total
