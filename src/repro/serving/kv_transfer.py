"""KV-cache handoff between prefill and decode replicas.

On a real multi-device runtime this is a resharding ``jax.device_put``:
the prefill replica's cache (laid out for its TP degree) is re-laid-out
to the decode replica's sharding; XLA emits the collective-permute /
ICI traffic. That is the TPU-idiomatic analogue of HexGen-2's
layer-matched NCCL SendRecv routing (DESIGN.md §3).

The helpers below also normalize capacity (prefill pads its cache to
the decode engine's slot capacity) and slice out single requests from a
prefill batch.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp


def slice_request(cache: Any, batch_index: int) -> Any:
    """Extract one request's cache (batch dim kept, size 1). Batch is
    axis 1 of every leaf (axis 0 is the period stack)."""

    def pick(leaf):
        if not hasattr(leaf, "ndim") or leaf.ndim < 2:
            return leaf
        return jax.lax.dynamic_slice_in_dim(leaf, batch_index, 1, axis=1)

    return jax.tree.map(pick, cache)


def pad_capacity(cache: Any, target: int) -> Any:
    """Grow attention caches' sequence dim (axis 2 of k/v/pos leaves) to
    ``target`` slots. Non-attention state (SSM/xLSTM) passes through."""

    def pad(path, leaf):
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        name = keys[-1] if keys else ""
        if name in ("k", "v") and leaf.ndim == 5 and leaf.shape[2] < target:
            cfgpad = [(0, 0)] * leaf.ndim
            cfgpad[2] = (0, target - leaf.shape[2])
            return jnp.pad(leaf, cfgpad)
        if name == "pos" and leaf.ndim == 3 and leaf.shape[2] < target:
            cfgpad = [(0, 0), (0, 0), (0, target - leaf.shape[2])]
            return jnp.pad(leaf, cfgpad, constant_values=-1)
        return leaf

    return jax.tree_util.tree_map_with_path(pad, cache)


def transfer(cache: Any, dst_shardings: Optional[Any] = None,
             donate: bool = False) -> Any:
    """Ship a cache pytree to the decode replica's layout.

    ``dst_shardings``: pytree of NamedSharding (or a single device) —
    None keeps placement (single-device test runtime)."""
    if dst_shardings is None:
        return cache
    return jax.device_put(cache, dst_shardings, donate=donate)


def transfer_bytes(cache: Any) -> int:
    """Wire size of a cache pytree (for logging / cost cross-checks)."""
    return int(sum(leaf.size * leaf.dtype.itemsize
                   for leaf in jax.tree.leaves(cache)
                   if hasattr(leaf, "size")))
