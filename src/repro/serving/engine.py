"""Real-JAX disaggregated serving engines.

The runtime-domain counterpart of the simulator: a PrefillEngine turns a
prompt batch into (first token, KV cache pytree); a DecodeEngine holds
fixed-capacity slot state (TPU static shapes — the continuous-batching
adaptation in DESIGN.md §3) and advances all active slots one token per
step. The KV handoff between them is ``kv_transfer.transfer`` — a
resharding device_put, the TPU analogue of HexGen-2's NCCL KV routing.

All steps are jit'd once per (batch, seq) bucket; buckets are powers of
two so a handful of compilations serves any trace.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.serving import kv_transfer


def _bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class PrefillEngine:
    """Serves the prefill phase: prompt → (first token, cache).

    ``prefill_batch`` is the serving entry point: prompts are padded to
    power-of-two (batch, seq) buckets so one jit'd compilation per
    bucket serves any trace, and the argmax is masked to each prompt's
    true last position. Padding is only safe when every mixer's state
    is position-masked (plain/cross attention: padded-tail KV is masked
    out of decode and overwritten as generation advances); recurrent
    mixers (mamba/xlstm) and sliding-window position rings would absorb
    the pad tokens, so those architectures fall back to exact-shape
    prefill (one compile per prompt length)."""

    def __init__(self, cfg: ArchConfig, params: Any,
                 cache_capacity: int = 256):
        self.cfg = cfg
        self.params = params
        self.cache_capacity = cache_capacity
        self.supports_padding = all(spec.mixer in ("attn", "cross_attn")
                                    for spec in cfg.period)
        # suffix-only prefill (DESIGN.md §9) is exact only for pure
        # attention+MLP stacks; recurrent/SWA archs fall back to full
        # prefill (their prefix "KV" is a constant-size state snapshot
        # a mid-sequence entry cannot re-seed exactly)
        self.supports_prefix_reuse = transformer.supports_prefix_continue(cfg)
        self._fn = jax.jit(
            functools.partial(transformer.prefill, cfg=cfg,
                              cache_capacity=cache_capacity),
            static_argnames=())
        self._suffix_fn = jax.jit(
            functools.partial(transformer.prefill_continue, cfg=cfg),
            static_argnames=("prefix_len",))

    def prefill(self, tokens: np.ndarray, **extra) -> Tuple[np.ndarray, Any]:
        """tokens [B,S] (exact shapes) → (next_token [B], cache)."""
        logits, cache = self._fn(self.params, tokens=jnp.asarray(tokens),
                                 **extra)
        next_tok = jnp.argmax(logits, axis=-1)
        return np.asarray(next_tok), cache

    def prefill_suffix(self, prompt: np.ndarray, cached_len: int,
                       slab: Any) -> Tuple[int, Any]:
        """Prefill only ``prompt[cached_len:]`` seeded from ``slab`` — a
        batch-1 cache pytree (the ``kv_transfer`` shape discipline)
        whose first ``cached_len`` sequence slots hold the shared
        prefix's KV. Returns (first_token, batch-1 cache) exactly like
        a ``prefill_batch`` element; bit-identical to full prefill on
        supporting archs (exact shapes: one compile per
        (suffix, prefix) length pair)."""
        assert self.supports_prefix_reuse, self.cfg.name
        assert 0 < cached_len < len(prompt), (cached_len, len(prompt))
        cap = kv_transfer.slab_capacity(slab, self.cfg)
        assert cap >= len(prompt), (cap, len(prompt))
        suffix = np.asarray(prompt[cached_len:], np.int32)[None]
        logits, cache = self._suffix_fn(self.params,
                                        tokens=jnp.asarray(suffix),
                                        caches=slab,
                                        prefix_len=int(cached_len))
        return int(np.asarray(jnp.argmax(logits, axis=-1))[0]), cache

    def prefill_batch(self, prompts: Sequence[np.ndarray],
                      extras: Optional[Sequence[Dict[str, Any]]] = None,
                      ) -> List[Tuple[int, Any]]:
        """Prefill ``prompts`` (ragged lengths) in ONE jit'd call when
        the architecture allows padding; returns per-request
        (first_token, single-request cache slice [.., 1, ..])."""
        n = len(prompts)
        extras = list(extras) if extras is not None else [{}] * n
        max_len = max(len(p) for p in prompts)
        uniform_extras = all(ex.keys() == extras[0].keys() for ex in extras)
        if (not self.supports_padding or max_len > self.cache_capacity
                or not uniform_extras):
            out = []
            for p, ex in zip(prompts, extras):
                tok, cache = self.prefill(np.asarray(p, np.int32)[None], **ex)
                out.append((int(tok[0]), kv_transfer.slice_request(cache, 0)))
            return out

        seq = min(_bucket(max_len), self.cache_capacity)
        bsz = _bucket(n, lo=1)
        toks = np.zeros((bsz, seq), np.int32)
        last = np.zeros((bsz,), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
            last[i] = len(p) - 1
        batched = {}
        for key in extras[0]:
            stack = np.concatenate([np.asarray(ex[key]) for ex in extras])
            if bsz > n:
                padshape = (bsz - n,) + stack.shape[1:]
                stack = np.concatenate(
                    [stack, np.zeros(padshape, stack.dtype)])
            batched[key] = stack
        logits, cache = self._fn(self.params, tokens=jnp.asarray(toks),
                                 last_index=jnp.asarray(last), **batched)
        first = np.asarray(jnp.argmax(logits, axis=-1))
        return [(int(first[i]), kv_transfer.slice_request(cache, i))
                for i in range(n)]


@dataclasses.dataclass
class Slot:
    rid: int = -1
    length: int = 0          # tokens written so far (prompt + generated)
    remaining: int = 0       # tokens still to generate
    active: bool = False


class DecodeEngine:
    """Continuous-batching decode over fixed slots.

    ``slots`` is the static batch capacity; per-slot KV lives stacked in
    one cache pytree. Admission copies a transferred prefill cache into
    a free slot (a dynamic_update on the batch dim)."""

    def __init__(self, cfg: ArchConfig, params: Any, slots: int,
                 capacity: int):
        self.cfg = cfg
        self.params = params
        self.num_slots = slots
        self.capacity = capacity
        self.cache = transformer.init_cache(cfg, slots, capacity)
        self.slots = [Slot() for _ in range(slots)]
        self.tokens = np.zeros((slots,), np.int32)

        def step(params, cache, tokens, positions):
            logits, cache = transformer.decode_step(
                params, cfg, cache, tokens[:, None], positions[:, None])
            return jnp.argmax(logits, axis=-1), cache

        self._step = jax.jit(step, donate_argnums=(1,))

    # -- slot admission -------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    def admit(self, rid: int, first_token: int, prompt_len: int,
              s_out: int, cache_slice: Any) -> int:
        """Install a transferred single-request cache into a free slot.

        ``cache_slice`` is the request's cache pytree with batch dim 1 and
        the SAME capacity as this engine (kv_transfer guarantees it)."""
        idx = self.free_slots()[0]

        def install(dst, src):
            if dst.ndim < 2 or not isinstance(src, jax.Array):
                return dst
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), idx, axis=1)

        self.cache = jax.tree.map(install, self.cache, cache_slice)
        self.slots[idx] = Slot(rid=rid, length=prompt_len + 1,
                               remaining=s_out - 1, active=True)
        self.tokens[idx] = first_token
        return idx

    def install_chunk(self, slot_idx: int, period_start: int,
                      chunk: Any) -> None:
        """Install one layer-group chunk of a transferred cache
        (DESIGN.md §10): ``chunk`` has the full cache pytree structure
        with every leaf's period-stack axis sliced to the group, and is
        written at ``(period_start, slot_idx)`` via a dynamic update —
        chunks land independently, in any order."""

        def install(dst, src):
            if dst.ndim < 2 or not isinstance(src, jax.Array):
                return dst
            starts = (period_start, slot_idx) + (0,) * (dst.ndim - 2)
            return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype),
                                                starts)

        self.cache = jax.tree.map(install, self.cache, chunk)

    def admit_chunked(self, rid: int, first_token: int, prompt_len: int,
                      s_out: int, chunks: Any) -> int:
        """Chunk-streaming admission: install each ``(period_start,
        chunk)`` as it lands, then activate the slot. Equivalent to
        ``admit`` once every chunk has arrived."""
        idx = self.free_slots()[0]
        for period_start, chunk in chunks:
            self.install_chunk(idx, period_start, chunk)
        self.slots[idx] = Slot(rid=rid, length=prompt_len + 1,
                               remaining=s_out - 1, active=True)
        self.tokens[idx] = first_token
        return idx

    # -- decode ----------------------------------------------------------
    def step(self) -> List[Tuple[int, int, bool]]:
        """Advance every active slot one token.

        Returns [(rid, token, finished)] for active slots."""
        if not any(s.active for s in self.slots):
            return []
        positions = np.array([max(s.length - 1, 0) for s in self.slots],
                             np.int32)
        toks, self.cache = self._step(self.params, self.cache,
                                      jnp.asarray(self.tokens),
                                      jnp.asarray(positions))
        toks = np.asarray(toks)
        out = []
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            s.length += 1
            s.remaining -= 1
            self.tokens[i] = toks[i]
            finished = s.remaining <= 0 or s.length >= self.capacity
            out.append((s.rid, int(toks[i]), finished))
            if finished:
                s.active = False
        return out
