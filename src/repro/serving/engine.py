"""Real-JAX disaggregated serving engines.

The runtime-domain counterpart of the simulator: a PrefillEngine turns a
prompt batch into (first token, KV cache pytree); a DecodeEngine holds
fixed-capacity slot state (TPU static shapes — the continuous-batching
adaptation in DESIGN.md §3) and advances all active slots one token per
step. The KV handoff between them is ``kv_transfer.transfer`` — a
resharding device_put, the TPU analogue of HexGen-2's NCCL KV routing.

All steps are jit'd once per (batch, seq) bucket; buckets are powers of
two so a handful of compilations serves any trace.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer


def _bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


class PrefillEngine:
    """Serves the prefill phase: prompt → (first token, cache)."""

    def __init__(self, cfg: ArchConfig, params: Any,
                 cache_capacity: int = 256):
        self.cfg = cfg
        self.params = params
        self.cache_capacity = cache_capacity
        self._fn = jax.jit(
            functools.partial(transformer.prefill, cfg=cfg,
                              cache_capacity=cache_capacity),
            static_argnames=())

    def prefill(self, tokens: np.ndarray, **extra) -> Tuple[np.ndarray, Any]:
        """tokens [B,S] (already bucketed/padded) → (next_token [B], cache)."""
        logits, cache = self._fn(self.params, tokens=jnp.asarray(tokens),
                                 **extra)
        next_tok = jnp.argmax(logits, axis=-1)
        return np.asarray(next_tok), cache


@dataclasses.dataclass
class Slot:
    rid: int = -1
    length: int = 0          # tokens written so far (prompt + generated)
    remaining: int = 0       # tokens still to generate
    active: bool = False


class DecodeEngine:
    """Continuous-batching decode over fixed slots.

    ``slots`` is the static batch capacity; per-slot KV lives stacked in
    one cache pytree. Admission copies a transferred prefill cache into
    a free slot (a dynamic_update on the batch dim)."""

    def __init__(self, cfg: ArchConfig, params: Any, slots: int,
                 capacity: int):
        self.cfg = cfg
        self.params = params
        self.num_slots = slots
        self.capacity = capacity
        self.cache = transformer.init_cache(cfg, slots, capacity)
        self.slots = [Slot() for _ in range(slots)]
        self.tokens = np.zeros((slots,), np.int32)

        def step(params, cache, tokens, positions):
            logits, cache = transformer.decode_step(
                params, cfg, cache, tokens[:, None], positions[:, None])
            return jnp.argmax(logits, axis=-1), cache

        self._step = jax.jit(step, donate_argnums=(1,))

    # -- slot admission -------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    def admit(self, rid: int, first_token: int, prompt_len: int,
              s_out: int, cache_slice: Any) -> int:
        """Install a transferred single-request cache into a free slot.

        ``cache_slice`` is the request's cache pytree with batch dim 1 and
        the SAME capacity as this engine (kv_transfer guarantees it)."""
        idx = self.free_slots()[0]

        def install(dst, src):
            if dst.ndim < 2 or not isinstance(src, jax.Array):
                return dst
            return jax.lax.dynamic_update_slice_in_dim(
                dst, src.astype(dst.dtype), idx, axis=1)

        self.cache = jax.tree.map(install, self.cache, cache_slice)
        self.slots[idx] = Slot(rid=rid, length=prompt_len + 1,
                               remaining=s_out - 1, active=True)
        self.tokens[idx] = first_token
        return idx

    # -- decode ----------------------------------------------------------
    def step(self) -> List[Tuple[int, int, bool]]:
        """Advance every active slot one token.

        Returns [(rid, token, finished)] for active slots."""
        if not any(s.active for s in self.slots):
            return []
        positions = np.array([max(s.length - 1, 0) for s in self.slots],
                             np.int32)
        toks, self.cache = self._step(self.params, self.cache,
                                      jnp.asarray(self.tokens),
                                      jnp.asarray(positions))
        toks = np.asarray(toks)
        out = []
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            s.length += 1
            s.remaining -= 1
            self.tokens[i] = toks[i]
            finished = s.remaining <= 0 or s.length >= self.capacity
            out.append((s.rid, int(toks[i]), finished))
            if finished:
                s.active = False
        return out
