"""Real-JAX disaggregated serving engines.

The runtime-domain counterpart of the simulator: a PrefillEngine turns a
prompt batch into (first token, KV cache pytree); a DecodeEngine holds
fixed-capacity slot state (TPU static shapes — the continuous-batching
adaptation in DESIGN.md §3) and advances all active slots one token per
step. The KV handoff between them is ``kv_transfer.transfer`` — a
resharding device_put, the TPU analogue of HexGen-2's NCCL KV routing.

All steps are jit'd once per (batch, seq) bucket; buckets are powers of
two so a handful of compilations serves any trace.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.serving import kv_transfer
from repro.serving.kv_compression import QuantizedLeaf
from repro.serving.paging import (NoFreeSlotError, OutOfPagesError,
                                  PagePool, PagedSlab, pages_for,
                                  shareable_pages)
from repro.serving.prefix_cache import PrefixCache

QUANT_EPS_SCALE = 1e-12  # matches kernels.kv_quant.EPS_SCALE


def _bucket(n: int, lo: int = 8) -> int:
    b = lo
    while b < n:
        b *= 2
    return b


def _quantize_page(chunk: jax.Array, kmajor: bool
                   ) -> Tuple[jax.Array, jax.Array]:
    """Quantize one page's float KV chunk to the resident int8 layout
    (DESIGN.md §16): symmetric max-abs with ONE fp32 scale per
    (period, kv-head). chunk [Pr,1,ps,kv,hd] ("bshd") / [Pr,1,kv,ps,hd]
    ("kmajor") → (q int8 same shape, scale [Pr,1,kv])."""
    xf = chunk.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=(3, 4) if kmajor else (2, 4))
    s = jnp.maximum(amax / 127.0, QUANT_EPS_SCALE)       # [Pr,1,kv]
    sb = s[:, :, :, None, None] if kmajor else s[:, :, None, :, None]
    q = jnp.clip(jnp.round(xf / sb), -127, 127).astype(jnp.int8)
    return q, s


def _rescale_rows_to_page(qc: jax.Array, sc: jax.Array, kmajor: bool
                          ) -> Tuple[jax.Array, jax.Array]:
    """Renormalize one page of int8 WIRE rows (per-(token, head) scales,
    the §10 codec) onto the pool's per-(page, kv-head) scale WITHOUT
    dequantizing: page_scale = max of the row scales (what quantize-once
    from float yields up to one fp32 division ulp, since max is
    associative), and each row's codes are rescaled by
    row_scale/page_scale ≤ 1 — integer
    renormalization, not a second quantization from float. qc
    [Pr,1,ps,kv,hd] / [Pr,1,kv,ps,hd] int8, sc same with hd→1 →
    (q int8, scale [Pr,1,kv])."""
    s = jnp.max(sc, axis=3 if kmajor else 2, keepdims=True)
    ratio = sc / s                                       # ≤ 1
    q = jnp.clip(jnp.round(qc.astype(jnp.float32) * ratio),
                 -127, 127).astype(jnp.int8)
    spage = s[:, :, :, 0, 0] if kmajor else s[:, :, 0, :, 0]  # [Pr,1,kv]
    return q, spage


class PrefillEngine:
    """Serves the prefill phase: prompt → (first token, cache).

    ``prefill_batch`` is the serving entry point: prompts are padded to
    power-of-two (batch, seq) buckets so one jit'd compilation per
    bucket serves any trace, and the argmax is masked to each prompt's
    true last position. Padding is only safe when every mixer's state
    is position-masked (plain/cross attention: padded-tail KV is masked
    out of decode and overwritten as generation advances); recurrent
    mixers (mamba/xlstm) and sliding-window position rings would absorb
    the pad tokens, so those architectures fall back to exact-shape
    prefill (one compile per prompt length)."""

    def __init__(self, cfg: ArchConfig, params: Any,
                 cache_capacity: int = 256):
        self.cfg = cfg
        self.params = params
        self.cache_capacity = cache_capacity
        self.supports_padding = all(spec.mixer in ("attn", "cross_attn")
                                    for spec in cfg.period)
        # suffix-only prefill (DESIGN.md §9) is exact only for pure
        # attention+MLP stacks; recurrent/SWA archs fall back to full
        # prefill (their prefix "KV" is a constant-size state snapshot
        # a mid-sequence entry cannot re-seed exactly)
        self.supports_prefix_reuse = transformer.supports_prefix_continue(cfg)
        self._fn = jax.jit(
            functools.partial(transformer.prefill, cfg=cfg,
                              cache_capacity=cache_capacity),
            static_argnames=())
        self._suffix_fn = jax.jit(
            functools.partial(transformer.prefill_continue, cfg=cfg),
            static_argnames=("prefix_len",))

    def prefill(self, tokens: np.ndarray, **extra) -> Tuple[np.ndarray, Any]:
        """tokens [B,S] (exact shapes) → (next_token [B], cache)."""
        logits, cache = self._fn(self.params, tokens=jnp.asarray(tokens),
                                 **extra)
        next_tok = jnp.argmax(logits, axis=-1)
        return np.asarray(next_tok), cache

    def prefill_suffix(self, prompt: np.ndarray, cached_len: int,
                       slab: Any) -> Tuple[int, Any]:
        """Prefill only ``prompt[cached_len:]`` seeded from ``slab`` — a
        batch-1 cache pytree (the ``kv_transfer`` shape discipline)
        whose first ``cached_len`` sequence slots hold the shared
        prefix's KV. Returns (first_token, batch-1 cache) exactly like
        a ``prefill_batch`` element; bit-identical to full prefill on
        supporting archs (exact shapes: one compile per
        (suffix, prefix) length pair)."""
        assert self.supports_prefix_reuse, self.cfg.name
        assert 0 < cached_len < len(prompt), (cached_len, len(prompt))
        cap = kv_transfer.slab_capacity(slab, self.cfg)
        assert cap >= len(prompt), (cap, len(prompt))
        suffix = np.asarray(prompt[cached_len:], np.int32)[None]
        logits, cache = self._suffix_fn(self.params,
                                        tokens=jnp.asarray(suffix),
                                        caches=slab,
                                        prefix_len=int(cached_len))
        return int(np.asarray(jnp.argmax(logits, axis=-1))[0]), cache

    def prefill_batch(self, prompts: Sequence[np.ndarray],
                      extras: Optional[Sequence[Dict[str, Any]]] = None,
                      ) -> List[Tuple[int, Any]]:
        """Prefill ``prompts`` (ragged lengths) in ONE jit'd call when
        the architecture allows padding; returns per-request
        (first_token, single-request cache slice [.., 1, ..])."""
        n = len(prompts)
        extras = list(extras) if extras is not None else [{}] * n
        max_len = max(len(p) for p in prompts)
        uniform_extras = all(ex.keys() == extras[0].keys() for ex in extras)
        if (not self.supports_padding or max_len > self.cache_capacity
                or not uniform_extras):
            out = []
            for p, ex in zip(prompts, extras):
                tok, cache = self.prefill(np.asarray(p, np.int32)[None], **ex)
                out.append((int(tok[0]), kv_transfer.slice_request(cache, 0)))
            return out

        seq = min(_bucket(max_len), self.cache_capacity)
        bsz = _bucket(n, lo=1)
        toks = np.zeros((bsz, seq), np.int32)
        last = np.zeros((bsz,), np.int32)
        for i, p in enumerate(prompts):
            toks[i, :len(p)] = p
            last[i] = len(p) - 1
        batched = {}
        for key in extras[0]:
            stack = np.concatenate([np.asarray(ex[key]) for ex in extras])
            if bsz > n:
                padshape = (bsz - n,) + stack.shape[1:]
                stack = np.concatenate(
                    [stack, np.zeros(padshape, stack.dtype)])
            batched[key] = stack
        logits, cache = self._fn(self.params, tokens=jnp.asarray(toks),
                                 last_index=jnp.asarray(last), **batched)
        first = np.asarray(jnp.argmax(logits, axis=-1))
        return [(int(first[i]), kv_transfer.slice_request(cache, i))
                for i in range(n)]


@dataclasses.dataclass
class Slot:
    rid: int = -1
    length: int = 0          # tokens written so far (prompt + generated)
    remaining: int = 0       # tokens still to generate
    active: bool = False
    # paged layout (DESIGN.md §11)
    pages: List[int] = dataclasses.field(default_factory=list)
    shared_pages: int = 0    # leading read-only aliases (never written)
    src_offset: int = 0      # slab blocks omitted from the shipped slab
    pages_seen: int = 0      # distinct pages ever held (the §11 stamp)
    admit_seq: int = -1      # admission order, for youngest-first preempt


@dataclasses.dataclass
class SharedReservation:
    """A pinned shared-prefix match handed out by
    ``DecodeEngine.reserve_shared`` ahead of a paged handoff
    (DESIGN.md §11): the coordinator ships the slab WITHOUT the
    ``blocks`` leading pages (``kv_transfer.drop_leading_blocks``) and
    the pinned radix path guarantees those pages survive slab eviction
    until ``admit`` aliases them. Consumed (unlocked) by ``admit``/
    ``admit_chunked`` — or ``release_reservation`` on failure."""
    blocks: int
    match: Any


class DecodeEngine:
    """Continuous-batching decode over fixed slots.

    ``slots`` is the static batch capacity. Two cache layouts:

      * dense (default): per-slot KV lives stacked in one cache pytree
        at full ``capacity`` — every slot pays capacity × bytes/token.
      * paged (``paged=True``, DESIGN.md §11): full-attention KV lives
        in a shared ref-counted page pool; each slot holds a block
        table and only ever occupies ``ceil(context / page_size)``
        pages, so the pool admits concurrency by real residency. Pages
        are allocated on demand as decode crosses page boundaries;
        exhaustion first evicts shared prefix slabs, then preempts the
        youngest slot (reported via ``preempted`` for recompute).

    Admission copies a transferred prefill cache into a free slot (a
    dynamic_update on the batch dim / per-page scatters into the pool).
    With ``share_prefix_pages=True`` the engine keeps a radix tree of
    admitted prompts whose nodes own pinned pages from the SAME pool
    (``PagedSlab``): a request over a cached prefix aliases the fully
    covered pages read-only and copies only the boundary page it will
    write — copy-on-write at page granularity."""

    def __init__(self, cfg: ArchConfig, params: Any, slots: int,
                 capacity: int, paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 share_prefix_pages: bool = False,
                 paged_dtype: Optional[str] = None):
        if paged_dtype not in (None, "int8"):
            raise ValueError(f"unsupported paged_dtype {paged_dtype!r}; "
                             "expected None (model dtype) or 'int8'")
        self.cfg = cfg
        self.params = params
        self.num_slots = slots
        self.paged = paged
        self.paged_dtype = paged_dtype if paged else None
        self.page_size = int(page_size)
        if paged:
            capacity = pages_for(capacity, self.page_size) * self.page_size
        self.capacity = capacity
        self.slots = [Slot() for _ in range(slots)]
        self.tokens = np.zeros((slots,), np.int32)
        self.preempted: List[int] = []    # rids evicted for recompute
        self._page_stamps: Dict[int, int] = {}
        self._admit_seq = 0

        if not paged:
            self.pool = None
            self.prefix_pages = None
            self.block_tables = None
            self.cache = transformer.init_cache(cfg, slots, capacity)

            def step(params, cache, tokens, positions):
                logits, cache = transformer.decode_step(
                    params, cfg, cache, tokens[:, None], positions[:, None])
                return jnp.argmax(logits, axis=-1), cache

            self._step = jax.jit(step, donate_argnums=(1,))
            return

        self.num_blocks = capacity // self.page_size
        # default pool: the dense engine's HBM budget (+1 scratch page);
        # callers size it down (or slots up) to realize the paging win
        n_pages = (slots * self.num_blocks + 1 if num_pages is None
                   else int(num_pages))
        self.cache = transformer.init_paged_cache(
            cfg, slots, n_pages, self.page_size,
            paged_dtype=self.paged_dtype)
        self.pool = PagePool(n_pages, self.page_size,
                             page_bytes=self._pool_bytes_per_page(),
                             dtype=self.paged_dtype)
        self.block_tables = np.full((slots, self.num_blocks), -1, np.int32)
        #: §11 pool sharing: radix tree over admitted prompts; nodes own
        #: pinned pages of THIS pool (payload release returns them)
        self.prefix_pages = PrefixCache() if share_prefix_pages else None

        def step_paged(params, cache, tokens, positions, block_tables):
            logits, cache = transformer.decode_step_paged(
                params, cfg, cache, tokens[:, None], positions[:, None],
                block_tables, self.page_size)
            return jnp.argmax(logits, axis=-1), cache

        self._step = jax.jit(step_paged, donate_argnums=(1,))

    def _pool_bytes_per_page(self) -> float:
        """Physical bytes one page occupies across the period-stacked
        attention pools (for slab byte accounting). Counts EVERY
        page-axis leaf, so an int8 pool's fp32 scale sidecar
        (``k_scale``/``v_scale``, DESIGN.md §16) is charged alongside
        the payload — utilization and prefix budgets see what HBM
        sees."""
        total = 0.0
        for spec, c in zip(self.cfg.period, self.cache):
            if spec.mixer == "attn":
                for leaf in c.values():
                    total += leaf.nbytes / leaf.shape[1]
        return total

    # -- slot admission -------------------------------------------------
    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if not s.active]

    @property
    def free_pages(self) -> int:
        return self.pool.free_pages if self.paged else 0

    def util(self) -> Dict[str, int]:
        """§14 utilization snapshot for the telemetry gauges: active vs
        total decode slots, and (paged engines) free vs total pool
        pages — the page-occupancy time series."""
        out = {"active_slots": sum(1 for s in self.slots if s.active),
               "num_slots": self.num_slots}
        if self.paged:
            out["free_pages"] = self.pool.free_pages
            out["num_pages"] = self.pool.num_pages
        return out

    def _reclaimable_slab_pages(self) -> int:
        """Pages slab eviction would ACTUALLY free: evictable-leaf slab
        pages whose only reference is the slab itself (a page an active
        slot still aliases stays resident through eviction)."""
        if self.prefix_pages is None:
            return 0
        freeable = set()
        for n in self.prefix_pages._evictable():
            if isinstance(n.payload, PagedSlab):
                freeable.update(p for p in n.payload.pages
                                if self.pool.refcount(p) == 1)
        return len(freeable)

    def can_admit(self, prompt_len: int) -> bool:
        """Whether ``admit`` would succeed right now: a free slot, and
        (paged) enough free-or-reclaimable pages for the prompt."""
        if not self.free_slots():
            return False
        if not self.paged:
            return True
        need = pages_for(prompt_len, self.page_size)
        return (self.pool.free_pages + self._reclaimable_slab_pages()
                >= need)

    def _take_slot(self) -> int:
        free = self.free_slots()
        if not free:
            raise NoFreeSlotError(
                f"all {self.num_slots} decode slots active "
                f"(rids {[s.rid for s in self.slots]})")
        return free[0]

    def _alloc(self, n: int) -> List[int]:
        """Allocate ``n`` pages, evicting LRU prefix slabs on demand.

        A doomed request fails fast WITHOUT evicting: when even full
        reclamation cannot free ``n`` pages (slab pages aliased by
        active slots survive eviction), wiping the radix would cost the
        future hit rate and gain nothing."""
        if (self.pool.free_pages < n
                and self.pool.free_pages + self._reclaimable_slab_pages()
                < n):
            return self.pool.alloc(n)   # raises OutOfPagesError
        while (self.prefix_pages is not None
               and self.pool.free_pages < n
               and self.prefix_pages.evict_tokens(1)):
            pass
        return self.pool.alloc(n)

    def _install_pages(self, src: Any, pages: Sequence[int],
                       first_block: int, period_start: int = 0,
                       src_offset: int = 0) -> None:
        """Scatter a page-aligned single-request slab into the pool.

        ``src`` kv leaves are [P_range, 1, S, kv, hd] (/kmajor); logical
        block ``first_block + j`` of the slab lands in physical page
        ``pages[j]`` (blocks below ``first_block`` are shared prefix
        pages, already pool-resident). ``src_offset`` blocks were
        DROPPED from the shipped slab (a reservation handoff —
        ``kv_transfer.drop_leading_blocks``), shifting where each
        logical block sits in ``src``. Non-kv leaves are per-slot and
        handled by ``_install_dense_leaves``.

        Int8-resident pools (DESIGN.md §16) accept BOTH wire forms: a
        float leaf is quantized ONCE at page granularity, and a
        ``QuantizedLeaf`` (int8 wire, §10) is renormalized onto the
        page scale by integer code rescaling — never the old
        dequant→requant round-trip, so exactly one quantization error
        survives end-to-end."""
        ps = self.page_size
        seq_axis = kv_transfer.kv_seq_axis(self.cfg)  # on the 5-d leaf
        kmajor = self.cfg.kv_layout == "kmajor"
        quant = self.paged_dtype == "int8"
        new = []
        for bi, (spec, dst) in enumerate(zip(self.cfg.period, self.cache)):
            if spec.mixer != "attn":
                new.append(dst)
                continue
            d = dict(dst)
            for name in ("k", "v"):
                leaf = src[bi][name]                   # [Pr,1,S,kv,hd]
                pool = d[name]                         # [P,N,(ps,kv|kv,ps),hd]
                spool = d.get(name + "_scale")         # [P,N,kv] (int8 mode)
                for j, pg in enumerate(pages):
                    s0 = (first_block + j - src_offset) * ps
                    starts = (period_start, pg) + (0,) * (pool.ndim - 2)
                    if quant:
                        if isinstance(leaf, QuantizedLeaf):
                            qc = jax.lax.slice_in_dim(leaf.q, s0, s0 + ps,
                                                      axis=seq_axis)
                            sc = jax.lax.slice_in_dim(leaf.scale, s0,
                                                      s0 + ps, axis=seq_axis)
                            qpage, spage = _rescale_rows_to_page(qc, sc,
                                                                 kmajor)
                        else:
                            chunk = jax.lax.slice_in_dim(leaf, s0, s0 + ps,
                                                         axis=seq_axis)
                            qpage, spage = _quantize_page(chunk, kmajor)
                        pool = jax.lax.dynamic_update_slice(pool, qpage,
                                                            starts)
                        spool = jax.lax.dynamic_update_slice(
                            spool, spage, (period_start, pg, 0))
                        continue
                    chunk = jax.lax.slice_in_dim(leaf, s0, s0 + ps,
                                                 axis=seq_axis)
                    # the slab's batch dim becomes the pool's page dim
                    pool = jax.lax.dynamic_update_slice(
                        pool, chunk.astype(pool.dtype), starts)
                d[name] = pool
                if spool is not None:
                    d[name + "_scale"] = spool
            new.append(d)
        self.cache = tuple(new)

    def _decode_dense_src(self, src: Any) -> Any:
        """Zero-requant handoff support: an int8-paged engine receives
        still-ENCODED caches (QuantizedLeaf kv leaves land in pages via
        ``_install_pages`` without a float round-trip). The per-slot
        dense leaves (SWA rings, recurrent state, cross-attn memory)
        still need their float form, so decode ONLY the non-attn
        entries before ``_install_dense_leaves``."""
        if self.paged_dtype != "int8":
            return src
        from repro.serving import kv_compression
        return tuple(c if spec.mixer == "attn" else kv_compression.decode(c)
                     for spec, c in zip(self.cfg.period, src))

    def _install_dense_leaves(self, idx: int, cache_slice: Any,
                              period_start: int = 0) -> None:
        """Install the per-slot (non-paged) leaves of a transferred
        cache — recurrent state, SWA rings, cross-attn memory."""
        new = []
        for spec, dst, src in zip(self.cfg.period, self.cache, cache_slice):
            if spec.mixer == "attn":
                new.append(dst)
                continue

            def install(d, s):
                if d.ndim < 2 or not isinstance(s, jax.Array):
                    return d
                starts = (period_start, idx) + (0,) * (d.ndim - 2)
                return jax.lax.dynamic_update_slice(d, s.astype(d.dtype),
                                                    starts)

            new.append(jax.tree.map(install, dst, src))
        self.cache = tuple(new)

    def reserve_shared(self, tokens: Optional[Sequence[int]],
                       prompt_len: int) -> Optional[SharedReservation]:
        """Pin the longest shareable cached prefix ahead of a handoff
        so the coordinator can ship the slab WITHOUT those blocks
        (``kv_transfer.drop_leading_blocks``). Returns None when
        nothing is shareable. The pin is consumed by the next
        ``admit``/``admit_chunked`` with ``reservation=``, or by
        ``release_reservation`` if admission is abandoned."""
        if not self.paged or self.prefix_pages is None or tokens is None:
            return None
        m = self.prefix_pages.match(tuple(int(t) for t in tokens),
                                    lock=True)
        k = 0
        if isinstance(m.payload, PagedSlab):
            k = min(len(m.payload.pages), m.length // self.page_size,
                    shareable_pages(prompt_len, self.page_size))
        if k <= 0:
            self.prefix_pages.unlock(m.node)
            return None
        return SharedReservation(blocks=k, match=m)

    def release_reservation(self,
                            resv: Optional[SharedReservation]) -> None:
        if resv is not None:
            self.prefix_pages.unlock(resv.match.node)
            resv.match = None

    def _admit_paged(self, idx: int, prompt_len: int,
                     tokens: Optional[Sequence[int]],
                     reservation: Optional[SharedReservation] = None
                     ) -> Tuple[List[int], int]:
        """Build slot ``idx``'s block table for a ``prompt_len`` prompt:
        alias shared prefix pages (copy-on-write boundary), allocate the
        rest. Returns (fresh pages to install into, shared count)."""
        ps = self.page_size
        need = pages_for(prompt_len, ps)
        if need > self.num_blocks:
            self.release_reservation(reservation)
            raise OutOfPagesError(
                f"prompt of {prompt_len} tokens needs {need} blocks; "
                f"block table holds {self.num_blocks}")
        shared_pages: List[int] = []
        if reservation is not None:
            # pre-pinned match: the shipped slab omits these blocks
            shared_pages = reservation.match.payload.pages[
                :reservation.blocks]
            self.pool.retain(shared_pages)
            self.release_reservation(reservation)
        elif self.prefix_pages is not None and tokens is not None:
            # lock the providing path so _alloc's slab eviction cannot
            # free the very pages we are about to alias
            m = self.prefix_pages.match(tuple(int(t) for t in tokens),
                                        lock=True)
            try:
                if isinstance(m.payload, PagedSlab):
                    k = min(len(m.payload.pages), m.length // ps,
                            shareable_pages(prompt_len, ps))
                    shared_pages = m.payload.pages[:k]
                    self.pool.retain(shared_pages)
            finally:
                self.prefix_pages.unlock(m.node)
        try:
            fresh = self._alloc(need - len(shared_pages))
        except OutOfPagesError:
            if shared_pages:
                self.pool.release(shared_pages)
            raise
        if shared_pages and need > len(shared_pages):
            self.pool.stats.cow_copies += 1   # boundary page copied
        row = shared_pages + fresh
        self.block_tables[idx, :] = -1
        self.block_tables[idx, :len(row)] = row
        slot = self.slots[idx]
        slot.pages = list(row)
        slot.shared_pages = len(shared_pages)
        slot.src_offset = (len(shared_pages) if reservation is not None
                           else 0)
        slot.pages_seen = len(row)
        return fresh, len(shared_pages)

    def _record_prefix(self, idx: int, prompt_len: int,
                       tokens: Optional[Sequence[int]]) -> None:
        """Pin the prompt's fully-covered pages as a radix slab so later
        prompts can share them (§11 pool sharing)."""
        if self.prefix_pages is None or tokens is None:
            return
        full = shareable_pages(prompt_len, self.page_size)
        if full <= 0:
            return
        slab = PagedSlab(self.pool, self.slots[idx].pages[:full])
        # the engine's radix has no byte budget of its own (pool
        # pressure reclaims via _alloc), so insert always attaches —
        # replacing an older slab releases its pages via the §11
        # prefix-cache payload hook
        self.prefix_pages.insert(
            tuple(int(t) for t in tokens[:full * self.page_size]),
            payload=slab, payload_bytes=slab.payload_bytes)

    def admit(self, rid: int, first_token: int, prompt_len: int,
              s_out: int, cache_slice: Any,
              tokens: Optional[Sequence[int]] = None,
              reservation: Optional[SharedReservation] = None) -> int:
        """Install a transferred single-request cache into a free slot.

        Dense: ``cache_slice`` has batch dim 1 and the SAME capacity as
        this engine (kv_transfer guarantees it). Paged: kv leaves may
        have any page-aligned extent covering the prompt — they land
        directly in pool pages; with a ``reservation`` the slab omits
        the reserved shared blocks and only the remainder ships/lands.
        Raises ``NoFreeSlotError`` / ``OutOfPagesError`` (never a bare
        IndexError) when admission is impossible, so the coordinator
        can requeue or shed load."""
        try:
            idx = self._take_slot()
        except NoFreeSlotError:
            self.release_reservation(reservation)
            raise
        if self.paged:
            fresh, shared = self._admit_paged(idx, prompt_len, tokens,
                                              reservation)
            if fresh:
                self._install_pages(cache_slice, fresh, first_block=shared,
                                    src_offset=self.slots[idx].src_offset)
            self._install_dense_leaves(idx, self._decode_dense_src(
                cache_slice))
        else:

            def install(dst, src):
                if dst.ndim < 2 or not isinstance(src, jax.Array):
                    return dst
                return jax.lax.dynamic_update_slice_in_dim(
                    dst, src.astype(dst.dtype), idx, axis=1)

            self.cache = jax.tree.map(install, self.cache, cache_slice)
        slot = self.slots[idx]
        slot.rid = rid
        slot.length = prompt_len + 1
        slot.remaining = s_out - 1
        slot.active = True
        slot.admit_seq = self._admit_seq
        self._admit_seq += 1
        self.tokens[idx] = first_token
        if self.paged:
            self._record_prefix(idx, prompt_len, tokens)
        return idx

    def install_chunk(self, slot_idx: int, period_start: int,
                      chunk: Any) -> None:
        """Install one layer-group chunk of a transferred cache
        (DESIGN.md §10): ``chunk`` has the full cache pytree structure
        with every leaf's period-stack axis sliced to the group, and is
        written at ``(period_start, slot_idx)`` — per-page scatters
        into the pool when paged — via dynamic updates; chunks land
        independently, in any order."""
        if self.paged:
            slot = self.slots[slot_idx]
            self._install_pages(chunk, slot.pages[slot.shared_pages:],
                                first_block=slot.shared_pages,
                                period_start=period_start,
                                src_offset=slot.src_offset)
            self._install_dense_leaves(slot_idx,
                                       self._decode_dense_src(chunk),
                                       period_start=period_start)
            return

        def install(dst, src):
            if dst.ndim < 2 or not isinstance(src, jax.Array):
                return dst
            starts = (period_start, slot_idx) + (0,) * (dst.ndim - 2)
            return jax.lax.dynamic_update_slice(dst, src.astype(dst.dtype),
                                                starts)

        self.cache = jax.tree.map(install, self.cache, chunk)

    def admit_chunked(self, rid: int, first_token: int, prompt_len: int,
                      s_out: int, chunks: Any,
                      tokens: Optional[Sequence[int]] = None,
                      reservation: Optional[SharedReservation] = None
                      ) -> int:
        """Chunk-streaming admission: install each ``(period_start,
        chunk)`` as it lands, then activate the slot. Equivalent to
        ``admit`` once every chunk has arrived. Same explicit
        ``NoFreeSlotError``/``OutOfPagesError`` contract as ``admit``."""
        try:
            idx = self._take_slot()
        except NoFreeSlotError:
            self.release_reservation(reservation)
            raise
        slot = self.slots[idx]
        if self.paged:
            self._admit_paged(idx, prompt_len, tokens, reservation)
        slot.rid = rid   # install_chunk needs the slot claimed
        for period_start, chunk in chunks:
            self.install_chunk(idx, period_start, chunk)
        slot.length = prompt_len + 1
        slot.remaining = s_out - 1
        slot.active = True
        slot.admit_seq = self._admit_seq
        self._admit_seq += 1
        self.tokens[idx] = first_token
        if self.paged:
            self._record_prefix(idx, prompt_len, tokens)
        return idx

    # -- page lifecycle --------------------------------------------------
    def _release_slot(self, idx: int) -> None:
        slot = self.slots[idx]
        if self.paged and slot.pages:
            self.pool.release(slot.pages)
            slot.pages = []
            slot.shared_pages = 0
            self.block_tables[idx, :] = -1
        self._page_stamps[slot.rid] = slot.pages_seen
        slot.pages_seen = 0
        slot.active = False

    def pop_page_stamp(self, rid: int) -> int:
        """Distinct pages the finished/preempted request's slot ever
        held — the runtime side of the §11 page-count parity stamp."""
        return self._page_stamps.pop(rid, 0)

    def cancel(self, rid: int) -> bool:
        """§12 client cancellation mid-decode: release ``rid``'s slot
        (paged: its pages return to the pool; the page stamp is left
        for ``pop_page_stamp``). Returns False when no active slot
        holds ``rid``."""
        for i, s in enumerate(self.slots):
            if s.active and s.rid == rid:
                self._release_slot(i)
                return True
        return False

    def _preempt_youngest(self) -> int:
        """Release the most recently admitted active slot for recompute
        (vLLM-style page-exhaustion preemption: the latest request
        yields). Returns the preempted slot index, or -1."""
        cands = [i for i, s in enumerate(self.slots) if s.active]
        if not cands:
            return -1
        idx = max(cands, key=lambda i: self.slots[i].admit_seq)
        self.preempted.append(self.slots[idx].rid)
        self._release_slot(idx)
        return idx

    def _grow(self, idx: int) -> bool:
        """Ensure slot ``idx`` has a page for the position it is about
        to write; on pool exhaustion the youngest active slot (possibly
        this one) is preempted for recompute. Returns False when the
        slot itself was preempted."""
        slot = self.slots[idx]
        need = pages_for(slot.length, self.page_size)  # writes length-1
        while len(slot.pages) < need:
            if len(slot.pages) >= self.num_blocks:
                # block table full: behave like dense capacity overflow
                return True
            try:
                pg = self._alloc(1)
            except OutOfPagesError:
                if self._preempt_youngest() == idx:
                    return False
                continue
            self.block_tables[idx, len(slot.pages)] = pg[0]
            slot.pages.extend(pg)
            slot.pages_seen += 1
        return True

    # -- decode ----------------------------------------------------------
    def step(self) -> List[Tuple[int, int, bool]]:
        """Advance every active slot one token.

        Returns [(rid, token, finished)] for active slots. Paged-mode
        page exhaustion preempts youngest slots first (their rids land
        in ``preempted`` for the coordinator to recompute) rather than
        failing the step."""
        if self.paged:
            for i, s in enumerate(self.slots):
                if s.active:
                    self._grow(i)
        if not any(s.active for s in self.slots):
            return []
        positions = np.array([max(s.length - 1, 0) for s in self.slots],
                             np.int32)
        if self.paged:
            toks, self.cache = self._step(self.params, self.cache,
                                          jnp.asarray(self.tokens),
                                          jnp.asarray(positions),
                                          jnp.asarray(self.block_tables))
        else:
            toks, self.cache = self._step(self.params, self.cache,
                                          jnp.asarray(self.tokens),
                                          jnp.asarray(positions))
        toks = np.asarray(toks)
        out = []
        for i, s in enumerate(self.slots):
            if not s.active:
                continue
            s.length += 1
            s.remaining -= 1
            self.tokens[i] = toks[i]
            finished = s.remaining <= 0 or s.length >= self.capacity
            out.append((s.rid, int(toks[i]), finished))
            if finished:
                if self.paged:
                    self._release_slot(i)
                else:
                    s.active = False
        return out
