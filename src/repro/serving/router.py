"""Production router tier (DESIGN.md §12): priority/SLO-aware routing,
cancellation, and failover over N replicas.

One ``Router`` fronts a fleet of replica handles — each a full
disaggregated cluster (prefill engines + KV handoff + decode engines).
The SAME Router implementation drives both domains:

  * runtime: ``CoordinatorReplica`` wraps a ``Coordinator`` and its
    long-lived ``ServeSession`` (real JAX execution);
  * scheduling: ``simulator.SimReplica`` mirrors the session's
    three-stage step pipeline over a virtual ``StepClock``.

Parity is by construction: every router decision — admission,
priority/aging pop order, dispatch target, failover re-dispatch,
cancellation — is a pure function of router step indices and replica
queue occupancy, never of wall-clock time. Driving the same seeded
trace through either replica kind therefore yields EXACTLY the same
``admitted/rejected/cancelled/redispatched`` counters and per-class
cache hit rates (the §12 parity contract, pinned by tests).

Queue discipline: the bounded admission queue orders on
``(effective_priority, submission_seq)`` where effective priority ages
toward 0 by one class every ``age_every`` router steps — so batch
work behind a flood of interactive traffic is delayed by a bounded
number of steps, never starved. Overflow raises the typed
``AdmissionRejected`` (the request's lifecycle records REJECTED; it is
never silently dropped).

Failover protocol: ``kill(idx)`` marks a replica dead and drains its
non-terminal requests. Each is re-dispatched through the §11
recompute-from-prompt path: lifecycle ``restart()`` (preserving the
§9/§10 stamps that reflect real work done), emitted tokens folded into
the prompt, the remaining token budget recomputed, and the entry
re-enqueued with its ORIGINAL seq/enqueue-step so FIFO-within-class
and the aging bound survive the failure. Tokens already streamed stay
streamed — the router's canonical per-request stream is append-only,
which is what makes "no loss, no duplication" testable. Re-dispatched
requests bypass the prefix caches in both domains (their folded
prompts contain generated tokens; caching them would pollute the radix
trees and their hit accounting).
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import (Any, Callable, Dict, List, Optional, Sequence, Tuple)

import numpy as np

from repro.serving.prefix_cache import route_score
from repro.serving.request import Request, RequestState
from repro.serving.telemetry import TraceRecorder, WindowedGauges

#: Conventional priority classes (smaller = more urgent). Any int works.
PRIORITY_INTERACTIVE = 0
PRIORITY_STANDARD = 1
PRIORITY_BATCH = 2


class AdmissionRejected(RuntimeError):
    """Typed admission-control refusal: the bounded queue is full."""

    def __init__(self, rid: int, queue_len: int, capacity: int):
        super().__init__(
            f"admission queue full ({queue_len}/{capacity}): "
            f"request {rid} rejected")
        self.rid = rid
        self.queue_len = queue_len
        self.capacity = capacity


class FleetExhausted(RuntimeError):
    """Typed refusal to take the fleet's LAST live replica out of
    service (DESIGN.md §13): ``kill()``/``drain()`` would strand the
    pending work with nowhere to (re-)dispatch and no capacity
    provisioning or warming behind it. An elastic controller registers
    ``Router.capacity_hook`` — while a join is in flight, the same kill
    PARKS the drained requests in the admission queue instead (they
    dispatch when the joining replica goes LIVE)."""

    def __init__(self, idx: int, unfinished: int):
        super().__init__(
            f"replica {idx} is the last live replica and {unfinished} "
            f"requests are pending with no capacity joining")
        self.idx = idx
        self.unfinished = unfinished


class StepClock:
    """Virtual clock for the scheduling domain: ``run_trace`` sets it to
    ``step * dt`` each router step, so simulated lifecycle stamps are a
    deterministic function of step indices."""

    def __init__(self):
        self.value = 0.0

    def __call__(self) -> float:
        return self.value


@dataclasses.dataclass
class _QEntry:
    life: Request
    seq: int              # admission order (never reassigned on failover)
    enqueue_step: int     # router step of FIRST admission (aging base)


class AdmissionQueue:
    """Bounded priority queue with aging (DESIGN.md §12).

    Pop order is ``(effective_priority, seq)`` where::

        effective_priority(step) =
            max(0, priority - (step - enqueue_step) // age_every)

    — strict priority order between classes, FIFO within a class, and
    every waiting request climbs one class per ``age_every`` router
    steps, so low-priority work is delayed by a BOUNDED number of
    steps: if a request of class p dispatches while one of class q < p
    still waits, the dispatched one must have waited at least
    ``age_every * (p - q)`` steps (the aging bound the property tests
    pin). ``push`` raises the typed ``AdmissionRejected`` at capacity;
    ``force=True`` bypasses the bound for failover re-admission
    (already-admitted work cannot be retroactively rejected).

    ``age_every="auto"`` derives the aging rate from observed per-class
    arrival rates instead of a fixed parameter (DESIGN.md §13): a
    waiting request should climb one class per arrival of traffic that
    can OVERTAKE it (any strictly more urgent class), so the queue
    ahead of a low-priority request cannot grow without bound —
    promotion keeps pace with overtaking pressure. Concretely::

        age_every = clamp(round(1 / rate_hi), 1, auto_cap)

    where ``rate_hi`` is arrivals-per-step of classes more urgent than
    the least urgent observed class, over the trailing arrival window
    (``observe_arrival`` feeds it; the router calls it on every
    submit). The starvation bound is UNCHANGED: it holds with the
    ``age_every`` in effect at pop time, because effective priorities
    at one pop are all computed under the same rate.
    """

    def __init__(self, capacity: int = 64, age_every=8,
                 rate_window: int = 128, auto_cap: int = 64):
        self.capacity = int(capacity)
        self.auto = age_every == "auto"
        self.auto_cap = max(1, int(auto_cap))
        self.age_every = (8 if self.auto else max(1, int(age_every)))
        self._arrivals: collections.deque = collections.deque(
            maxlen=rate_window)
        self._entries: List[_QEntry] = []

    def __len__(self) -> int:
        return len(self._entries)

    def observe_arrival(self, priority: int, step: int) -> None:
        """Feed one arrival (admitted OR rejected — both are pressure)
        to the auto-aging derivation. No-op at a fixed rate."""
        if not self.auto:
            return
        self._arrivals.append((int(step), int(priority)))
        self.age_every = self._derived_age_every()

    def _derived_age_every(self) -> int:
        if len(self._arrivals) < 2:
            return self.age_every
        pmax = max(p for _, p in self._arrivals)
        steps = [s for s, p in self._arrivals if p < pmax]
        span = self._arrivals[-1][0] - self._arrivals[0][0]
        if not steps or span <= 0:
            return self.auto_cap      # nothing can overtake: age slowly
        rate_hi = len(steps) / span
        return min(self.auto_cap, max(1, int(round(1.0 / rate_hi))))

    def effective_priority(self, entry: _QEntry, step: int) -> int:
        waited = max(0, step - entry.enqueue_step)
        return max(0, entry.life.priority - waited // self.age_every)

    def push(self, entry: _QEntry, force: bool = False) -> None:
        if not force and len(self._entries) >= self.capacity:
            raise AdmissionRejected(entry.life.rid, len(self._entries),
                                    self.capacity)
        self._entries.append(entry)

    def pop(self, step: int) -> _QEntry:
        i = min(range(len(self._entries)),
                key=lambda j: (self.effective_priority(self._entries[j],
                                                      step),
                               self._entries[j].seq))
        return self._entries.pop(i)

    def pop_fifo(self) -> _QEntry:
        """Admission-order pop, ignoring priority — the round-robin
        baseline's discipline."""
        i = min(range(len(self._entries)),
                key=lambda j: self._entries[j].seq)
        return self._entries.pop(i)

    def remove(self, rid: int) -> Optional[_QEntry]:
        for i, e in enumerate(self._entries):
            if e.life.rid == rid:
                return self._entries.pop(i)
        return None

    def rids(self) -> List[int]:
        return [e.life.rid for e in self._entries]


#: Streaming callback: (rid, token, finished).
TokenCallback = Callable[[int, int, bool], None]


@dataclasses.dataclass
class _RouterEntry:
    life: Request
    prompt: Optional[Tuple[int, ...]]   # original prompt tokens
    max_new: int                        # original token budget
    seq: int
    submit_step: int
    on_token: Optional[TokenCallback] = None
    replica: Optional[int] = None       # current home (None while queued)
    tokens: List[int] = dataclasses.field(default_factory=list)


class Router:
    """Fronts N replica handles with admission control, priority/SLO-
    aware dispatch, cancellation, and failover (DESIGN.md §12).

    A replica handle is duck-typed — ``CoordinatorReplica`` (runtime)
    and ``simulator.SimReplica`` (scheduling domain) both provide::

        alive: bool
        max_inflight: int                      # dispatch window
        matched_len(tokens) -> int             # best prefix-cache match
        submit(life, prompt, max_new, *, on_token, no_cache, start_index)
        step() -> bool
        cancel(rid) -> bool
        drain_in_flight() -> List[Request]     # failover handoff

    ``policy`` picks the dispatch rule: ``"slo"`` pops the priority/
    aging queue and routes by the §9 ``route_score`` (matched-prefix
    ratio vs normalized flow-weighted load; exact score ties break to
    the LOWEST replica index — deterministic, seed-reproducible);
    ``"rr"`` is the FIFO/round-robin baseline the benchmark beats.
    """

    def __init__(self, replicas: Sequence[Any], *,
                 queue_capacity: int = 64, age_every: int = 8,
                 policy: str = "slo", cache_alpha: float = 2.0,
                 route_weights: Optional[Sequence[float]] = None,
                 clock: Optional[Callable[[], float]] = None,
                 gauge_window: int = 64,
                 telemetry: Optional[TraceRecorder] = None,
                 calibration=None):
        assert policy in ("slo", "rr"), policy
        self.replicas = list(replicas)
        n = len(self.replicas)
        assert n > 0, "router needs at least one replica"
        self.policy = policy
        self.cache_alpha = cache_alpha
        self.queue = AdmissionQueue(queue_capacity, age_every)
        self._clock = clock or time.perf_counter
        self._virtual = clock if isinstance(clock, StepClock) else None
        self._t0 = 0.0 if self._virtual is not None else self._clock()
        w = list(route_weights or [1.0] * n)
        assert len(w) == n
        self._weights_raw = [float(x) for x in w]
        self._weights = np.asarray(w, float) / sum(w)
        self._routed = np.zeros(n)
        self._inflight = [0] * n
        #: replicas accepting no NEW work while their in-flight finishes
        self._draining: set = set()
        #: elastic-fleet hooks (DESIGN.md §13). ``capacity_hook`` answers
        #: "is capacity provisioning/warming?" — consulted before
        #: declaring the fleet exhausted; ``on_submit``/``on_dispatch``
        #: let a FleetController observe demand and stamp cold-window
        #: penalties without owning the drive loop.
        self.capacity_hook: Optional[Callable[[], bool]] = None
        self.on_submit: Optional[Callable[[Request, int], None]] = None
        self.on_dispatch: Optional[Callable[[Request, int, int], None]] = None
        self._entries: Dict[int, _RouterEntry] = {}
        self._order: List[int] = []
        self._active: set = set()           # rids dispatched, not terminal
        self._seq = 0
        self._rr = 0
        self._step_idx = 0
        self._decode_tokens = 0
        self._makespan = 0.0
        #: (rid, priority, submit_step, dispatch_step, replica,
        #:  redispatch) rows — the property tests' window into ordering
        self.dispatch_log: List[Dict[str, int]] = []
        #: §14 telemetry: rolling-window live gauges fed at the terminal
        #: sweep (both domains drive this same code, so the windows are
        #: parity-exact), and an optional event bus for stage events /
        #: utilization series (None = zero overhead)
        self.gauges = WindowedGauges(gauge_window)
        self.telemetry = telemetry
        #: §15 cost-model calibration (``CalibrationStore`` or None):
        #: predicted stage costs stamped at dispatch (after the fleet
        #: hook priced any warm-up), observed-vs-predicted errors
        #: scored at the terminal sweep — both on shared router code,
        #: so two domains' stores agree exactly on the same trace
        self.calibration = calibration

    # -- clock ----------------------------------------------------------
    def now(self) -> float:
        return self._clock() - self._t0

    @property
    def step_index(self) -> int:
        return self._step_idx

    # -- admission ------------------------------------------------------
    def submit(self, life: Request,
               on_token: Optional[TokenCallback] = None) -> int:
        """Admit ``life`` into the bounded queue. Raises the typed
        ``AdmissionRejected`` on overflow — the record is stamped
        REJECTED first, so rejected traffic still shows up in metrics
        (nothing is silently dropped). ``life.arrival`` is re-stamped
        to the router clock: queueing delay counts against TTFT/SLO."""
        rid = life.rid
        assert rid not in self._entries, f"duplicate rid {rid}"
        life.arrival = self.now()
        prompt = (tuple(int(t) for t in life.tokens)
                  if life.tokens is not None else None)
        entry = _RouterEntry(life=life, prompt=prompt, max_new=life.s_out,
                             seq=self._seq, submit_step=self._step_idx,
                             on_token=on_token)
        self._seq += 1
        self._entries[rid] = entry
        self._order.append(rid)
        self.queue.observe_arrival(life.priority, self._step_idx)
        if self.on_submit is not None:
            self.on_submit(life, self._step_idx)
        if len(self.queue) >= self.queue.capacity:
            life.advance(RequestState.REJECTED, self.now())
            if self.telemetry is not None:
                self.telemetry.emit("reject", self.now(), rid=rid,
                                    queue_len=len(self.queue))
            raise AdmissionRejected(rid, len(self.queue),
                                    self.queue.capacity)
        self.queue.push(_QEntry(life, entry.seq, entry.submit_step))
        return rid

    # -- cancellation ---------------------------------------------------
    def cancel(self, rid: int) -> bool:
        """Cancel at any lifecycle stage. Queued requests leave the
        admission queue; dispatched ones are cancelled inside their
        replica (which reclaims decode pages / prefix pins / queue
        slots on the stage-specific edge). Returns False when the
        request is unknown or already terminal."""
        entry = self._entries.get(rid)
        if entry is None or entry.life.is_terminal:
            return False
        qe = self.queue.remove(rid)
        if qe is not None:
            entry.life.advance(RequestState.CANCELLED, self.now())
            return True
        idx = entry.replica
        if idx is None or not self.replicas[idx].alive:
            return False
        if self.replicas[idx].cancel(rid):
            self._inflight[idx] -= 1
            self._active.discard(rid)
            return True
        return False

    # -- fleet membership (DESIGN.md §13) -------------------------------
    def _capacity_pending(self) -> bool:
        return bool(self.capacity_hook is not None and self.capacity_hook())

    def spawn(self, replica: Any, weight: float = 1.0) -> int:
        """A new replica JOINS the fleet (the arriving half ``kill()``
        is the departing half of): append its handle, extend the
        routing state, and return its index. The replica starts cold —
        empty prefix cache, zero in-flight — and is immediately a
        dispatch candidate; lifecycle gating (PROVISIONING/WARMING
        delays, cold-window penalties) belongs to the FleetController,
        which only calls spawn once the replica is LIVE."""
        assert replica.alive, "spawned replica must be alive"
        self.replicas.append(replica)
        self._weights_raw.append(float(weight))
        self._weights = (np.asarray(self._weights_raw, float)
                         / sum(self._weights_raw))
        self._routed = np.append(self._routed, 0.0)
        self._inflight.append(0)
        return len(self.replicas) - 1

    def drain(self, idx: int) -> None:
        """Gracefully retire replica ``idx`` — ``kill()`` without the
        data loss: no NEW dispatches, in-flight requests run to
        completion, and ``step()`` marks it dead once its last request
        finishes. Raises ``FleetExhausted`` when ``idx`` is the last
        live undraining replica and no capacity is joining (queued work
        would wait forever)."""
        rep = self.replicas[idx]
        if not rep.alive or idx in self._draining:
            return
        others = any(r.alive and j not in self._draining
                     for j, r in enumerate(self.replicas) if j != idx)
        if (not others and self.unfinished > 0
                and not self._capacity_pending()):
            raise FleetExhausted(idx, self.unfinished)
        self._draining.add(idx)

    def set_route_weights(self, weights: Sequence[float]) -> None:
        """Adopt new per-replica flow weights (the §13 capacity-drift
        re-solve feeds the solved φ→δ flow shares back into dispatch)."""
        w = [float(x) for x in weights]
        assert len(w) == len(self.replicas) and sum(w) > 0
        self._weights_raw = w
        self._weights = np.asarray(w, float) / sum(w)

    # -- failover -------------------------------------------------------
    def kill(self, idx: int, park: bool = False) -> List[int]:
        """Mark replica ``idx`` dead and re-dispatch its in-flight
        requests (§12 failover). Returns the re-queued rids.

        Killing the LAST live replica while work is pending raises the
        typed ``FleetExhausted`` — unless capacity is provisioning/
        warming behind it (``capacity_hook``) or ``park=True``, in
        which case the drained requests are parked in the admission
        queue until a replica is LIVE again."""
        rep = self.replicas[idx]
        if not rep.alive:
            return []
        # a DRAINING survivor doesn't count: it takes no new dispatches,
        # so work re-queued off the killed replica would strand anyway
        others = any(r.alive and j not in self._draining
                     for j, r in enumerate(self.replicas) if j != idx)
        if (not others and not park and self.unfinished > 0
                and not self._capacity_pending()):
            raise FleetExhausted(idx, self.unfinished)
        rep.alive = False
        self._draining.discard(idx)
        if self.telemetry is not None:
            self.telemetry.emit("kill", self.now(), track=f"replica:{idx}",
                                inflight=self._inflight[idx])
        moved = []
        for life in rep.drain_in_flight():
            entry = self._entries[life.rid]
            self._inflight[idx] -= 1
            self._active.discard(life.rid)
            self._redispatch(entry)
            moved.append(life.rid)
        return moved

    def _redispatch(self, entry: _RouterEntry) -> None:
        """§11 recompute-from-prompt across replicas: restart the
        lifecycle (preserving §9/§10 stamps — that work really
        happened), fold the already-emitted tokens into the prompt,
        and re-enqueue with the ORIGINAL seq/enqueue-step so queue
        ordering guarantees survive the failure. The dead replica's
        page stamps are unreachable (its allocator died with it)."""
        life = entry.life
        snap = (life.kv_bytes_raw, life.kv_bytes_wire,
                life.kv_serialized_s, life.kv_overlap_s, life.cached_len)
        life.restart()
        (life.kv_bytes_raw, life.kv_bytes_wire, life.kv_serialized_s,
         life.kv_overlap_s, life.cached_len) = snap
        life.redispatches += 1
        entry.replica = None
        self.queue.push(_QEntry(life, entry.seq, entry.submit_step),
                        force=True)

    # -- dispatch -------------------------------------------------------
    def _candidates(self) -> List[int]:
        return [i for i, rep in enumerate(self.replicas)
                if rep.alive and i not in self._draining
                and self._inflight[i] < rep.max_inflight]

    def _pick_replica(self, entry: _RouterEntry,
                      cands: List[int]) -> int:
        if self.policy == "rr":
            idx = cands[self._rr % len(cands)]
            self._rr += 1
            return idx
        base = (self._routed + 1) / np.maximum(self._weights, 1e-9)
        lo = float(min(base[i] for i in cands))
        cur = self._current_prompt(entry)
        no_cache = entry.life.redispatches > 0
        scores = {}
        for i in cands:
            hit = 0.0
            if cur is not None and not no_cache:
                hit = self.replicas[i].matched_len(cur) / max(len(cur), 1)
            scores[i] = route_score(hit, float(base[i]), lo,
                                    self.cache_alpha)
        # exact ties break to the lowest replica index (deterministic)
        return max(cands, key=lambda i: (scores[i], -i))

    def _current_prompt(self, entry: _RouterEntry
                        ) -> Optional[Tuple[int, ...]]:
        if entry.prompt is None:
            return None
        return entry.prompt + tuple(entry.tokens)

    def _make_cb(self, entry: _RouterEntry) -> TokenCallback:
        def cb(rid: int, tok: int, fin: bool) -> None:
            entry.tokens.append(int(tok))
            self._decode_tokens += 1
            if entry.on_token is not None:
                entry.on_token(rid, tok, fin)
        return cb

    def _dispatch(self) -> bool:
        did = False
        while len(self.queue):
            cands = self._candidates()
            if not cands:
                break
            qe = (self.queue.pop(self._step_idx) if self.policy == "slo"
                  else self.queue.pop_fifo())
            entry = self._entries[qe.life.rid]
            idx = self._pick_replica(entry, cands)
            self._routed[idx] += 1
            prompt = self._current_prompt(entry)
            start = len(entry.tokens)
            self.replicas[idx].submit(
                entry.life, prompt, entry.max_new - start,
                on_token=self._make_cb(entry),
                no_cache=entry.life.redispatches > 0,
                start_index=start)
            entry.replica = idx
            self._inflight[idx] += 1
            self._active.add(entry.life.rid)
            if self.on_dispatch is not None:
                self.on_dispatch(entry.life, idx, self._step_idx)
            if self.calibration is not None:
                # after on_dispatch: the predicted warm-up is whatever
                # cold-window penalty the controller just priced
                self.calibration.stamp(entry.life, idx)
            self.dispatch_log.append(dict(
                rid=entry.life.rid, priority=entry.life.priority,
                submit_step=qe.enqueue_step,
                dispatch_step=self._step_idx, replica=idx,
                redispatch=entry.life.redispatches))
            if self.telemetry is not None:
                kind = ("redispatch" if entry.life.redispatches
                        else "dispatch")
                self.telemetry.emit(kind, self.now(),
                                    track=f"replica:{idx}",
                                    rid=entry.life.rid,
                                    step=self._step_idx)
            did = True
        return did

    # -- driving --------------------------------------------------------
    def step(self) -> bool:
        """One router step: dispatch from the queue, step every alive
        replica with work, collect finished requests. Returns whether
        anything progressed."""
        progressed = self._dispatch()
        for i, rep in enumerate(self.replicas):
            if rep.alive and self._inflight[i] > 0:
                progressed = bool(rep.step()) or progressed
        for rid in [r for r in self._active
                    if self._entries[r].life.is_terminal]:
            entry = self._entries[rid]
            self._active.discard(rid)
            self._inflight[entry.replica] -= 1
            if entry.life.phase is RequestState.DONE:
                # canonical total across failover re-dispatches (a
                # replica's own count restarts from the folded prompt)
                entry.life.tokens_out = len(entry.tokens)
            if entry.life.decode_end is not None:
                self._makespan = max(self._makespan, entry.life.decode_end)
            # §14: feed the live window at the terminal edge — shared
            # router code, so both domains observe identical sequences
            self.gauges.observe(entry.life, self._step_idx)
            # §15: score predicted-vs-observed stage costs on the same
            # edge (same order ⇒ identical EWMA folds in both domains)
            if self.calibration is not None:
                self.calibration.observe(entry.life, self.now())
        for i in list(self._draining):       # graceful-retire completion
            if self._inflight[i] == 0:
                self.replicas[i].alive = False
                self._draining.discard(i)
        self.gauges.advance(self._step_idx)
        if self.telemetry is not None:
            t = self.now()
            self.telemetry.gauge("queue_depth", t, len(self.queue))
            for i, rep in enumerate(self.replicas):
                if rep.alive:
                    self.telemetry.gauge("inflight", t, self._inflight[i],
                                         track=f"replica:{i}")
        self._step_idx += 1
        return progressed

    @property
    def unfinished(self) -> int:
        return len(self._active) + len(self.queue)

    def run_trace(self, trace: Sequence[Request], dt: float = 0.05,
                  failures: Optional[Dict[int, Any]] = None,
                  cancels: Optional[Dict[int, Sequence[int]]] = None,
                  on_token: Optional[TokenCallback] = None,
                  on_step: Optional[Callable[[int], None]] = None,
                  max_steps: int = 200_000) -> "ServeMetrics":
        """Drive a full trace to completion: at router step k (time
        ``k * dt``) apply scheduled replica failures (``failures``:
        {step: replica_idx or [idx, ...]}), submit every request whose
        ``arrival <= k * dt`` (admission overflow records REJECTED and
        moves on), apply scheduled cancellations (``cancels``:
        {step: [rid, ...]}), call ``on_step(k)`` (the FleetController's
        control point — it sees this step's arrivals, before dispatch),
        then ``step()``. Arrival pacing is in STEPS, identically in
        both domains — the parity contract."""
        failures = failures or {}
        cancels = cancels or {}
        pending = collections.deque(sorted(trace, key=lambda r: r.arrival))
        idle = 0
        while pending or self.unfinished:
            s = self._step_idx
            if self._virtual is not None:
                self._virtual.value = s * dt
            kills = failures.get(s, ())
            for idx in ([kills] if isinstance(kills, int) else kills):
                # with an elastic controller attached (capacity_hook
                # registered), a crash of the last replica PARKS the
                # drained work — the controller's repair policy will
                # provision a replacement (§13); bare fleets still get
                # the typed FleetExhausted
                self.kill(idx, park=self.capacity_hook is not None)
            while pending and pending[0].arrival <= s * dt + 1e-9:
                try:
                    self.submit(pending.popleft(), on_token=on_token)
                except AdmissionRejected:
                    pass                      # recorded as REJECTED
            for rid in cancels.get(s, ()):
                self.cancel(rid)
            if on_step is not None:
                on_step(s)
            progressed = self.step()
            if not pending and self.unfinished and not progressed:
                if (not any(rep.alive for rep in self.replicas)
                        and not self._capacity_pending()):
                    raise RuntimeError(
                        f"router: every replica is dead with "
                        f"{self.unfinished} requests unfinished")
                idle += 1
                if idle > 1000:
                    raise RuntimeError(
                        f"router stalled: {self.unfinished} unfinished, "
                        "no progress in 1000 steps")
            else:
                idle = 0
            if self._step_idx > max_steps:
                raise RuntimeError("router: max_steps exceeded")
        return self.metrics()

    # -- results --------------------------------------------------------
    def tokens(self, rid: int) -> List[int]:
        """The canonical (append-only) token stream for ``rid`` —
        survives failover re-dispatch intact."""
        return list(self._entries[rid].tokens)

    def results(self) -> List[Tuple[int, List[int], Request]]:
        """(rid, tokens, lifecycle) in submission order."""
        return [(rid, list(self._entries[rid].tokens),
                 self._entries[rid].life) for rid in self._order]

    def metrics(self) -> "ServeMetrics":
        from repro.serving.metrics import ServeMetrics
        return ServeMetrics(
            requests=[self._entries[rid].life for rid in self._order],
            makespan=self._makespan, decode_tokens=self._decode_tokens)

    @property
    def counters(self) -> Dict[str, int]:
        """The §12 conservation counters, derived from the lifecycle
        records (admitted + rejected + cancelled == submitted)."""
        m = self.metrics()
        return {"admitted": m.admitted, "rejected": m.rejected,
                "cancelled": m.cancelled, "redispatched": m.redispatched}


class CoordinatorReplica:
    """Runtime replica handle: one ``Coordinator`` plus one long-lived
    ``ServeSession`` driven by the router's shared clock. The dispatch
    window (``max_inflight``) is the replica's total decode slots plus
    its prefill micro-batch — enough to keep every stage fed without
    letting the router bury a replica in queued work it can't start
    (queue depth belongs to the router, where priorities exist)."""

    def __init__(self, coord: Any, max_prefill_batch: int = 4,
                 clock: Optional[Callable[[], float]] = None,
                 telemetry: Optional[TraceRecorder] = None):
        self.coord = coord
        self.session = coord.session(max_prefill_batch=max_prefill_batch,
                                     clock=clock, telemetry=telemetry)
        self.alive = True

    @property
    def max_inflight(self) -> int:
        return (sum(e.num_slots for e in self.coord.decode_engines)
                + self.session.max_prefill_batch)

    def matched_len(self, tokens: Sequence[int]) -> int:
        caches = self.coord.prefix_caches
        if not caches:
            return 0
        return max(c.matched_len(tokens) for c in caches)

    def submit(self, life: Request, prompt: Sequence[int], max_new: int,
               *, on_token: Optional[TokenCallback] = None,
               no_cache: bool = False, start_index: int = 0) -> None:
        from repro.serving.coordinator import ServeRequest
        assert prompt is not None, \
            "runtime replicas need prompt token content"
        req = ServeRequest(life.rid, np.asarray(prompt, np.int32),
                           max_new, no_cache=no_cache)
        self.session.submit(req, on_token=on_token, life=life)

    def step(self) -> bool:
        return self.session.step()

    def cancel(self, rid: int) -> bool:
        return self.session.cancel(rid)

    def drain_in_flight(self) -> List[Request]:
        return self.session.drain_in_flight()
