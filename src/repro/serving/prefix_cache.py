"""Shared-prefix KV reuse: a token-level radix-tree cache (DESIGN.md §9).

One tree per prefill replica, in BOTH domains. Heavy real traffic
(multi-turn chat, shared system prompts, few-shot agentic templates)
re-prefills the same prefix tokens endlessly; caching KV by token
prefix and prefilling only the uncached suffix is the dominant
production optimization (SGLang's RadixAttention, vLLM's prefix
caching). The two domains use the same tree:

  * runtime (``serving/coordinator.py``): nodes carry a real KV slab —
    the single-request cache pytree a finished prefill produced, at the
    engine's slot capacity (``kv_transfer`` shape discipline). A hit
    seeds ``PrefillEngine.prefill_suffix``.
  * simulator (``serving/simulator.py``): nodes carry no payload; the
    tree only answers "how many prompt tokens does this replica already
    hold", and the cost model charges prefill on the uncached suffix.

Accounting follows the domain: the simulator charges
``bytes_per_token`` per stored edge token (radix sharing stores a
shared prefix once); the runtime charges each attached slab's real
buffer bytes (slabs are capacity-padded, so per-token accounting would
undercount). Budgets come from the cost model's memory headroom
(``repro.core.cost_model.prefix_cache_budget``).

Eviction is LRU over *unpinned leaves* only: ``match(..., lock=True)``
ref-counts the path that backs an in-flight prefill, and interior
nodes are never dropped before their children — so a pinned prefix can
never be yanked out from under a running suffix prefill.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Dict, List, Optional, Sequence, Tuple


def _release_payload(payload: Any) -> None:
    """Payloads may own external resources — §11 ``PagedSlab`` nodes
    pin ref-counted pages of the decode engine's pool. Eviction,
    replacement, and ``clear`` call the payload's ``release()`` (when
    it has one) so those pages return to the pool with the node."""
    rel = getattr(payload, "release", None)
    if callable(rel):
        rel()


def _common_len(a: Sequence[int], b: Sequence[int]) -> int:
    n = min(len(a), len(b))
    i = 0
    while i < n and a[i] == b[i]:
        i += 1
    return i


class _Node:
    """One radix edge: ``edge`` tokens appended to the parent's path."""

    __slots__ = ("edge", "children", "parent", "refs", "last_access",
                 "payload", "payload_bytes", "depth")

    def __init__(self, edge: Tuple[int, ...], parent: Optional["_Node"]):
        self.edge = edge
        self.children: Dict[int, "_Node"] = {}
        self.parent = parent
        self.refs = 0                  # in-flight readers pinning this path
        self.last_access = 0
        self.payload: Any = None       # runtime KV slab (None in simulator)
        self.payload_bytes = 0
        self.depth = (parent.depth if parent else 0) + len(edge)


@dataclasses.dataclass
class MatchResult:
    """Longest cached prefix of a prompt on one replica.

    ``length`` tokens are already held; ``payload`` (runtime only) is a
    KV slab covering at least ``length`` positions; ``node`` is the
    pinned handle to pass to ``unlock`` when ``lock=True`` was used."""
    length: int
    payload: Any = None
    node: Optional[_Node] = None


@dataclasses.dataclass
class CacheStats:
    lookups: int = 0
    hits: int = 0                 # lookups with length > 0
    reused_tokens: int = 0
    inserted_tokens: int = 0
    evicted_tokens: int = 0


class PrefixCache:
    """Token-level radix tree with ref-counted nodes and LRU leaf
    eviction under a byte budget (DESIGN.md §9)."""

    def __init__(self, capacity_bytes: Optional[float] = None,
                 bytes_per_token: float = 0.0):
        self.capacity_bytes = (float("inf") if capacity_bytes is None
                               else float(capacity_bytes))
        self.bytes_per_token = float(bytes_per_token)
        self.root = _Node((), None)
        self.used_bytes = 0.0
        self.stats = CacheStats()
        self._clock = itertools.count(1)

    # -- internals ------------------------------------------------------
    def _touch(self, node: _Node) -> None:
        t = next(self._clock)
        while node is not None:
            node.last_access = t
            node = node.parent

    def _walk(self, tokens: Sequence[int]) -> Tuple[_Node, int]:
        """Descend as far as ``tokens`` match. Returns (deepest node the
        match reaches into, matched length). The node may be matched
        only partway through its edge (matched < node.depth)."""
        node, i = self.root, 0
        while i < len(tokens):
            child = node.children.get(tokens[i])
            if child is None:
                break
            c = _common_len(child.edge, tokens[i:])
            i += c
            node = child
            if c < len(child.edge):
                break
        return node, i

    def _split(self, node: _Node, at: int) -> _Node:
        """Split ``node``'s edge after ``at`` tokens; returns the new
        parent holding the first ``at`` tokens. Byte usage, refs, and
        payload placement are preserved (payload stays on the deeper
        half — it covers the full original path)."""
        assert 0 < at < len(node.edge)
        top = _Node(node.edge[:at], node.parent)
        top.refs = node.refs           # a pinned path pins every ancestor
        top.last_access = node.last_access
        node.parent.children[top.edge[0]] = top
        node.edge = node.edge[at:]
        node.parent = top
        node.depth = top.depth + len(node.edge)
        top.children[node.edge[0]] = node
        return top

    def _find_payload(self, node: _Node) -> Any:
        """Any slab in ``node``'s subtree covers the path prefix through
        ``node`` (slabs are inserted for full prompts, so a descendant's
        slab is a superstring's KV)."""
        stack = [node]
        while stack:
            n = stack.pop()
            if n.payload is not None:
                return n.payload, n
            stack.extend(n.children.values())
        return None, None

    # -- queries --------------------------------------------------------
    def match(self, tokens: Sequence[int], lock: bool = False) -> MatchResult:
        """Longest cached prefix of ``tokens``. With ``lock=True`` the
        providing path is pinned (ref-counted) until ``unlock``."""
        self.stats.lookups += 1
        if not len(tokens):
            return MatchResult(0)
        node, length = self._walk(tokens)
        if length == 0:
            return MatchResult(0)
        self.stats.hits += 1
        payload, holder = (None, None)
        if node is not self.root:
            payload, holder = self._find_payload(node)
        self._touch(node)
        pinned = None
        if lock:
            pinned = holder if holder is not None else node
            n = pinned
            while n is not None:
                n.refs += 1
                n = n.parent
        return MatchResult(length, payload, pinned)

    def unlock(self, node: Optional[_Node]) -> None:
        while node is not None:
            node.refs -= 1
            assert node.refs >= 0, "prefix-cache refcount underflow"
            node = node.parent

    def matched_len(self, tokens: Sequence[int]) -> int:
        """Match length without touching stats or LRU order (routing
        probes score every replica; only the winner 'uses' its cache)."""
        if not len(tokens):
            return 0
        _, length = self._walk(tokens)
        return length

    # -- insertion ------------------------------------------------------
    def insert(self, tokens: Sequence[int], payload: Any = None,
               payload_bytes: int = 0) -> int:
        """Record that this replica now holds KV for ``tokens``.

        Returns the number of NEW tokens stored (0 if fully present or
        the budget cannot fit them). ``payload`` (runtime) is attached
        at the deepest node of the path; replacing an existing slab
        swaps the byte charge."""
        tokens = tuple(int(t) for t in tokens)
        if not tokens:
            return 0
        node, length = self._walk(tokens)
        if length < node.depth:                     # stopped mid-edge
            node = self._split(node, len(node.edge) - (node.depth - length))
        new = tokens[length:]
        need = len(new) * self.bytes_per_token
        if payload is not None:
            need += payload_bytes
            if not new:
                # replacing the payload already attached at this node:
                # its bytes are freed by the swap, so only charge the
                # delta — evicting bystanders for a net-zero replacement
                # would throw away their cached prefixes for nothing
                need -= node.payload_bytes
        # pin the extension point: _make_room's LRU sweep must not evict
        # the (possibly unpinned-leaf) node the new edge attaches to —
        # it would orphan the insert and leak its byte charge
        anchor = node
        pin = anchor
        while pin is not None:
            pin.refs += 1
            pin = pin.parent
        try:
            if not self._make_room(need):
                self._touch(node)
                return 0
            if new:
                leaf = _Node(new, node)
                node.children[new[0]] = leaf
                node = leaf
                self.used_bytes += len(new) * self.bytes_per_token
                self.stats.inserted_tokens += len(new)
            if payload is not None:
                if node.payload is not None:
                    self.used_bytes -= node.payload_bytes
                    if node.payload is not payload:
                        _release_payload(node.payload)
                node.payload = payload
                node.payload_bytes = payload_bytes
                self.used_bytes += payload_bytes
            self._touch(node)
            return len(new)
        finally:
            pin = anchor
            while pin is not None:
                pin.refs -= 1
                pin = pin.parent

    # -- eviction -------------------------------------------------------
    def _evictable(self) -> List[_Node]:
        out = []
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            if not n.children and n.refs == 0:
                out.append(n)
            stack.extend(n.children.values())
        return out

    def _drop_leaf(self, leaf: _Node) -> float:
        freed = len(leaf.edge) * self.bytes_per_token + leaf.payload_bytes
        self.used_bytes -= freed
        self.stats.evicted_tokens += len(leaf.edge)
        if leaf.payload is not None:
            _release_payload(leaf.payload)
        del leaf.parent.children[leaf.edge[0]]
        return freed

    def _make_room(self, need: float) -> bool:
        """Evict LRU unpinned leaves until ``need`` more bytes fit.
        Never drops a pinned node. Returns False when impossible."""
        if need > self.capacity_bytes:
            return False
        while self.used_bytes + need > self.capacity_bytes:
            leaves = self._evictable()
            if not leaves:
                return False
            victim = min(leaves, key=lambda n: n.last_access)
            self._drop_leaf(victim)
            # a payload-less interior node that just became a bare leaf
            # answers matches it can no longer back — let the LRU sweep
            # reclaim it on the next round (its last_access is stale)
        return True

    def evict_tokens(self, n_tokens: int) -> int:
        """Explicitly drop ≥ n_tokens of unpinned LRU leaves (used by
        tests and by operators shrinking a replica's budget)."""
        dropped = 0
        while dropped < n_tokens:
            leaves = self._evictable()
            if not leaves:
                break
            victim = min(leaves, key=lambda n: n.last_access)
            dropped += len(victim.edge)
            self._drop_leaf(victim)
        return dropped

    def clear(self) -> None:
        """Invalidate everything — a §7 placement swap moves the replica
        off the devices that hold this KV. Attached payloads are
        released (their pages return to the pool, §11)."""
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            if n.payload is not None:
                _release_payload(n.payload)
            stack.extend(n.children.values())
        self.root = _Node((), None)
        self.used_bytes = 0.0

    # -- introspection --------------------------------------------------
    @property
    def num_tokens(self) -> int:
        total = 0
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            total += len(n.edge)
            stack.extend(n.children.values())
        return total

    @property
    def num_nodes(self) -> int:
        total = 0
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            total += 1
            stack.extend(n.children.values())
        return total

    @property
    def hit_rate(self) -> float:
        return self.stats.hits / max(self.stats.lookups, 1)

    @property
    def occupancy(self) -> float:
        """Budget fill fraction in [0, 1] — the §14 telemetry gauge.
        Unbounded caches (capacity inf) report 0.0: there is no budget
        to fill, and a non-finite gauge would poison the time series."""
        if self.capacity_bytes == float("inf"):
            return 0.0
        return min(self.used_bytes / max(self.capacity_bytes, 1e-12), 1.0)


# ---------------------------------------------------------------------------
# Cache-aware routing score (mirrors vLLM production-stack's KV router)
# ---------------------------------------------------------------------------


def route_score(hit_ratio: float, load: float, min_load: float,
                cache_alpha: float = 2.0) -> float:
    """Blend matched-prefix ratio with normalized flow-weighted load.

    ``load`` is the replica's (dispatched+1)/flow_weight term,
    ``min_load`` the fleet minimum; with no cache hits anywhere the rule
    reduces exactly to least-normalized-load dispatch (the pre-§9 rule).
    ``cache_alpha`` is how many multiples of the fleet-relative load
    imbalance one full prefix hit is worth.

    Callers comparing scores across replicas MUST break exact ties by
    the lowest replica index (stable order) — the §12 determinism rule
    all three scorers (coordinator, simulator, router) follow, pinned
    by the tie-break regression test."""
    return cache_alpha * hit_ratio - (load / max(min_load, 1e-12) - 1.0)
