"""Paged KV-cache accounting: ref-counted page pool + block tables
(DESIGN.md §11).

The decode phase is memory-capacity-bound (HexGen-2 sizes decode groups
by per-device HBM; "Beyond the Buzz" makes the same point for
disaggregated decode), yet dense per-slot slabs charge every slot
``capacity × bytes/token`` regardless of actual length. Paging converts
that padding into admitted concurrency: KV lives in fixed-size pages, a
per-slot block table maps token positions onto pages, and a request
only ever occupies ``ceil(context / page_size)`` pages.

This module is the pure-accounting half, shared by BOTH serving
domains:

  * the runtime ``DecodeEngine`` drives a ``PagePool`` for its real
    pool-laid-out cache arrays (``models.transformer.init_paged_cache``);
  * the simulator drives an identical ``PagePool`` against the cost
    model's page budget — same allocator, same refcounts, so simulated
    and measured page counts agree EXACTLY on the same trace (the §11
    parity contract, like the §10 byte accounting).

Pages are ref-counted so one physical page can back several readers:
radix prefix slabs pin the pages of prompts they cache, and a new
request admitted over a shared prefix retains those pages instead of
re-installing them (copy-on-write: only the boundary page the request
will write into is copied — see ``shareable_pages``).

Page 0 is a reserved scratch page, never allocated: decode steps run
over every slot (TPU-static batch), and inactive slots' writes are
steered into it so they can never corrupt live pages.

No JAX here — the scheduling domain must stay importable without it.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, List


class PagingError(RuntimeError):
    """Base class for paged-admission failures the coordinator can act
    on (requeue, evict, preempt) instead of crashing on an IndexError."""


class NoFreeSlotError(PagingError):
    """Admission found no free decode slot (block-table row)."""


class OutOfPagesError(PagingError):
    """The page pool cannot satisfy an allocation."""


def pages_for(tokens: int, page_size: int) -> int:
    """Pages needed to hold ``tokens`` KV slots."""
    assert page_size > 0
    return max(0, -(-int(tokens) // int(page_size)))


def pages_for_request(s_in: int, s_out: int, page_size: int) -> int:
    """Total pages a request's decode residency ever occupies.

    Decode writes positions ``s_in .. s_in + s_out - 2`` (the final
    sampled token's KV is never written), so peak context is
    ``s_in + s_out - 1`` slots; single-token requests (``s_out <= 1``)
    finish at prefill and never hold pages (§8). BOTH domains stamp
    ``Request.kv_pages_allocated`` from this arithmetic — the runtime
    via its real allocator, whose count must match (tested)."""
    if s_out <= 1:
        return 0
    return pages_for(s_in + s_out - 1, page_size)


def shareable_pages(prefix_tokens: int, page_size: int) -> int:
    """Leading pages of a cached prefix a new request may share
    read-only. Decode writes from position ``prefix_tokens`` onward, so
    only pages FULLY below it are safe to alias; the boundary page is
    copied (copy-on-write at page granularity)."""
    return int(prefix_tokens) // int(page_size)


@dataclasses.dataclass
class PoolStats:
    allocs: int = 0            # pages handed out (incl. CoW copies)
    releases: int = 0          # refcount drops that freed a page
    shares: int = 0            # refcount bumps on already-live pages
    cow_copies: int = 0        # boundary-page copies
    failed_allocs: int = 0     # OutOfPagesError raised


class PagePool:
    """Fixed-size ref-counted page allocator (TPU-static: the page
    count never changes; identity is an index, not a pointer).

    ``alloc`` hands out free pages with refcount 1; ``retain`` bumps a
    live page (prefix-slab pinning / shared admission); ``release``
    drops one reference and returns the page to the free list when the
    last reader leaves. ``page_bytes`` is optional metadata for byte
    accounting (the cost model's ``kv_page_bytes``); when ``dtype`` is
    a quantized resident dtype ("int8", DESIGN.md §16) it must already
    INCLUDE the fp32 scale-sidecar bytes — allocation itself is
    dtype-blind (a page is a page), the dtype is carried so accounting
    consumers (utilization, prefix budgets) agree on what one page
    costs."""

    def __init__(self, num_pages: int, page_size: int,
                 page_bytes: float = 0.0, reserve_scratch: bool = True,
                 dtype: str = None):
        assert num_pages >= (2 if reserve_scratch else 1), num_pages
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.page_bytes = float(page_bytes)
        self.dtype = dtype
        self.scratch = 0 if reserve_scratch else None
        self._refs = [0] * self.num_pages
        first = 1 if reserve_scratch else 0
        # LIFO free list: recently-freed pages are re-used first (warm)
        self._free: List[int] = list(range(self.num_pages - 1,
                                           first - 1, -1))
        self.stats = PoolStats()

    # -- introspection ---------------------------------------------------
    @property
    def num_allocatable(self) -> int:
        return self.num_pages - (1 if self.scratch is not None else 0)

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def pages_in_use(self) -> int:
        return self.num_allocatable - self.free_pages

    @property
    def utilization(self) -> float:
        return self.pages_in_use / max(self.num_allocatable, 1)

    def refcount(self, page: int) -> int:
        return self._refs[page]

    # -- allocation ------------------------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Allocate ``n`` pages (refcount 1 each) or raise
        ``OutOfPagesError`` leaving the pool untouched."""
        if n > len(self._free):
            self.stats.failed_allocs += 1
            raise OutOfPagesError(
                f"need {n} pages, {len(self._free)} free "
                f"of {self.num_allocatable}")
        out = [self._free.pop() for _ in range(n)]
        for p in out:
            assert self._refs[p] == 0, (p, self._refs[p])
            self._refs[p] = 1
        self.stats.allocs += n
        return out

    def retain(self, pages: Iterable[int]) -> None:
        """Add one reference to each (live) page — sharing, not copying."""
        for p in pages:
            assert self._refs[p] > 0, f"retain of dead page {p}"
            assert p != self.scratch
            self._refs[p] += 1
            self.stats.shares += 1

    def release(self, pages: Iterable[int]) -> None:
        """Drop one reference per page; a page whose last reference
        leaves returns to the free list."""
        for p in pages:
            assert self._refs[p] > 0, f"release of dead page {p}"
            self._refs[p] -= 1
            if self._refs[p] == 0:
                self._free.append(p)
                self.stats.releases += 1


@dataclasses.dataclass
class BlockTable:
    """One slot's ordered page list: logical block ``i`` (token
    positions ``[i*page_size, (i+1)*page_size)``) lives in physical
    page ``pages[i]``. ``shared_prefix_pages`` marks how many leading
    entries are read-only aliases of prefix-slab pages (refcounted in
    the pool; never written — decode writes start past them)."""

    pages: List[int] = dataclasses.field(default_factory=list)
    shared_prefix_pages: int = 0

    def __len__(self) -> int:
        return len(self.pages)


class PagedSlab:
    """A pinned, read-only run of pages holding a cached prefix's KV —
    the payload a radix ``PrefixCache`` node owns when prefix slabs and
    decode residency share one pool (DESIGN.md §11). Covers
    ``tokens = len(pages) * page_size`` positions exactly (only FULL
    pages are ever exported; the partial tail page belongs to the slot
    that will keep writing it).

    Constructing a slab retains its pages; ``release()`` (called by the
    prefix cache's eviction hook) drops them. ``payload_bytes`` charges
    the pool bytes ONCE per physical page regardless of how many
    readers share it — sharing is the point."""

    def __init__(self, pool: PagePool, pages: Iterable[int] = ()):
        self.pool = pool
        self.pages = list(pages)
        pool.retain(self.pages)
        self._released = False

    @property
    def tokens(self) -> int:
        return len(self.pages) * self.pool.page_size

    @property
    def payload_bytes(self) -> float:
        return len(self.pages) * self.pool.page_bytes

    def release(self) -> None:
        if not self._released:
            self.pool.release(self.pages)
            self._released = True

    def __repr__(self) -> str:  # pragma: no cover
        return (f"PagedSlab({len(self.pages)} pages x "
                f"{self.pool.page_size} tok"
                f"{' released' if self._released else ''})")
