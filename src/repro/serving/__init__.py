"""Disaggregated serving: engines (runtime domain), simulator
(scheduling domain), workload generators, request lifecycle."""
from repro.serving.request import Phase, Request
from repro.serving.workload import (TracePhase, drifting_workload,
                                    observed_workload, offline_workload,
                                    online_workload, WORKLOAD_DISTS)
from repro.serving.simulator import (OnlineSimResult, RescheduleEvent,
                                     SimResult, simulate, simulate_colocated,
                                     simulate_online, slo_baselines)
from repro.serving.engine import DecodeEngine, PrefillEngine, Slot
from repro.serving.coordinator import Coordinator, ServeRequest, ServeResult
from repro.serving import kv_transfer

__all__ = ["Phase", "Request", "TracePhase", "drifting_workload",
           "observed_workload", "offline_workload", "online_workload",
           "WORKLOAD_DISTS", "OnlineSimResult", "RescheduleEvent",
           "SimResult", "simulate", "simulate_colocated", "simulate_online",
           "slo_baselines", "DecodeEngine", "PrefillEngine", "Slot",
           "Coordinator", "ServeRequest", "ServeResult", "kv_transfer"]
