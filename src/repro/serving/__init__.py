"""Disaggregated serving: engines (runtime domain), simulator
(scheduling domain), workload generators, and the shared request
lifecycle + metrics schema both domains report (DESIGN.md §8)."""
from repro.serving.request import (IllegalTransition, Phase, Request,
                                   RequestState, TRANSITIONS)
from repro.serving.metrics import METRIC_FIELDS, ServeMetrics
from repro.serving.prefix_cache import (CacheStats, MatchResult, PrefixCache,
                                        route_score)
from repro.serving.workload import (PREFIX_TRACES, TracePhase,
                                    drifting_workload,
                                    fewshot_agentic_workload,
                                    multi_turn_workload, observed_workload,
                                    offline_workload, online_workload,
                                    prefix_trace,
                                    shared_system_prompt_workload,
                                    WORKLOAD_DISTS)
from repro.serving.simulator import (OnlineSimResult, RescheduleEvent,
                                     SimResult, simulate, simulate_colocated,
                                     simulate_online, slo_baselines)
from repro.serving.engine import DecodeEngine, PrefillEngine, Slot
from repro.serving.coordinator import (Coordinator, PollStatus, ServeRequest,
                                       ServeResult, ServeSession)
from repro.serving import kv_compression, kv_transfer
from repro.serving.kv_compression import (CODECS, ChunkedTransferPlan,
                                          KVCodec, QuantizedLeaf, get_codec)
from repro.serving.paging import (BlockTable, NoFreeSlotError,
                                  OutOfPagesError, PagePool, PagedSlab,
                                  PagingError, pages_for, pages_for_request,
                                  shareable_pages)

__all__ = ["IllegalTransition", "Phase", "Request", "RequestState",
           "TRANSITIONS", "METRIC_FIELDS", "ServeMetrics", "CacheStats",
           "MatchResult", "PrefixCache", "route_score", "PREFIX_TRACES",
           "TracePhase", "drifting_workload", "fewshot_agentic_workload",
           "multi_turn_workload", "observed_workload", "offline_workload",
           "online_workload", "prefix_trace",
           "shared_system_prompt_workload", "WORKLOAD_DISTS",
           "OnlineSimResult", "RescheduleEvent", "SimResult", "simulate",
           "simulate_colocated", "simulate_online", "slo_baselines",
           "DecodeEngine", "PrefillEngine", "Slot", "Coordinator",
           "PollStatus", "ServeRequest", "ServeResult", "ServeSession",
           "kv_transfer", "kv_compression", "CODECS", "ChunkedTransferPlan",
           "KVCodec", "QuantizedLeaf", "get_codec",
           "BlockTable", "NoFreeSlotError", "OutOfPagesError", "PagePool",
           "PagedSlab", "PagingError", "pages_for", "pages_for_request",
           "shareable_pages"]
