"""Disaggregated serving: engines (runtime domain), simulator
(scheduling domain), workload generators, and the shared request
lifecycle + metrics schema both domains report (DESIGN.md §8)."""
from repro.serving.request import (IllegalTransition, Phase, Request,
                                   RequestState, TERMINAL_STATES,
                                   TRANSITIONS, TTFT_BUCKETS)
from repro.serving.calibration import (CalibrationStore, plan_predictor,
                                       placement_predictor)
from repro.serving.telemetry import (MetricsEndpoint, Span, TelemetryEvent,
                                     TraceRecorder, WindowedGauges,
                                     chrome_trace, prometheus_text,
                                     request_spans, span_stream,
                                     validate_chrome_trace)
from repro.serving.metrics import METRIC_FIELDS, ServeMetrics
from repro.serving.prefix_cache import (CacheStats, MatchResult, PrefixCache,
                                        route_score)
from repro.serving.workload import (PREFIX_TRACES, TracePhase,
                                    calibration_workload,
                                    drifting_workload,
                                    fewshot_agentic_workload,
                                    multi_turn_workload, observed_workload,
                                    offline_workload, online_workload,
                                    prefix_trace,
                                    mixed_priority_workload,
                                    shared_system_prompt_workload,
                                    surge_workload,
                                    WORKLOAD_DISTS)
from repro.serving.fleet import (FleetController, FleetSpec, ReplicaState,
                                 ScaleEvent)
from repro.serving.simulator import (FleetResult, OnlineSimResult,
                                     RescheduleEvent, SimReplica,
                                     SimResult, simulate, simulate_colocated,
                                     simulate_fleet,
                                     simulate_online, slo_baselines)
from repro.serving.engine import DecodeEngine, PrefillEngine, Slot
from repro.serving.coordinator import (Coordinator, PollStatus, ServeRequest,
                                       ServeResult, ServeSession)
from repro.serving.router import (AdmissionQueue, AdmissionRejected,
                                  CoordinatorReplica, FleetExhausted,
                                  PRIORITY_BATCH, PRIORITY_INTERACTIVE,
                                  PRIORITY_STANDARD, Router, StepClock)
from repro.serving import kv_compression, kv_transfer
from repro.serving.kv_compression import (CODECS, ChunkedTransferPlan,
                                          KVCodec, QuantizedLeaf, get_codec)
from repro.serving.paging import (BlockTable, NoFreeSlotError,
                                  OutOfPagesError, PagePool, PagedSlab,
                                  PagingError, pages_for, pages_for_request,
                                  shareable_pages)

__all__ = ["IllegalTransition", "Phase", "Request", "RequestState",
           "TERMINAL_STATES", "TTFT_BUCKETS",
           "CalibrationStore", "plan_predictor", "placement_predictor",
           "MetricsEndpoint",
           "Span", "TelemetryEvent", "TraceRecorder", "WindowedGauges",
           "chrome_trace", "prometheus_text", "request_spans",
           "span_stream", "validate_chrome_trace",
           "TRANSITIONS", "METRIC_FIELDS", "ServeMetrics", "CacheStats",
           "MatchResult", "PrefixCache", "route_score", "PREFIX_TRACES",
           "TracePhase", "calibration_workload", "drifting_workload",
           "fewshot_agentic_workload",
           "mixed_priority_workload",
           "multi_turn_workload", "observed_workload", "offline_workload",
           "online_workload", "prefix_trace",
           "shared_system_prompt_workload", "surge_workload",
           "WORKLOAD_DISTS",
           "FleetController", "FleetSpec", "ReplicaState", "ScaleEvent",
           "FleetResult", "OnlineSimResult", "RescheduleEvent",
           "SimReplica", "SimResult", "simulate",
           "simulate_colocated", "simulate_fleet", "simulate_online",
           "slo_baselines",
           "DecodeEngine", "PrefillEngine", "Slot", "Coordinator",
           "PollStatus", "ServeRequest", "ServeResult", "ServeSession",
           "AdmissionQueue", "AdmissionRejected", "CoordinatorReplica",
           "FleetExhausted",
           "PRIORITY_BATCH", "PRIORITY_INTERACTIVE", "PRIORITY_STANDARD",
           "Router", "StepClock",
           "kv_transfer", "kv_compression", "CODECS", "ChunkedTransferPlan",
           "KVCodec", "QuantizedLeaf", "get_codec",
           "BlockTable", "NoFreeSlotError", "OutOfPagesError", "PagePool",
           "PagedSlab", "PagingError", "pages_for", "pages_for_request",
           "shareable_pages"]
