"""Disaggregated serving: engines (runtime domain), simulator
(scheduling domain), workload generators, request lifecycle."""
from repro.serving.request import Phase, Request
from repro.serving.workload import (offline_workload, online_workload,
                                    WORKLOAD_DISTS)
from repro.serving.simulator import (SimResult, simulate, simulate_colocated,
                                     slo_baselines)
from repro.serving.engine import DecodeEngine, PrefillEngine, Slot
from repro.serving.coordinator import Coordinator, ServeRequest, ServeResult
from repro.serving import kv_transfer

__all__ = ["Phase", "Request", "offline_workload", "online_workload",
           "WORKLOAD_DISTS", "SimResult", "simulate", "simulate_colocated",
           "slo_baselines", "DecodeEngine", "PrefillEngine", "Slot",
           "Coordinator", "ServeRequest", "ServeResult", "kv_transfer"]
