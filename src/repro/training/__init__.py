"""Training substrate: optimizer, data pipeline, checkpointing, loop."""
from repro.training import checkpoint, data, optimizer
from repro.training.train_loop import TrainResult, make_train_step, train

__all__ = ["checkpoint", "data", "optimizer", "TrainResult",
           "make_train_step", "train"]
