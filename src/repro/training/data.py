"""Synthetic token data pipeline.

Deterministic, seedable stream of (tokens, labels) batches with
next-token targets over a Zipf-ish unigram distribution plus injected
n-gram structure, so training loss measurably decreases (the smoke
criterion) without external corpora. Supports sharding a global batch
into per-host slices.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    ngram_order: int = 2
    ngram_strength: float = 0.8


class SyntheticTokenStream:
    """Markov-chain token generator: each vocab id has a preferred
    successor table, mixed with Zipf unigram noise."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        # Zipf unigram distribution
        ranks = np.arange(1, cfg.vocab + 1)
        self._unigram = (1.0 / ranks) / np.sum(1.0 / ranks)
        # deterministic successor table (the learnable structure)
        self._succ = rng.permutation(cfg.vocab)
        self._step = 0

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(cfg.seed * 1_000_003 + step)
        b, s = cfg.global_batch, cfg.seq_len
        toks = np.empty((b, s + 1), np.int32)
        toks[:, 0] = rng.choice(cfg.vocab, size=b, p=self._unigram)
        noise = rng.random((b, s))
        rand_toks = rng.choice(cfg.vocab, size=(b, s), p=self._unigram)
        for t in range(s):
            follow = self._succ[toks[:, t]]
            toks[:, t + 1] = np.where(noise[:, t] < cfg.ngram_strength,
                                      follow, rand_toks[:, t])
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def host_shard(batch: Dict[str, np.ndarray], host_index: int,
               host_count: int) -> Dict[str, np.ndarray]:
    """Slice a global batch into this host's rows (multi-host input
    pipeline contract: every host feeds its own slice of the batch)."""
    out = {}
    for k, v in batch.items():
        per = v.shape[0] // host_count
        out[k] = v[host_index * per:(host_index + 1) * per]
    return out
