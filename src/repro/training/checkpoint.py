"""Checkpointing: save/restore params + optimizer state + step.

Flat-key .npz per checkpoint with a small JSON manifest; atomic via
tmp-rename. No external deps (orbax is not in the image).
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        arr = np.asarray(leaf)
        if arr.dtype.kind not in "biufc":  # bf16 etc. → store as fp32
            arr = arr.astype(np.float32)
        flat[key] = arr
    return flat


def save(directory: str, step: int, params: Any,
         opt_state: Optional[Any] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    payload = {f"params/{k}": v for k, v in _flatten(params).items()}
    if opt_state is not None:
        payload.update({f"opt/{k}": v
                        for k, v in _flatten(opt_state).items()})
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz")
    os.close(fd)
    np.savez(tmp, **payload)
    os.replace(tmp, path)
    manifest = os.path.join(directory, "manifest.json")
    meta = {"latest_step": step, "latest": os.path.basename(path)}
    with open(manifest, "w") as f:
        json.dump(meta, f)
    return path


def latest_step(directory: str) -> Optional[int]:
    manifest = os.path.join(directory, "manifest.json")
    if not os.path.exists(manifest):
        return None
    with open(manifest) as f:
        return json.load(f)["latest_step"]


def restore(directory: str, step: int, params_like: Any,
            opt_like: Optional[Any] = None) -> Tuple[Any, Optional[Any]]:
    """Restore into pytrees shaped like the given templates."""
    path = os.path.join(directory, f"ckpt_{step:08d}.npz")
    data = np.load(path)

    def rebuild(prefix: str, template: Any) -> Any:
        flat = _flatten(template)
        leaves = []
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        for kp, leaf in paths:
            key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                           for p in kp)
            arr = data[f"{prefix}/{key}"]
            assert arr.shape == leaf.shape, (key, arr.shape, leaf.shape)
            leaves.append(jnp.asarray(arr).astype(leaf.dtype))
        return jax.tree_util.tree_unflatten(treedef, leaves)

    params = rebuild("params", params_like)
    opt = rebuild("opt", opt_like) if opt_like is not None else None
    return params, opt
