"""Training loop: jit'd train_step (grad + AdamW) and a driver.

``make_train_step`` returns the pure step function the launch layer
lowers for the train_4k dry-run (with shardings) and the smoke tests run
eagerly on CPU.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer
from repro.training import checkpoint as ckpt_lib
from repro.training import optimizer as opt_lib
from repro.training.data import DataConfig, SyntheticTokenStream


def make_train_step(cfg: ArchConfig, opt_cfg: opt_lib.AdamWConfig
                    ) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            extra = {k: v for k, v in batch.items()
                     if k not in ("tokens", "labels")}
            return transformer.train_forward(p, cfg, batch["tokens"],
                                             batch["labels"], **extra)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state = opt_lib.apply(opt_cfg, params, grads, opt_state)
        return params, opt_state, {"loss": loss}

    return train_step


@dataclasses.dataclass
class TrainResult:
    losses: list
    steps: int
    tokens_seen: int
    elapsed_s: float


def train(cfg: ArchConfig, steps: int, batch: int, seq: int,
          opt_cfg: Optional[opt_lib.AdamWConfig] = None,
          seed: int = 0, ckpt_dir: Optional[str] = None,
          ckpt_every: int = 0, log_every: int = 10,
          verbose: bool = False) -> TrainResult:
    """Single-host training driver (smoke scale on CPU)."""
    opt_cfg = opt_cfg or opt_lib.AdamWConfig(total_steps=steps,
                                             warmup_steps=max(steps // 20, 5))
    params = transformer.init_params(jax.random.PRNGKey(seed), cfg)
    opt_state = opt_lib.init(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    stream = SyntheticTokenStream(DataConfig(cfg.vocab, seq, batch, seed))

    extra = {}
    if cfg.is_encdec:
        extra["encoder_frames"] = jnp.zeros(
            (batch, cfg.encoder_frames, cfg.d_model), jnp.bfloat16)
    if cfg.num_image_tokens:
        extra["image_embeds"] = jnp.zeros(
            (batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)

    losses = []
    t0 = time.perf_counter()
    for step in range(steps):
        np_batch = stream.batch(step)
        jb = {k: jnp.asarray(v) for k, v in np_batch.items()}
        jb.update(extra)
        params, opt_state, metrics = step_fn(params, opt_state, jb)
        loss = float(metrics["loss"])
        losses.append(loss)
        if verbose and step % log_every == 0:
            print(f"step {step:5d} loss {loss:.4f}")
        if ckpt_dir and ckpt_every and (step + 1) % ckpt_every == 0:
            ckpt_lib.save(ckpt_dir, step + 1, params, opt_state)
    return TrainResult(losses, steps, steps * batch * seq,
                       time.perf_counter() - t0)
