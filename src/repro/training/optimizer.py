"""AdamW with warmup-cosine schedule (no external deps).

Optimizer state is a pytree shaped like params (fp32 moments), so the
launch layer can shard it with the same rules as the parameters
(ZeRO-style over the data axis in the fsdp_tp profile).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array           # scalar int32
    mu: Any                   # fp32 pytree like params
    nu: Any                   # fp32 pytree like params


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0
    # §Perf: bf16 moments halve optimizer HBM (update math stays fp32);
    # standard practice for ≥100B models on 16 GB/chip parts.
    moments_dtype: str = "float32"   # "float32" | "bfloat16"


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    s = step.astype(jnp.float32)
    warm = s / max(cfg.warmup_steps, 1)
    prog = jnp.clip((s - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(s < cfg.warmup_steps, warm, 0.1 + 0.9 * cos)


def init(params: Any, moments_dtype=jnp.float32) -> AdamWState:
    dt = jnp.dtype(moments_dtype)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, dt), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=zeros,
                      nu=jax.tree.map(jnp.copy, zeros))


def _decay_mask(params: Any) -> Any:
    """No weight decay on norms/biases/scalars (ndim < 2)."""
    return jax.tree.map(lambda p: jnp.asarray(1.0 if p.ndim >= 2 else 0.0,
                                              jnp.float32), params)


def apply(cfg: AdamWConfig, params: Any, grads: Any,
          state: AdamWState) -> Tuple[Any, AdamWState]:
    # global-norm clip
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)) + 1e-12)
    scale = jnp.minimum(1.0, cfg.grad_clip / gnorm)
    step = state.step + 1
    lr = schedule(cfg, step)
    b1c = 1.0 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.beta2 ** step.astype(jnp.float32)
    mask = _decay_mask(params)

    mdt = jnp.dtype(cfg.moments_dtype)

    def upd(p, g, m, v, wd):
        gf = g.astype(jnp.float32) * scale
        m = cfg.beta1 * m.astype(jnp.float32) + (1 - cfg.beta1) * gf
        v = cfg.beta2 * v.astype(jnp.float32) + (1 - cfg.beta2) * gf * gf
        mh, vh = m / b1c, v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) \
            + cfg.weight_decay * wd * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                m.astype(mdt), v.astype(mdt))

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    flat_w = jax.tree.leaves(mask)
    new_p, new_m, new_v = [], [], []
    for p, g, m, v, w in zip(flat_p, flat_g, flat_m, flat_v, flat_w):
        np_, nm, nv = upd(p, g, m, v, w)
        new_p.append(np_)
        new_m.append(nm)
        new_v.append(nv)
    return (jax.tree.unflatten(treedef, new_p),
            AdamWState(step, jax.tree.unflatten(treedef, new_m),
                       jax.tree.unflatten(treedef, new_v)))
