"""Mamba (S6) selective-state-space mixer — Jamba's recurrent layer.

Prefill runs the selective scan with ``jax.lax.scan`` (time-major);
decode is a single recurrence step against the carried
(conv_state, ssm_state). The recurrent state is the SSM analogue of the
KV cache and is what the disaggregated runtime ships from prefill to
decode replicas — constant-size in sequence length (see DESIGN.md §5).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common


def d_inner(d_model: int, expand: int) -> int:
    return expand * d_model


def dt_rank(d_model: int) -> int:
    return max(1, -(-d_model // 16))  # ceil(D/16)


def init_mamba(key: jax.Array, d_model: int, state: int, conv: int,
               expand: int, dtype=common.DEFAULT_DTYPE) -> Dict:
    di = d_inner(d_model, expand)
    dr = dt_rank(d_model)
    ks = common.split_keys(key, 7)
    a = jnp.tile(jnp.arange(1, state + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "in_proj": common.dense_init(ks[0], (d_model, 2 * di), dtype),
        "conv_w": common.dense_init(ks[1], (conv, di), dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": common.dense_init(ks[2], (di, dr + 2 * state), dtype),
        "dt_proj": common.dense_init(ks[3], (dr, di), dtype),
        "dt_bias": (jax.random.uniform(ks[4], (di,), jnp.float32,
                                       minval=-4.6, maxval=-2.3)),  # softplus⁻¹ of ~1e-2..1e-1
        "a_log": jnp.log(a),                       # [di, state] fp32
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": common.dense_init(ks[5], (di, d_model), dtype),
    }


def _ssm_inputs(params: Dict, x: jax.Array, state: int
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """x [..., di] -> dt [..., di], b [..., state], c [..., state] (fp32)."""
    dr = params["dt_proj"].shape[0]
    proj = (x @ params["x_proj"]).astype(jnp.float32)
    dt, b, c = jnp.split(proj, [dr, dr + state], axis=-1)
    dt = jax.nn.softplus(dt @ params["dt_proj"].astype(jnp.float32)
                         + params["dt_bias"])
    return dt, b, c


def mamba_prefill(params: Dict, x: jax.Array, state: int, conv: int
                  ) -> Tuple[jax.Array, Dict]:
    """x [B,S,D] -> (y [B,S,D], final_state {conv, ssm})."""
    bsz, s, _ = x.shape
    di = params["out_proj"].shape[0]
    xz = x @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                 # [B,S,di]

    # causal depthwise conv over time. fp32 taps accumulated in the same
    # order as mamba_decode so the prefill→decode handoff is drift-free:
    # a bf16 tap sum here vs a fused contraction there rounds differently
    # and compounds through the SSM recurrence.
    pad = jnp.zeros((bsz, conv - 1, di), xi.dtype)
    xpad = jnp.concatenate([pad, xi], axis=1)
    xpad32 = xpad.astype(jnp.float32)
    w32 = params["conv_w"].astype(jnp.float32)
    conv_out = sum(xpad32[:, i:i + s] * w32[i] for i in range(conv))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))

    dt, b, c = _ssm_inputs(params, conv_out.astype(x.dtype), state)
    a = -jnp.exp(params["a_log"])                     # [di, N]

    def step(h, inp):
        u_t, dt_t, b_t, c_t = inp                     # [B,di],[B,di],[B,N],[B,N]
        da = jnp.exp(dt_t[..., None] * a)             # [B,di,N]
        db = dt_t[..., None] * b_t[:, None, :]        # [B,di,N]
        h = da * h + db * u_t[..., None]
        y = jnp.einsum("bdn,bn->bd", h, c_t)
        return h, y

    h0 = jnp.zeros((bsz, di, state), jnp.float32)
    xs = (jnp.moveaxis(conv_out, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                        # [B,S,di] fp32
    y = y + conv_out * params["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = y @ params["out_proj"]
    # conv cache = last (conv-1) raw inner inputs (pre-activation)
    if conv > 1:
        # xpad has (conv-1) zeros prepended, so the last (conv-1) inner
        # inputs live at xpad[:, s : s+conv-1] (zero-padded when s < conv-1)
        conv_cache = xpad[:, s:s + conv - 1].astype(x.dtype)
    else:
        conv_cache = jnp.zeros((bsz, 0, di), x.dtype)
    return out, {"conv": conv_cache, "ssm": h_final}


def mamba_decode(params: Dict, x: jax.Array, cache: Dict, state: int,
                 conv: int) -> Tuple[jax.Array, Dict]:
    """x [B,1,D]; cache {conv [B,conv-1,di], ssm [B,di,N]}."""
    bsz = x.shape[0]
    xz = x[:, 0] @ params["in_proj"]
    xi, z = jnp.split(xz, 2, axis=-1)                 # [B,di]

    hist = jnp.concatenate([cache["conv"], xi[:, None]], axis=1)  # [B,conv,di]
    # fp32 taps, summed in the same order as mamba_prefill (see there)
    hist32 = hist.astype(jnp.float32)
    w32 = params["conv_w"].astype(jnp.float32)
    conv_out = sum(hist32[:, i] * w32[i] for i in range(conv))
    conv_out = jax.nn.silu(conv_out + params["conv_b"].astype(jnp.float32))

    dt, b, c = _ssm_inputs(params, conv_out.astype(x.dtype), state)
    a = -jnp.exp(params["a_log"])
    da = jnp.exp(dt[..., None] * a)
    db = dt[..., None] * b[:, None, :]
    h = da * cache["ssm"] + db * conv_out[..., None]
    y = jnp.einsum("bdn,bn->bd", h, c) + conv_out * params["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = (y @ params["out_proj"])[:, None]
    return out, {"conv": hist[:, 1:], "ssm": h}


def init_state(bsz: int, d_model: int, state: int, conv: int, expand: int,
               dtype=common.DEFAULT_DTYPE) -> Dict:
    di = d_inner(d_model, expand)
    return {"conv": jnp.zeros((bsz, conv - 1, di), dtype),
            "ssm": jnp.zeros((bsz, di, state), jnp.float32)}
