"""Dense feed-forward blocks: SwiGLU (llama/qwen/yi), squared-ReLU
(Nemotron-4), GELU (whisper)."""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.models import common


def init_mlp(key: jax.Array, d_model: int, d_ff: int,
             activation: str, dtype=common.DEFAULT_DTYPE) -> Dict:
    ks = common.split_keys(key, 3)
    if activation == "swiglu":
        return {
            "w_gate": common.dense_init(ks[0], (d_model, d_ff), dtype),
            "w_up": common.dense_init(ks[1], (d_model, d_ff), dtype),
            "w_down": common.dense_init(ks[2], (d_ff, d_model), dtype),
        }
    # 2-matrix FFN (relu2 / gelu)
    return {
        "w_up": common.dense_init(ks[0], (d_model, d_ff), dtype),
        "w_down": common.dense_init(ks[1], (d_ff, d_model), dtype),
    }


def apply_mlp(params: Dict, x: jax.Array, activation: str) -> jax.Array:
    if activation == "swiglu":
        gate = x @ params["w_gate"]
        up = x @ params["w_up"]
        return common.swiglu(gate, up) @ params["w_down"]
    h = x @ params["w_up"]
    h = common.relu2(h) if activation == "relu2" else common.gelu(h)
    return h @ params["w_down"]
