"""Model zoo: composable blocks + the period-scan model builder."""
from repro.models.transformer import (count_active_params, count_params,
                                      decode_step, init_cache, init_params,
                                      prefill, prefill_continue,
                                      supports_prefix_continue,
                                      train_forward, cache_specs)

__all__ = ["count_active_params", "count_params", "decode_step",
           "init_cache", "init_params", "prefill", "prefill_continue",
           "supports_prefix_continue", "train_forward",
           "cache_specs"]
