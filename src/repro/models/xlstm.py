"""xLSTM mixers: mLSTM (matrix-memory, parallelizable) and sLSTM
(scalar-memory with block-diagonal recurrence) — arXiv:2405.04517.

The recurrent states (mLSTM's per-head matrix memory C and sLSTM's
scalar cells) play the role of the KV cache in the disaggregated
runtime: constant-size in sequence length, shipped once from prefill to
decode replicas.

Both prefill paths use ``jax.lax.scan`` over time with exponential-gate
log-space stabilization (the ``m`` carry).
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common


# ---------------------------------------------------------------------------
# mLSTM — matrix memory per head, no hidden-to-hidden recurrence in q/k/v
# ---------------------------------------------------------------------------


def init_mlstm(key: jax.Array, d_model: int, heads: int,
               dtype=common.DEFAULT_DTYPE) -> Dict:
    m = 2 * d_model  # proj_factor 2 inner width
    ks = common.split_keys(key, 5)
    return {
        "in_proj": common.dense_init(ks[0], (d_model, m), dtype),
        "z_proj": common.dense_init(ks[1], (d_model, m), dtype),
        "qkv": common.dense_init(ks[2], (m, 3 * m), dtype),
        "gates": common.dense_init(ks[3], (m, 2 * heads), jnp.float32),
        "out_norm": jnp.ones((m,), jnp.float32),
        "out_proj": common.dense_init(ks[4], (m, d_model), dtype),
    }


def _mlstm_qkvg(params: Dict, x: jax.Array, heads: int):
    """x [B,S,D] -> q,k,v [B,S,h,dh], igate/fgate preacts [B,S,h], z [B,S,m]."""
    m = params["in_proj"].shape[1]
    dh = m // heads
    xi = x @ params["in_proj"]                        # [B,S,m]
    z = x @ params["z_proj"]
    qkv = xi @ params["qkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    shp = x.shape[:-1] + (heads, dh)
    q, k, v = (t.reshape(shp) for t in (q, k, v))
    k = k / jnp.sqrt(float(dh))
    gates = (xi.astype(jnp.float32) @ params["gates"])
    ig, fg = jnp.split(gates, 2, axis=-1)             # [B,S,h]
    return q, k, v, ig, fg, z


def _mlstm_step(carry, inp):
    """carry: (C [B,h,dh,dh], n [B,h,dh], m [B,h]); inp per-t tensors."""
    c_mat, n_vec, m_run = carry
    q, k, v, ig, fg = inp                             # [B,h,dh]×3, [B,h]×2
    logf = jax.nn.log_sigmoid(fg)                     # [B,h]
    m_new = jnp.maximum(logf + m_run, ig)
    i_p = jnp.exp(ig - m_new)[..., None]              # [B,h,1]
    f_p = jnp.exp(logf + m_run - m_new)[..., None]
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    c_mat = f_p[..., None] * c_mat + i_p[..., None] * (
        vf[..., :, None] * kf[..., None, :])          # [B,h,dh,dh]
    n_vec = f_p * n_vec + i_p * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhij,bhj->bhi", c_mat, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhj,bhj->bh", n_vec, qf)), 1.0)
    h = num / den[..., None]                          # [B,h,dh]
    return (c_mat, n_vec, m_new), h


def mlstm_prefill(params: Dict, x: jax.Array, heads: int
                  ) -> Tuple[jax.Array, Dict]:
    bsz, s, d = x.shape
    m_width = params["in_proj"].shape[1]
    dh = m_width // heads
    q, k, v, ig, fg, z = _mlstm_qkvg(params, x, heads)
    carry = (jnp.zeros((bsz, heads, dh, dh), jnp.float32),
             jnp.zeros((bsz, heads, dh), jnp.float32),
             jnp.zeros((bsz, heads), jnp.float32))
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (q, k, v, ig, fg))
    carry, hs = jax.lax.scan(_mlstm_step, carry, xs)
    h = jnp.moveaxis(hs, 0, 1).reshape(bsz, s, m_width)  # fp32
    h = common.rms_norm(h.astype(x.dtype), params["out_norm"])
    out = (h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)) \
        @ params["out_proj"]
    cache = {"C": carry[0], "n": carry[1], "m": carry[2]}
    return out, cache


def mlstm_decode(params: Dict, x: jax.Array, cache: Dict, heads: int
                 ) -> Tuple[jax.Array, Dict]:
    bsz = x.shape[0]
    m_width = params["in_proj"].shape[1]
    q, k, v, ig, fg, z = _mlstm_qkvg(params, x, heads)  # seq dim = 1
    carry = (cache["C"], cache["n"], cache["m"])
    inp = tuple(t[:, 0] for t in (q, k, v, ig, fg))
    carry, h = _mlstm_step(carry, inp)
    h = h.reshape(bsz, 1, m_width)
    h = common.rms_norm(h.astype(x.dtype), params["out_norm"])
    out = (h * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype)) \
        @ params["out_proj"]
    return out, {"C": carry[0], "n": carry[1], "m": carry[2]}


def mlstm_init_state(bsz: int, d_model: int, heads: int) -> Dict:
    m = 2 * d_model
    dh = m // heads
    return {"C": jnp.zeros((bsz, heads, dh, dh), jnp.float32),
            "n": jnp.zeros((bsz, heads, dh), jnp.float32),
            "m": jnp.zeros((bsz, heads), jnp.float32)}


# ---------------------------------------------------------------------------
# sLSTM — scalar memory, block-diagonal hidden recurrence per head
# ---------------------------------------------------------------------------


def init_slstm(key: jax.Array, d_model: int, heads: int,
               dtype=common.DEFAULT_DTYPE) -> Dict:
    dh = d_model // heads
    ks = common.split_keys(key, 3)
    return {
        "w": common.dense_init(ks[0], (d_model, 4 * d_model), dtype),
        "r": common.dense_init(ks[1], (4, heads, dh, dh), jnp.float32),
        "out_norm": jnp.ones((d_model,), jnp.float32),
        "out_proj": common.dense_init(ks[2], (d_model, d_model), dtype),
    }


def _slstm_step(params, carry, wx_t):
    """carry: (c,n,h,m) each [B,D]; wx_t [B,4D] input preactivations."""
    c, n, h, m_run = carry
    bsz, d = c.shape
    heads, dh = params["r"].shape[1], params["r"].shape[2]
    hh = h.reshape(bsz, heads, dh)
    rec = jnp.einsum("ghij,bhj->gbhi", params["r"], hh)  # [4,B,heads,dh]
    rec = rec.reshape(4, bsz, d)
    zt, it, ft, ot = [wx_t[..., i * d:(i + 1) * d].astype(jnp.float32) + rec[i]
                      for i in range(4)]
    logf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(logf + m_run, it)
    i_p = jnp.exp(it - m_new)
    f_p = jnp.exp(logf + m_run - m_new)
    c = f_p * c + i_p * jnp.tanh(zt)
    n = f_p * n + i_p
    h = jax.nn.sigmoid(ot) * c / jnp.maximum(n, 1e-6)
    return (c, n, h, m_new)


def slstm_prefill(params: Dict, x: jax.Array, heads: int
                  ) -> Tuple[jax.Array, Dict]:
    bsz, s, d = x.shape
    wx = x @ params["w"]                              # [B,S,4D]
    carry = tuple(jnp.zeros((bsz, d), jnp.float32) for _ in range(4))

    def step(carry, wx_t):
        new = _slstm_step(params, carry, wx_t)
        return new, new[2]

    carry, hs = jax.lax.scan(step, carry, jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1)                        # [B,S,D] fp32
    h = common.rms_norm(h.astype(x.dtype), params["out_norm"])
    out = h @ params["out_proj"]
    c, n, hh, m = carry
    return out, {"c": c, "n": n, "h": hh, "m": m}


def slstm_decode(params: Dict, x: jax.Array, cache: Dict, heads: int
                 ) -> Tuple[jax.Array, Dict]:
    wx = (x[:, 0] @ params["w"])
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    c, n, h, m = _slstm_step(params, carry, wx)
    out = common.rms_norm(h[:, None].astype(x.dtype), params["out_norm"]) \
        @ params["out_proj"]
    return out, {"c": c, "n": n, "h": h, "m": m}


def slstm_init_state(bsz: int, d_model: int) -> Dict:
    z = jnp.zeros((bsz, d_model), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": z}
