"""Mixture-of-Experts FFN with capacity-bounded sort/scatter dispatch.

TPU-native design notes (hardware adaptation, see DESIGN.md §3):

* Expert weights are stacked ``[E, ...]`` and sharded over the ``model``
  mesh axis (expert parallelism). Under GSPMD the scatter into the
  expert-major buffer lowers to all-to-all-class collectives.
* Dispatch is GATHER/SCATTER-based (argsort by expert id + capacity
  clipping), not the GShard one-hot-einsum — the one-hot matmul would
  inflate HLO_FLOPs with fake compute and poison the roofline's
  MODEL_FLOPS/HLO_FLOPs ratio.
* Capacity factor bounds the per-expert token count so every shape is
  static. Overflowing tokens are dropped (standard GShard semantics);
  the router's aux loss (load-balance, Switch-style) discourages
  overflow during training.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import common


def init_moe(key: jax.Array, d_model: int, d_ff: int, num_experts: int,
             activation: str, shared_expert: bool,
             dtype=common.DEFAULT_DTYPE) -> Dict:
    ks = common.split_keys(key, 8)
    p = {
        "router": common.dense_init(ks[0], (d_model, num_experts), jnp.float32),
        "w_gate": common.dense_init(ks[1], (num_experts, d_model, d_ff), dtype),
        "w_up": common.dense_init(ks[2], (num_experts, d_model, d_ff), dtype),
        "w_down": common.dense_init(ks[3], (num_experts, d_ff, d_model), dtype),
    }
    if shared_expert:
        p["shared"] = {
            "w_gate": common.dense_init(ks[4], (d_model, d_ff), dtype),
            "w_up": common.dense_init(ks[5], (d_model, d_ff), dtype),
            "w_down": common.dense_init(ks[6], (d_ff, d_model), dtype),
        }
    return p


def _capacity(num_tokens: int, num_experts: int, top_k: int,
              capacity_factor: float) -> int:
    cap = int(num_tokens * top_k * capacity_factor / num_experts)
    return max(4, ((cap + 3) // 4) * 4)  # multiple of 4, ≥4


def apply_moe(params: Dict, x: jax.Array, top_k: int,
              capacity_factor: float = 1.25
              ) -> Tuple[jax.Array, jax.Array]:
    """x [B,S,D] -> (y [B,S,D], aux_loss []).

    Sort-based dispatch:
      1. router top-k per token  → (expert_id, gate) pairs, T·k entries
      2. argsort by expert id    → expert-contiguous order
      3. rank within expert      → capacity slot (clipped)
      4. scatter tokens into     [E, C, D] expert buffers
      5. batched expert FFN      einsum over stacked expert weights
      6. gather back + weighted combine
    """
    b, s, d = x.shape
    e = params["router"].shape[-1]
    t = b * s
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ params["router"])        # [T,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)         # [T,k]
    gate_vals = gate_vals / (jnp.sum(gate_vals, axis=-1, keepdims=True) + 1e-9)

    # Switch-style load-balance aux loss
    me = jnp.mean(probs, axis=0)                                # [E]
    ce = jnp.mean(
        jax.nn.one_hot(expert_ids[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    cap = _capacity(t, e, top_k, capacity_factor)

    flat_expert = expert_ids.reshape(-1)                        # [T*k]
    flat_gate = gate_vals.reshape(-1)
    flat_tok = jnp.repeat(jnp.arange(t), top_k)

    order = jnp.argsort(flat_expert, stable=True)               # expert-major
    sorted_expert = flat_expert[order]
    sorted_tok = flat_tok[order]
    sorted_gate = flat_gate[order]

    # rank of each entry within its expert segment
    seg_start = jnp.searchsorted(sorted_expert, jnp.arange(e), side="left")
    rank = jnp.arange(t * top_k) - seg_start[sorted_expert]
    keep = rank < cap
    slot = jnp.where(keep, rank, cap - 1)

    # scatter tokens into expert buffers [E, C, D]
    buf = jnp.zeros((e, cap, d), x.dtype)
    src = jnp.where(keep[:, None], xt[sorted_tok], 0).astype(x.dtype)
    buf = buf.at[sorted_expert, slot].add(src, mode="drop")

    # expert FFN over stacked weights (expert-parallel under GSPMD)
    if "w_gate" in params and params.get("w_gate") is not None:
        g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
        u = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
        h = common.swiglu(g, u)
    else:  # pragma: no cover — all assigned MoE archs are gated
        h = common.gelu(jnp.einsum("ecd,edf->ecf", buf, params["w_up"]))
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["w_down"])   # [E,C,D]

    # gather back and combine with gates
    picked = out_buf[sorted_expert, slot]                       # [T*k, D]
    picked = jnp.where(keep[:, None], picked, 0)
    contrib = picked * sorted_gate[:, None].astype(picked.dtype)
    yt = jnp.zeros((t, d), x.dtype).at[sorted_tok].add(
        contrib.astype(x.dtype), mode="drop")

    if "shared" in params:
        sh = params["shared"]
        yt = yt + (common.swiglu(xt @ sh["w_gate"], xt @ sh["w_up"])
                   @ sh["w_down"])
    return yt.reshape(b, s, d), aux


# ---------------------------------------------------------------------------
# Grouped (data-shard-local) dispatch — §Perf iteration for MoE archs.
#
# Plain apply_moe builds ONE [E, C, D] buffer from globally-sharded
# tokens; under GSPMD the scatter contributions are partial per data
# shard and XLA ALL-REDUCES the full buffer across the data axis (the
# 33 TB/device pathology measured on llama4-maverick prefill_32k — see
# EXPERIMENTS.md §Perf). Adding a leading group dim g (= data shards)
# keeps the scatter local (buf[g] is built only from group g's tokens);
# the only cross-device movement left is the E-axis resharding before
# the expert einsum, which lowers to the canonical expert-parallel
# all-to-all.
# ---------------------------------------------------------------------------


def apply_moe_grouped(params: Dict, x: jax.Array, top_k: int,
                      capacity_factor: float = 1.25,
                      groups: int = 8,
                      constrain: bool = False
                      ) -> Tuple[jax.Array, jax.Array]:
    """x [B,S,D] -> (y [B,S,D], aux). Token groups dispatch independently
    (capacity is per group). ``constrain`` adds GSPMD sharding
    constraints (g over 'data', E over 'model') — requires a mesh
    context at trace time."""
    b, s, d = x.shape
    e = params["router"].shape[-1]
    t = b * s
    assert t % groups == 0, (t, groups)
    tl = t // groups
    xg = x.reshape(groups, tl, d)

    logits = (xg.astype(jnp.float32) @ params["router"])     # [g,tl,E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, top_k)      # [g,tl,k]
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    me = jnp.mean(probs.reshape(t, e), axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[..., 0].reshape(t), e,
                                 dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    cap = _capacity(tl, e, top_k, capacity_factor)

    def dispatch(xt, eids, gates):
        flat_expert = eids.reshape(-1)
        flat_gate = gates.reshape(-1)
        flat_tok = jnp.repeat(jnp.arange(tl), top_k)
        order = jnp.argsort(flat_expert, stable=True)
        se, stok = flat_expert[order], flat_tok[order]
        sgate = flat_gate[order]
        seg_start = jnp.searchsorted(se, jnp.arange(e), side="left")
        rank = jnp.arange(tl * top_k) - seg_start[se]
        keep = rank < cap
        slot = jnp.where(keep, rank, cap - 1)
        buf = jnp.zeros((e, cap, d), xt.dtype)
        src = jnp.where(keep[:, None], xt[stok], 0).astype(xt.dtype)
        buf = buf.at[se, slot].add(src, mode="drop")
        return buf, (se, stok, sgate, keep, slot)

    buf, meta = jax.vmap(dispatch)(xg, expert_ids, gate_vals)  # [g,E,C,D]

    if constrain:
        from jax.sharding import PartitionSpec as P
        buf = jax.lax.with_sharding_constraint(
            buf, P("data", "model", None, None))

    g_ = jnp.einsum("gecd,edf->gecf", buf, params["w_gate"])
    u_ = jnp.einsum("gecd,edf->gecf", buf, params["w_up"])
    h = common.swiglu(g_, u_)
    out_buf = jnp.einsum("gecf,efd->gecd", h, params["w_down"])
    if constrain:
        from jax.sharding import PartitionSpec as P
        out_buf = jax.lax.with_sharding_constraint(
            out_buf, P("data", "model", None, None))

    def combine(ob, xt, meta_g):
        se, stok, sgate, keep, slot = meta_g
        picked = ob[se, slot]
        picked = jnp.where(keep[:, None], picked, 0)
        contrib = picked * sgate[:, None].astype(picked.dtype)
        return jnp.zeros((tl, d), xt.dtype).at[stok].add(
            contrib.astype(xt.dtype), mode="drop")

    yt = jax.vmap(combine)(out_buf, xg, meta)                # [g,tl,D]
    yt = yt.reshape(b, s, d)
    if "shared" in params:
        sh = params["shared"]
        xt = x.reshape(t, d)
        yt = yt + (common.swiglu(xt @ sh["w_gate"], xt @ sh["w_up"])
                   @ sh["w_down"]).reshape(b, s, d)
    return yt, aux
