"""Shared model primitives: norms, activations, RoPE, init helpers."""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

DEFAULT_DTYPE = jnp.bfloat16


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: jax.Array,
               eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def swiglu(gate: jax.Array, up: jax.Array) -> jax.Array:
    return jax.nn.silu(gate.astype(jnp.float32)).astype(gate.dtype) * up


def relu2(x: jax.Array) -> jax.Array:
    """Squared ReLU (Nemotron-4)."""
    r = jnp.maximum(x, 0)
    return r * r


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)              # [hd/2]
    angles = positions.astype(jnp.float32)[..., None] * freqs  # [..., s, hd/2]
    angles = angles[..., None, :]                    # [..., s, 1, hd/2]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    """Whisper-style sinusoidal embeddings computed on the fly (the paper
    model uses learned positions; we use sinusoidal so the assigned long
    shapes have no table-size limit — recorded in DESIGN.md)."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10000.0) * jnp.arange(half, dtype=jnp.float32)
                    / max(half - 1, 1))
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# Init helpers
# ---------------------------------------------------------------------------


def dense_init(key: jax.Array, shape: Tuple[int, ...],
               dtype=DEFAULT_DTYPE) -> jax.Array:
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key: jax.Array, shape: Tuple[int, ...],
               dtype=DEFAULT_DTYPE) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


def split_keys(key: jax.Array, n: int):
    return list(jax.random.split(key, n))
