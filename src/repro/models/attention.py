"""Attention mixers: GQA self-attention (full / sliding-window / chunked),
single-step decode attention over a KV cache, and cross-attention.

Pure-jnp implementations double as (a) the dry-run lowering path, (b) the
oracle for the Pallas kernels. On TPU the prefill path dispatches to the
flash kernel in ``repro.kernels`` (see ``use_flash``).

Layouts:  q [B,S,H,hd]; k,v [B,S,KV,hd]; GQA groups G = H // KV.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30
Q_CHUNK = 512  # prefill query-chunk size for the memory-bounded path


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q [B,Sq,H,hd], k [B,Sk,KV,hd] -> scores [B,KV,G,Sq,Sk] (fp32)."""
    b, sq, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, sq, kv, g, hd)
    return jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                      preferred_element_type=jnp.float32) / jnp.sqrt(float(hd))


def _gqa_out(probs: jax.Array, v: jax.Array, dtype) -> jax.Array:
    """probs [B,KV,G,Sq,Sk], v [B,Sk,KV,hd] -> out [B,Sq,H,hd]."""
    b, kv, g, sq, sk = probs.shape
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(v.dtype), v)
    return out.reshape(b, sq, kv * g, v.shape[-1]).astype(dtype)


def _masked_softmax(scores: jax.Array, mask: jax.Array) -> jax.Array:
    scores = jnp.where(mask, scores, NEG_INF)
    m = jnp.max(scores, axis=-1, keepdims=True)
    e = jnp.exp(scores - jax.lax.stop_gradient(jnp.maximum(m, NEG_INF / 2)))
    return e / (jnp.sum(e, axis=-1, keepdims=True) + 1e-30)


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   causal: bool = True, window: int = 0,
                   q_offset: int = 0) -> jax.Array:
    """Reference attention; materializes [Sq,Sk] scores. Used for short
    sequences and as the kernel oracle."""
    sq, sk = q.shape[1], k.shape[1]
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(sk)
    mask = jnp.ones((sq, sk), dtype=bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = _gqa_scores(q, k)                        # [B,KV,G,Sq,Sk]
    probs = _masked_softmax(scores, mask[None, None, None])
    return _gqa_out(probs, v, q.dtype)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True, window: int = 0,
                      q_chunk: int = Q_CHUNK,
                      q_offset: int = 0) -> jax.Array:
    """Query-chunked attention: O(q_chunk · Sk) live scores. The XLA-level
    flash-attention analogue used for long-prefill lowering on any
    backend. ``q_offset``: absolute position of q's first row (suffix
    prefill attends a suffix against a longer cached context)."""
    b, s, h, hd = q.shape
    if s <= q_chunk:
        return full_attention(q, k, v, causal=causal, window=window,
                              q_offset=q_offset)
    assert s % q_chunk == 0, (s, q_chunk)
    n = s // q_chunk

    def body(i):
        qc = jax.lax.dynamic_slice_in_dim(q, i * q_chunk, q_chunk, axis=1)
        return full_attention(qc, k, v, causal=causal, window=window,
                              q_offset=q_offset + i * q_chunk)

    out = jax.lax.map(body, jnp.arange(n))            # [n,B,qc,H,hd]
    return jnp.moveaxis(out, 0, 1).reshape(b, s, h, hd)


def prefill_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      causal: bool = True, window: int = 0,
                      use_flash: Optional[bool] = None) -> jax.Array:
    """Dispatch: Pallas flash kernel on TPU, chunked jnp elsewhere."""
    if use_flash is None:
        use_flash = jax.default_backend() == "tpu"
    if use_flash:
        from repro.kernels import ops as kops
        if kops.flash_supported(q, k, v):
            return kops.flash_attention(q, k, v, causal=causal, window=window)
    if q.shape[1] > Q_CHUNK and q.shape[1] % Q_CHUNK == 0:
        return chunked_attention(q, k, v, causal=causal, window=window)
    return full_attention(q, k, v, causal=causal, window=window)


# ---------------------------------------------------------------------------
# Decode: one new token against a KV cache
# ---------------------------------------------------------------------------


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     valid_len: jax.Array, window: int = 0,
                     positions: Optional[jax.Array] = None,
                     use_kernel: Optional[bool] = None,
                     kv_layout: str = "bshd") -> jax.Array:
    """q [B,1,H,hd]; cache [B,S,KV,hd] ("bshd") or [B,KV,S,hd]
    ("kmajor"); valid_len [] or [B] — entries with index < valid_len
    participate. The new token's own (k,v) must already be written into
    the cache.

    ``window``/``positions``: for ring-buffer sliding-window caches the
    slot order is rotated; masking is by stored absolute position instead
    of slot index (positions [B,S])."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel and kv_layout == "bshd":
        from repro.kernels import ops as kops
        if kops.decode_supported(q, k_cache, v_cache):
            return kops.gqa_decode_attention(q, k_cache, v_cache, valid_len)
    if kv_layout == "kmajor":
        b, _, h, hd = q.shape
        kv = k_cache.shape[1]
        s = k_cache.shape[2]
        g = h // kv
        qg = q.reshape(b, 1, kv, g, hd)
        scores = jnp.einsum("bqkgd,bksd->bkgqs", qg, k_cache,
                            preferred_element_type=jnp.float32) \
            / jnp.sqrt(float(hd))
        if positions is not None:
            cur = jnp.max(positions, axis=-1, keepdims=True)
            mask = positions <= cur
            if window > 0:
                mask &= positions > cur - window
            mask = mask[:, None, None, None, :]
        else:
            idx = jnp.arange(s)
            vl = jnp.asarray(valid_len)
            vl = vl[:, None] if vl.ndim == 1 else vl[None, None]
            mask = (idx[None] < vl)[:, None, None, None, :]
        probs = _masked_softmax(scores, mask)
        out = jnp.einsum("bkgqs,bksd->bqkgd", probs.astype(v_cache.dtype),
                         v_cache)
        return out.reshape(b, 1, h, hd).astype(q.dtype)
    s = k_cache.shape[1]
    if positions is not None:
        cur = jnp.max(positions, axis=-1, keepdims=True)        # [B,1]
        mask = positions <= cur
        if window > 0:
            mask &= positions > cur - window
        mask = mask[:, None, None, None, :]                     # [B,1,1,1,S]
    else:
        idx = jnp.arange(s)
        vl = jnp.asarray(valid_len)
        vl = vl[:, None] if vl.ndim == 1 else vl[None, None]
        mask = (idx[None] < vl)[:, None, None, None, :]
    scores = _gqa_scores(q, k_cache)                  # [B,KV,G,1,S]
    probs = _masked_softmax(scores, mask)             # mask [B,1,1,1,S]
    return _gqa_out(probs, v_cache, q.dtype)


def gather_pages(pages: jax.Array, block_tables: jax.Array,
                 kv_layout: str = "bshd") -> jax.Array:
    """Materialize the dense per-sequence view of a paged pool
    (DESIGN.md §11): pool [N,ps,KV,hd] ("bshd") or [N,KV,ps,hd]
    ("kmajor") + block tables [B,nb] (entries < 0 → scratch page 0)
    → [B,nb*ps,KV,hd] / [B,KV,nb*ps,hd].

    This is the oracle/off-TPU lowering of paged decode: positions the
    table doesn't back read the scratch page and MUST be masked by
    valid_len downstream. With ``nb*ps`` equal to a dense cache's
    capacity the gathered view is shape-identical to that cache, so the
    downstream attention reduction is bit-identical too."""
    b, nb = block_tables.shape
    bt = jnp.maximum(block_tables, 0)
    g = pages[bt]                        # [B,nb,(ps,KV|KV,ps),hd]
    if kv_layout == "kmajor":
        n, kv, ps, hd = pages.shape
        return jnp.moveaxis(g, 2, 1).reshape(b, kv, nb * ps, hd)
    n, ps, kv, hd = pages.shape
    return g.reshape(b, nb * ps, kv, hd)


def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, block_tables: jax.Array,
                           valid_len: jax.Array,
                           use_kernel: Optional[bool] = None,
                           kv_layout: str = "bshd") -> jax.Array:
    """One new token against a PAGED KV cache (DESIGN.md §11).

    q [B,1,H,hd]; pools [N,ps,KV,hd] ("bshd") / [N,KV,ps,hd]
    ("kmajor"); block_tables [B,nb] int32. On TPU the Pallas kernel
    walks the block table directly (no dense materialization); off-TPU
    the gathered dense view reuses ``decode_attention`` — bit-identical
    to a dense cache of capacity nb*ps holding the same values."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel and kv_layout == "bshd":
        from repro.kernels import ops as kops
        if kops.paged_decode_supported(q, k_pages):
            return kops.gqa_paged_decode_attention(q, k_pages, v_pages,
                                                   block_tables, valid_len)
    kd = gather_pages(k_pages, block_tables, kv_layout)
    vd = gather_pages(v_pages, block_tables, kv_layout)
    return decode_attention(q, kd, vd, valid_len=valid_len,
                            use_kernel=use_kernel, kv_layout=kv_layout)


def dequantize_pages(pages: jax.Array, scales: jax.Array,
                     kv_layout: str = "bshd",
                     dtype=jnp.float32) -> jax.Array:
    """Dequantize an int8 model-layout page pool with per-(page, kv-head)
    fp32 scales: pool [N,ps,KV,hd] ("bshd") / [N,KV,ps,hd] ("kmajor"),
    scales [N,KV] → float pool of the same layout."""
    if kv_layout == "kmajor":
        return (pages.astype(jnp.float32)
                * scales[:, :, None, None]).astype(dtype)
    return (pages.astype(jnp.float32)
            * scales[:, None, :, None]).astype(dtype)


def paged_decode_quant_attention(q: jax.Array, k_pages: jax.Array,
                                 v_pages: jax.Array, k_scales: jax.Array,
                                 v_scales: jax.Array,
                                 block_tables: jax.Array,
                                 valid_len: jax.Array,
                                 use_kernel: Optional[bool] = None,
                                 kv_layout: str = "bshd") -> jax.Array:
    """One new token against an INT8-resident paged KV cache
    (DESIGN.md §16).

    q [B,1,H,hd]; int8 pools [N,ps,KV,hd] ("bshd") / [N,KV,ps,hd]
    ("kmajor"); fp32 scales [N,KV]; block_tables [B,nb] int32. On TPU
    the fused Pallas kernel dequantizes in-register while walking the
    block table; off-TPU (or kmajor) the pools are dequantized to fp32
    and the float paged path is reused — same values, so the logits
    match the fused kernel to fp32 rounding."""
    if use_kernel is None:
        use_kernel = jax.default_backend() == "tpu"
    if use_kernel and kv_layout == "bshd":
        from repro.kernels import ops as kops
        if kops.paged_decode_quant_supported(q, k_pages):
            return kops.gqa_paged_decode_quant_attention(
                q, k_pages, v_pages, k_scales, v_scales,
                block_tables, valid_len)
    kd = dequantize_pages(k_pages, k_scales, kv_layout)
    vd = dequantize_pages(v_pages, v_scales, kv_layout)
    return paged_decode_attention(q, kd, vd, block_tables, valid_len,
                                  use_kernel=False, kv_layout=kv_layout)


def cross_attention(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Non-causal attention over a fixed memory (image tokens / enc output)."""
    scores = _gqa_scores(q, k)
    probs = _masked_softmax(scores, jnp.ones(scores.shape[-2:], bool)[None, None, None])
    return _gqa_out(probs, v, q.dtype)
