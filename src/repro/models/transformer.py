"""Composable model builder for every assigned architecture.

A model is a stack of ``num_periods`` repetitions of the config's
``period`` (a tuple of BlockSpecs). Parameters for each block position
are *stacked* over periods (leading dim P) and the stack is executed
with ``jax.lax.scan`` — compile time scales with the period length, not
the layer count (Jamba: 8 bodies for 32 layers; Vision-90B: 5 for 100).

Three entry points, matching the assigned input shapes:
    train_forward  — full-sequence logits + loss          (train_4k)
    prefill        — prompt → (last-token logits, cache)  (prefill_32k)
    decode_step    — one token against a cache            (decode_32k/long_500k)

Caches are plain dict pytrees stacked the same way as params, so
prefill's ys slot directly into decode's xs. The disaggregated serving
runtime ships exactly this pytree from prefill to decode replicas.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, BlockSpec
from repro.models import attention, common, mamba, mlp, moe, xlstm

Params = Dict[str, Any]
Cache = Dict[str, Any]


class Ctx(NamedTuple):
    """Per-call context threaded through block functions."""
    positions: jax.Array                 # [B,S] absolute positions
    cross_embeds: Optional[jax.Array]    # [B,T,D] image / encoder memory
    causal: bool                         # False inside the audio encoder
    cache_capacity: int                  # attention cache slots to allocate
    want_cache: bool = True              # False for train/encoder (no ys)
    # paged decode (DESIGN.md §11): physical page per logical s-block,
    # shared by every full-attention layer (all layers see the same
    # positions); None = dense per-slot slabs
    block_tables: Optional[jax.Array] = None   # [B, num_blocks] int32
    page_size: int = 0


# ---------------------------------------------------------------------------
# Block init
# ---------------------------------------------------------------------------


def _init_attn(key, cfg: ArchConfig, cross: bool) -> Params:
    ks = common.split_keys(key, 6)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p: Params = {
        "wq": common.dense_init(ks[0], (d, qd)),
        "wk": common.dense_init(ks[1], (d, kvd)),
        "wv": common.dense_init(ks[2], (d, kvd)),
        "wo": common.dense_init(ks[3], (qd, d)),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((qd,), common.DEFAULT_DTYPE)
        p["bk"] = jnp.zeros((kvd,), common.DEFAULT_DTYPE)
        p["bv"] = jnp.zeros((kvd,), common.DEFAULT_DTYPE)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
        p["k_norm"] = jnp.ones((cfg.head_dim,), jnp.float32)
    if cross and cfg.num_image_tokens:
        p["gate"] = jnp.zeros((), jnp.float32)  # llama-3.2-vision gated x-attn
    return p


def init_block(key, cfg: ArchConfig, spec: BlockSpec) -> Params:
    ks = common.split_keys(key, 3)
    p: Params = {"norm1": jnp.ones((cfg.d_model,), jnp.float32)}
    if spec.mixer in ("attn", "swa", "cross_attn"):
        p["attn"] = _init_attn(ks[0], cfg, spec.mixer == "cross_attn")
    elif spec.mixer == "mamba":
        p["mamba"] = mamba.init_mamba(ks[0], cfg.d_model, cfg.ssm_state,
                                      cfg.ssm_conv, cfg.ssm_expand)
    elif spec.mixer == "mlstm":
        p["mlstm"] = xlstm.init_mlstm(ks[0], cfg.d_model, cfg.xlstm_heads)
    elif spec.mixer == "slstm":
        p["slstm"] = xlstm.init_slstm(ks[0], cfg.d_model, cfg.xlstm_heads)
    else:  # pragma: no cover
        raise ValueError(spec.mixer)
    if spec.ffn == "mlp":
        p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["mlp"] = mlp.init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.activation)
    elif spec.ffn == "moe":
        p["norm2"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["moe"] = moe.init_moe(ks[1], cfg.d_model, cfg.moe_d_ff or cfg.d_ff,
                                cfg.num_experts, cfg.activation,
                                cfg.shared_expert)
    return p


def init_params(key: jax.Array, cfg: ArchConfig) -> Params:
    ks = common.split_keys(key, 6)
    P = cfg.num_periods

    def stacked(key, init_fn):
        return jax.vmap(init_fn)(jax.random.split(key, P))

    blocks = []
    for bi, spec in enumerate(cfg.period):
        blocks.append(stacked(jax.random.fold_in(ks[0], bi),
                              lambda k, s=spec: init_block(k, cfg, s)))
    params: Params = {
        "embed": common.embed_init(ks[1], (cfg.vocab, cfg.d_model)),
        "blocks": tuple(blocks),
        "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        "lm_head": common.dense_init(ks[2], (cfg.d_model, cfg.vocab)),
    }
    if cfg.is_encdec:
        enc_spec = BlockSpec("attn", "mlp")
        enc = stacked(ks[3], lambda k: init_block(k, cfg, enc_spec))
        params["encoder"] = {
            "blocks": (enc,),
            "final_norm": jnp.ones((cfg.d_model,), jnp.float32),
        }
    return params


def count_params(cfg: ArchConfig) -> int:
    shapes = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    return int(sum(x.size for x in jax.tree.leaves(shapes)))


def count_active_params(cfg: ArchConfig) -> int:
    """Parameters touched per token (MoE experts scaled to top_k/E)."""
    total = 0
    shapes = jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))

    def visit(path, leaf):
        nonlocal total
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        in_moe = any(k == "moe" for k in keys)
        is_expert = in_moe and any(k in ("w_gate", "w_up", "w_down")
                                   for k in keys) and not any(
                                       k == "shared" for k in keys)
        n = leaf.size
        if is_expert and cfg.num_experts:
            n = n * cfg.top_k // cfg.num_experts
        total += n

    jax.tree_util.tree_map_with_path(visit, shapes)
    return int(total)


# ---------------------------------------------------------------------------
# Attention block forward
# ---------------------------------------------------------------------------


def _qkv(p: Params, cfg: ArchConfig, x: jax.Array,
         positions: Optional[jax.Array], rope: bool
         ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    b, s, _ = x.shape
    q = x @ p["wq"] + (p["bq"] if "bq" in p else 0)
    k = x @ p["wk"] + (p["bk"] if "bk" in p else 0)
    v = x @ p["wv"] + (p["bv"] if "bv" in p else 0)
    q = q.reshape(b, s, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, s, cfg.kv_heads, cfg.head_dim)
    v = v.reshape(b, s, cfg.kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = common.rms_norm(q, p["q_norm"])
        k = common.rms_norm(k, p["k_norm"])
    if rope and positions is not None:
        q = common.apply_rope(q, positions, cfg.rope_theta)
        k = common.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _attn_prefill(spec: BlockSpec, cfg: ArchConfig, p: Params, x: jax.Array,
                  ctx: Ctx) -> Tuple[jax.Array, Cache]:
    b, s, _ = x.shape
    h = common.rms_norm(x, p["norm1"])
    ap = p["attn"]
    if spec.mixer == "cross_attn":
        mem = ctx.cross_embeds
        assert mem is not None, "cross_attn block needs cross_embeds"
        q, _, _ = _qkv(ap, cfg, h, None, rope=False)
        tm = mem.shape[1]
        k = (mem @ ap["wk"]).reshape(b, tm, cfg.kv_heads, cfg.head_dim)
        v = (mem @ ap["wv"]).reshape(b, tm, cfg.kv_heads, cfg.head_dim)
        out = attention.cross_attention(q, k, v)
        out = out.reshape(b, s, cfg.q_dim) @ ap["wo"]
        if "gate" in ap:
            out = jnp.tanh(ap["gate"]).astype(x.dtype) * out
        x = x + out
        cache = {"k": k, "v": v} if ctx.want_cache else {}
        return x, cache
    use_rope = not cfg.is_encdec  # whisper uses absolute positions
    q, k, v = _qkv(ap, cfg, h, ctx.positions if use_rope else None, use_rope)
    if cfg.attn_data_local:
        from jax.sharding import PartitionSpec as P
        wsc = jax.lax.with_sharding_constraint
        q = wsc(q, P("data", None, None, None))
        k = wsc(k, P("data", None, None, None))
        v = wsc(v, P("data", None, None, None))
    window = cfg.sliding_window if spec.mixer == "swa" else 0
    out = attention.prefill_attention(q, k, v, causal=ctx.causal,
                                      window=window)
    x = x + out.reshape(b, s, cfg.q_dim) @ ap["wo"]
    if not ctx.causal or not ctx.want_cache:
        return x, {}  # encoder / train: no cache
    cap = window if window else ctx.cache_capacity
    if window:
        # ring buffer holding the last `window` tokens + their positions
        take = min(s, window)
        kc = jnp.zeros((b, window, cfg.kv_heads, cfg.head_dim), k.dtype)
        vc = jnp.zeros_like(kc)
        pc = jnp.full((b, window), -1, jnp.int32)
        slots = (ctx.positions[:, s - take:]) % window      # [B,take]
        bidx = jnp.arange(b)[:, None]
        kc = kc.at[bidx, slots].set(k[:, s - take:])
        vc = vc.at[bidx, slots].set(v[:, s - take:])
        pc = pc.at[bidx, slots].set(ctx.positions[:, s - take:])
        if cfg.kv_layout == "kmajor":
            kc, vc = kc.swapaxes(1, 2), vc.swapaxes(1, 2)
        return x, {"k": kc, "v": vc, "pos": pc}
    if cap > s:
        pad = [(0, 0), (0, cap - s), (0, 0), (0, 0)]
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    if cfg.kv_layout == "kmajor":
        k, v = k.swapaxes(1, 2), v.swapaxes(1, 2)   # [B,kv,cap,hd]
    return x, {"k": k, "v": v}


def _attn_decode(spec: BlockSpec, cfg: ArchConfig, p: Params, x: jax.Array,
                 cache: Cache, ctx: Ctx) -> Tuple[jax.Array, Cache]:
    b = x.shape[0]
    h = common.rms_norm(x, p["norm1"])
    ap = p["attn"]
    if spec.mixer == "cross_attn":
        q, _, _ = _qkv(ap, cfg, h, None, rope=False)
        out = attention.cross_attention(q, cache["k"], cache["v"])
        out = out.reshape(b, 1, cfg.q_dim) @ ap["wo"]
        if "gate" in ap:
            out = jnp.tanh(ap["gate"]).astype(x.dtype) * out
        return x + out, cache

    use_rope = not cfg.is_encdec
    pos = ctx.positions                                  # [B,1]
    q, k, v = _qkv(ap, cfg, h, pos if use_rope else None, use_rope)
    if cfg.attn_data_local:
        from jax.sharding import PartitionSpec as P
        wsc = jax.lax.with_sharding_constraint
        q = wsc(q, P("data", None, None, None))
        k = wsc(k, P("data", None, None, None))
        v = wsc(v, P("data", None, None, None))
    window = cfg.sliding_window if spec.mixer == "swa" else 0
    bidx = jnp.arange(b)
    layout = cfg.kv_layout

    def write(c, slot, new):                             # new [B,kv,hd]
        if layout == "kmajor":                           # c [B,kv,S,hd]
            return jax.vmap(lambda ci, si, ui:
                            ci.at[:, si].set(ui))(c, slot, new)
        return c.at[bidx, slot].set(new)                 # c [B,S,kv,hd]

    if window:
        slot = pos[:, 0] % window
        kc = write(cache["k"], slot, k[:, 0])
        vc = write(cache["v"], slot, v[:, 0])
        pc = cache["pos"].at[bidx, slot].set(pos[:, 0])
        out = attention.decode_attention(q, kc, vc, valid_len=None,
                                         window=window, positions=pc,
                                         kv_layout=layout)
        new_cache = {"k": kc, "v": vc, "pos": pc}
    else:
        slot = pos[:, 0]
        kc = write(cache["k"], slot, k[:, 0])
        vc = write(cache["v"], slot, v[:, 0])
        out = attention.decode_attention(q, kc, vc, valid_len=pos[:, 0] + 1,
                                         kv_layout=layout)
        new_cache = {"k": kc, "v": vc}
    x = x + out.reshape(b, 1, cfg.q_dim) @ ap["wo"]
    return x, new_cache


QUANT_EPS_SCALE = 1e-12  # matches kernels.kv_quant.EPS_SCALE


def _quant_page_write(pool: jax.Array, scales: jax.Array, page: jax.Array,
                      off: jax.Array, row: jax.Array, layout: str
                      ) -> Tuple[jax.Array, jax.Array]:
    """Scatter one new token's k or v row into an int8 page pool
    (DESIGN.md §16). The per-(page, kv-head) scale can only grow
    (symmetric max-abs); when it does, the touched page's existing
    payload is rescaled in the same write — old_scale/new_scale ≤ 1, so
    rescaled codes stay in range, and when the scale is unchanged the
    ratio is exactly 1.0 and int8 codes round-trip bit-exactly.

    pool [N,ps,KV,hd] ("bshd") / [N,KV,ps,hd] ("kmajor") int8; scales
    [N,KV] fp32; page/off [B] int32; row [B,KV,hd]."""
    b = row.shape[0]
    bidx = jnp.arange(b)
    rowf = row.astype(jnp.float32)
    old_s = scales[page]                                     # [B,KV]
    row_max = jnp.max(jnp.abs(rowf), axis=-1)                # [B,KV]
    new_s = jnp.maximum(jnp.maximum(old_s, row_max / 127.0),
                        QUANT_EPS_SCALE)
    ratio = old_s / new_s                                    # ≤ 1
    pg = pool[page].astype(jnp.float32)   # [B,ps,KV,hd] / [B,KV,ps,hd]
    qrow = jnp.clip(jnp.round(rowf / new_s[..., None]), -127, 127)
    if layout == "kmajor":
        pg = jnp.round(pg * ratio[:, :, None, None])
        pg = pg.at[bidx, :, off].set(qrow)
    else:
        pg = jnp.round(pg * ratio[:, None, :, None])
        pg = pg.at[bidx, off].set(qrow)
    pool = pool.at[page].set(jnp.clip(pg, -127, 127).astype(jnp.int8))
    scales = scales.at[page].set(new_s)
    return pool, scales


def _attn_decode_paged(spec: BlockSpec, cfg: ArchConfig, p: Params,
                       x: jax.Array, cache: Cache, ctx: Ctx
                       ) -> Tuple[jax.Array, Cache]:
    """Full-attention decode over a PAGED cache (DESIGN.md §11): the
    k/v leaves are page pools shared by every slot; ``ctx.block_tables``
    maps each slot's logical s-blocks onto physical pages. The new
    token's k/v scatter into (page, offset); unadmitted slots carry
    table entries < 0, clamped onto the reserved scratch page so their
    writes can never touch live pages. Attention is bit-identical to
    the dense path on the same values (``attention.gather_pages``).

    When the cache carries ``k_scale``/``v_scale`` sidecar leaves the
    pools are int8-resident (DESIGN.md §16): the new token is quantized
    into its page (growing the page scale if needed) and attention
    dequantizes in-register via the fused kernel."""
    b = x.shape[0]
    h = common.rms_norm(x, p["norm1"])
    ap = p["attn"]
    pos = ctx.positions                                  # [B,1]
    q, k, v = _qkv(ap, cfg, h, pos if not cfg.is_encdec else None,
                   not cfg.is_encdec)
    ps = ctx.page_size
    blk = pos[:, 0] // ps
    off = pos[:, 0] % ps
    bidx = jnp.arange(b)
    page = jnp.maximum(ctx.block_tables[bidx, blk], 0)   # <0 → scratch 0
    layout = cfg.kv_layout
    if "k_scale" in cache:                               # int8-resident §16
        kc, ks = _quant_page_write(cache["k"], cache["k_scale"],
                                   page, off, k[:, 0], layout)
        vc, vs = _quant_page_write(cache["v"], cache["v_scale"],
                                   page, off, v[:, 0], layout)
        out = attention.paged_decode_quant_attention(
            q, kc, vc, ks, vs, ctx.block_tables,
            valid_len=pos[:, 0] + 1, kv_layout=layout)
        x = x + out.reshape(b, 1, cfg.q_dim) @ ap["wo"]
        return x, {"k": kc, "v": vc, "k_scale": ks, "v_scale": vs}
    if layout == "kmajor":                               # pool [N,KV,ps,hd]
        kc = cache["k"].at[page, :, off].set(k[:, 0])
        vc = cache["v"].at[page, :, off].set(v[:, 0])
    else:                                                # pool [N,ps,KV,hd]
        kc = cache["k"].at[page, off].set(k[:, 0])
        vc = cache["v"].at[page, off].set(v[:, 0])
    out = attention.paged_decode_attention(q, kc, vc, ctx.block_tables,
                                           valid_len=pos[:, 0] + 1,
                                           kv_layout=layout)
    x = x + out.reshape(b, 1, cfg.q_dim) @ ap["wo"]
    return x, {"k": kc, "v": vc}


# ---------------------------------------------------------------------------
# Generic block forward (prefill / decode)
# ---------------------------------------------------------------------------


def block_prefill(spec: BlockSpec, cfg: ArchConfig, p: Params, x: jax.Array,
                  ctx: Ctx) -> Tuple[jax.Array, Cache, jax.Array]:
    aux = jnp.zeros((), jnp.float32)
    if spec.mixer in ("attn", "swa", "cross_attn"):
        x, cache = _attn_prefill(spec, cfg, p, x, ctx)
    elif spec.mixer == "mamba":
        h = common.rms_norm(x, p["norm1"])
        out, cache = mamba.mamba_prefill(p["mamba"], h, cfg.ssm_state,
                                         cfg.ssm_conv)
        x = x + out
    elif spec.mixer == "mlstm":
        h = common.rms_norm(x, p["norm1"])
        out, cache = xlstm.mlstm_prefill(p["mlstm"], h, cfg.xlstm_heads)
        x = x + out
    elif spec.mixer == "slstm":
        h = common.rms_norm(x, p["norm1"])
        out, cache = xlstm.slstm_prefill(p["slstm"], h, cfg.xlstm_heads)
        x = x + out
    else:  # pragma: no cover
        raise ValueError(spec.mixer)
    if spec.ffn == "mlp":
        h = common.rms_norm(x, p["norm2"])
        x = x + mlp.apply_mlp(p["mlp"], h, cfg.activation)
    elif spec.ffn == "moe":
        h = common.rms_norm(x, p["norm2"])
        if cfg.moe_groups > 1:
            out, aux = moe.apply_moe_grouped(
                p["moe"], h, cfg.top_k, cfg.moe_capacity_factor,
                groups=cfg.moe_groups, constrain=cfg.moe_shard_constraints)
        else:
            out, aux = moe.apply_moe(p["moe"], h, cfg.top_k,
                                     cfg.moe_capacity_factor)
        x = x + out
    return x, cache, aux


def block_decode(spec: BlockSpec, cfg: ArchConfig, p: Params, x: jax.Array,
                 cache: Cache, ctx: Ctx) -> Tuple[jax.Array, Cache]:
    if spec.mixer == "attn" and ctx.block_tables is not None:
        # paged layout applies only to growable full-attention slabs;
        # SWA rings, cross-attn memory, and recurrent state are
        # constant-size per slot and keep the dense layout (§11)
        x, cache = _attn_decode_paged(spec, cfg, p, x, cache, ctx)
    elif spec.mixer in ("attn", "swa", "cross_attn"):
        x, cache = _attn_decode(spec, cfg, p, x, cache, ctx)
    elif spec.mixer == "mamba":
        h = common.rms_norm(x, p["norm1"])
        out, cache = mamba.mamba_decode(p["mamba"], h, cache, cfg.ssm_state,
                                        cfg.ssm_conv)
        x = x + out
    elif spec.mixer == "mlstm":
        h = common.rms_norm(x, p["norm1"])
        out, cache = xlstm.mlstm_decode(p["mlstm"], h, cache, cfg.xlstm_heads)
        x = x + out
    elif spec.mixer == "slstm":
        h = common.rms_norm(x, p["norm1"])
        out, cache = xlstm.slstm_decode(p["slstm"], h, cache, cfg.xlstm_heads)
        x = x + out
    if spec.ffn == "mlp":
        h = common.rms_norm(x, p["norm2"])
        x = x + mlp.apply_mlp(p["mlp"], h, cfg.activation)
    elif spec.ffn == "moe":
        h = common.rms_norm(x, p["norm2"])
        if cfg.moe_groups > 1:
            out, _ = moe.apply_moe_grouped(
                p["moe"], h, cfg.top_k, cfg.moe_capacity_factor,
                groups=cfg.moe_groups, constrain=cfg.moe_shard_constraints)
        else:
            out, _ = moe.apply_moe(p["moe"], h, cfg.top_k,
                                   cfg.moe_capacity_factor)
        x = x + out
    return x, cache


# ---------------------------------------------------------------------------
# Stack execution (scan over periods)
# ---------------------------------------------------------------------------


def _stack_prefill(blocks: Tuple, cfg: ArchConfig, x: jax.Array, ctx: Ctx,
                   remat: bool = False) -> Tuple[jax.Array, Tuple, jax.Array]:
    """Run all periods; returns (x, caches stacked per block pos, aux sum)."""

    def period_body(carry, period_params):
        x, aux = carry
        caches = []
        for bi, spec in enumerate(cfg.period):
            x, cache, a = block_prefill(spec, cfg, period_params[bi], x, ctx)
            caches.append(cache)
            aux = aux + a
        return (x, aux), tuple(caches)

    body = jax.checkpoint(period_body) if remat else period_body
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    blocks)
    return x, caches, aux


def _stack_decode(blocks: Tuple, cfg: ArchConfig, x: jax.Array,
                  caches: Tuple, ctx: Ctx) -> Tuple[jax.Array, Tuple]:
    def period_body(x, scan_in):
        period_params, period_caches = scan_in
        new_caches = []
        for bi, spec in enumerate(cfg.period):
            x, c = block_decode(spec, cfg, period_params[bi], x,
                                period_caches[bi], ctx)
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(period_body, x, (blocks, caches))
    return x, new_caches


def _embed(params: Params, cfg: ArchConfig, tokens: jax.Array,
           positions: jax.Array) -> jax.Array:
    x = params["embed"][tokens]
    if cfg.is_encdec:  # whisper: absolute positions, no rope
        x = x + common.sinusoidal_positions(positions, cfg.d_model
                                            ).astype(x.dtype)
    return x


def _run_encoder(params: Params, cfg: ArchConfig,
                 frames: jax.Array) -> jax.Array:
    """Audio encoder over (stubbed) conv-frontend frame embeddings."""
    b, f, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(f), (b, f))
    x = frames + common.sinusoidal_positions(pos, cfg.d_model
                                             ).astype(frames.dtype)
    ctx = Ctx(positions=pos, cross_embeds=None, causal=False,
              cache_capacity=f, want_cache=False)
    enc = params["encoder"]
    x, _, _ = _stack_prefill(enc["blocks"], dataclasses.replace(
        cfg, period=(BlockSpec("attn", "mlp"),),
        num_periods=cfg.encoder_periods), x, ctx)
    return common.rms_norm(x, enc["final_norm"])


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------


def _cross_memory(params: Params, cfg: ArchConfig,
                  extra: Dict[str, jax.Array]) -> Optional[jax.Array]:
    if cfg.is_encdec:
        return _run_encoder(params, cfg, extra["encoder_frames"])
    if cfg.num_image_tokens:
        return extra["image_embeds"]
    return None


def prefill(params: Params, cfg: ArchConfig, tokens: jax.Array,
            cache_capacity: Optional[int] = None,
            last_index: Optional[jax.Array] = None,
            **extra: jax.Array) -> Tuple[jax.Array, Tuple]:
    """tokens [B,S] → (last-token logits [B,V], cache pytree).

    ``last_index`` [B]: per-row index of the true last prompt token.
    When prompts are right-padded to a shape bucket (serving), the
    logits must be read at the true position, not the padded tail —
    causal masking keeps positions ≤ last_index pad-invariant."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = _embed(params, cfg, tokens, positions)
    ctx = Ctx(positions=positions,
              cross_embeds=_cross_memory(params, cfg, extra),
              causal=True, cache_capacity=cache_capacity or s)
    x, caches, _ = _stack_prefill(params["blocks"], cfg, x, ctx)
    if last_index is None:
        x = x[:, -1:]
    else:
        idx = jnp.asarray(last_index, jnp.int32).reshape(b, 1, 1)
        x = jnp.take_along_axis(x, jnp.broadcast_to(idx, (b, 1, x.shape[-1])),
                                axis=1)
    x = common.rms_norm(x, params["final_norm"])
    logits = (x @ params["lm_head"])[:, 0]
    return logits, caches


def _attn_prefill_continue(cfg: ArchConfig, p: Params, x: jax.Array,
                           cache: Cache, ctx: Ctx,
                           prefix_len: int) -> Tuple[jax.Array, Cache]:
    """Full-attention block over suffix rows against a seeded KV slab.

    The suffix's q/k/v are computed exactly as in ``_attn_prefill``
    (absolute positions → identical RoPE), and each suffix row's
    attention spans cached keys [0, prefix_len) plus the causal suffix
    — per-row the same reduction as full prefill's row at that
    position, so outputs are bit-identical against full prefill's
    reference/chunked lowering (attention, norms, and MLP are all
    row-wise; see tests/test_prefix_cache.py). When full prefill
    dispatches to the TPU flash kernel the two paths differ at ulp
    level, as any two attention reduction orders do."""
    b, s, _ = x.shape
    h = common.rms_norm(x, p["norm1"])
    ap = p["attn"]
    q, k, v = _qkv(ap, cfg, h, ctx.positions, rope=not cfg.is_encdec)
    kc, vc = cache["k"], cache["v"]
    kmajor = cfg.kv_layout == "kmajor"
    if kmajor:
        kc, vc = kc.swapaxes(1, 2), vc.swapaxes(1, 2)    # → [B,S,kv,hd]
    k_ctx = jnp.concatenate([kc[:, :prefix_len], k], axis=1)
    v_ctx = jnp.concatenate([vc[:, :prefix_len], v], axis=1)
    # same lowering rule as prefill_attention's non-flash path: long
    # suffixes take the query-chunked O(q_chunk·Sk) route instead of
    # materializing the full [S_suf, S_total] score tensor
    if s > attention.Q_CHUNK and s % attention.Q_CHUNK == 0:
        out = attention.chunked_attention(q, k_ctx, v_ctx, causal=True,
                                          q_offset=prefix_len)
    else:
        out = attention.full_attention(q, k_ctx, v_ctx, causal=True,
                                       q_offset=prefix_len)
    x = x + out.reshape(b, s, cfg.q_dim) @ ap["wo"]
    # write the suffix KV into the slab; stale entries past the prompt
    # (a longer cached superstring) stay behind — decode masks them out
    # via valid_len, exactly like prefill's zero padding
    kc = jax.lax.dynamic_update_slice_in_dim(kc, k, prefix_len, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, v, prefix_len, axis=1)
    if kmajor:
        kc, vc = kc.swapaxes(1, 2), vc.swapaxes(1, 2)
    return x, {"k": kc, "v": vc}


def _stack_prefill_continue(blocks: Tuple, cfg: ArchConfig, x: jax.Array,
                            caches: Tuple, ctx: Ctx,
                            prefix_len: int) -> Tuple[jax.Array, Tuple]:
    def period_body(x, scan_in):
        period_params, period_caches = scan_in
        new_caches = []
        for bi, spec in enumerate(cfg.period):
            p = period_params[bi]
            x, c = _attn_prefill_continue(cfg, p, x, period_caches[bi], ctx,
                                          prefix_len)
            h = common.rms_norm(x, p["norm2"])
            x = x + mlp.apply_mlp(p["mlp"], h, cfg.activation)
            new_caches.append(c)
        return x, tuple(new_caches)

    x, new_caches = jax.lax.scan(period_body, x, (blocks, caches))
    return x, new_caches


def supports_prefix_continue(cfg: ArchConfig) -> bool:
    """Suffix-only prefill is row-wise-exact only for pure full-attention
    + dense-MLP stacks: recurrent mixers and sliding-window rings carry
    running state a mid-sequence entry cannot seed, and MoE capacity
    clipping couples rows across the batch. ``attn_data_local`` configs
    are excluded too — the continue path does not replicate
    ``_attn_prefill``'s data-axis sharding constraints."""
    return (all(spec.mixer == "attn" and spec.ffn == "mlp"
                for spec in cfg.period)
            and not cfg.is_encdec and not cfg.num_image_tokens
            and not cfg.attn_data_local)


def prefill_continue(params: Params, cfg: ArchConfig, tokens: jax.Array,
                     caches: Tuple, prefix_len: int
                     ) -> Tuple[jax.Array, Tuple]:
    """Suffix-only prefill seeded from a cached KV slab (DESIGN.md §9).

    ``tokens`` [B,S_suf] are the prompt's uncached suffix, occupying
    absolute positions ``prefix_len .. prefix_len+S_suf-1``; ``caches``
    is a capacity-sized cache pytree whose first ``prefix_len``
    sequence slots hold the shared prefix's KV (the shape
    ``kv_transfer`` ships). Returns (last-token logits, updated
    caches) — exactly what ``prefill`` returns for the full prompt.
    ``prefix_len`` must be static (one compile per (suffix, prefix)
    shape pair, like exact-shape prefill)."""
    assert supports_prefix_continue(cfg), cfg.name
    b, s = tokens.shape
    positions = prefix_len + jnp.broadcast_to(jnp.arange(s), (b, s))
    x = _embed(params, cfg, tokens, positions)
    ctx = Ctx(positions=positions, cross_embeds=None, causal=True,
              cache_capacity=0)
    x, new_caches = _stack_prefill_continue(params["blocks"], cfg, x,
                                            caches, ctx, prefix_len)
    x = common.rms_norm(x[:, -1:], params["final_norm"])
    logits = (x @ params["lm_head"])[:, 0]
    return logits, new_caches


def decode_step(params: Params, cfg: ArchConfig, caches: Tuple,
                tokens: jax.Array, positions: jax.Array
                ) -> Tuple[jax.Array, Tuple]:
    """tokens [B,1], positions [B,1] → (logits [B,V], new caches)."""
    x = _embed(params, cfg, tokens, positions)
    ctx = Ctx(positions=positions, cross_embeds=None, causal=True,
              cache_capacity=0)
    x, new_caches = _stack_decode(params["blocks"], cfg, x, caches, ctx)
    x = common.rms_norm(x, params["final_norm"])
    logits = (x @ params["lm_head"])[:, 0]
    return logits, new_caches


def decode_step_paged(params: Params, cfg: ArchConfig, caches: Tuple,
                      tokens: jax.Array, positions: jax.Array,
                      block_tables: jax.Array, page_size: int
                      ) -> Tuple[jax.Array, Tuple]:
    """``decode_step`` over a paged cache (DESIGN.md §11): ``caches`` is
    an ``init_paged_cache`` pytree (full-attention leaves are page
    pools), ``block_tables`` [B, num_blocks] int32 maps every slot's
    logical s-blocks to physical pages (< 0 = unallocated → scratch).
    One table serves every attention layer — the period stack shares
    positions. Bit-identical to ``decode_step`` on a dense cache
    holding the same values at the same positions."""
    x = _embed(params, cfg, tokens, positions)
    ctx = Ctx(positions=positions, cross_embeds=None, causal=True,
              cache_capacity=0, block_tables=block_tables,
              page_size=int(page_size))
    x, new_caches = _stack_decode(params["blocks"], cfg, x, caches, ctx)
    x = common.rms_norm(x, params["final_norm"])
    logits = (x @ params["lm_head"])[:, 0]
    return logits, new_caches


def train_forward(params: Params, cfg: ArchConfig, tokens: jax.Array,
                  labels: jax.Array, **extra: jax.Array) -> jax.Array:
    """Next-token cross-entropy loss (labels already shifted)."""
    b, s = tokens.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    x = _embed(params, cfg, tokens, positions)
    ctx = Ctx(positions=positions,
              cross_embeds=_cross_memory(params, cfg, extra),
              causal=True, cache_capacity=s, want_cache=False)
    x, _, aux = _stack_prefill(params["blocks"], cfg, x, ctx, remat=True)
    x = common.rms_norm(x, params["final_norm"])
    logits = (x @ params["lm_head"]).astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = jnp.mean(logz - gold)
    return loss + 0.01 * aux / max(cfg.num_periods, 1)


# ---------------------------------------------------------------------------
# Cache construction for decode-only entry (dry-run / serving slots)
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, capacity: int,
               dtype=common.DEFAULT_DTYPE) -> Tuple:
    """Zero-filled cache pytree with given attention capacity (stacked
    over periods, mirroring _stack_prefill's ys)."""
    P = cfg.num_periods
    caches = []
    for spec in cfg.period:
        if spec.mixer == "attn":
            shp = ((P, batch, cfg.kv_heads, capacity, cfg.head_dim)
                   if cfg.kv_layout == "kmajor"
                   else (P, batch, capacity, cfg.kv_heads, cfg.head_dim))
            c = {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
        elif spec.mixer == "swa":
            w = cfg.sliding_window
            shp = ((P, batch, cfg.kv_heads, w, cfg.head_dim)
                   if cfg.kv_layout == "kmajor"
                   else (P, batch, w, cfg.kv_heads, cfg.head_dim))
            c = {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype),
                 "pos": jnp.full((P, batch, w), -1, jnp.int32)}
        elif spec.mixer == "cross_attn":
            t = cfg.num_image_tokens or cfg.encoder_frames
            c = {"k": jnp.zeros((P, batch, t, cfg.kv_heads, cfg.head_dim),
                                dtype),
                 "v": jnp.zeros((P, batch, t, cfg.kv_heads, cfg.head_dim),
                                dtype)}
        elif spec.mixer == "mamba":
            di = mamba.d_inner(cfg.d_model, cfg.ssm_expand)
            c = {"conv": jnp.zeros((P, batch, cfg.ssm_conv - 1, di), dtype),
                 "ssm": jnp.zeros((P, batch, di, cfg.ssm_state), jnp.float32)}
        elif spec.mixer == "mlstm":
            m = 2 * cfg.d_model
            dh = m // cfg.xlstm_heads
            c = {"C": jnp.zeros((P, batch, cfg.xlstm_heads, dh, dh),
                                jnp.float32),
                 "n": jnp.zeros((P, batch, cfg.xlstm_heads, dh), jnp.float32),
                 "m": jnp.zeros((P, batch, cfg.xlstm_heads), jnp.float32)}
        elif spec.mixer == "slstm":
            z = jnp.zeros((P, batch, cfg.d_model), jnp.float32)
            c = {"c": z, "n": z, "h": z, "m": z}
        else:  # pragma: no cover
            raise ValueError(spec.mixer)
        caches.append(c)
    return tuple(caches)


def cache_specs(cfg: ArchConfig, batch: int, capacity: int) -> Tuple:
    """ShapeDtypeStruct version of init_cache (no allocation)."""
    return jax.eval_shape(lambda: init_cache(cfg, batch, capacity))


def init_paged_cache(cfg: ArchConfig, batch: int, num_pages: int,
                     page_size: int, dtype=common.DEFAULT_DTYPE,
                     paged_dtype: Optional[str] = None) -> Tuple:
    """Paged variant of ``init_cache`` (DESIGN.md §11): full-attention
    k/v leaves become SHARED page pools — [P, num_pages, page_size, kv,
    hd] ("bshd") / [P, num_pages, kv, page_size, hd] ("kmajor") — with
    no batch dim (the block table supplies per-slot structure); every
    other mixer keeps its constant-size per-slot layout from
    ``init_cache``. Pools are zero-filled, so scratch-page reads are
    finite and masked reductions stay exact.

    ``paged_dtype="int8"`` (DESIGN.md §16): pools are int8 with fp32
    ``k_scale``/``v_scale`` sidecar leaves [P, num_pages, kv] — one
    symmetric scale per (page, kv-head). With the default ``None`` the
    pytree is identical to the §11 layout (no sidecar keys)."""
    dense = init_cache(cfg, batch, page_size, dtype)   # non-attn leaves
    P = cfg.num_periods
    caches = []
    for spec, c in zip(cfg.period, dense):
        if spec.mixer == "attn":
            shp = ((P, num_pages, cfg.kv_heads, page_size, cfg.head_dim)
                   if cfg.kv_layout == "kmajor"
                   else (P, num_pages, page_size, cfg.kv_heads,
                         cfg.head_dim))
            if paged_dtype == "int8":
                sshp = (P, num_pages, cfg.kv_heads)
                c = {"k": jnp.zeros(shp, jnp.int8),
                     "v": jnp.zeros(shp, jnp.int8),
                     "k_scale": jnp.zeros(sshp, jnp.float32),
                     "v_scale": jnp.zeros(sshp, jnp.float32)}
            else:
                c = {"k": jnp.zeros(shp, dtype), "v": jnp.zeros(shp, dtype)}
        caches.append(c)
    return tuple(caches)
