"""Paper Figures 6 & 7: offline serving throughput (tokens/s).

LLaMA-2-70B (Fig 6) and OPT-30B (Fig 7) across the heterogeneous
settings × four workloads; baselines: HexGen (colocated, same cluster)
and DistServe (disaggregated, homogeneous 8×H100).
"""
from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks.common import (N_OFFLINE, cached_schedule, emit,
                               hexgen2_throughput)
from repro.core import LLAMA2_70B, OPT_30B, distserve_schedule, WORKLOADS
from repro.core.cluster import PAPER_SETTINGS
from repro.serving import offline_workload, simulate, simulate_colocated

SETTINGS = ["hetero1", "hetero2", "hetero3", "hetero4"]
WLS = ["HPLD", "HPHD", "LPHD", "LPLD"]


def run() -> List[Tuple[str, float, str]]:
    rows = []
    homog = PAPER_SETTINGS["homogeneous"]()
    for profile in (LLAMA2_70B, OPT_30B):
        # DistServe on the homogeneous budget-equivalent cluster
        for wl in WLS:
            t0 = time.perf_counter()
            ds = distserve_schedule(homog, profile, WORKLOADS[wl])
            sim = simulate(homog, profile, ds.placement,
                           offline_workload(wl, N_OFFLINE, seed=0))
            us = (time.perf_counter() - t0) * 1e6
            rows.append((f"fig6.distserve.{profile.name}.homog.{wl}",
                         us, f"{sim.decode_throughput:.0f} tok/s"))
        for setting in SETTINGS:
            cl = PAPER_SETTINGS[setting]()
            for wl in WLS:
                t0 = time.perf_counter()
                thr = hexgen2_throughput(cl, profile, wl)
                res = cached_schedule(cl, profile, wl)
                col = simulate_colocated(
                    cl, profile, res.placement.replicas,
                    offline_workload(wl, N_OFFLINE, seed=0))
                us = (time.perf_counter() - t0) * 1e6
                ratio = thr / max(col.decode_throughput, 1e-9)
                rows.append((
                    f"fig6.hexgen2.{profile.name}.{setting}.{wl}", us,
                    f"{thr:.0f} tok/s ({ratio:.2f}x vs colocated "
                    f"{col.decode_throughput:.0f})"))
    return rows


if __name__ == "__main__":
    emit(run())
