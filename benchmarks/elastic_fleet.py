"""Elastic fleet: scale-to-demand vs static fleets on a surge trace.

Beyond-paper benchmark (DESIGN.md §13). HexGen-2 schedules a FIXED
device pool; real deployments rent and release machines. The §13
``FleetController`` provisions, warms (weight-load time priced by the
cost model against each device type's host link), joins, and drains
replicas to track demand, re-solving max-flow when capacity drifts.

Three parts:

  1. Scale-to-demand: a quiet → 4x burst → quiet mixed-priority trace
     served by (a) a static fleet sized for the quiet phase, (b) a
     static fleet sized for the burst peak, and (c) the elastic
     controller starting from the small fleet. Elastic must attain
     >= 1.2x static-small's stated-SLO attainment while spending FEWER
     replica-steps than static-peak — better SLOs per machine-step
     than either sizing, the acceptance check.
  2. Capacity-drift re-solve: solve hetero1, join 4xA100 via
     ``grow_cluster``, re-solve with ``reschedule_capacity``. The
     joining devices must get typed (prefill/decode) and the φ→δ
     route set must SHIFT (not just grow a row) without losing flow.
  3. Cross-domain parity: the same seeded burst through SimReplicas
     and through REAL Coordinators (reduced arch), both under
     FleetControllers with the same spec. Scale events, per-state
     replica-step totals, and conservation counters must agree
     EXACTLY — the §13 parity contract.

Run:  PYTHONPATH=src python -m benchmarks.elastic_fleet
      (or python -m benchmarks.run elastic)
"""
from __future__ import annotations

import os
import time
from typing import List, Tuple

from repro.core import (LLAMA2_70B, WORKLOADS, WorkloadMonitor,
                        grow_cluster, reschedule_capacity, schedule,
                        warmup_steps)
from repro.core.cluster import A100, PAPER_SETTINGS
from repro.serving import (FleetSpec, mixed_priority_workload,
                           simulate_fleet, surge_workload)
from repro.serving.telemetry import span_stream

from benchmarks.router_fleet import breakdown_rows

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))

DT = 0.05
#: quiet → burst → quiet; the burst outruns one replica's dispatch
#: capacity, the quiet phases idle a peak-sized fleet
TRACE = (dict(n=160, rate_rps=3.0, seed=3, surge=4.0) if SMOKE
         else dict(n=240, rate_rps=3.0, seed=3, surge=4.0))
SMALL, PEAK = 1, 4

#: warm-up priced by the cost model: LLAMA2-70B sharded over a 4xA100
#: pod, weights staged over the A100 host link (~72 steps at dt=50ms
#: unsharded; /4 sharded)
WARMUP_STEPS = warmup_steps(LLAMA2_70B, A100, DT, parallel=4)

SPEC = FleetSpec(min_replicas=SMALL, max_replicas=PEAK,
                 provision_steps=4, warmup_steps=WARMUP_STEPS,
                 cold_window_steps=6, queue_high=1.0, queue_low=0.25,
                 sustain_steps=3, cooldown_steps=10, hysteresis_steps=40)
FLEET = dict(slots_per_replica=4, max_prefill_batch=4, capacity=128,
             dt=DT, queue_capacity=96)


def _attainment_per_kstep(res) -> float:
    return (res.slo_attainment_stated
            / max(sum(res.replica_steps_by_state.values()), 1) * 1000)


def _scale_to_demand() -> List[Tuple[str, float, str]]:
    rows = []
    results = {}
    for name, reps, spec in (("static_small", SMALL, None),
                             ("static_peak", PEAK, None),
                             ("elastic", SMALL, SPEC)):
        t0 = time.perf_counter()
        monitor = (WorkloadMonitor(WORKLOADS["LPLD"], estimator="ewma")
                   if spec is not None else None)
        res = simulate_fleet(surge_workload(**TRACE), num_replicas=reps,
                             autoscale=spec, monitor=monitor, **FLEET)
        us = (time.perf_counter() - t0) * 1e6
        results[name] = res
        steps = sum(res.replica_steps_by_state.values())
        rows.append((f"elastic.{name}.surge", us,
                     f"slo={res.slo_attainment_stated:.3f} "
                     f"replica_steps={steps} "
                     f"slo_per_kstep={_attainment_per_kstep(res):.3f} "
                     f"ups={res.scale_up_events} "
                     f"downs={res.scale_down_events} "
                     f"warm_pen={res.warmup_ttft_penalty_s:.2f}s"))
        if name == "elastic":
            rows.extend(breakdown_rows("elastic", res))
    small, peak, el = (results["static_small"], results["static_peak"],
                       results["elastic"])
    gain = (el.slo_attainment_stated
            / max(small.slo_attainment_stated, 1e-9))
    el_steps = sum(el.replica_steps_by_state.values())
    peak_steps = sum(peak.replica_steps_by_state.values())
    ok = (gain >= 1.2 and el_steps < peak_steps
          and el.scale_up_events >= 1 and el.scale_down_events >= 1)
    rows.append(("elastic.vs_static", 0.0,
                 f"attainment_gain={gain:.2f}x_vs_small "
                 f"steps={el_steps}_vs_peak={peak_steps} "
                 f"warmup_steps={WARMUP_STEPS} "
                 f"{'PASS' if ok else 'FAIL'}"))
    if not ok:
        raise AssertionError(
            "scale-to-demand must attain >= 1.2x static-small at fewer "
            f"replica-steps than static-peak: gain {gain:.2f}x, steps "
            f"{el_steps} vs {peak_steps}, ups={el.scale_up_events} "
            f"downs={el.scale_down_events}")
    return rows


# -- capacity-drift max-flow re-solve ----------------------------------------

REFINE_ITERS = 4 if SMOKE else 8


def _capacity_resolve() -> List[Tuple[str, float, str]]:
    cl = PAPER_SETTINGS["hetero1"]()
    wl = WORKLOADS["LPHD"]
    t0 = time.perf_counter()
    base = schedule(cl, LLAMA2_70B, wl, max_refine_iters=REFINE_ITERS)
    base_us = (time.perf_counter() - t0) * 1e6
    grown, new = grow_cluster(cl, [("A100", 4)])
    t0 = time.perf_counter()
    cap = reschedule_capacity(grown, LLAMA2_70B, base, wl, new,
                              max_refine_iters=REFINE_ITERS)
    cap_us = (time.perf_counter() - t0) * 1e6
    new_groups = [i for i, g in enumerate(cap.partition.groups)
                  if set(g) & set(new)]
    typing = {("prefill" if cap.partition.is_prefill[i] else "decode")
              for i in new_groups}
    shifted = dict(base.placement.kv_routes) != dict(cap.placement.kv_routes)
    flow_ratio = cap.placement.max_flow / max(base.placement.max_flow, 1e-9)
    ok = shifted and flow_ratio >= 1.0 and bool(typing)
    rows = [
        ("elastic.schedule.hetero1", base_us,
         f"max_flow={base.placement.max_flow:.0f} "
         f"groups={len(base.partition.groups)}"),
        ("elastic.resolve.hetero1+4xA100", cap_us,
         f"max_flow={cap.placement.max_flow:.0f} "
         f"groups={len(cap.partition.groups)} "
         f"joined_typed_as={'+'.join(sorted(typing))} "
         f"routes_shifted={shifted}"),
        ("elastic.capacity_resolve", 0.0,
         f"flow_gain={flow_ratio:.2f}x {'PASS' if ok else 'FAIL'}"),
    ]
    if not ok:
        raise AssertionError(
            "a capacity join must re-type the new devices, shift the "
            f"kv routes, and not lose flow: shifted={shifted} "
            f"flow {base.placement.max_flow:.0f} -> "
            f"{cap.placement.max_flow:.0f}")
    return rows


# -- cross-domain parity of controller decisions -----------------------------

PARITY_TRACE = dict(n=10, rate_rps=100.0, seed=7, system_lens=(8, 6, 4),
                    user_lens=(4, 6, 8), out_lens=(3, 5, 8))
PARITY_SPEC = FleetSpec(min_replicas=1, max_replicas=2, provision_steps=2,
                        warmup_steps=3, cold_window_steps=4,
                        queue_high=0.5, sustain_steps=2, cooldown_steps=4,
                        hysteresis_steps=8)
PARITY_FLEET = dict(slots=2, max_prefill_batch=2, capacity=96,
                    queue_capacity=8)


def _runtime_elastic(reqs):
    import jax
    from repro.configs import ARCHS
    from repro.models import init_params
    from repro.serving import (Coordinator, CoordinatorReplica,
                               FleetController, Router, StepClock)

    cfg = ARCHS["qwen3-1.7b"].reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    clock = StepClock()    # virtual clock: lifecycle stamps match the sim

    def factory(_slot):
        return CoordinatorReplica(
            Coordinator(cfg, params, num_decode_engines=1,
                        slots_per_engine=PARITY_FLEET["slots"],
                        capacity=PARITY_FLEET["capacity"],
                        num_prefill_engines=1,
                        prefix_cache_bytes=float("inf")),
            max_prefill_batch=PARITY_FLEET["max_prefill_batch"],
            clock=clock)

    router = Router([factory(0)],
                    queue_capacity=PARITY_FLEET["queue_capacity"],
                    policy="slo", clock=clock)
    ctrl = FleetController(router, factory, PARITY_SPEC, dt=DT)
    metrics = ctrl.run_trace(reqs)
    return ctrl, router, metrics


def _parity_trace(vocab: int):
    return mixed_priority_workload(vocab=vocab, **PARITY_TRACE)


def _cross_domain() -> List[Tuple[str, float, str]]:
    from repro.configs import ARCHS
    vocab = min(ARCHS["qwen3-1.7b"].reduced().vocab, 256)

    t0 = time.perf_counter()
    sim = simulate_fleet(_parity_trace(vocab), num_replicas=1,
                         slots_per_replica=PARITY_FLEET["slots"],
                         max_prefill_batch=PARITY_FLEET["max_prefill_batch"],
                         capacity=PARITY_FLEET["capacity"], dt=DT,
                         queue_capacity=PARITY_FLEET["queue_capacity"],
                         policy="slo", autoscale=PARITY_SPEC)
    sim_us = (time.perf_counter() - t0) * 1e6

    t0 = time.perf_counter()
    ctrl, router, rt = _runtime_elastic(_parity_trace(vocab))
    rt_us = (time.perf_counter() - t0) * 1e6

    rt_events = [(e.step, e.kind, e.replica) for e in ctrl.events]
    events_ok = rt_events == sim.scale_events
    steps_ok = dict(ctrl.replica_steps_by_state) == \
        sim.replica_steps_by_state
    counters_ok = router.counters == sim.counters
    # §14 parity contract: derived span streams bitwise-identical
    sim_spans = span_stream(sim.requests, sim.dispatch_log)
    rt_spans = span_stream(rt.requests, router.dispatch_log)
    spans_ok = sim_spans == rt_spans
    ok = events_ok and steps_ok and counters_ok and spans_ok
    rows = [
        ("elastic.sim_fleet.burst", sim_us,
         f"events={len(sim.scale_events)} "
         + " ".join(f"{k}={v}" for k, v in sorted(sim.counters.items()))),
        ("elastic.runtime_fleet.qwen3-1.7b-reduced", rt_us,
         f"events={len(rt_events)} "
         + " ".join(f"{k}={v}" for k, v in sorted(router.counters.items()))),
        ("elastic.sim_vs_runtime", 0.0,
         f"scale_events_exact={events_ok} "
         f"replica_steps_exact={steps_ok} counters_exact={counters_ok} "
         f"spans_exact={spans_ok} n_spans={len(sim_spans)} "
         f"{'PASS' if ok else 'FAIL'}"),
    ]
    rows.extend(breakdown_rows("elastic.runtime", rt))
    if not ok:
        raise AssertionError(
            "sim and runtime fleet controllers must agree exactly on "
            f"the same trace: events {sim.scale_events} vs {rt_events}, "
            f"steps {sim.replica_steps_by_state} vs "
            f"{dict(ctrl.replica_steps_by_state)}, counters "
            f"{sim.counters} vs {router.counters}, spans_exact={spans_ok}")
    return rows


def run() -> List[Tuple[str, float, str]]:
    return _scale_to_demand() + _capacity_resolve() + _cross_domain()


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
