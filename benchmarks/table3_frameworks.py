"""Paper Table 3 (Appendix F): framework comparison on LLaMA-2-70B.

HexGen-2 (hetero-1) vs HexGen (colocated, hetero-1) vs DistServe
(homogeneous) vs a vLLM-like baseline (colocated continuous batching on
the homogeneous cluster with a single uniform parallel plan).
"""
from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks.common import N_OFFLINE, cached_schedule, emit
from repro.core import (LLAMA2_70B, WORKLOADS, distserve_schedule)
from repro.core.cost_model import make_plan
from repro.core.placement import ReplicaPlacement
from repro.core.cluster import PAPER_SETTINGS
from repro.serving import offline_workload, simulate, simulate_colocated

WLS = ["HPLD", "HPHD", "LPHD", "LPLD"]


def _vllm_like(cluster, profile):
    """One colocated replica per TP-8 slice (vLLM default-ish plan)."""
    n = cluster.num_devices
    reps = []
    for i, start in enumerate(range(0, n, 8)):
        devs = list(range(start, min(start + 8, n)))
        plan = make_plan([devs], profile.num_layers, cluster)
        reps.append(ReplicaPlacement(i, devs, False, plan, 0.0))
    return reps


def run() -> List[Tuple[str, float, str]]:
    rows = []
    hetero = PAPER_SETTINGS["hetero1"]()
    homog = PAPER_SETTINGS["homogeneous"]()
    for wl in WLS:
        reqs = lambda: offline_workload(wl, N_OFFLINE, seed=0)  # noqa: E731
        t0 = time.perf_counter()
        h2 = cached_schedule(hetero, LLAMA2_70B, wl)
        s_h2 = simulate(hetero, LLAMA2_70B, h2.placement, reqs())
        s_hx = simulate_colocated(hetero, LLAMA2_70B, h2.placement.replicas,
                                  reqs())
        ds = distserve_schedule(homog, LLAMA2_70B, WORKLOADS[wl])
        s_ds = simulate(homog, LLAMA2_70B, ds.placement, reqs())
        s_vl = simulate_colocated(homog, LLAMA2_70B,
                                  _vllm_like(homog, LLAMA2_70B), reqs())
        us = (time.perf_counter() - t0) * 1e6
        rows.append((
            f"table3.{wl}", us,
            f"hexgen2={s_h2.decode_throughput:.0f} "
            f"hexgen={s_hx.decode_throughput:.0f} "
            f"distserve={s_ds.decode_throughput:.0f} "
            f"vllm_like={s_vl.decode_throughput:.0f} tok/s"))
    return rows


if __name__ == "__main__":
    emit(run())
