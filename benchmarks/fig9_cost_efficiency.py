"""Paper Figure 9: cost efficiency — HexGen-2 on the 70%-budget
heterogeneous setting 5 vs DistServe on the full-budget homogeneous
cluster."""
from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks.common import N_OFFLINE, cached_schedule, emit
from repro.core import LLAMA2_70B, WORKLOADS, distserve_schedule
from repro.core.cluster import PAPER_SETTINGS
from repro.serving import offline_workload, simulate

WLS = ["HPLD", "HPHD", "LPHD", "LPLD"]


def run() -> List[Tuple[str, float, str]]:
    rows = []
    cheap = PAPER_SETTINGS["hetero5"]()
    homog = PAPER_SETTINGS["homogeneous"]()
    for wl in WLS:
        t0 = time.perf_counter()
        h2 = cached_schedule(cheap, LLAMA2_70B, wl)
        s_h2 = simulate(cheap, LLAMA2_70B, h2.placement,
                        offline_workload(wl, N_OFFLINE, seed=0))
        ds = distserve_schedule(homog, LLAMA2_70B, WORKLOADS[wl])
        s_ds = simulate(homog, LLAMA2_70B, ds.placement,
                        offline_workload(wl, N_OFFLINE, seed=0))
        us = (time.perf_counter() - t0) * 1e6
        ratio = s_h2.decode_throughput / max(s_ds.decode_throughput, 1e-9)
        rows.append((
            f"fig9.70pct_budget.{wl}", us,
            f"hexgen2@70%=${cheap.price_per_hour:.1f}/h "
            f"{s_h2.decode_throughput:.0f} tok/s vs "
            f"distserve@100%=${homog.price_per_hour:.1f}/h "
            f"{s_ds.decode_throughput:.0f} tok/s ({ratio:.2f}x)"))

    # Calibrated variant: derate H100 to the serving utilization implied
    # by the paper's own measured DistServe numbers (368 tok/s on HPHD vs
    # 871 first-principles → ×0.42). Under this calibration the paper's
    # "comparable at 70% budget" claim reproduces on the light workloads
    # (see EXPERIMENTS.md §Paper-validation / calibration note).
    import repro.core.cluster as cc
    derate = 0.42
    orig = cc.GPU_TYPES["H100"]
    h100c = cc.GPUType("H100", orig.flops * derate,
                       orig.hbm_bandwidth * derate, orig.memory,
                       orig.price_per_hour)
    try:
        cc.GPU_TYPES["H100"] = h100c
        homog_c = cc.build_cluster([("H100", 8)], name="homog-calibrated")
        for wl in WLS:
            t0 = time.perf_counter()
            ds = distserve_schedule(homog_c, LLAMA2_70B, WORKLOADS[wl])
            s_ds = simulate(homog_c, LLAMA2_70B, ds.placement,
                            offline_workload(wl, N_OFFLINE, seed=0))
            cc.GPU_TYPES["H100"] = orig
            h2 = cached_schedule(cheap, LLAMA2_70B, wl)
            s_h2 = simulate(cheap, LLAMA2_70B, h2.placement,
                            offline_workload(wl, N_OFFLINE, seed=0))
            cc.GPU_TYPES["H100"] = h100c
            us = (time.perf_counter() - t0) * 1e6
            ratio = s_h2.decode_throughput / max(s_ds.decode_throughput,
                                                 1e-9)
            rows.append((
                f"fig9.calibrated_h100.{wl}", us,
                f"hexgen2@70% {s_h2.decode_throughput:.0f} vs "
                f"distserve(cal)@100% {s_ds.decode_throughput:.0f} tok/s "
                f"({ratio:.2f}x)"))
    finally:
        cc.GPU_TYPES["H100"] = orig
    return rows


if __name__ == "__main__":
    emit(run())
