"""Shared helpers for the benchmark suite (one module per paper figure)."""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

from repro.core import (LLAMA2_70B, OPT_30B, WORKLOADS, ModelProfile,
                        ScheduleResult, schedule)
from repro.core.cluster import PAPER_SETTINGS, ClusterSpec
from repro.serving import offline_workload, online_workload, simulate

N_OFFLINE = 60
N_ONLINE = 60

_sched_cache: Dict[Tuple[str, str, str], ScheduleResult] = {}


def cached_schedule(cluster: ClusterSpec, profile: ModelProfile,
                    wl_name: str, **kw) -> ScheduleResult:
    key = (cluster.name, profile.name, wl_name)
    if key not in _sched_cache:
        _sched_cache[key] = schedule(cluster, profile, WORKLOADS[wl_name],
                                     max_refine_iters=8, **kw)
    return _sched_cache[key]


def hexgen2_throughput(cluster: ClusterSpec, profile: ModelProfile,
                       wl_name: str, seed: int = 0) -> float:
    res = cached_schedule(cluster, profile, wl_name)
    sim = simulate(cluster, profile, res.placement,
                   offline_workload(wl_name, N_OFFLINE, seed=seed))
    return sim.decode_throughput


def emit(rows: List[Tuple[str, float, str]]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
