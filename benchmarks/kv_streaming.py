"""Compressed, chunked, compute-overlapped KV handoff (DESIGN.md §10).

Beyond-paper benchmark on a bandwidth-skewed cluster — capable compute
behind a starved inter-node fabric, so the φ→δ KV links are the binding
constraint. Three parts:

  1. Codec sweep (scheduling domain): the same trace under the staged
     KV-handoff model with codec none (blocking, uncompressed) vs int8
     vs int8+chunked. int8+chunked must beat the blocking uncompressed
     handoff on mean TTFT — the §10 acceptance check — and the rows
     report shipped bytes, compression ratio, and the fraction of
     transfer time hidden behind prefill compute.

  2. Scheduler feedback: the int8 codec ratio fed into the flowgraph's
     φ→δ edge capacities must CHANGE a placement decision — the
     max-flow assignment on a fixed partition shifts (asserted), and
     the full two-phase search typically re-types whole groups
     (prefill/decode flips are reported).

  3. Cross-domain parity: the same shared-prefix trace through the
     REAL runtime (reduced arch, int8 codec) and the simulator with the
     same ``ModelProfile.from_arch`` accounting profile —
     ``kv_bytes_shipped`` must agree exactly and
     ``kv_compression_ratio`` to 1e-9, per the METRIC_FIELDS parity
     contract. The runtime's measured padded-slab bytes are reported
     alongside.

Run:  PYTHONPATH=src python -m benchmarks.kv_streaming
      (or python -m benchmarks.run kvstream; REPRO_BENCH_SMOKE=1
      shrinks every part to CI-smoke sizes)
"""
from __future__ import annotations

import math
import os
import time
from typing import List, Tuple

import numpy as np

from repro.core import LLAMA2_70B, WORKLOADS, schedule
from repro.core.cluster import kv_skewed_setting
from repro.core.flowgraph import solve_flow
from repro.core.partition import GroupPartition
from repro.serving import offline_workload, simulate
from repro.serving.kv_compression import profile_kv_ratio

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
WL = WORKLOADS["HPLD"]
N_REQS = 16 if SMOKE else 48
REFINE_ITERS = 2 if SMOKE else 6
CODECS = ("none", "int8", "int8-chunked")


def _codec_sweep() -> List[Tuple[str, float, str]]:
    rows = []
    cl = kv_skewed_setting()
    sched = schedule(cl, LLAMA2_70B, WL, max_refine_iters=REFINE_ITERS)
    results = {}
    for codec in CODECS:
        t0 = time.perf_counter()
        reqs = offline_workload("HPLD", N_REQS, seed=5)
        sim = simulate(cl, LLAMA2_70B, sched.placement, reqs, kv_codec=codec)
        us = (time.perf_counter() - t0) * 1e6
        results[codec] = sim
        rows.append((f"kvstream.{codec}.{cl.name}", us,
                     f"avg_ttft={sim.avg_ttft * 1e3:.1f}ms "
                     f"avg_lat={sim.avg_latency:.2f}s "
                     f"shipped={sim.kv_bytes_shipped:.3e}B "
                     f"ratio={sim.kv_compression_ratio:.2f} "
                     f"overlap={sim.transfer_overlap_frac:.2f}"))
    none, chunked = results["none"], results["int8-chunked"]
    gain = none.avg_ttft / max(chunked.avg_ttft, 1e-12)
    ok = (chunked.avg_ttft < none.avg_ttft
          and results["int8"].avg_ttft < none.avg_ttft)
    rows.append(("kvstream.chunked_vs_blocking", 0.0,
                 f"ttft_gain={gain:.2f}x "
                 f"bytes_saved={none.kv_bytes_shipped - chunked.kv_bytes_shipped:.3e}B "
                 f"{'PASS' if ok else 'FAIL'}"))
    if not ok:
        raise AssertionError(
            "int8+chunked streaming must beat the blocking uncompressed "
            f"handoff on mean TTFT: {chunked.avg_ttft:.4f}s vs "
            f"{none.avg_ttft:.4f}s")
    return rows


# -- scheduler feedback ------------------------------------------------------

#: Fixed partition for the deterministic flow-shift check: prefill on
#: the H100 node, decode groups on each remaining node — every KV edge
#: crosses the starved fabric except the A100 pair's.
FIXED_PART = ([[0, 1], [2, 3], [4, 5], [6, 7]],
              [True, False, False, False])


def _scheduler_delta() -> List[Tuple[str, float, str]]:
    rows = []
    cl = kv_skewed_setting()
    ratio = profile_kv_ratio(LLAMA2_70B, "int8")

    t0 = time.perf_counter()
    part = GroupPartition([list(g) for g in FIXED_PART[0]],
                          list(FIXED_PART[1]))
    r_raw = solve_flow(cl, LLAMA2_70B, part, WL)
    r_cmp = solve_flow(cl, LLAMA2_70B, part, WL, kv_compression_ratio=ratio)
    us = (time.perf_counter() - t0) * 1e6
    moved = r_cmp.placement.max_flow - r_raw.placement.max_flow
    routes_changed = {k: round(v, 6) for k, v in
                      r_raw.placement.kv_routes.items()} \
        != {k: round(v, 6) for k, v in r_cmp.placement.kv_routes.items()}
    rows.append(("kvstream.flow_shift", us,
                 f"ratio={ratio:.2f} flow {r_raw.placement.max_flow:.0f}->"
                 f"{r_cmp.placement.max_flow:.0f} (+{moved:.0f}) "
                 f"routes_changed={routes_changed} "
                 f"{'PASS' if routes_changed else 'FAIL'}"))
    if not routes_changed:
        raise AssertionError(
            "feeding the codec ratio into the flowgraph must change the "
            "max-flow KV assignment on the bandwidth-skewed cluster")

    if not SMOKE:
        t0 = time.perf_counter()
        s_raw = schedule(cl, LLAMA2_70B, WL, max_refine_iters=REFINE_ITERS)
        s_cmp = schedule(cl, LLAMA2_70B, WL, max_refine_iters=REFINE_ITERS,
                         kv_compression_ratio=ratio)
        us = (time.perf_counter() - t0) * 1e6
        flips = sum(a != b for a, b in zip(s_raw.partition.is_prefill,
                                           s_cmp.partition.is_prefill))
        regrouped = s_raw.partition.groups != s_cmp.partition.groups
        rows.append(("kvstream.schedule_delta", us,
                     f"type_flips={flips} regrouped={regrouped} flow "
                     f"{s_raw.placement.max_flow:.0f}->"
                     f"{s_cmp.placement.max_flow:.0f}"))
    return rows


# -- cross-domain byte-accounting parity -------------------------------------

RT_TRACE = dict(conversations=4, turns=2, rate_rps=4.0, system_len=12,
                user_len=6, out_len=4)


def _runtime_parity() -> List[Tuple[str, float, str]]:
    import jax
    from repro.configs import ARCHS
    from repro.core import make_plan
    from repro.core.cluster import homogeneous_setting
    from repro.core.cost_model import ModelProfile
    from repro.core.placement import Placement, ReplicaPlacement
    from repro.models import init_params
    from repro.models.common import DEFAULT_DTYPE
    from repro.serving import (Coordinator, ServeRequest,
                               multi_turn_workload)

    cfg = ARCHS["qwen3-1.7b"].reduced()
    prof = ModelProfile.from_arch(cfg, kv_dtype=DEFAULT_DTYPE)

    t0 = time.perf_counter()
    cl = homogeneous_setting()
    reps, routes = [], {}
    for g in range(4):
        devs = [2 * g, 2 * g + 1]
        reps.append(ReplicaPlacement(g, devs, g < 2,
                                     make_plan([devs], prof.num_layers, cl),
                                     1.0))
    for p in range(2):
        for d in (2, 3):
            routes[(p, d)] = 1.0
    placement = Placement(reps, routes, max_flow=4.0, period=600.0)
    reqs_sim = multi_turn_workload(seed=9, vocab=cfg.vocab, **RT_TRACE)
    sim = simulate(cl, prof, placement, reqs_sim, kv_codec="int8")
    sim_us = (time.perf_counter() - t0) * 1e6

    t0 = time.perf_counter()
    params = init_params(jax.random.PRNGKey(0), cfg)
    coord = Coordinator(cfg, params, num_decode_engines=2,
                        slots_per_engine=6, capacity=128,
                        num_prefill_engines=2, kv_codec="int8")
    sess = coord.session(max_prefill_batch=1)
    for r in sorted(multi_turn_workload(seed=9, vocab=cfg.vocab, **RT_TRACE),
                    key=lambda r: r.arrival):
        sess.submit(ServeRequest(r.rid, np.asarray(r.tokens, np.int32),
                                 r.s_out), arrival_time=r.arrival)
    m = sess.run().metrics()
    rt_us = (time.perf_counter() - t0) * 1e6

    phys_ratio = (sess.kv_physical_bytes_raw
                  / max(sess.kv_physical_bytes_wire, 1))
    # per-request stamps are identical; the sums may differ by float
    # non-associativity (the two domains iterate requests in different
    # orders), so compare to relative 1e-12 rather than bit equality
    ok = (math.isclose(sim.kv_bytes_shipped, m.kv_bytes_shipped,
                       rel_tol=1e-12)
          and abs(sim.kv_compression_ratio - m.kv_compression_ratio) < 1e-9)
    rows = [
        ("kvstream.sim_bytes.homog", sim_us,
         f"shipped={sim.kv_bytes_shipped:.0f}B "
         f"ratio={sim.kv_compression_ratio:.3f}"),
        ("kvstream.runtime_bytes.qwen3-1.7b-reduced", rt_us,
         f"shipped={m.kv_bytes_shipped:.0f}B "
         f"ratio={m.kv_compression_ratio:.3f} "
         f"measured_slab_ratio={phys_ratio:.3f}"),
        ("kvstream.sim_vs_runtime", 0.0,
         f"bytes_delta={abs(sim.kv_bytes_shipped - m.kv_bytes_shipped):.0f} "
         f"{'PASS' if ok else 'FAIL'}"),
    ]
    if not ok:
        raise AssertionError(
            "simulator and runtime must stamp identical kv_bytes_shipped/"
            f"kv_compression_ratio on the same trace: "
            f"sim ({sim.kv_bytes_shipped:.0f}, "
            f"{sim.kv_compression_ratio:.4f}) vs runtime "
            f"({m.kv_bytes_shipped:.0f}, {m.kv_compression_ratio:.4f})")
    return rows


def run() -> List[Tuple[str, float, str]]:
    return _codec_sweep() + _scheduler_delta() + _runtime_parity()


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
