"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``us_per_call`` is the
wall-clock of producing that row (scheduling + simulation); ``derived``
is the headline metric (throughput, latency, SLO attainment, scheduler
time, roofline terms).

Usage:  PYTHONPATH=src python -m benchmarks.run [module ...]
        modules default to all; names: fig6, fig8, fig9, fig10,
        table3, table4, table5, roofline, drift, serving, prefix
"""
from __future__ import annotations

import sys
import time
import traceback

from benchmarks.common import emit

MODULES = {
    "fig6": "benchmarks.fig6_fig7_throughput",
    "fig8": "benchmarks.fig8_latency",
    "fig9": "benchmarks.fig9_cost_efficiency",
    "fig10": "benchmarks.fig10_convergence",
    "table3": "benchmarks.table3_frameworks",
    "table4": "benchmarks.table4_homogeneous",
    "table5": "benchmarks.table5_scalability",
    "roofline": "benchmarks.roofline_report",
    "drift": "benchmarks.drift_reschedule",
    "serving": "benchmarks.serving_pipeline",
    "prefix": "benchmarks.prefix_reuse",
}


def main() -> None:
    names = sys.argv[1:] or list(MODULES)
    t0 = time.perf_counter()
    failures = 0
    for name in names:
        modname = MODULES.get(name, name)
        try:
            mod = __import__(modname, fromlist=["run"])
            emit(mod.run())
        except Exception as e:  # noqa: BLE001 — report and continue
            failures += 1
            print(f"{name}.ERROR,0.0,{type(e).__name__}: {e}")
            traceback.print_exc(limit=4, file=sys.stderr)
    print(f"benchmarks.total,{(time.perf_counter() - t0) * 1e6:.0f},"
          f"{len(names)} modules {failures} failures")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
