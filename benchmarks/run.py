"""Benchmark driver — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. ``us_per_call`` is the
wall-clock of producing that row (scheduling + simulation); ``derived``
is the headline metric (throughput, latency, SLO attainment, scheduler
time, roofline terms).

Each module additionally persists a machine-readable
``BENCH_<name>.json`` artifact in the working directory — rows plus the
git sha and run config — so the perf trajectory is trackable across
PRs (the artifacts are .gitignored; diff them out-of-band).

Usage:  PYTHONPATH=src python -m benchmarks.run [module ...]
        modules default to all; names: fig6, fig8, fig9, fig10,
        table3, table4, table5, roofline, drift, serving, prefix,
        kvstream, paged, qpaged, router, elastic, calib

``REPRO_BENCH_SMOKE=1`` shrinks the modules that support it (kvstream,
prefix, paged, qpaged, router, elastic, calib) to CI-smoke sizes
(``make bench-smoke``), and
additionally mirrors each artifact into ``benchmarks/artifacts/`` —
the TRACKED perf-trajectory record (full-size artifacts in the
working directory stay gitignored).

``--check [module ...]`` is the perf-regression gate: it compares the
fresh ``BENCH_<name>.json`` artifacts in the working directory (from a
preceding bench run) against the COMMITTED baselines under
``benchmarks/artifacts/`` (read via ``git show HEAD:...`` so a smoke
run's mirror can't mask the baseline). A missing row, a derived column
that flipped to FAIL, or a per-row wall-clock beyond the ± tolerance
(``REPRO_BENCH_TOL``, default 3.0 → 4x slower fails; timing rows at 0
are informational and skipped) exits non-zero.
"""
from __future__ import annotations

import datetime
import json
import math
import os
import platform
import subprocess
import sys
import time
import traceback
from typing import List, Tuple

from benchmarks.common import emit

MODULES = {
    "fig6": "benchmarks.fig6_fig7_throughput",
    "fig8": "benchmarks.fig8_latency",
    "fig9": "benchmarks.fig9_cost_efficiency",
    "fig10": "benchmarks.fig10_convergence",
    "table3": "benchmarks.table3_frameworks",
    "table4": "benchmarks.table4_homogeneous",
    "table5": "benchmarks.table5_scalability",
    "roofline": "benchmarks.roofline_report",
    "drift": "benchmarks.drift_reschedule",
    "serving": "benchmarks.serving_pipeline",
    "prefix": "benchmarks.prefix_reuse",
    "kvstream": "benchmarks.kv_streaming",
    "paged": "benchmarks.paged_decode",
    "qpaged": "benchmarks.quantized_paged",
    "router": "benchmarks.router_fleet",
    "elastic": "benchmarks.elastic_fleet",
    "calib": "benchmarks.calibration",
}


def json_safe(obj):
    """Recursively replace non-finite floats with ``None``: JSON has no
    ``Infinity``/``NaN``, and ``ServeMetrics`` aggregates are ``inf``
    for a class that never finished — ``json.dump`` would emit the
    non-standard ``Infinity`` literal strict parsers reject."""
    if isinstance(obj, float):
        return obj if math.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: json_safe(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [json_safe(v) for v in obj]
    return obj


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, check=True).stdout.strip()
    except Exception:  # noqa: BLE001 — artifacts must not break the run
        return "unknown"


def write_artifact(name: str, rows: List[Tuple[str, float, str]],
                   elapsed_s: float) -> None:
    """Persist one module's rows as ``BENCH_<name>.json`` (metrics +
    config + git sha) in the working directory."""
    artifact = {
        "benchmark": name,
        "git_sha": _git_sha(),
        "generated_utc": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
        "elapsed_s": round(elapsed_s, 3),
        "config": {
            "python": platform.python_version(),
            "platform": platform.platform(),
            "smoke": bool(os.environ.get("REPRO_BENCH_SMOKE")),
            "argv": sys.argv[1:],
        },
        "rows": [{"name": n, "us_per_call": us, "derived": derived}
                 for n, us, derived in rows],
    }
    artifact = json_safe(artifact)
    paths = [f"BENCH_{name}.json"]
    if artifact["config"]["smoke"]:
        # the tracked perf-trajectory record: smoke runs are CI-sized
        # and deterministic enough to commit (make bench-smoke)
        adir = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                            "artifacts")
        os.makedirs(adir, exist_ok=True)
        paths.append(os.path.join(adir, f"BENCH_{name}.json"))
    for path in paths:
        try:
            with open(path, "w") as f:
                # allow_nan=False pins the sanitization: a non-finite
                # value reaching here is a bug, not an "Infinity" token
                json.dump(artifact, f, indent=2, allow_nan=False)
                f.write("\n")
        except OSError as e:  # pragma: no cover — read-only checkouts
            print(f"{name}.ARTIFACT_SKIPPED,0.0,{e}", file=sys.stderr)


def _baseline(name: str):
    """The COMMITTED baseline artifact for ``name``, or ``None`` if the
    benchmark has no tracked baseline yet. Read via ``git show`` — a
    smoke run mirrors fresh artifacts over ``benchmarks/artifacts/``,
    so the on-disk copy is the candidate, not the baseline."""
    rel = f"benchmarks/artifacts/BENCH_{name}.json"
    try:
        out = subprocess.run(
            ["git", "show", f"HEAD:{rel}"], capture_output=True, text=True,
            timeout=10, check=True,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        return json.loads(out.stdout)
    except Exception:  # noqa: BLE001 — untracked/new benchmark, no git
        return None


def check(names: List[str]) -> int:
    """Perf-regression gate (``--check``): fresh working-dir artifacts
    vs committed baselines. Returns the number of regressions."""
    tol = float(os.environ.get("REPRO_BENCH_TOL", "3.0"))
    regressions = 0
    for name in names:
        fresh_path = f"BENCH_{name}.json"
        if not os.path.exists(fresh_path):
            print(f"check.{name},0.0,MISSING fresh artifact {fresh_path} "
                  "(run the benchmark first)")
            regressions += 1
            continue
        with open(fresh_path) as f:
            fresh = json.load(f)
        base = _baseline(name)
        if base is None:
            print(f"check.{name},0.0,SKIP no committed baseline")
            continue
        fresh_rows = {r["name"]: r for r in fresh["rows"]}
        bad = []
        for row in base["rows"]:
            got = fresh_rows.get(row["name"])
            if got is None:
                bad.append(f"{row['name']}: row disappeared")
                continue
            if "FAIL" in str(got.get("derived", "")):
                bad.append(f"{row['name']}: derived FAIL")
            b_us, g_us = row.get("us_per_call"), got.get("us_per_call")
            if (b_us and g_us and b_us > 0.0
                    and g_us > b_us * (1.0 + tol)):
                bad.append(f"{row['name']}: {g_us:.0f}us > "
                           f"{b_us:.0f}us * {1.0 + tol:g}")
        if bad:
            regressions += 1
            print(f"check.{name},0.0,REGRESSION " + "; ".join(bad))
        else:
            print(f"check.{name},0.0,OK {len(base['rows'])} rows "
                  f"tol=+{tol:g}x")
    return regressions


def main() -> None:
    argv = sys.argv[1:]
    if argv and argv[0] == "--check":
        # default to every module with a committed baseline: the gate
        # covers exactly what the repo tracks
        names = argv[1:] or [n for n in MODULES if _baseline(n)]
        n = check(names)
        print(f"benchmarks.check,0.0,{len(names)} modules "
              f"{n} regressions")
        if n:
            raise SystemExit(1)
        return
    names = argv or list(MODULES)
    t0 = time.perf_counter()
    failures = 0
    for name in names:
        modname = MODULES.get(name, name)
        t_mod = time.perf_counter()
        try:
            mod = __import__(modname, fromlist=["run"])
            rows = mod.run()
            emit(rows)
            write_artifact(name, rows, time.perf_counter() - t_mod)
        except Exception as e:  # noqa: BLE001 — report and continue
            failures += 1
            print(f"{name}.ERROR,0.0,{type(e).__name__}: {e}")
            traceback.print_exc(limit=4, file=sys.stderr)
    print(f"benchmarks.total,{(time.perf_counter() - t0) * 1e6:.0f},"
          f"{len(names)} modules {failures} failures")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
