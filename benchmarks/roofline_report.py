"""Roofline table from the dry-run report (§Roofline deliverable).

Reads reports/dryrun_report.json (produced by repro.launch.dryrun) and
prints the three-term roofline per (arch × shape × mesh) with the
dominant bottleneck and the MODEL_FLOPS/HLO_FLOPs useful-compute ratio.
"""
from __future__ import annotations

import json
import os
from typing import List, Tuple

REPORT = os.environ.get("REPRO_DRYRUN_REPORT",
                        os.path.join(os.path.dirname(__file__), "..",
                                     "reports", "dryrun_report.json"))


def load():
    with open(REPORT) as f:
        return json.load(f)


def run() -> List[Tuple[str, float, str]]:
    rows = []
    try:
        records = load()
    except FileNotFoundError:
        return [("roofline.missing", 0.0,
                 "run `python -m repro.launch.dryrun` first")]
    ok = [r for r in records if r.get("status") == "ok"]
    fails = [r for r in records if r.get("status") != "ok"]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        name = f"roofline.{r['arch']}.{r['shape']}.{r['mesh']}"
        us = r.get("t_compile_s", 0.0) * 1e6
        rows.append((name, us,
                     f"comp={r['t_compute_s']:.3e}s "
                     f"mem={r['t_memory_s']:.3e}s "
                     f"coll={r['t_collective_s']:.3e}s "
                     f"bottleneck={r['bottleneck']} "
                     f"useful={r['useful_flops_ratio']:.1%}"))
    rows.append(("roofline.summary", 0.0,
                 f"{len(ok)} ok / {len(fails)} failed"))

    # optimized-flags sweep (before/after, §Perf levers applied globally)
    opt_path = REPORT.replace("dryrun_report", "dryrun_optimized")
    if os.path.exists(opt_path):
        with open(opt_path) as f:
            opt = {(r["arch"], r["shape"], r["mesh"]): r
                   for r in json.load(f) if r.get("status") == "ok"}
        base = {(r["arch"], r["shape"], r["mesh"]): r for r in ok}
        gains = []
        for key, o in sorted(opt.items()):
            b = base.get(key)
            if b is None:
                continue
            bdom = max(b["t_compute_s"], b["t_memory_s"],
                       b["t_collective_s"])
            odom = max(o["t_compute_s"], o["t_memory_s"],
                       o["t_collective_s"])
            gain = bdom / odom if odom > 0 else 1.0
            gains.append(gain)
            rows.append((f"roofline_opt.{key[0]}.{key[1]}", 0.0,
                         f"dominant {bdom:.3e}s -> {odom:.3e}s "
                         f"({gain:.2f}x) useful "
                         f"{b['useful_flops_ratio']:.1%}->"
                         f"{o['useful_flops_ratio']:.1%}"))
        if gains:
            import numpy as np
            rows.append(("roofline_opt.summary", 0.0,
                         f"median dominant-term gain "
                         f"{float(np.median(gains)):.2f}x over "
                         f"{len(gains)} pairs"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
