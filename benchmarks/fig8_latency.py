"""Paper Figure 8: online latency / SLO attainment.

Online Poisson trace at ~75% of estimated peak; reports average latency
and SLO attainment at several SLO scales for HexGen-2 vs the colocated
baseline on heterogeneous setting 1, and DistServe on homogeneous.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks.common import N_ONLINE, cached_schedule, emit
from repro.core import LLAMA2_70B, WORKLOADS, distserve_schedule
from repro.core.cluster import PAPER_SETTINGS
from repro.serving import (online_workload, simulate, simulate_colocated,
                           slo_baselines)

SLO_SCALES = (2.0, 5.0, 10.0)


def _online_rate(cluster, profile, placement) -> float:
    from repro.serving import offline_workload
    sim = simulate(cluster, profile, placement,
                   offline_workload("HPHD", 30, seed=9))
    peak_rps = len(sim.requests) / sim.makespan
    return 0.75 * peak_rps


def run() -> List[Tuple[str, float, str]]:
    rows = []
    cl = PAPER_SETTINGS["hetero1"]()
    res = cached_schedule(cl, LLAMA2_70B, "HPHD")
    rate = _online_rate(cl, LLAMA2_70B, res.placement)

    t0 = time.perf_counter()
    reqs = online_workload(N_ONLINE, rate, seed=0)
    sim = simulate(cl, LLAMA2_70B, res.placement, reqs)
    slo = slo_baselines(cl, LLAMA2_70B, res.placement, reqs)
    us = (time.perf_counter() - t0) * 1e6
    att = " ".join(f"slo{int(s)}x={sim.slo_attainment(slo, s):.2f}"
                   for s in SLO_SCALES)
    rows.append(("fig8.hexgen2.hetero1.online", us,
                 f"avg_lat={sim.avg_latency:.1f}s {att}"))

    t0 = time.perf_counter()
    reqs2 = online_workload(N_ONLINE, rate, seed=0)
    col = simulate_colocated(cl, LLAMA2_70B, res.placement.replicas, reqs2)
    slo2 = slo_baselines(cl, LLAMA2_70B, res.placement, reqs2)
    us = (time.perf_counter() - t0) * 1e6
    att2 = " ".join(f"slo{int(s)}x={col.slo_attainment(slo2, s):.2f}"
                    for s in SLO_SCALES)
    ratio = col.avg_latency / max(sim.avg_latency, 1e-9)
    rows.append(("fig8.hexgen_coloc.hetero1.online", us,
                 f"avg_lat={col.avg_latency:.1f}s {att2} "
                 f"(hexgen2 {ratio:.2f}x lower)"))

    homog = PAPER_SETTINGS["homogeneous"]()
    ds = distserve_schedule(homog, LLAMA2_70B, WORKLOADS["HPHD"])
    t0 = time.perf_counter()
    reqs3 = online_workload(N_ONLINE, rate, seed=0)
    dsim = simulate(homog, LLAMA2_70B, ds.placement, reqs3)
    slo3 = slo_baselines(homog, LLAMA2_70B, ds.placement, reqs3)
    us = (time.perf_counter() - t0) * 1e6
    att3 = " ".join(f"slo{int(s)}x={dsim.slo_attainment(slo3, s):.2f}"
                    for s in SLO_SCALES)
    rows.append(("fig8.distserve.homog.online", us,
                 f"avg_lat={dsim.avg_latency:.1f}s {att3}"))
    return rows


if __name__ == "__main__":
    emit(run())
