"""Prefill/decode interference in the REAL runtime: inline vs pipelined.

HexGen-2's premise is that prefill must not stall decode (paper Fig. 1).
The legacy ``Coordinator.serve`` loop violated it in-process: every
admission ran the whole prefill burst inline — one exact-shape jit call
per request — before the next decode step, so in-flight requests saw
token gaps proportional to the burst size. The event-driven
``ServeSession`` (DESIGN.md §8) bounds prefill work per ``step()`` to
one bucketed/padded micro-batch, so decode cadence stays flat through
bursts.

This benchmark serves a warm decode population on the reduced arch
(real JAX execution), injects a burst of long-prompt prefills, and
measures the warm requests' decode inter-token gap inside the burst
window in both modes (median of ``REPEATS`` runs). The pipelined
session must improve the worst-case gap.

Run:  PYTHONPATH=src python -m benchmarks.serving_pipeline
      (or python -m benchmarks.run serving)
"""
from __future__ import annotations

import time
from typing import Dict, List, Tuple

import jax
import numpy as np

from repro.configs import ARCHS
from repro.models import init_params
from repro.serving import Coordinator, ServeRequest

ARCH = "qwen3-1.7b"
WARM = 4             # in-flight decode requests whose cadence we measure
BURST = 16           # prefill burst injected mid-decode
WARM_PROMPT = 16
BURST_PROMPT = 112   # long prompts: prefill work dominates a decode step
WARM_NEW = 96
BURST_NEW = 2
CAPACITY = 192
REPEATS = 3


def _requests(cfg, rng, n, rid0, prompt_len, max_new):
    return [ServeRequest(rid0 + i,
                         rng.integers(0, cfg.vocab,
                                      prompt_len).astype(np.int32),
                         max_new) for i in range(n)]


def _run_once(coord, cfg, rng, inline: bool) -> Dict[str, float]:
    sess = coord.session(inline_prefill=inline)
    stamps: Dict[int, List[float]] = {}
    burst_first: Dict[int, float] = {}

    def warm_cb(rid, tok, fin):
        stamps.setdefault(rid, []).append(sess.now())

    def burst_cb(rid, tok, fin):
        # the first streamed token marks that request's prefill completion
        burst_first.setdefault(rid, sess.now())

    warm = _requests(cfg, rng, WARM, 0, WARM_PROMPT, WARM_NEW)
    for r in warm:
        sess.submit(r, on_token=warm_cb)
    # run until every warm request has an established decode cadence
    while any(len(stamps.get(r.rid, [])) < 4 for r in warm):
        sess.step()

    t_burst = sess.now()
    for r in _requests(cfg, rng, BURST, 100, BURST_PROMPT, BURST_NEW):
        sess.submit(r, on_token=burst_cb)
    sess.run()

    # decode cadence of warm requests while burst prefills were running:
    # every warm inter-token interval that overlaps the burst window
    window_end = max(burst_first.values())
    gaps = []
    for r in warm:
        ts = stamps[r.rid]
        gaps.extend(b - a for a, b in zip(ts, ts[1:])
                    if b >= t_burst and a <= window_end)
    return {"max_gap": float(np.max(gaps)),
            "mean_gap": float(np.mean(gaps)),
            "burst_window": window_end - t_burst}


def _run_mode(cfg, params, inline: bool) -> Dict[str, float]:
    coord = Coordinator(cfg, params, num_decode_engines=2,
                        slots_per_engine=(WARM + BURST) // 2 + 1,
                        capacity=CAPACITY)
    rng = np.random.default_rng(0)
    # compile warmup: both prompt shapes + the decode step
    warmup = coord.session(inline_prefill=inline)
    for r in _requests(cfg, rng, 4, 10_000, WARM_PROMPT, 2):
        warmup.submit(r)
    for r in _requests(cfg, rng, 4, 20_000, BURST_PROMPT, 2):
        warmup.submit(r)
    warmup.run()

    runs = [_run_once(coord, cfg, rng, inline) for _ in range(REPEATS)]
    return {k: float(np.median([r[k] for r in runs])) for k in runs[0]}


def run() -> List[Tuple[str, float, str]]:
    cfg = ARCHS[ARCH].reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    rows = []
    results = {}
    for label, inline in (("inline", True), ("pipelined", False)):
        t0 = time.perf_counter()
        r = _run_mode(cfg, params, inline)
        us = (time.perf_counter() - t0) * 1e6
        results[label] = r
        rows.append((f"serving.{label}.{ARCH}", us,
                     f"max_decode_gap={r['max_gap'] * 1e3:.1f}ms "
                     f"mean_gap={r['mean_gap'] * 1e3:.1f}ms "
                     f"burst_window={r['burst_window'] * 1e3:.0f}ms"))
    ratio = results["inline"]["max_gap"] / max(results["pipelined"]["max_gap"],
                                               1e-9)
    rows.append(("serving.pipeline_gain", 0.0,
                 f"max_gap_improvement={ratio:.2f}x "
                 f"(burst={BURST}x{BURST_PROMPT}tok prefills over "
                 f"{WARM} decoding)"))
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
