"""Shared-prefix KV reuse: cache-aware vs cache-blind routing.

Beyond-paper benchmark (DESIGN.md §9). Multi-turn conversation traffic
re-prefills an ever-growing shared history every turn; the radix
prefix cache keeps each prefill replica's served prompts, routing
sends a request to the replica holding its longest prefix, and prefill
pays only for the uncached suffix.

Two parts:

  1. Scheduling domain (hetero1 + Llama2-70B): the same multi-turn
     trace simulated cache-blind and cache-aware. Cache-aware must win
     on mean TTFT and on total prefill tokens computed — the
     acceptance check for the subsystem.
  2. Cross-domain agreement: the same token trace driven through the
     REAL runtime (reduced arch, 2 prefill engines + per-engine radix
     caches) and through the simulator on a placement with the same
     replica counts. Both sides stamp ``Request.cached_len`` from
     their own radix state, so the token-level hit rates must agree
     within 10% — the §9 parity claim.

Run:  PYTHONPATH=src python -m benchmarks.prefix_reuse
      (or python -m benchmarks.run prefix)
"""
from __future__ import annotations

import os
import time
from typing import List, Tuple

import numpy as np

from repro.core import (LLAMA2_70B, OPT_30B, WORKLOADS, make_plan,
                        schedule)
from repro.core.cluster import PAPER_SETTINGS, homogeneous_setting
from repro.core.cost_model import ModelProfile
from repro.core.placement import Placement, ReplicaPlacement
from repro.serving import simulate
from repro.serving.workload import multi_turn_workload

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
TRACE = (dict(conversations=6, turns=3, rate_rps=4.0) if SMOKE
         else dict(conversations=16, turns=4, rate_rps=4.0))


def _sim_pair() -> List[Tuple[str, float, str]]:
    rows = []
    cl = PAPER_SETTINGS["hetero1"]()
    sched = schedule(cl, LLAMA2_70B, WORKLOADS["LPLD"],
                     max_refine_iters=2 if SMOKE else 6)
    results = {}
    for label, caching in (("blind", False), ("aware", True)):
        t0 = time.perf_counter()
        reqs = multi_turn_workload(seed=3, **TRACE)
        sim = simulate(cl, LLAMA2_70B, sched.placement, reqs,
                       prefix_caching=caching)
        us = (time.perf_counter() - t0) * 1e6
        results[label] = sim
        rows.append((f"prefix.{label}.hetero1", us,
                     f"avg_ttft={sim.avg_ttft * 1e3:.1f}ms "
                     f"p99_ttft={sim.p99_ttft * 1e3:.1f}ms "
                     f"prefill_tok={sim.prefill_tokens_computed} "
                     f"hit={sim.cache_hit_rate:.3f}"))
    blind, aware = results["blind"], results["aware"]
    ttft_gain = blind.avg_ttft / max(aware.avg_ttft, 1e-12)
    tok_saved = blind.prefill_tokens_computed - aware.prefill_tokens_computed
    ok = (aware.avg_ttft < blind.avg_ttft
          and aware.prefill_tokens_computed < blind.prefill_tokens_computed)
    rows.append(("prefix.aware_vs_blind", 0.0,
                 f"ttft_gain={ttft_gain:.2f}x prefill_tok_saved={tok_saved} "
                 f"hit={aware.cache_hit_rate:.3f} "
                 f"{'PASS' if ok else 'FAIL'}"))
    if not ok:
        raise AssertionError(
            "cache-aware routing must beat cache-blind on mean TTFT and "
            f"prefill tokens: ttft {aware.avg_ttft:.4f} vs "
            f"{blind.avg_ttft:.4f}, tokens {aware.prefill_tokens_computed} "
            f"vs {blind.prefill_tokens_computed}")
    return rows


# -- cross-domain hit-rate agreement ----------------------------------------

RT_TRACE = dict(conversations=6, turns=3, rate_rps=4.0, system_len=24,
                user_len=10, out_len=6)
N_PREFILL = 2
N_DECODE = 2


def _two_by_two_placement(cl, profile: ModelProfile) -> Placement:
    """2 prefill + 2 decode TP-2 replicas with uniform flow — the
    scheduling-domain mirror of the runtime coordinator below. TP=2
    leaves each H100 pair real memory headroom, so the cost model
    grants a non-zero prefix-cache budget."""
    reps, routes = [], {}
    for g in range(N_PREFILL + N_DECODE):
        devs = [2 * g, 2 * g + 1]
        plan = make_plan([devs], profile.num_layers, cl)
        reps.append(ReplicaPlacement(g, devs, g < N_PREFILL, plan, 1.0))
    for p in range(N_PREFILL):
        for d in range(N_PREFILL, N_PREFILL + N_DECODE):
            routes[(p, d)] = 1.0
    return Placement(reps, routes, max_flow=4.0, period=600.0)


def _runtime_hit_rate(reqs) -> Tuple[float, dict]:
    import jax
    from repro.configs import ARCHS
    from repro.models import init_params
    from repro.serving import Coordinator, ServeRequest

    cfg = ARCHS["qwen3-1.7b"].reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    coord = Coordinator(cfg, params, num_decode_engines=N_DECODE,
                        slots_per_engine=6, capacity=128,
                        num_prefill_engines=N_PREFILL,
                        prefix_cache_bytes=float("inf"))
    # max_prefill_batch=1 mirrors the simulator's one-request-at-a-time
    # prefill replicas, so both domains see the same insert/match order
    sess = coord.session(max_prefill_batch=1)
    for r in sorted(reqs, key=lambda r: r.arrival):
        sess.submit(ServeRequest(r.rid, np.asarray(r.tokens, np.int32),
                                 r.s_out), arrival_time=r.arrival)
    sess.run()
    m = sess.metrics()
    return m.cache_hit_rate, m.summary()


def _cross_domain() -> List[Tuple[str, float, str]]:
    from repro.configs import ARCHS
    vocab = ARCHS["qwen3-1.7b"].reduced().vocab

    t0 = time.perf_counter()
    reqs_sim = multi_turn_workload(seed=9, vocab=vocab, **RT_TRACE)
    # OPT-30B: fits a single H100 with headroom, so the cost model
    # grants each single-device replica a real prefix-cache budget
    cl = homogeneous_setting()
    sim = simulate(cl, OPT_30B, _two_by_two_placement(cl, OPT_30B),
                   reqs_sim, prefix_caching=True)
    sim_us = (time.perf_counter() - t0) * 1e6

    t0 = time.perf_counter()
    reqs_rt = multi_turn_workload(seed=9, vocab=vocab, **RT_TRACE)
    rt_hit, _ = _runtime_hit_rate(reqs_rt)
    rt_us = (time.perf_counter() - t0) * 1e6

    delta = abs(sim.cache_hit_rate - rt_hit)
    rel = delta / max(sim.cache_hit_rate, rt_hit, 1e-9)
    ok = rel <= 0.10
    rows = [
        ("prefix.sim_hit.homog", sim_us, f"hit={sim.cache_hit_rate:.3f} "
         f"reused={sim.reused_tokens}"),
        ("prefix.runtime_hit.qwen3-1.7b-reduced", rt_us,
         f"hit={rt_hit:.3f}"),
        ("prefix.sim_vs_runtime", 0.0,
         f"delta={delta:.3f} rel={rel:.2%} {'PASS' if ok else 'FAIL'}"),
    ]
    if not ok:
        raise AssertionError(
            "simulator and runtime cache hit rates must agree within 10%: "
            f"sim {sim.cache_hit_rate:.3f} vs runtime {rt_hit:.3f}")
    return rows


def run() -> List[Tuple[str, float, str]]:
    return _sim_pair() + _cross_domain()


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
