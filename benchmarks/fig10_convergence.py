"""Paper Figures 10 & 11: scheduling-algorithm effectiveness.

Convergence (max-flow vs wall-clock) of: our max-flow-guided edge swap,
the truncated variant (random swaps), and the genetic algorithm — plus
the serving-throughput consequence of each on heterogeneous setting 1.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks.common import N_OFFLINE, emit
from repro.core import (LLAMA2_70B, WORKLOADS, genetic_schedule,
                        random_swap_schedule, schedule)
from repro.core.cluster import PAPER_SETTINGS
from repro.serving import offline_workload, simulate

WLS = ["HPLD", "HPHD", "LPHD", "LPLD"]


def run() -> List[Tuple[str, float, str]]:
    rows = []
    cl = PAPER_SETTINGS["hetero1"]()
    for wl in WLS:
        variants = {
            "maxflow_swap": lambda: schedule(cl, LLAMA2_70B, WORKLOADS[wl],
                                             max_refine_iters=10),
            "random_swap": lambda: random_swap_schedule(cl, LLAMA2_70B,
                                                        WORKLOADS[wl]),
            "genetic": lambda: genetic_schedule(cl, LLAMA2_70B,
                                                WORKLOADS[wl],
                                                population=8,
                                                generations=12),
        }
        flows = {}
        for name, fn in variants.items():
            t0 = time.perf_counter()
            res = fn()
            us = (time.perf_counter() - t0) * 1e6
            flows[name] = res
            sim = simulate(cl, LLAMA2_70B, res.placement,
                           offline_workload(wl, N_OFFLINE, seed=0))
            rows.append((
                f"fig10.{name}.{wl}", us,
                f"flow={res.placement.max_flow:.0f}/T "
                f"thr={sim.decode_throughput:.0f} tok/s "
                f"steps={len(res.trace)} sched_t={res.elapsed_s:.2f}s"))
        ours = flows["maxflow_swap"].placement.max_flow
        ga = flows["genetic"].placement.max_flow
        rows.append((f"fig10.ratio.{wl}", 0.0,
                     f"maxflow/genetic={ours / max(ga, 1e-9):.2f}x"))
    return rows


if __name__ == "__main__":
    emit(run())
