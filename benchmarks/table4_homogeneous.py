"""Paper Table 4 (Appendix G): homogeneous 4×H100, OPT-30B — HexGen-2
vs DistServe vs colocated HexGen on the same hardware."""
from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks.common import N_OFFLINE, emit
from repro.core import OPT_30B, WORKLOADS, distserve_schedule, schedule
from repro.core.cluster import build_cluster
from repro.serving import offline_workload, simulate, simulate_colocated

WLS = ["HPLD", "HPHD", "LPHD", "LPLD"]


def run() -> List[Tuple[str, float, str]]:
    rows = []
    cl = build_cluster([("H100", 4)], name="homog-4xH100")
    for wl in WLS:
        t0 = time.perf_counter()
        ours = schedule(cl, OPT_30B, WORKLOADS[wl], max_refine_iters=8)
        s_h2 = simulate(cl, OPT_30B, ours.placement,
                        offline_workload(wl, N_OFFLINE, seed=0))
        ds = distserve_schedule(cl, OPT_30B, WORKLOADS[wl])
        s_ds = simulate(cl, OPT_30B, ds.placement,
                        offline_workload(wl, N_OFFLINE, seed=0))
        s_hx = simulate_colocated(cl, OPT_30B, ours.placement.replicas,
                                  offline_workload(wl, N_OFFLINE, seed=0))
        us = (time.perf_counter() - t0) * 1e6
        rows.append((
            f"table4.{wl}", us,
            f"hexgen2={s_h2.decode_throughput:.0f} "
            f"distserve={s_ds.decode_throughput:.0f} "
            f"hexgen={s_hx.decode_throughput:.0f} tok/s"))
    return rows


if __name__ == "__main__":
    emit(run())
