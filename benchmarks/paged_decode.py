"""Paged KV decode: block-table cache layout (DESIGN.md §11).

Beyond-paper benchmark on a memory-skewed cluster — capable compute on
every node behind a fast fabric, but sharply unequal HBM — so decode
group sizing is bound by KV residency, the regime HexGen-2's
memory-aware decode placement targets. Four parts:

  1. Admitted-concurrency gain (scheduling domain): per decode group,
     the max batch under DENSE accounting (per-slot slabs at the
     runtime's power-of-two bucket capacity — what every slot really
     pays) vs PAGED accounting (page-pool budget at mean residency),
     at equal HBM. The §11 acceptance check: >= 1.5x aggregate. The
     same placements then serve one trace through the simulator.

  2. Scheduler feedback: the paged capacity accounting fed into
     ``solve_flow`` must CHANGE the max-flow decode-group assignment
     on a decode-bound partition (asserted), lifting max_flow; the
     full two-phase search reports prefill/decode type flips.

  3. Cross-domain page parity: the same trace through the REAL paged
     runtime (reduced arch) and the paged simulator —
     ``kv_pages_allocated`` must agree EXACTLY (both stamp their
     allocator's count; preemption-free pools), per METRIC_FIELDS.

  4. Runtime micro: a real paged ``DecodeEngine`` at the dense
     engine's exact HBM budget admits >= 1.5x the concurrent requests
     for short-lived contexts (measured admissions, not estimates).

Run:  PYTHONPATH=src python -m benchmarks.paged_decode
      (or python -m benchmarks.run paged; REPRO_BENCH_SMOKE=1 shrinks
      every part to CI-smoke sizes)
"""
from __future__ import annotations

import os
import time
from typing import List, Tuple

import numpy as np

from repro.core import LLAMA2_70B, WORKLOADS, schedule
from repro.core.cluster import memory_skewed_setting
from repro.core.cost_model import (dense_slot_capacity,
                                   max_decode_batch_paged)
from repro.core.flowgraph import solve_flow
from repro.core.partition import GroupPartition
from repro.serving import offline_workload, simulate
from repro.serving.paging import pages_for_request

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
WL = WORKLOADS["HPHD"]
PAGE = 16
N_REQS = 24 if SMOKE else 64
REFINE_ITERS = 2 if SMOKE else 6

#: Decode-bound partition on the memory-skewed cluster: decode pinned
#: to the memory-starved H100 pair (weights barely fit — KV residency
#: is the binding constraint), prefill on the roomy A100/A6000 nodes.
FIXED_PART = ([[0, 1], [2, 3, 4, 5], [6, 7, 8, 9], [10, 11, 12, 13]],
              [False, True, True, True])


def _placements(cl):
    part = GroupPartition([list(g) for g in FIXED_PART[0]],
                          list(FIXED_PART[1]))
    bucket = dense_slot_capacity(WL.s_in + WL.s_out)
    dense = solve_flow(cl, LLAMA2_70B, part, WL,
                       dense_slot_capacity=bucket)
    paged = solve_flow(cl, LLAMA2_70B, part, WL, paged_kv=True,
                       page_size=PAGE)
    return part, bucket, dense, paged


def _concurrency_and_sim() -> List[Tuple[str, float, str]]:
    rows = []
    cl = memory_skewed_setting()
    part, bucket, r_dense, r_paged = _placements(cl)

    t0 = time.perf_counter()
    total_d = total_p = 0
    for gid, (group, is_pref) in enumerate(zip(part.groups,
                                               part.is_prefill)):
        if is_pref:
            continue
        plan = r_dense.placement.replica_by_group(gid).plan
        bd = max_decode_batch_paged(cl, LLAMA2_70B, plan, WL,
                                    page_size=PAGE, slot_capacity=bucket)
        bp = max_decode_batch_paged(cl, LLAMA2_70B, plan, WL,
                                    page_size=PAGE)
        total_d += bd
        total_p += bp
    us = (time.perf_counter() - t0) * 1e6
    gain = total_p / max(total_d, 1)
    rows.append((f"paged.concurrency.{cl.name}", us,
                 f"dense_batch={total_d} paged_batch={total_p} "
                 f"slot_bucket={bucket} gain={gain:.2f}x "
                 f"{'PASS' if gain >= 1.5 else 'FAIL'}"))
    if gain < 1.5:
        raise AssertionError(
            "paged accounting must admit >= 1.5x the dense decode "
            f"concurrency at equal HBM: {total_p} vs {total_d}")

    for name, res, paged in (("dense", r_dense, False),
                             ("paged", r_paged, True)):
        t0 = time.perf_counter()
        reqs = offline_workload("HPHD", N_REQS, seed=7)
        sim = simulate(cl, LLAMA2_70B, res.placement, reqs,
                       paged_kv=paged, page_size=PAGE)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"paged.sim.{name}", us,
                     f"thpt={sim.decode_throughput:.1f}tok/s "
                     f"avg_lat={sim.avg_latency:.2f}s "
                     f"pages={sim.kv_pages_allocated} "
                     f"util={sim.page_utilization:.3f} "
                     f"frag={sim.page_fragmentation:.3f}"))
    return rows


def _scheduler_delta() -> List[Tuple[str, float, str]]:
    rows = []
    cl = memory_skewed_setting()
    t0 = time.perf_counter()
    _, bucket, r_dense, r_paged = _placements(cl)
    us = (time.perf_counter() - t0) * 1e6
    rd = {k: round(v, 6) for k, v in r_dense.placement.kv_routes.items()}
    rp = {k: round(v, 6) for k, v in r_paged.placement.kv_routes.items()}
    changed = rd != rp
    lift = (r_paged.placement.max_flow
            / max(r_dense.placement.max_flow, 1e-9))
    rows.append(("paged.flow_shift", us,
                 f"flow {r_dense.placement.max_flow:.0f}->"
                 f"{r_paged.placement.max_flow:.0f} ({lift:.2f}x) "
                 f"routes {sorted(rd)}->{sorted(rp)} "
                 f"changed={changed} {'PASS' if changed else 'FAIL'}"))
    if not changed:
        raise AssertionError(
            "paged capacity accounting must shift the max-flow decode "
            f"assignment on {cl.name}: {rd} vs {rp}")

    if not SMOKE:
        t0 = time.perf_counter()
        s_dense = schedule(cl, LLAMA2_70B, WL,
                           max_refine_iters=REFINE_ITERS)
        s_paged = schedule(cl, LLAMA2_70B, WL,
                           max_refine_iters=REFINE_ITERS, paged_kv=True,
                           page_size=PAGE)
        us = (time.perf_counter() - t0) * 1e6
        flips = sum(a != b for a, b in zip(s_dense.partition.is_prefill,
                                           s_paged.partition.is_prefill))
        regrouped = s_dense.partition.groups != s_paged.partition.groups
        rows.append(("paged.schedule_delta", us,
                     f"type_flips={flips} regrouped={regrouped} flow "
                     f"{s_dense.placement.max_flow:.0f}->"
                     f"{s_paged.placement.max_flow:.0f}"))
    return rows


# -- cross-domain page-count parity ------------------------------------------

RT_TRACE = dict(conversations=4, turns=2, rate_rps=4.0, system_len=12,
                user_len=6, out_len=4)


def _runtime_parity() -> List[Tuple[str, float, str]]:
    import jax
    from repro.configs import ARCHS
    from repro.core import make_plan
    from repro.core.cluster import homogeneous_setting
    from repro.core.cost_model import ModelProfile
    from repro.core.placement import Placement, ReplicaPlacement
    from repro.models import init_params
    from repro.models.common import DEFAULT_DTYPE
    from repro.serving import (Coordinator, ServeRequest,
                               multi_turn_workload)

    cfg = ARCHS["qwen3-1.7b"].reduced()
    prof = ModelProfile.from_arch(cfg, kv_dtype=DEFAULT_DTYPE)

    t0 = time.perf_counter()
    cl = homogeneous_setting()
    reps, routes = [], {}
    for g in range(4):
        devs = [2 * g, 2 * g + 1]
        reps.append(ReplicaPlacement(g, devs, g < 2,
                                     make_plan([devs], prof.num_layers, cl),
                                     1.0))
    for p in range(2):
        for d in (2, 3):
            routes[(p, d)] = 1.0
    placement = Placement(reps, routes, max_flow=4.0, period=600.0)
    reqs_sim = multi_turn_workload(seed=9, vocab=cfg.vocab, **RT_TRACE)
    sim = simulate(cl, prof, placement, reqs_sim, paged_kv=True,
                   page_size=PAGE)
    sim_us = (time.perf_counter() - t0) * 1e6

    t0 = time.perf_counter()
    params = init_params(jax.random.PRNGKey(0), cfg)
    coord = Coordinator(cfg, params, num_decode_engines=2,
                        slots_per_engine=6, capacity=128,
                        num_prefill_engines=2, paged=True, page_size=PAGE)
    sess = coord.session(max_prefill_batch=1)
    for r in sorted(multi_turn_workload(seed=9, vocab=cfg.vocab, **RT_TRACE),
                    key=lambda r: r.arrival):
        sess.submit(ServeRequest(r.rid, np.asarray(r.tokens, np.int32),
                                 r.s_out), arrival_time=r.arrival)
    m = sess.run().metrics()
    rt_us = (time.perf_counter() - t0) * 1e6

    exp = sum(pages_for_request(r.s_in, r.s_out, PAGE) for r in reqs_sim)
    ok = (sim.kv_pages_allocated == m.kv_pages_allocated == exp
          and abs(sim.page_utilization - m.page_utilization) < 1e-12)
    rows = [
        ("paged.sim_pages.homog", sim_us,
         f"pages={sim.kv_pages_allocated} "
         f"util={sim.page_utilization:.4f}"),
        ("paged.runtime_pages.qwen3-1.7b-reduced", rt_us,
         f"pages={m.kv_pages_allocated} util={m.page_utilization:.4f} "
         f"preemptions={sum(r.preemptions for r in m.requests)}"),
        ("paged.sim_vs_runtime", 0.0,
         f"delta={abs(sim.kv_pages_allocated - m.kv_pages_allocated)} "
         f"{'PASS' if ok else 'FAIL'}"),
    ]
    if not ok:
        raise AssertionError(
            "simulator and runtime must stamp identical "
            f"kv_pages_allocated on the same trace: sim "
            f"{sim.kv_pages_allocated} vs runtime {m.kv_pages_allocated} "
            f"(arithmetic {exp})")
    return rows


def _runtime_micro() -> List[Tuple[str, float, str]]:
    """Real paged engine at the dense engine's exact HBM budget: count
    measured admissions of short-context requests."""
    import jax
    from repro.configs import ARCHS
    from repro.models import init_params
    from repro.serving import kv_transfer
    from repro.serving.engine import DecodeEngine, PrefillEngine
    from repro.serving.paging import PagingError

    cfg = ARCHS["qwen3-1.7b"].reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    cap, prompt_len, s_out = 128, 17, 4
    dense_slots = 2

    t0 = time.perf_counter()
    pe = PrefillEngine(cfg, params, cache_capacity=cap)
    dense = DecodeEngine(cfg, params, slots=dense_slots, capacity=cap)
    # equal HBM: the paged pool holds exactly the dense slabs' pages
    paged = DecodeEngine(cfg, params, slots=32, capacity=cap, paged=True,
                         page_size=PAGE,
                         num_pages=dense_slots * (cap // PAGE) + 1)
    rng = np.random.default_rng(0)
    admitted = {"dense": 0, "paged": 0}
    for name, eng in (("dense", dense), ("paged", paged)):
        for rid in range(64):
            prompt = rng.integers(0, cfg.vocab, prompt_len).astype(np.int32)
            first, slab = pe.prefill_batch([prompt])[0]
            try:
                if eng.paged:
                    eng.admit(rid, first, prompt_len, s_out,
                              kv_transfer.trim_to_pages(slab, prompt_len,
                                                        PAGE, cfg=cfg))
                else:
                    eng.admit(rid, first, prompt_len, s_out,
                              kv_transfer.pad_capacity(slab, cap, cfg=cfg))
            except PagingError:
                break
            admitted[name] += 1
    us = (time.perf_counter() - t0) * 1e6
    gain = admitted["paged"] / max(admitted["dense"], 1)
    ok = gain >= 1.5
    rows = [("paged.engine_hbm_parity", us,
             f"dense_admitted={admitted['dense']} "
             f"paged_admitted={admitted['paged']} gain={gain:.1f}x "
             f"pool={paged.pool.num_allocatable}pages "
             f"{'PASS' if ok else 'FAIL'}")]
    if not ok:
        raise AssertionError(
            "a paged engine at the dense HBM budget must admit >= 1.5x "
            f"concurrent short requests: {admitted}")
    return rows


def run() -> List[Tuple[str, float, str]]:
    return (_concurrency_and_sim() + _scheduler_delta()
            + _runtime_parity() + _runtime_micro())


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
