"""Int8-resident paged KV decode (DESIGN.md §16).

Beyond-paper benchmark: keeping pages quantized IN the pool halves the
per-page HBM footprint (int8 payload + fp32 per-(page, kv-head) scale
sidecar), so the same decode-group memory admits ~2x the concurrency
the bf16-paged accounting does — on the memory-skewed cluster where KV
residency binds decode placement. Four parts:

  1. Admitted-concurrency gain (scheduling domain): per decode group,
     the max batch under bf16-paged vs int8-paged page budgets at
     equal HBM. The §16 acceptance check: >= 1.5x aggregate.

  2. Scheduler feedback: the int8 page budget fed into ``solve_flow``
     must CHANGE the max-flow decode routing on a decode-bound
     partition (asserted), lifting max_flow.

  3. Cross-domain parity: the same trace through the REAL int8-paged
     runtime (reduced arch) and the int8-paged simulator —
     ``kv_pages_allocated`` must agree EXACTLY and both sides must
     stamp ``kv_cache_dtype="int8"``, per METRIC_FIELDS.

  4. Runtime micro: a real int8 ``DecodeEngine`` holding the bf16
     pool's exact byte budget admits >= 1.5x the concurrent requests
     (measured admissions against the sidecar-inclusive page bytes).

Run:  PYTHONPATH=src python -m benchmarks.quantized_paged
      (or python -m benchmarks.run qpaged; REPRO_BENCH_SMOKE=1 shrinks
      every part to CI-smoke sizes)
"""
from __future__ import annotations

import os
import time
from typing import List, Tuple

import numpy as np

from repro.core import LLAMA2_70B, WORKLOADS
from repro.core.cluster import memory_skewed_setting
from repro.core.cost_model import max_decode_batch_paged
from repro.core.flowgraph import solve_flow
from repro.core.partition import GroupPartition
from repro.serving import offline_workload, simulate
from repro.serving.paging import pages_for_request

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))
WL = WORKLOADS["HPHD"]
PAGE = 16
N_REQS = 24 if SMOKE else 64

#: Same decode-bound partition as benchmarks.paged_decode: decode on
#: the memory-starved H100 pair, prefill on the roomy nodes.
FIXED_PART = ([[0, 1], [2, 3, 4, 5], [6, 7, 8, 9], [10, 11, 12, 13]],
              [False, True, True, True])


def _placements(cl):
    part = GroupPartition([list(g) for g in FIXED_PART[0]],
                          list(FIXED_PART[1]))
    bf16 = solve_flow(cl, LLAMA2_70B, part, WL, paged_kv=True,
                      page_size=PAGE)
    int8 = solve_flow(cl, LLAMA2_70B, part, WL, paged_kv=True,
                      page_size=PAGE, kv_cache_dtype="int8")
    return part, bf16, int8


def _concurrency_and_sim() -> List[Tuple[str, float, str]]:
    rows = []
    cl = memory_skewed_setting()
    part, r_bf16, r_int8 = _placements(cl)

    t0 = time.perf_counter()
    total_b = total_q = 0
    for gid, (group, is_pref) in enumerate(zip(part.groups,
                                               part.is_prefill)):
        if is_pref:
            continue
        plan = r_bf16.placement.replica_by_group(gid).plan
        total_b += max_decode_batch_paged(cl, LLAMA2_70B, plan, WL,
                                          page_size=PAGE)
        total_q += max_decode_batch_paged(cl, LLAMA2_70B, plan, WL,
                                          page_size=PAGE,
                                          kv_cache_dtype="int8")
    us = (time.perf_counter() - t0) * 1e6
    gain = total_q / max(total_b, 1)
    rows.append((f"qpaged.concurrency.{cl.name}", us,
                 f"bf16_batch={total_b} int8_batch={total_q} "
                 f"gain={gain:.2f}x "
                 f"{'PASS' if gain >= 1.5 else 'FAIL'}"))
    if gain < 1.5:
        raise AssertionError(
            "int8-resident pages must admit >= 1.5x the bf16-paged "
            f"decode concurrency at equal HBM: {total_q} vs {total_b}")

    for name, res, dtype in (("bf16", r_bf16, None),
                             ("int8", r_int8, "int8")):
        t0 = time.perf_counter()
        reqs = offline_workload("HPHD", N_REQS, seed=7)
        sim = simulate(cl, LLAMA2_70B, res.placement, reqs,
                       paged_kv=True, page_size=PAGE,
                       kv_cache_dtype=dtype)
        us = (time.perf_counter() - t0) * 1e6
        rows.append((f"qpaged.sim.{name}", us,
                     f"thpt={sim.decode_throughput:.1f}tok/s "
                     f"avg_lat={sim.avg_latency:.2f}s "
                     f"pages={sim.kv_pages_allocated} "
                     f"dtype={sim.kv_cache_dtype}"))
    return rows


def _flow_shift() -> List[Tuple[str, float, str]]:
    rows = []
    cl = memory_skewed_setting()
    t0 = time.perf_counter()
    _, r_bf16, r_int8 = _placements(cl)
    us = (time.perf_counter() - t0) * 1e6
    rb = {k: round(v, 6) for k, v in r_bf16.placement.kv_routes.items()}
    rq = {k: round(v, 6) for k, v in r_int8.placement.kv_routes.items()}
    changed = rb != rq
    lift = (r_int8.placement.max_flow
            / max(r_bf16.placement.max_flow, 1e-9))
    rows.append(("qpaged.flow_shift", us,
                 f"flow {r_bf16.placement.max_flow:.0f}->"
                 f"{r_int8.placement.max_flow:.0f} ({lift:.2f}x) "
                 f"changed={changed} {'PASS' if changed else 'FAIL'}"))
    if not changed:
        raise AssertionError(
            "the int8 page budget must shift the max-flow decode "
            f"routing on {cl.name}: {rb} vs {rq}")
    return rows


# -- cross-domain parity ------------------------------------------------------

RT_TRACE = dict(conversations=4, turns=2, rate_rps=4.0, system_len=12,
                user_len=6, out_len=4)


def _runtime_parity() -> List[Tuple[str, float, str]]:
    import jax
    from repro.configs import ARCHS
    from repro.core import make_plan
    from repro.core.cluster import homogeneous_setting
    from repro.core.cost_model import ModelProfile
    from repro.core.placement import Placement, ReplicaPlacement
    from repro.models import init_params
    from repro.models.common import DEFAULT_DTYPE
    from repro.serving import (Coordinator, ServeRequest,
                               multi_turn_workload)

    cfg = ARCHS["qwen3-1.7b"].reduced()
    prof = ModelProfile.from_arch(cfg, kv_dtype=DEFAULT_DTYPE)

    t0 = time.perf_counter()
    cl = homogeneous_setting()
    reps, routes = [], {}
    for g in range(4):
        devs = [2 * g, 2 * g + 1]
        reps.append(ReplicaPlacement(g, devs, g < 2,
                                     make_plan([devs], prof.num_layers, cl),
                                     1.0))
    for p in range(2):
        for d in (2, 3):
            routes[(p, d)] = 1.0
    placement = Placement(reps, routes, max_flow=4.0, period=600.0)
    reqs_sim = multi_turn_workload(seed=9, vocab=cfg.vocab, **RT_TRACE)
    sim = simulate(cl, prof, placement, reqs_sim, paged_kv=True,
                   page_size=PAGE, kv_cache_dtype="int8")
    sim_us = (time.perf_counter() - t0) * 1e6

    t0 = time.perf_counter()
    params = init_params(jax.random.PRNGKey(0), cfg)
    coord = Coordinator(cfg, params, num_decode_engines=2,
                        slots_per_engine=6, capacity=128,
                        num_prefill_engines=2, paged=True, page_size=PAGE,
                        paged_dtype="int8")
    sess = coord.session(max_prefill_batch=1)
    for r in sorted(multi_turn_workload(seed=9, vocab=cfg.vocab, **RT_TRACE),
                    key=lambda r: r.arrival):
        sess.submit(ServeRequest(r.rid, np.asarray(r.tokens, np.int32),
                                 r.s_out), arrival_time=r.arrival)
    m = sess.run().metrics()
    rt_us = (time.perf_counter() - t0) * 1e6

    exp = sum(pages_for_request(r.s_in, r.s_out, PAGE) for r in reqs_sim)
    ok = (sim.kv_pages_allocated == m.kv_pages_allocated == exp
          and sim.kv_cache_dtype == m.kv_cache_dtype == "int8")
    rows = [
        ("qpaged.sim_pages.homog", sim_us,
         f"pages={sim.kv_pages_allocated} dtype={sim.kv_cache_dtype}"),
        ("qpaged.runtime_pages.qwen3-1.7b-reduced", rt_us,
         f"pages={m.kv_pages_allocated} dtype={m.kv_cache_dtype} "
         f"preemptions={sum(r.preemptions for r in m.requests)}"),
        ("qpaged.sim_vs_runtime", 0.0,
         f"delta={abs(sim.kv_pages_allocated - m.kv_pages_allocated)} "
         f"{'PASS' if ok else 'FAIL'}"),
    ]
    if not ok:
        raise AssertionError(
            "int8-paged simulator and runtime must stamp identical "
            f"kv_pages_allocated and kv_cache_dtype: sim "
            f"{sim.kv_pages_allocated}/{sim.kv_cache_dtype} vs runtime "
            f"{m.kv_pages_allocated}/{m.kv_cache_dtype} "
            f"(arithmetic {exp})")
    return rows


def _runtime_micro() -> List[Tuple[str, float, str]]:
    """Real int8 engine holding the bf16 pool's exact BYTE budget:
    count measured admissions of short-context requests."""
    import jax
    from repro.configs import ARCHS
    from repro.models import init_params
    from repro.serving import kv_transfer
    from repro.serving.engine import DecodeEngine, PrefillEngine
    from repro.serving.paging import PagingError

    cfg = ARCHS["qwen3-1.7b"].reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    cap, prompt_len, s_out = 128, 17, 4
    bf16_pages = 16 + 1

    t0 = time.perf_counter()
    pe = PrefillEngine(cfg, params, cache_capacity=cap)
    bf16 = DecodeEngine(cfg, params, slots=32, capacity=cap, paged=True,
                        page_size=PAGE, num_pages=bf16_pages)
    # equal HBM: the int8 pool holds as many (payload + sidecar) pages
    # as the bf16 pool's bytes buy
    probe = DecodeEngine(cfg, params, slots=1, capacity=cap, paged=True,
                         page_size=PAGE, num_pages=2, paged_dtype="int8")
    budget = (bf16_pages - 1) * bf16.pool.page_bytes
    int8_pages = int(budget // probe.pool.page_bytes) + 1
    int8 = DecodeEngine(cfg, params, slots=32, capacity=cap, paged=True,
                        page_size=PAGE, num_pages=int8_pages,
                        paged_dtype="int8")
    rng = np.random.default_rng(0)
    admitted = {"bf16": 0, "int8": 0}
    for name, eng in (("bf16", bf16), ("int8", int8)):
        for rid in range(64):
            prompt = rng.integers(0, cfg.vocab, prompt_len).astype(np.int32)
            first, slab = pe.prefill_batch([prompt])[0]
            try:
                eng.admit(rid, first, prompt_len, s_out,
                          kv_transfer.trim_to_pages(slab, prompt_len,
                                                    PAGE, cfg=cfg))
            except PagingError:
                break
            admitted[name] += 1
    us = (time.perf_counter() - t0) * 1e6
    gain = admitted["int8"] / max(admitted["bf16"], 1)
    ok = gain >= 1.5
    rows = [("qpaged.engine_hbm_parity", us,
             f"bf16_admitted={admitted['bf16']} "
             f"int8_admitted={admitted['int8']} gain={gain:.1f}x "
             f"int8_pool={int8.pool.num_allocatable}pages "
             f"{'PASS' if ok else 'FAIL'}")]
    if not ok:
        raise AssertionError(
            "an int8 engine at the bf16 pool's byte budget must admit "
            f">= 1.5x concurrent short requests: {admitted}")
    return rows


def run() -> List[Tuple[str, float, str]]:
    return (_concurrency_and_sim() + _flow_shift()
            + _runtime_parity() + _runtime_micro())


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
