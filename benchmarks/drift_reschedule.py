"""Online workload-drift rescheduling: static vs adaptive placement.

Beyond-paper benchmark (DESIGN.md §7). The trace starts heavy-prefill
(HPLD) and drifts to heavy-decode (LPHD) at a rate the HPLD-optimized
placement cannot sustain. The static run keeps that placement for the
whole trace; the online run watches the arrival mix with a
WorkloadMonitor and warm-start-reschedules (phase-3 refinement from the
current partition) when it drifts, paying the KV-drain cost at each
placement swap.

The monitor runs the production-faithful ``estimator="ewma"`` path
(DESIGN.md §13): output lengths are LEARNED from completions streamed
off the simulator's DONE edges, not read from the oracle at arrival —
drift detection pays the real one-mean-latency lag and still has to
clear the gate.

Reports decode throughput, SLO attainment (same static-placement SLO
base for both runs), and the swap log. Online must beat static 1.2x on
decode throughput without giving up SLO attainment — the acceptance
check for the rescheduling subsystem.

Run:  PYTHONPATH=src python -m benchmarks.drift_reschedule
      (or python -m benchmarks.run drift)
"""
from __future__ import annotations

import time
from typing import List, Tuple

from repro.core import (LLAMA2_70B, WORKLOADS, WorkloadMonitor, reschedule,
                        schedule)
from repro.core.cluster import PAPER_SETTINGS
from repro.serving import (TracePhase, drifting_workload, simulate,
                           simulate_online, slo_baselines)

SLO_SCALE = 5.0
PHASE_B_RATE = 8.0   # req/s: > static HPLD placement's LPHD capacity (~5.5),
                     # < the rescheduled placement's (~17.6)


def _trace(rate_a: float, seed: int):
    phases = [TracePhase(150.0, rate_a, {"HPLD": 1.0}),
              TracePhase(450.0, PHASE_B_RATE, {"LPHD": 1.0})]
    return drifting_workload(phases, seed=seed)


def run() -> List[Tuple[str, float, str]]:
    rows = []
    cl = PAPER_SETTINGS["hetero1"]()
    wl0 = WORKLOADS["HPLD"]
    sched0 = schedule(cl, LLAMA2_70B, wl0, max_refine_iters=6)
    rate_a = 0.6 * sched0.placement.throughput_rps

    # static: the HPLD placement serves the whole drifted trace
    t0 = time.perf_counter()
    reqs_s = _trace(rate_a, seed=3)
    stat = simulate(cl, LLAMA2_70B, sched0.placement, reqs_s)
    slo_s = slo_baselines(cl, LLAMA2_70B, sched0.placement, reqs_s)
    att_s = stat.slo_attainment(slo_s, SLO_SCALE)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("drift.static.hetero1", us,
                 f"thpt={stat.decode_throughput:.0f}tok/s "
                 f"slo{SLO_SCALE:.0f}x={att_s:.3f} "
                 f"avg_lat={stat.avg_latency:.1f}s"))

    # online: monitor + warm-start reschedule + mid-trace swap
    t0 = time.perf_counter()
    reqs_o = _trace(rate_a, seed=3)
    monitor = WorkloadMonitor(wl0, window=64, threshold=0.3,
                              min_observations=32, estimator="ewma")

    def rescheduler(wl):
        return reschedule(cl, LLAMA2_70B, sched0, wl,
                          max_refine_iters=8).placement

    on = simulate_online(cl, LLAMA2_70B, sched0.placement, reqs_o,
                         monitor=monitor, rescheduler=rescheduler,
                         min_gap_s=120.0)
    slo_o = slo_baselines(cl, LLAMA2_70B, sched0.placement, reqs_o)
    att_o = on.slo_attainment(slo_o, SLO_SCALE)
    us = (time.perf_counter() - t0) * 1e6
    swaps = " ".join(f"swap@{ev.time:.0f}s(drain={ev.drain_s:.1f}s,"
                     f"kv={ev.migrated})" for ev in on.reschedules)
    rows.append(("drift.online.hetero1", us,
                 f"thpt={on.decode_throughput:.0f}tok/s "
                 f"slo{SLO_SCALE:.0f}x={att_o:.3f} "
                 f"avg_lat={on.avg_latency:.1f}s {swaps}"))

    speedup = on.decode_throughput / max(stat.decode_throughput, 1e-9)
    ok = speedup >= 1.2 and att_o >= att_s
    rows.append(("drift.online_vs_static", 0.0,
                 f"thpt_ratio={speedup:.2f}x "
                 f"slo_delta={att_o - att_s:+.3f} "
                 f"{'PASS' if ok else 'FAIL'}"))
    if not ok:
        raise AssertionError(
            "online rescheduling (ewma estimator) must beat static 1.2x: "
            f"thpt {on.decode_throughput:.0f} vs {stat.decode_throughput:.0f}"
            f" tok/s ({speedup:.2f}x), slo {att_o:.3f} vs {att_s:.3f}")
    return rows


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
