"""Router tier: SLO-aware routing vs round-robin under replica failure.

Beyond-paper benchmark (DESIGN.md §12). HexGen-2 places one
disaggregated fleet; real traffic adds replicas, priority classes, and
replicas dying mid-serve. The §12 ``Router`` fronts N replicas with a
bounded priority/aging admission queue, sticky prefix-aware dispatch,
cancellation, and failover re-dispatch.

Two parts:

  1. Scheduling domain: the same seeded mixed-priority trace (three
     classes — interactive/standard/batch — with per-class SLOs and
     shared system prompts), 2 replicas, one KILLED mid-trace, driven
     under ``policy="slo"`` and ``policy="rr"`` (FIFO + round-robin).
     SLO-aware routing must attain >= 1.2x the round-robin baseline's
     stated-SLO attainment — the acceptance check.
  2. Cross-domain parity: the same trace driven through the REAL
     runtime (2 Coordinators on a reduced arch behind the same Router)
     and through ``simulate_fleet``. The admitted/rejected/cancelled/
     redispatched counters and the per-class cache hit rates must
     agree EXACTLY — the §12 parity contract.

Run:  PYTHONPATH=src python -m benchmarks.router_fleet
      (or python -m benchmarks.run router)
"""
from __future__ import annotations

import os
import time
from typing import List, Tuple

from repro.serving import mixed_priority_workload, simulate_fleet
from repro.serving.telemetry import span_stream

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))

#: mixed-priority fleet trace: arrivals outpace the (half-dead) fleet
#: so the admission queue backs up and discipline matters
TRACE = (dict(n=60, rate_rps=60.0, seed=3, slo_s=(1.5, 6.0, 60.0))
         if SMOKE else
         dict(n=120, rate_rps=60.0, seed=3, slo_s=(1.5, 6.0, 60.0)))
FLEET = dict(num_replicas=2, slots_per_replica=2, max_prefill_batch=2,
             capacity=128, dt=0.05, queue_capacity=96, age_every=40)
KILL_STEP = 20 if SMOKE else 40


def breakdown_rows(prefix: str, metrics) -> List[Tuple[str, float, str]]:
    """§14 TTFT attribution report: per-class mean fractions of TTFT
    spent in each pipeline stage. Also asserts the per-request
    fractions partition TTFT exactly (sum to 1 within 1e-9)."""
    for req in metrics.requests:
        fr = req.ttft_fractions()
        if fr is None:
            continue
        s = sum(fr.values())
        if abs(s - 1.0) > 1e-9:
            raise AssertionError(
                f"ttft fractions must sum to 1.0: rid={req.rid} sum={s!r}")
    rows = []
    for cls, frac in sorted(metrics.ttft_breakdown.items()):
        rows.append((f"{prefix}.ttft_breakdown.c{cls}", 0.0,
                     " ".join(f"{k}={v:.3f}" for k, v in frac.items())))
    return rows


def _fleet_pair() -> List[Tuple[str, float, str]]:
    rows = []
    results = {}
    for policy in ("slo", "rr"):
        t0 = time.perf_counter()
        res = simulate_fleet(mixed_priority_workload(**TRACE),
                             policy=policy, failures={KILL_STEP: 1},
                             **FLEET)
        us = (time.perf_counter() - t0) * 1e6
        results[policy] = res
        cls = " ".join(f"c{c}={v:.2f}" for c, v in
                       sorted(res.slo_attainment_by_class.items()))
        rows.append((f"router.{policy}.2rep_kill1", us,
                     f"slo={res.slo_attainment_stated:.3f} {cls} "
                     f"admitted={res.counters['admitted']} "
                     f"rejected={res.counters['rejected']} "
                     f"redispatched={res.counters['redispatched']}"))
        rows.extend(breakdown_rows(f"router.{policy}", res))
    slo, rr = results["slo"], results["rr"]
    gain = (slo.slo_attainment_stated
            / max(rr.slo_attainment_stated, 1e-9))
    ok = gain >= 1.2
    rows.append(("router.slo_vs_rr", 0.0,
                 f"attainment_gain={gain:.2f}x "
                 f"({slo.slo_attainment_stated:.3f} vs "
                 f"{rr.slo_attainment_stated:.3f}) "
                 f"{'PASS' if ok else 'FAIL'}"))
    if not ok:
        raise AssertionError(
            "SLO-aware routing must attain >= 1.2x round-robin on the "
            f"mixed-priority failure trace: {gain:.2f}x "
            f"({slo.slo_attainment_stated:.3f} vs "
            f"{rr.slo_attainment_stated:.3f})")
    return rows


# -- cross-domain counter parity --------------------------------------------

PARITY_TRACE = dict(n=12, rate_rps=100.0, seed=7, system_lens=(8, 6, 4),
                    user_lens=(4, 6, 8), out_lens=(3, 5, 8))
PARITY_FLEET = dict(slots=2, max_prefill_batch=2, capacity=96,
                    queue_capacity=8, age_every=8)
PARITY_KILL = {2: 1}


def _runtime_fleet(reqs):
    import jax
    from repro.configs import ARCHS
    from repro.models import init_params
    from repro.serving import (Coordinator, CoordinatorReplica, Router,
                               StepClock)

    cfg = ARCHS["qwen3-1.7b"].reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    clock = StepClock()    # virtual clock: lifecycle stamps match the sim
    reps = [CoordinatorReplica(
        Coordinator(cfg, params, num_decode_engines=1,
                    slots_per_engine=PARITY_FLEET["slots"],
                    capacity=PARITY_FLEET["capacity"],
                    num_prefill_engines=1,
                    prefix_cache_bytes=float("inf")),
        max_prefill_batch=PARITY_FLEET["max_prefill_batch"], clock=clock)
        for _ in range(2)]
    router = Router(reps, queue_capacity=PARITY_FLEET["queue_capacity"],
                    age_every=PARITY_FLEET["age_every"], policy="slo",
                    clock=clock)
    metrics = router.run_trace(reqs, dt=0.05, failures=PARITY_KILL)
    return router.counters, metrics, list(router.dispatch_log)


def _parity_trace(vocab: int):
    return mixed_priority_workload(vocab=vocab, **PARITY_TRACE)


def _cross_domain() -> List[Tuple[str, float, str]]:
    from repro.configs import ARCHS
    vocab = min(ARCHS["qwen3-1.7b"].reduced().vocab, 256)

    t0 = time.perf_counter()
    sim = simulate_fleet(_parity_trace(vocab), num_replicas=2,
                         slots_per_replica=PARITY_FLEET["slots"],
                         max_prefill_batch=PARITY_FLEET["max_prefill_batch"],
                         capacity=PARITY_FLEET["capacity"], dt=0.05,
                         queue_capacity=PARITY_FLEET["queue_capacity"],
                         age_every=PARITY_FLEET["age_every"], policy="slo",
                         failures=PARITY_KILL)
    sim_us = (time.perf_counter() - t0) * 1e6

    t0 = time.perf_counter()
    rt_counters, rt, rt_log = _runtime_fleet(_parity_trace(vocab))
    rt_us = (time.perf_counter() - t0) * 1e6

    counters_ok = rt_counters == sim.counters
    hits_ok = rt.cache_hit_rate_by_class == sim.cache_hit_rate_by_class
    # §14 parity contract: the derived span streams (event types,
    # per-request ordering, step-quantized durations) must be
    # bitwise-identical across domains on the same seeded trace
    sim_spans = span_stream(sim.requests, sim.dispatch_log)
    rt_spans = span_stream(rt.requests, rt_log)
    spans_ok = sim_spans == rt_spans
    rows = [
        ("router.sim_fleet.2rep_kill1", sim_us,
         " ".join(f"{k}={v}" for k, v in sorted(sim.counters.items()))),
        ("router.runtime_fleet.qwen3-1.7b-reduced", rt_us,
         " ".join(f"{k}={v}" for k, v in sorted(rt_counters.items()))),
        ("router.sim_vs_runtime", 0.0,
         f"counters_exact={counters_ok} hit_by_class_exact={hits_ok} "
         f"spans_exact={spans_ok} n_spans={len(sim_spans)} "
         f"{'PASS' if counters_ok and hits_ok and spans_ok else 'FAIL'}"),
    ]
    rows.extend(breakdown_rows("router.runtime", rt))
    if not (counters_ok and hits_ok and spans_ok):
        raise AssertionError(
            "sim and runtime routers must agree exactly on the same "
            f"trace: counters {sim.counters} vs {rt_counters}, hit rates "
            f"{sim.cache_hit_rate_by_class} vs {rt.cache_hit_rate_by_class}, "
            f"spans_exact={spans_ok}")
    return rows


def run() -> List[Tuple[str, float, str]]:
    return _fleet_pair() + _cross_domain()


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
