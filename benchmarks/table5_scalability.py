"""Paper Table 5 (Appendix H): scheduler wall-clock vs cluster size.

The paper reports minutes at 64–320 GPUs (their search includes running
real profiling); our reproduction is pure-algorithmic, so absolute times
are smaller — the deliverable is the polynomial scaling trend.
"""
from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks.common import emit
from repro.core import LLAMA2_70B, WORKLOADS, schedule
from repro.core.cluster import build_cluster

SIZES = [16, 32, 64, 128]


def _big_cluster(n: int):
    # mixed pool: repeat the 4-type pattern, 4 GPUs per node
    spec = []
    kinds = ["H100", "A100", "L40", "A6000"]
    for i in range(n // 4):
        spec.append((kinds[i % 4], 4))
    return build_cluster(spec, name=f"scale-{n}")


def run() -> List[Tuple[str, float, str]]:
    rows = []
    prev = None
    for n in SIZES:
        cl = _big_cluster(n)
        t0 = time.perf_counter()
        res = schedule(cl, LLAMA2_70B, WORKLOADS["HPHD"],
                       max_refine_iters=6,
                       prefill_shares=(0.5,))
        dt = time.perf_counter() - t0
        growth = f" ({dt / prev:.1f}x vs prev)" if prev else ""
        prev = dt
        rows.append((f"table5.n{n}", dt * 1e6,
                     f"sched_time={dt:.2f}s flow={res.placement.max_flow:.0f}"
                     f"{growth}"))
    return rows


if __name__ == "__main__":
    emit(run())
