"""Cost-model calibration: predicted-vs-observed error and the
calibrated re-solve (DESIGN.md §15).

The max-flow scheduler prices every placement off the analytical cost
model; a miscalibrated cluster spec silently degrades every solve.
Three parts:

  1. Calibrated re-solve: the scheduler solves a placement on the
     cluster spec it BELIEVES (kv-skewed fabric at 0.15x link tiers),
     but the trace runs on hardware whose inter-node interconnect is
     3x slower than that belief. A ``CalibrationStore`` fed by the
     simulator learns per-surface observed/predicted factors; a
     corrected ``reschedule`` (factors rescaling every flowgraph
     capacity, with role-flip seeding) must genuinely SHIFT the φ→δ
     assignment and recover >= 1.2x mean TTFT over the miscalibrated
     static schedule on the real hardware — the acceptance check.
  2. Miscalibration trigger: the same store behind a ``FleetController``
     with ``miscal_bound`` set; the damped (sustain + cooldown) trigger
     must fire ``recalibrate`` exactly through the resolver hook, and
     fire it ONCE for one sustained error episode.
  3. Sim-vs-runtime parity: identically-configured stores driven by the
     scheduling-domain fleet (SimReplicas) and the REAL runtime
     (reduced-arch Coordinators) over the same seeded trace must end
     with EXACTLY equal per-(surface, group) error state — predictions
     are pure functions of identically-constructed predictor args,
     observations pure functions of the parity-exact lifecycle stamps.

Run:  PYTHONPATH=src python -m benchmarks.calibration
      (or python -m benchmarks.run calib)
"""
from __future__ import annotations

import os
import time
from typing import List, Tuple

from repro.core import LLAMA2_70B, WORKLOADS, reschedule, schedule
from repro.core.cluster import kv_skewed_setting
from repro.serving import (CalibrationStore, FleetSpec, calibration_workload,
                           mixed_priority_workload, simulate, simulate_fleet)
from repro.serving.calibration import placement_predictor, plan_predictor

SMOKE = bool(os.environ.get("REPRO_BENCH_SMOKE"))

#: the spec the scheduler believes vs the fabric the trace runs on:
#: same devices, inter-node links 3x slower than believed
BELIEVED_SCALE, REAL_SCALE = 0.15, 0.05
PROFILE = LLAMA2_70B
WL = WORKLOADS["HPLD"]
#: refinement budgets: the believed solve is deliberately modest (the
#: production default), the corrected re-solve gets the deeper budget a
#: triggered recalibration justifies
SCHED_ITERS, RESOLVE_ITERS = 6, 12
TRACE = dict(n=64, rate_rps=8.0, seed=1, slo_s=2.0)


def _calibrated_resolve() -> List[Tuple[str, float, str]]:
    believed = kv_skewed_setting(BELIEVED_SCALE)
    real = kv_skewed_setting(REAL_SCALE)
    sched = schedule(believed, PROFILE, WL, max_refine_iters=SCHED_ITERS,
                     seed=0)

    def trace():
        return calibration_workload(**TRACE)

    # learn: serve the miscalibrated schedule on the real fabric with a
    # store stamping predictions from the BELIEVED spec
    store = CalibrationStore(
        placement_predictor(believed, PROFILE, sched.placement))
    t0 = time.perf_counter()
    simulate(real, PROFILE, sched.placement, trace(), calibration=store)
    learn_us = (time.perf_counter() - t0) * 1e6
    factors = {k: round(v, 3) for k, v in store.factors().items()}
    corr = store.corrections()

    # re-solve: corrected capacities + role-flip seeding
    t0 = time.perf_counter()
    cal = reschedule(believed, PROFILE, sched, WL, corrections=corr,
                     max_refine_iters=RESOLVE_ITERS)
    resolve_us = (time.perf_counter() - t0) * 1e6
    shifted = (dict(sched.placement.kv_routes).keys()
               != dict(cal.placement.kv_routes).keys())

    # score both placements on the real fabric, fresh traces
    t0 = time.perf_counter()
    mis = simulate(real, PROFILE, sched.placement, trace()).summary()
    calm = simulate(real, PROFILE, cal.placement, trace()).summary()
    sim_us = (time.perf_counter() - t0) * 1e6 / 2
    gain_ttft = mis["avg_ttft"] / max(calm["avg_ttft"], 1e-9)
    gain_slo = (calm["slo_attainment_stated"]
                / max(mis["slo_attainment_stated"], 1e-9))
    ok = shifted and store.miscalibrated() and max(gain_ttft, gain_slo) >= 1.2
    rows = [
        ("calib.learn.kv_skewed_3x", learn_us,
         " ".join(f"{k}={v}" for k, v in sorted(factors.items()))
         + f" max_error={store.max_error():.2f}"
         f" miscalibrated={store.miscalibrated()}"),
        ("calib.resolve.corrected", resolve_us,
         f"routes={sorted(cal.placement.kv_routes)} "
         f"was={sorted(sched.placement.kv_routes)} shifted={shifted}"),
        ("calib.simulate.real_fabric", sim_us,
         f"miscal_ttft={mis['avg_ttft']:.3f}s "
         f"calib_ttft={calm['avg_ttft']:.3f}s "
         f"miscal_slo={mis['slo_attainment_stated']:.3f} "
         f"calib_slo={calm['slo_attainment_stated']:.3f}"),
        ("calib.recovery", 0.0,
         f"ttft_gain={gain_ttft:.2f}x slo_gain={gain_slo:.2f}x "
         f"{'PASS' if ok else 'FAIL'}"),
    ]
    if not ok:
        raise AssertionError(
            "calibrated re-solve must shift the kv routes and recover "
            f">= 1.2x on the real fabric: shifted={shifted} "
            f"ttft_gain={gain_ttft:.2f}x slo_gain={gain_slo:.2f}x "
            f"factors={factors}")
    return rows


# -- miscalibration trigger ---------------------------------------------------

TRIGGER_SPEC = FleetSpec(min_replicas=2, max_replicas=2,
                         queue_high=1e9,          # scaling policy quiet
                         sustain_steps=3, cooldown_steps=4,
                         miscal_bound=0.2, recal_cooldown_steps=10**6)


def _trigger() -> List[Tuple[str, float, str]]:
    # predictions come from the believed analytic model; SimReplica's
    # step cadence is what it is — the error is real and sustained, so
    # the damped trigger must fire, and exactly once under a cooldown
    # longer than the trace
    believed = kv_skewed_setting(BELIEVED_SCALE)
    sched = schedule(believed, PROFILE, WORKLOADS["LPLD"],
                     max_refine_iters=2, seed=0)
    pre = next(r for r in sched.placement.prefill_replicas()
               if r.plan is not None)
    dec = next(r for r in sched.placement.decode_replicas()
               if r.plan is not None)
    store = CalibrationStore(
        plan_predictor(believed, PROFILE, pre.plan, dec.plan),
        min_observations=4)
    resolves = []

    def resolver(ctrl, event):
        resolves.append((event.kind, ctrl._calibration_store().max_error()))
        return None

    trace = mixed_priority_workload(n=40, rate_rps=40.0, seed=5,
                                    out_lens=(3, 5, 8))
    t0 = time.perf_counter()
    res = simulate_fleet(trace, num_replicas=2, autoscale=TRIGGER_SPEC,
                         resolver=resolver, calibration=store, dt=0.05)
    us = (time.perf_counter() - t0) * 1e6
    recals = [e for e in res.scale_events if e[1] == "recalibrate"]
    ok = len(recals) == 1 and len(resolves) == 1 \
        and resolves[0][0] == "recalibrate" and resolves[0][1] > 0.2
    rows = [("calib.trigger.damped", us,
             f"recalibrate_events={len(recals)} resolver_calls="
             f"{len(resolves)} max_error="
             f"{store.max_error():.2f} {'PASS' if ok else 'FAIL'}")]
    if not ok:
        raise AssertionError(
            "the damped miscalibration trigger must fire the resolver "
            f"exactly once: events={recals} resolves={resolves}")
    return rows


# -- sim-vs-runtime parity ----------------------------------------------------

PARITY_TRACE = dict(n=10, rate_rps=100.0, seed=7, system_lens=(8, 6, 4),
                    user_lens=(4, 6, 8), out_lens=(3, 5, 8))
PARITY_FLEET = dict(slots=2, max_prefill_batch=2, capacity=96,
                    queue_capacity=8)


def _parity() -> List[Tuple[str, float, str]]:
    import jax
    from repro.configs import ARCHS
    from repro.models import init_params
    from repro.serving import (Coordinator, CoordinatorReplica, Router,
                               StepClock)

    believed = kv_skewed_setting(BELIEVED_SCALE)
    sched = schedule(believed, PROFILE, WORKLOADS["LPLD"],
                     max_refine_iters=2, seed=0)
    pre = next(r for r in sched.placement.prefill_replicas()
               if r.plan is not None)
    dec = next(r for r in sched.placement.decode_replicas()
               if r.plan is not None)

    def mk_store():
        return CalibrationStore(
            plan_predictor(believed, PROFILE, pre.plan, dec.plan),
            min_observations=4)

    cfg = ARCHS["qwen3-1.7b"].reduced()
    vocab = min(cfg.vocab, 256)

    def trace():
        return mixed_priority_workload(vocab=vocab, **PARITY_TRACE)

    s_sim = mk_store()
    t0 = time.perf_counter()
    simulate_fleet(trace(), num_replicas=2,
                   slots_per_replica=PARITY_FLEET["slots"],
                   max_prefill_batch=PARITY_FLEET["max_prefill_batch"],
                   capacity=PARITY_FLEET["capacity"], dt=0.05,
                   queue_capacity=PARITY_FLEET["queue_capacity"],
                   policy="slo", calibration=s_sim)
    sim_us = (time.perf_counter() - t0) * 1e6

    params = init_params(jax.random.PRNGKey(0), cfg)
    clock = StepClock()

    def factory(_slot):
        return CoordinatorReplica(
            Coordinator(cfg, params, num_decode_engines=1,
                        slots_per_engine=PARITY_FLEET["slots"],
                        capacity=PARITY_FLEET["capacity"],
                        num_prefill_engines=1,
                        prefix_cache_bytes=float("inf")),
            max_prefill_batch=PARITY_FLEET["max_prefill_batch"],
            clock=clock)

    s_rt = mk_store()
    t0 = time.perf_counter()
    router = Router([factory(0), factory(1)],
                    queue_capacity=PARITY_FLEET["queue_capacity"],
                    policy="slo", clock=clock, calibration=s_rt)
    router.run_trace(trace(), dt=0.05)
    rt_us = (time.perf_counter() - t0) * 1e6

    factors_ok = s_sim.factors() == s_rt.factors()
    snap_ok = s_sim.snapshot() == s_rt.snapshot()
    ok = factors_ok and snap_ok and s_sim.observations > 0
    rows = [
        ("calib.sim_fleet.parity", sim_us,
         f"observations={s_sim.observations} "
         + " ".join(f"{k}={v:.4f}" for k, v in sorted(s_sim.factors().items()))),
        ("calib.runtime_fleet.qwen3-1.7b-reduced", rt_us,
         f"observations={s_rt.observations} "
         + " ".join(f"{k}={v:.4f}" for k, v in sorted(s_rt.factors().items()))),
        ("calib.sim_vs_runtime", 0.0,
         f"factors_exact={factors_ok} snapshot_exact={snap_ok} "
         f"cells={len(s_sim.snapshot())} {'PASS' if ok else 'FAIL'}"),
    ]
    if not ok:
        raise AssertionError(
            "sim and runtime calibration stores must agree exactly on "
            f"the same trace: {s_sim.snapshot()} vs {s_rt.snapshot()}")
    return rows


def run() -> List[Tuple[str, float, str]]:
    return _calibrated_resolve() + _trigger() + _parity()


if __name__ == "__main__":
    from benchmarks.common import emit
    emit(run())
