# One-word entry points for the tier-1 workflow (see README.md).
PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench bench-drift lint

# Tier-1 verify: the whole test suite, stop at first failure.
test:
	$(PYTHON) -m pytest -x -q

# All paper benchmarks (figures/tables) + the drift-rescheduling one.
bench:
	$(PYTHON) -m benchmarks.run

# Just the online-rescheduling benchmark (static vs adaptive placement).
bench-drift:
	$(PYTHON) -m benchmarks.run drift

# Byte-compile everything — catches syntax/indentation errors without
# needing a linter wheel in the image.
lint:
	$(PYTHON) -m compileall -q src tests benchmarks examples
	@echo "lint OK"
