# One-word entry points for the tier-1 workflow (see README.md).
PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test unit serve-smoke bench bench-drift bench-serving bench-prefix \
	bench-kvstream bench-paged bench-qpaged bench-router bench-elastic \
	bench-calib bench-smoke bench-check lint

# Tier-1 verify: the whole test suite (stop at first failure), then the
# serving smoke run through the real session API on the reduced arch.
test: unit serve-smoke

unit:
	$(PYTHON) -m pytest -x -q

# End-to-end smoke: event-driven ServeSession on the reduced arch with
# Poisson arrivals + streaming (DESIGN.md §8), then a shared-prefix
# trace through the radix prefix caches with cache-aware routing (§9),
# then the int8+chunked KV-handoff codec end to end (§10), then the
# §12 router fleet — 2 replicas, one killed mid-trace (the launcher
# exits non-zero unless failover re-dispatch actually fired; this leg
# also writes and schema-validates the §14 Chrome trace + Prometheus
# snapshot via --trace-out/--metrics-out, exiting non-zero on a
# malformed or empty trace, and serves + one-shot-scrapes the §15
# /metrics + /healthz endpoint via --metrics-port), then the §13
# elastic fleet — autoscaling
# on a surge trace (exits non-zero unless a scale-up fires during the
# burst).
serve-smoke:
	$(PYTHON) -m repro.launch.serve --requests 4 --prompt-len 12 \
		--max-new 6 --decode-engines 2 --rate-rps 8
	$(PYTHON) -m repro.launch.serve --requests 8 --max-new 4 \
		--decode-engines 2 --prefill-engines 2 --rate-rps 8 \
		--prefix-trace multiturn
	$(PYTHON) -m repro.launch.serve --requests 6 --prompt-len 12 \
		--max-new 5 --decode-engines 2 --rate-rps 8 \
		--kv-codec int8-chunked
	$(PYTHON) -m repro.launch.serve --requests 8 --prompt-len 18 \
		--max-new 6 --decode-engines 2 --slots 4 --rate-rps 8 \
		--paged --page-size 16
	$(PYTHON) -m repro.launch.serve --requests 6 --prompt-len 18 \
		--max-new 5 --decode-engines 2 --slots 4 --rate-rps 8 \
		--paged --page-size 16 --paged-dtype int8
	$(PYTHON) -m repro.launch.serve --replicas 2 --requests 8 \
		--max-new 5 --kill-replica --trace-out serve_trace.json \
		--metrics-out serve_metrics.prom --metrics-port 19109
	$(PYTHON) -m repro.launch.serve --requests 12 --max-new 5 \
		--rate-rps 40 --prefill-batch 2 --autoscale --surge-trace

# All paper benchmarks (figures/tables) + the beyond-paper ones.
bench:
	$(PYTHON) -m benchmarks.run

# Just the online-rescheduling benchmark (static vs adaptive placement).
bench-drift:
	$(PYTHON) -m benchmarks.run drift

# Prefill/decode interference: legacy inline path vs pipelined session.
bench-serving:
	$(PYTHON) -m benchmarks.run serving

# Shared-prefix KV reuse: cache-aware vs cache-blind routing (§9).
bench-prefix:
	$(PYTHON) -m benchmarks.run prefix

# Compressed/chunked KV handoff: codec sweep + scheduler feedback (§10).
bench-kvstream:
	$(PYTHON) -m benchmarks.run kvstream

# Paged KV decode: dense-vs-paged capacity, flow shift, page parity (§11).
bench-paged:
	$(PYTHON) -m benchmarks.run paged

# Int8-resident paged KV: concurrency gain at equal HBM, flow shift,
# exact sim-vs-runtime page/dtype parity (§16).
bench-qpaged:
	$(PYTHON) -m benchmarks.run qpaged

# Router tier: SLO-aware vs round-robin under replica failure + the
# sim-vs-runtime counter-parity contract (§12).
bench-router:
	$(PYTHON) -m benchmarks.run router

# Elastic fleet: scale-to-demand vs static sizings on a surge trace,
# capacity-drift max-flow re-solve, sim-vs-runtime parity (§13).
bench-elastic:
	$(PYTHON) -m benchmarks.run elastic

# Cost-model calibration: learn per-surface predicted-vs-observed
# factors on a fabric 3x slower than believed, calibrated re-solve
# recovery, miscalibration trigger, sim-vs-runtime parity (§15).
bench-calib:
	$(PYTHON) -m benchmarks.run calib

# CI-sized benchmark smoke: paged + qpaged + kvstream + prefix + router
# + elastic + calib at toy sizes; every module writes BENCH_<name>.json
# (gitignored) AND mirrors it into benchmarks/artifacts/ (tracked — the
# perf trajectory).
bench-smoke:
	REPRO_BENCH_SMOKE=1 $(PYTHON) -m benchmarks.run paged qpaged kvstream prefix router elastic calib

# Perf-regression gate (§15): fresh working-dir artifacts from a
# preceding bench run vs the committed benchmarks/artifacts/ baselines,
# ± REPRO_BENCH_TOL. Non-zero exit on regression.
bench-check:
	$(PYTHON) -m benchmarks.run --check

# Byte-compile everything — catches syntax/indentation errors without
# needing a linter wheel in the image.
lint:
	$(PYTHON) -m compileall -q src tests benchmarks examples
	@echo "lint OK"
