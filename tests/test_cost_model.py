"""Table-1 cost model: units, monotonicity, memory feasibility."""
import numpy as np
import pytest
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core import (HPHD, HPLD, LLAMA2_70B, LPHD, OPT_30B, ModelProfile,
                        decode_capacity, decode_latency, kv_transfer_time,
                        make_plan, max_decode_batch, plan_fits_memory,
                        prefill_capacity, prefill_latency)
from repro.core.cluster import (build_cluster, heterogeneous_setting_1,
                                homogeneous_setting)


@pytest.fixture(scope="module")
def homog():
    return homogeneous_setting()


@pytest.fixture(scope="module")
def hetero():
    return heterogeneous_setting_1()


def _plan(cluster, devices, profile, pp=1):
    n = len(devices)
    per = n // pp
    stages = [devices[i * per:(i + 1) * per] for i in range(pp)]
    return make_plan(stages, profile.num_layers, cluster)


def test_prefill_latency_scales_with_seq(homog):
    plan = _plan(homog, list(range(4)), LLAMA2_70B)
    l1 = prefill_latency(homog, LLAMA2_70B, plan, 1, 256)
    l2 = prefill_latency(homog, LLAMA2_70B, plan, 1, 1024)
    assert l2 > l1 * 3.5  # superlinear (attention) but roughly ~4x


def test_tp_reduces_prefill_latency(homog):
    p2 = _plan(homog, list(range(2)), LLAMA2_70B)
    p8 = _plan(homog, list(range(8)), LLAMA2_70B)
    assert prefill_latency(homog, LLAMA2_70B, p8, 1, 1024) < \
        prefill_latency(homog, LLAMA2_70B, p2, 1, 1024)


def test_decode_latency_increases_with_batch_but_sublinear(homog):
    plan = _plan(homog, list(range(8)), LLAMA2_70B)
    l1 = decode_latency(homog, LLAMA2_70B, plan, 1, 512, 128)
    l32 = decode_latency(homog, LLAMA2_70B, plan, 32, 512, 128)
    assert l32 > l1
    assert l32 < 32 * l1  # batching amortizes the weight scan


def test_memory_limit_enforced(homog):
    one = _plan(homog, [0], LLAMA2_70B)  # 140GB model on one 80GB GPU
    assert not plan_fits_memory(homog, LLAMA2_70B, one, 1, 1024)
    eight = _plan(homog, list(range(8)), LLAMA2_70B)
    assert plan_fits_memory(homog, LLAMA2_70B, eight, 1, 1024)


def test_max_decode_batch_monotone_in_devices(homog):
    p4 = _plan(homog, list(range(4)), OPT_30B)
    p8 = _plan(homog, list(range(8)), OPT_30B)
    assert max_decode_batch(homog, OPT_30B, p8, 1024) >= \
        max_decode_batch(homog, OPT_30B, p4, 1024)


def test_kv_transfer_scales_with_seq(homog):
    src = _plan(homog, [0, 1], LLAMA2_70B)
    dst = _plan(homog, [2, 3], LLAMA2_70B)
    t1 = kv_transfer_time(homog, LLAMA2_70B, src, dst, 1, 256)
    t2 = kv_transfer_time(homog, LLAMA2_70B, src, dst, 1, 2048)
    assert t2 > t1 * 4


def test_ssm_profile_has_constant_kv():
    ssm = ModelProfile.ssm("ssm", 24, 2048, 50000, state_bytes_layer=1e6)
    assert ssm.kv_bytes_per_request(100) == ssm.kv_bytes_per_request(100000)


def test_gqa_reduces_kv_volume():
    mha = ModelProfile.dense("mha", 32, 4096, 11008, 32, 32, 32000)
    gqa = ModelProfile.dense("gqa", 32, 4096, 11008, 32, 8, 32000)
    assert gqa.kv_bytes_per_request(1024) == \
        pytest.approx(mha.kv_bytes_per_request(1024) / 4)


def test_heterogeneous_slowest_dominates():
    # a stage mixing H100 with A6000 (same node, PCIe) is as slow as an
    # A6000-only stage at the same TP degree: the slowest member gates
    cl = build_cluster([("H100", 2)], name="h")
    cl2 = build_cluster([("A6000", 2)], name="a")
    import numpy as np
    from repro.core.cluster import ClusterSpec, Device, GPU_TYPES, LINK_PCIE
    devs = [Device(0, GPU_TYPES["H100"], 0), Device(1, GPU_TYPES["A6000"], 0),
            Device(2, GPU_TYPES["A6000"], 0), Device(3, GPU_TYPES["A6000"], 0)]
    bw = np.full((4, 4), LINK_PCIE[0]); np.fill_diagonal(bw, 0)
    lat = np.full((4, 4), LINK_PCIE[1]); np.fill_diagonal(lat, 0)
    mix = ClusterSpec(devs, bw, lat, name="mixed")
    mixed = _plan(mix, [0, 1], OPT_30B)       # H100 + A6000
    slow = _plan(mix, [2, 3], OPT_30B)        # A6000 + A6000
    lm = prefill_latency(mix, OPT_30B, mixed, 1, 512)
    ls = prefill_latency(mix, OPT_30B, slow, 1, 512)
    assert lm == pytest.approx(ls, rel=0.05)  # same links, slowest gates


@settings(max_examples=20, deadline=None)
@given(st.integers(128, 2048), st.integers(16, 256))
def test_capacities_positive_and_finite(s_in, s_out):
    from repro.core.cost_model import Workload
    cl = homogeneous_setting()
    plan = _plan(cl, list(range(8)), OPT_30B)
    wl = Workload("w", s_in=s_in, s_out=s_out)
    pc = prefill_capacity(cl, OPT_30B, plan, wl, 600.0)
    dc = decode_capacity(cl, OPT_30B, plan, wl, 600.0)
    assert 0 < pc < 1e9 and 0 <= dc < 1e9
