"""End-to-end scheduling: two-phase + refinement + baselines."""
import numpy as np
import pytest

from repro.core import (HPHD, HPLD, LPHD, LPLD, LLAMA2_70B, OPT_30B,
                        colocated_throughput, distserve_schedule,
                        genetic_schedule, schedule, solve_flow)
from repro.core.cluster import (heterogeneous_setting_1, homogeneous_setting)
from repro.core.partition import initial_partition


@pytest.fixture(scope="module")
def hetero():
    return heterogeneous_setting_1()


@pytest.fixture(scope="module")
def homog():
    return homogeneous_setting()


@pytest.fixture(scope="module")
def sched(hetero):
    return schedule(hetero, LLAMA2_70B, HPHD, max_refine_iters=8)


def test_schedule_produces_feasible_placement(sched, hetero):
    p = sched.placement
    assert p.max_flow > 0
    assert p.prefill_replicas() and p.decode_replicas()
    devices = sorted(d for r in p.replicas for d in r.devices)
    assert devices == list(range(hetero.num_devices))
    for r in p.replicas:
        if r.plan is not None:
            assert sorted(r.plan.devices) == sorted(r.devices)


def test_flow_routes_consistent_with_capacities(sched):
    p = sched.placement
    for (src, dst), f in p.kv_routes.items():
        assert f >= -1e-9
        assert p.replica_by_group(src).is_prefill
        assert not p.replica_by_group(dst).is_prefill
    # total routed flow equals max flow
    assert sum(p.kv_routes.values()) == pytest.approx(p.max_flow, rel=1e-6)


def test_refinement_never_decreases_flow(sched):
    flows = [t.max_flow for t in sched.trace]
    assert all(b >= a - 1e-9 for a, b in zip(flows, flows[1:]))


def test_flow_bounded_by_replica_capacity(hetero):
    part = initial_partition(hetero, LLAMA2_70B)
    res = solve_flow(hetero, LLAMA2_70B, part, HPHD)
    p = res.placement
    pref_cap = sum(r.capacity for r in p.prefill_replicas())
    dec_cap = sum(r.capacity for r in p.decode_replicas())
    assert p.max_flow <= min(pref_cap, dec_cap) + 1e-6


def test_guided_beats_or_matches_genetic(hetero):
    ours = schedule(hetero, LLAMA2_70B, LPHD, max_refine_iters=8, seed=0)
    ga = genetic_schedule(hetero, LLAMA2_70B, LPHD, population=6,
                          generations=6, seed=0)
    assert ours.placement.max_flow >= 0.8 * ga.placement.max_flow


def test_distserve_homogeneous(homog):
    res = distserve_schedule(homog, OPT_30B, HPLD)
    assert res.placement.max_flow > 0
    # uniform shapes: every replica TP degree is a power of two
    for r in res.placement.replicas:
        if r.plan:
            for tp in r.plan.tp_degrees:
                assert tp in (1, 2, 4, 8)


def test_disaggregated_beats_colocated_estimate(hetero):
    ours = schedule(hetero, LLAMA2_70B, HPHD, max_refine_iters=8)
    groups = [r.devices for r in ours.placement.replicas]
    coloc = colocated_throughput(hetero, LLAMA2_70B, HPHD, groups)
    assert ours.placement.max_flow > coloc * 0.9  # ≥ colocated (usually ≫)


def test_workload_shifts_resources(hetero):
    """LPHD should allocate at least as much decode capacity share as
    HPLD (paper Appendix E)."""
    hpld = schedule(hetero, LLAMA2_70B, HPLD, max_refine_iters=8)
    lphd = schedule(hetero, LLAMA2_70B, LPHD, max_refine_iters=8)

    def decode_share(res):
        dec = sum(len(r.devices) for r in res.placement.decode_replicas())
        return dec / hetero.num_devices

    assert decode_share(lphd) >= decode_share(hpld) - 0.15


def test_annealed_refinement_returns_best_seen(hetero):
    """SA acceptance (beyond-paper) may walk downhill but must return the
    best-seen partition — never worse than greedy's start, and valid."""
    from repro.core.partition import initial_partition
    from repro.core.refine import iterative_refinement
    part = initial_partition(hetero, LLAMA2_70B)
    g_part, g_res, _ = iterative_refinement(hetero, LLAMA2_70B, part, HPHD,
                                            max_iters=8, seed=1)
    a_part, a_res, a_trace = iterative_refinement(
        hetero, LLAMA2_70B, part, HPHD, max_iters=8, seed=1, anneal=0.05)
    a_part.validate(hetero.num_devices)
    # best-seen is monotone vs the initial point
    assert a_res.placement.max_flow >= a_trace[0].max_flow - 1e-6
    # and within noise of (or better than) greedy
    assert a_res.placement.max_flow >= 0.9 * g_res.placement.max_flow
