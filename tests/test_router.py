"""Router tier (DESIGN.md §12): admission queue discipline, fault
injection / failover re-dispatch, cancellation at every lifecycle
stage, sim-vs-runtime counter parity, and the route-score tie-break
determinism rule."""
import collections

import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.models import init_params
from repro.serving import (AdmissionQueue, AdmissionRejected, Coordinator,
                           CoordinatorReplica, DecodeEngine, METRIC_FIELDS,
                           PrefillEngine, Request, RequestState, Router,
                           ServeRequest, SimReplica, StepClock, kv_transfer,
                           mixed_priority_workload, simulate_fleet)
from repro.serving.router import _QEntry

KEY = jax.random.PRNGKey(12)
PS = 16


def _qe(rid, priority, seq, step=0):
    return _QEntry(Request(rid=rid, s_in=1, s_out=1, arrival=0.0,
                           priority=priority), seq, step)


# ---------------------------------------------------------------------------
# Admission queue discipline
# ---------------------------------------------------------------------------


def test_queue_priority_between_classes_fifo_within():
    q = AdmissionQueue(capacity=8, age_every=10 ** 9)
    q.push(_qe(0, 2, 0))
    q.push(_qe(1, 0, 1))
    q.push(_qe(2, 0, 2))
    q.push(_qe(3, 1, 3))
    assert [q.pop(0).life.rid for _ in range(4)] == [1, 2, 3, 0]


def test_queue_overflow_raises_typed_error():
    q = AdmissionQueue(capacity=2)
    q.push(_qe(0, 0, 0))
    q.push(_qe(1, 0, 1))
    with pytest.raises(AdmissionRejected) as ei:
        q.push(_qe(2, 0, 2))
    assert (ei.value.rid, ei.value.queue_len, ei.value.capacity) == (2, 2, 2)
    # failover re-admission bypasses the bound: admitted work cannot be
    # retroactively rejected
    q.push(_qe(3, 0, 3), force=True)
    assert len(q) == 3


def test_queue_aging_promotes_stale_batch_work():
    q = AdmissionQueue(capacity=8, age_every=4)
    q.push(_qe(0, 2, 0, step=0))       # batch, waiting since step 0
    q.push(_qe(1, 0, 1, step=7))       # fresh interactive
    # one step before full promotion the interactive one still wins
    assert q.pop(7).life.rid == 1
    q.push(_qe(1, 0, 1, step=7))
    # at step 8 the batch entry has aged to class 0 and its older seq
    # breaks the tie — bounded delay, not starvation
    assert q.pop(8).life.rid == 0


def test_queue_pop_fifo_ignores_priority():
    q = AdmissionQueue(capacity=8)
    q.push(_qe(0, 2, 0))
    q.push(_qe(1, 0, 1))
    assert q.pop_fifo().life.rid == 0


def test_queue_remove():
    q = AdmissionQueue(capacity=8)
    q.push(_qe(0, 0, 0))
    assert q.remove(0).life.rid == 0
    assert q.remove(0) is None
    assert len(q) == 0


# ---------------------------------------------------------------------------
# Scheduling-domain fleet: failover, cancellation, overflow, tie-break
# ---------------------------------------------------------------------------


def _sim_router(num_replicas=2, num_slots=2, mpb=2, prefix_caching=False,
                **kw):
    clock = StepClock()
    reps = [SimReplica(num_slots=num_slots, max_prefill_batch=mpb,
                       capacity=64, prefix_caching=prefix_caching,
                       clock=clock) for _ in range(num_replicas)]
    return Router(reps, clock=clock, **kw)


def _flat_trace(n, s_out=6):
    return [Request(rid=i, s_in=8, s_out=s_out, arrival=0.0,
                    priority=i % 3) for i in range(n)]


def test_kill_replica_mid_trace_completes_everything():
    """Fault injection: a replica dies with a full complement of
    in-flight work; every request still finishes elsewhere with its
    stream intact — no token loss, no duplication."""
    router = _sim_router()
    streams = collections.defaultdict(list)
    m = router.run_trace(_flat_trace(8), failures={2: 1},
                         on_token=lambda rid, t, fin:
                         streams[rid].append(int(t)))
    assert not router.replicas[1].alive
    # replica 1 held 4 of the 8 (load-balanced dispatch), none finished
    # by step 2 — all of them must have been re-dispatched
    assert m.redispatched == 4
    assert router.counters == {"admitted": 8, "rejected": 0,
                               "cancelled": 0, "redispatched": 4}
    for rid, toks, life in router.results():
        assert life.phase is RequestState.DONE
        # synthetic sim tokens are sequential indices: exactly-once
        # delivery across the failover is directly visible
        assert toks == list(range(life.s_out))
        assert streams[rid] == toks          # stream == result ordering
        assert life.tokens_out == life.s_out


def test_cancellation_in_sim_fleet_conserves():
    router = _sim_router(num_replicas=1, num_slots=1, mpb=1)
    # rid 0 is DECODING after step 0; rid 4 still queued in the router
    m = router.run_trace(_flat_trace(5), cancels={1: [0, 4]})
    by_phase = collections.Counter(r.phase for r in m.requests)
    assert by_phase[RequestState.CANCELLED] == 2
    assert by_phase[RequestState.DONE] == 3
    assert m.admitted + m.rejected + m.cancelled == 5
    assert m.cancelled == 2 and m.rejected == 0
    for r in m.requests:                 # cancelled: never "served"
        if r.phase is RequestState.CANCELLED:
            assert r.latency is None and r.decode_end is None


def test_admission_overflow_records_rejected():
    router = _sim_router(num_replicas=1, num_slots=1, mpb=1,
                         queue_capacity=2)
    trace = _flat_trace(5, s_out=3)
    for life in trace[:2]:
        router.submit(life)
    for life in trace[2:]:
        with pytest.raises(AdmissionRejected):
            router.submit(life)
        assert life.phase is RequestState.REJECTED
    while router.unfinished:
        router.step()
    m = router.metrics()
    assert m.admitted + m.rejected + m.cancelled == 5
    assert (m.admitted, m.rejected) == (2, 3)
    s = m.summary()
    assert all(np.isfinite(v) for v in s.values())


def test_route_score_ties_break_to_lowest_replica_index():
    """§12 determinism regression: with identical scores everywhere
    (no caches, equal weights, equal load) dispatch must walk the
    replicas in stable index order, never by dict/set iteration."""
    router = _sim_router(num_replicas=3)
    for life in _flat_trace(3, s_out=2):
        router.submit(life)
    router.step()
    assert [row["replica"] for row in router.dispatch_log] == [0, 1, 2]


def test_fleet_result_carries_metric_schema():
    res = simulate_fleet(mixed_priority_workload(n=6, rate_rps=50.0,
                                                 seed=1),
                         num_replicas=2, slots_per_replica=2,
                         max_prefill_batch=2, capacity=64)
    for f in METRIC_FIELDS:
        assert hasattr(res, f), f
    assert isinstance(res.avg_ttft_by_class, dict)
    assert isinstance(res.slo_attainment_by_class, dict)
    assert isinstance(res.cache_hit_rate_by_class, dict)
    assert all(np.isfinite(v) for v in res.summary().values())


# ---------------------------------------------------------------------------
# Runtime domain: real engines behind the same Router
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_rt():
    cfg = ARCHS["qwen3-1.7b"].reduced()
    return cfg, init_params(KEY, cfg)


def _rt_trace(cfg, n=10):
    return mixed_priority_workload(n=n, rate_rps=100.0, seed=7,
                                   vocab=min(cfg.vocab, 256),
                                   system_lens=(8, 6, 4),
                                   user_lens=(4, 6, 8), out_lens=(3, 5, 8))


def _rt_router(cfg, params, **kw):
    clock = StepClock()
    reps = [CoordinatorReplica(
        Coordinator(cfg, params, num_decode_engines=1, slots_per_engine=2,
                    capacity=96, num_prefill_engines=1,
                    prefix_cache_bytes=float("inf")),
        max_prefill_batch=2, clock=clock) for _ in range(2)]
    return Router(reps, queue_capacity=8, age_every=8, clock=clock, **kw)


def test_runtime_failover_no_token_loss(small_rt):
    """Kill the replica sticky routing loaded first, mid-trace: every
    in-flight request completes on the survivor via recompute-from-
    prompt, streamed tokens match the final results exactly, and every
    request produces its full budget."""
    cfg, params = small_rt
    router = _rt_router(cfg, params)
    streams = collections.defaultdict(list)
    m = router.run_trace(_rt_trace(cfg), dt=0.05, failures={2: 0},
                         on_token=lambda rid, t, fin:
                         streams[rid].append(int(t)))
    assert m.redispatched >= 1
    assert router.counters["admitted"] == 10
    assert router.counters["rejected"] == 0
    for rid, toks, life in router.results():
        assert life.phase is RequestState.DONE
        assert streams[rid] == toks          # no loss, no duplication
        assert len(toks) == life.s_out == life.tokens_out
        if life.redispatches:
            assert life.cached_len == 0      # folded prompts bypass cache


def test_sim_runtime_counter_parity(small_rt):
    """§12 parity contract: the SAME seeded trace through SimReplicas
    and through real Coordinators must agree EXACTLY — counters,
    per-class hit rates, and (both on the virtual step clock) even the
    per-class TTFTs."""
    cfg, params = small_rt
    sim = simulate_fleet(_rt_trace(cfg), num_replicas=2,
                         slots_per_replica=2, max_prefill_batch=2,
                         capacity=96, dt=0.05, queue_capacity=8,
                         age_every=8, failures={2: 1})
    router = _rt_router(cfg, params)
    rt = router.run_trace(_rt_trace(cfg), dt=0.05, failures={2: 1})
    assert router.counters == sim.counters
    assert rt.cache_hit_rate_by_class == sim.cache_hit_rate_by_class
    assert rt.avg_ttft_by_class == sim.avg_ttft_by_class
    assert rt.slo_attainment_by_class == sim.slo_attainment_by_class


def test_cancellation_reclaims_pages_at_every_stage(small_rt):
    """Cancel one request at each lifecycle stage on a paged replica:
    each stage's edge reclaims what it holds, and the page pool ends
    back at its baseline."""
    cfg, params = small_rt
    coord = Coordinator(cfg, params, num_decode_engines=1,
                        slots_per_engine=1, capacity=64, paged=True,
                        page_size=PS)
    eng = coord.decode_engines[0]
    baseline = eng.pool.free_pages
    sess = coord.session(max_prefill_batch=4)
    rng = np.random.default_rng(3)

    def cb(rid, tok, fin):
        if rid == 2:                   # §12: cancel from inside the
            sess.cancel(2)             # stream, mid-prefill-batch

    for i in range(4):
        prompt = rng.integers(0, cfg.vocab, 12).astype(np.int32)
        sess.submit(ServeRequest(i, prompt, 6),
                    on_token=cb if i == 2 else None)
    assert sess.cancel(3)                          # QUEUED
    sess.step()    # rid 0 -> DECODING (the only slot); 1, 2 queued
    sess.step()    # rid 1 -> KV_TRANSFER (slot busy)
    lives = {r.lifecycle.rid: r.lifecycle for r in sess.results()}
    assert lives[1].phase is RequestState.KV_TRANSFER
    assert sess.cancel(1)                          # KV_TRANSFER
    assert lives[0].phase is RequestState.DECODING
    assert sess.cancel(0)                          # DECODING
    assert lives[0].kv_pages_allocated > 0         # stamp folded in
    sess.step()    # rid 2 prefills; its callback cancels it in-batch
    for rid in range(4):
        assert lives[rid].phase is RequestState.CANCELLED, rid
        assert not sess.cancel(rid)                # terminal: no-op
    assert eng.pool.free_pages == baseline
    m = sess.metrics()
    assert m.cancelled == 4
    assert m.admitted + m.rejected + m.cancelled == 4


def test_decode_engine_cancel(small_rt):
    cfg, params = small_rt
    pe = PrefillEngine(cfg, params, cache_capacity=64)
    eng = DecodeEngine(cfg, params, slots=2, capacity=64, paged=True,
                       page_size=PS)
    free0 = eng.pool.free_pages
    prompt = np.arange(20, dtype=np.int32) % cfg.vocab
    first, slab = pe.prefill_batch([prompt])[0]
    eng.admit(0, first, 20, 4,
              kv_transfer.trim_to_pages(slab, 20, PS, cfg=cfg))
    assert eng.pool.free_pages < free0
    assert eng.cancel(0)
    assert eng.pool.free_pages == free0            # pages reclaimed
    assert eng.pop_page_stamp(0) > 0               # stamp preserved
    assert not eng.cancel(0)                       # already released
    assert not eng.cancel(99)                      # unknown rid
