"""Property tests for the §13 elastic fleet tier (hypothesis): request
conservation under arbitrary join/drain/kill interleavings, the
hysteresis bound on controller decisions, and METRIC_FIELDS schema
parity for elastic results."""
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

import numpy as np  # noqa: E402

from repro.serving import (FleetExhausted, FleetSpec, METRIC_FIELDS,  # noqa: E402
                           Request, RequestState, Router, SimReplica,
                           StepClock, simulate_fleet, surge_workload)
from repro.serving.metrics import ServeMetrics  # noqa: E402


def _rep(clock):
    return SimReplica(num_slots=2, max_prefill_batch=2, clock=clock)


# ---------------------------------------------------------------------------
# Conservation across join / drain / kill interleavings
# ---------------------------------------------------------------------------


ops = st.lists(
    st.one_of(
        st.tuples(st.just("submit"), st.integers(1, 4)),   # burst size
        st.tuples(st.just("spawn"), st.just(0)),
        st.tuples(st.just("drain"), st.integers(0, 7)),    # replica idx
        st.tuples(st.just("kill"), st.integers(0, 7)),
        st.tuples(st.just("step"), st.integers(1, 6)),     # step count
    ),
    min_size=1, max_size=40)


@settings(max_examples=40, deadline=None)
@given(ops, st.integers(1, 3))
def test_conservation_under_join_drain_kill_interleavings(script, seed_reps):
    """Whatever interleaving of joins, graceful drains, and crash
    kills the fleet suffers, no admitted request is ever lost: at the
    end, admitted == done + still-in-system, and every completed
    request carries its full token budget. ``FleetExhausted`` refusals
    leave the router state intact."""
    clock = StepClock()
    router = Router([_rep(clock) for _ in range(seed_reps)],
                    queue_capacity=256, clock=clock)
    rid = 0
    for op, arg in script:
        if op == "submit":
            for _ in range(arg):
                router.submit(Request(rid=rid, s_in=3, s_out=3,
                                      arrival=clock()))
                rid += 1
        elif op == "spawn":
            router.spawn(_rep(clock))
        elif op in ("drain", "kill"):
            idx = arg % len(router.replicas)
            before = ([r.alive for r in router.replicas],
                      router.unfinished)
            try:
                if op == "drain":
                    router.drain(idx)
                else:
                    router.kill(idx)
            except FleetExhausted:
                after = ([r.alive for r in router.replicas],
                         router.unfinished)
                assert before == after       # refusal mutates nothing
        else:
            for _ in range(arg):
                clock.value += 0.05
                router.step()
    # drive to quiescence (spawn capacity if no dispatchable replica —
    # alive-and-undraining — remains)
    if router.unfinished and not any(
            r.alive and i not in router._draining
            for i, r in enumerate(router.replicas)):
        router.spawn(_rep(clock))
    guard = 0
    while router.unfinished:
        clock.value += 0.05
        router.step()
        guard += 1
        assert guard < 10_000
    c = router.counters
    assert c["admitted"] + c["rejected"] == rid
    done = [life for _, _, life in router.results()
            if life.phase is RequestState.DONE]
    assert len(done) == c["admitted"]
    for life in done:
        assert life.tokens_out == life.s_out


# ---------------------------------------------------------------------------
# Hysteresis bound on controller decisions
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 6), st.integers(4, 40), st.integers(2, 10),
       st.floats(2.0, 8.0))
def test_hysteresis_and_damper_bounds(seed, hysteresis, cooldown, surge):
    """On any surge trace and damper setting: (a) no scale-up fires
    within ``hysteresis_steps`` after a scale-down, (b) consecutive
    scale decisions are at least ``cooldown_steps`` apart, (c) at most
    one join is in flight at a time, and (d) the fleet never exceeds
    ``max_replicas`` concurrent non-dead replicas."""
    spec = FleetSpec(min_replicas=1, max_replicas=4, provision_steps=3,
                     warmup_steps=5, sustain_steps=2,
                     cooldown_steps=cooldown, hysteresis_steps=hysteresis)
    res = simulate_fleet(surge_workload(80, 3.0, seed=seed, surge=surge),
                         num_replicas=1, dt=0.05, autoscale=spec)
    decisions = [(s, k) for s, k, _ in res.scale_events
                 if k in ("scale_up", "scale_down")]
    for (s1, _), (s2, _) in zip(decisions, decisions[1:]):
        assert s2 - s1 >= cooldown
    downs = [s for s, k in decisions if k == "scale_down"]
    ups = [s for s, k in decisions if k == "scale_up"]
    for d in downs:
        assert not any(d < u < d + hysteresis for u in ups)
    # joins are serialized and bounded by max_replicas
    alive = 1
    joining = 0
    for s, k, _ in res.scale_events:
        if k == "scale_up":
            assert joining == 0
            joining += 1
        elif k == "live":
            joining -= 1
            alive += 1
        elif k == "dead":
            alive -= 1
        assert alive + joining <= spec.max_replicas


# ---------------------------------------------------------------------------
# Schema parity for elastic results
# ---------------------------------------------------------------------------


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 4))
def test_metric_fields_schema_parity_elastic(seed):
    """Every METRIC_FIELDS name resolves on elastic FleetResults and on
    bare ServeMetrics; summary() stays finite-scalar-only; the scale
    scalars agree with the event stream; per-state replica-steps are
    positive and account for every controller state seen."""
    spec = FleetSpec(min_replicas=1, max_replicas=3, provision_steps=3,
                     warmup_steps=4, cold_window_steps=3, sustain_steps=2,
                     cooldown_steps=6, hysteresis_steps=12)
    res = simulate_fleet(surge_workload(60, 3.0, seed=seed),
                         num_replicas=1, dt=0.05, autoscale=spec)
    bare = ServeMetrics(requests=list(res.requests), makespan=res.makespan,
                        decode_tokens=res.decode_tokens)
    for obj in (res, bare):
        for f in METRIC_FIELDS:
            assert hasattr(obj, f), f
        s = obj.summary()
        assert all(isinstance(v, float) and np.isfinite(v)
                   for v in s.values())
    assert res.scale_up_events == \
        sum(1 for _, k, _ in res.scale_events if k == "scale_up")
    assert res.scale_down_events == \
        sum(1 for _, k, _ in res.scale_events if k == "scale_down")
    assert all(isinstance(k, str) and v > 0
               for k, v in res.replica_steps_by_state.items())
    states = {k for _, k, _ in res.scale_events}
    if "scale_up" in states:
        assert {"provisioning", "warming"} <= \
            set(res.replica_steps_by_state)
