"""Serving: disaggregated coordinator == monolithic generation; simulator
invariants; KV transfer helpers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import HPHD, LLAMA2_70B, schedule
from repro.core.cluster import heterogeneous_setting_1
from repro.models import decode_step, init_params, prefill
from repro.serving import (Coordinator, ServeRequest, kv_transfer,
                           offline_workload, online_workload, simulate,
                           simulate_colocated, slo_baselines)

KEY = jax.random.PRNGKey(11)


@pytest.fixture(scope="module")
def small_model():
    cfg = ARCHS["qwen3-1.7b"].reduced()
    return cfg, init_params(KEY, cfg)


def _ref_generate(cfg, params, prompt, n_new, capacity):
    logits, cache = prefill(params, cfg, jnp.asarray(prompt)[None],
                            cache_capacity=capacity)
    toks = [int(jnp.argmax(logits, -1)[0])]
    pos = len(prompt)
    for _ in range(n_new - 1):
        lg, cache = decode_step(params, cfg, cache,
                                jnp.array([[toks[-1]]], jnp.int32),
                                jnp.array([[pos]], jnp.int32))
        toks.append(int(jnp.argmax(lg, -1)[0]))
        pos += 1
    return toks


def test_disaggregated_equals_monolithic(small_model):
    cfg, params = small_model
    rng = np.random.default_rng(5)
    prompts = [rng.integers(0, cfg.vocab, size=5).astype(np.int32)
               for _ in range(4)]
    refs = [_ref_generate(cfg, params, list(p), 4, 32) for p in prompts]
    coord = Coordinator(cfg, params, num_decode_engines=2,
                        slots_per_engine=2, capacity=32)
    outs = coord.serve([ServeRequest(i, prompts[i], 4) for i in range(4)])
    for i, o in enumerate(outs):
        assert o.tokens == refs[i], f"req {i}"


def test_more_requests_than_slots(small_model):
    """Continuous batching must recycle slots across waves."""
    cfg, params = small_model
    rng = np.random.default_rng(9)
    prompts = [rng.integers(0, cfg.vocab, size=4).astype(np.int32)
               for _ in range(5)]
    coord = Coordinator(cfg, params, num_decode_engines=1,
                        slots_per_engine=2, capacity=32)
    outs = coord.serve([ServeRequest(i, prompts[i], 3) for i in range(5)])
    assert all(len(o.tokens) == 3 for o in outs)
    refs = [_ref_generate(cfg, params, list(p), 3, 32) for p in prompts]
    for i, o in enumerate(outs):
        assert o.tokens == refs[i]


def test_kv_transfer_helpers(small_model):
    cfg, params = small_model
    toks = jnp.zeros((2, 4), jnp.int32)
    _, cache = prefill(params, cfg, toks, cache_capacity=8)
    one = kv_transfer.slice_request(cache, 1)
    assert jax.tree.leaves(one)[0].shape[1] == 1
    grown = kv_transfer.pad_capacity(one, 16)
    k = grown[0]["k"]
    assert k.shape[2] == 16
    assert kv_transfer.transfer_bytes(grown) > 0


# ---------------------------------------------------------------------------
# scheduling-domain simulator
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def placed():
    cl = heterogeneous_setting_1()
    res = schedule(cl, LLAMA2_70B, HPHD, max_refine_iters=6)
    return cl, res.placement


def test_simulator_completes_all_requests(placed):
    cl, placement = placed
    reqs = offline_workload("HPHD", 60, seed=1)
    sim = simulate(cl, LLAMA2_70B, placement, reqs)
    assert all(r.decode_end is not None for r in sim.requests)
    assert sim.decode_tokens == sum(r.s_out for r in reqs)
    assert sim.decode_throughput > 0
    for r in sim.requests:
        assert r.prefill_end >= r.prefill_start >= r.arrival
        assert r.transfer_end >= r.prefill_end
        assert r.decode_end >= r.transfer_end


def test_simulator_online_latency_reasonable(placed):
    cl, placement = placed
    reqs = online_workload(40, rate_rps=1.0, seed=2)
    sim = simulate(cl, LLAMA2_70B, placement, reqs)
    slo = slo_baselines(cl, LLAMA2_70B, placement, reqs)
    att = sim.slo_attainment(slo, scale=10.0)
    assert 0.0 <= att <= 1.0
    assert sim.avg_latency < sim.makespan


def test_disaggregated_beats_colocated_in_sim(placed):
    cl, placement = placed
    r1 = offline_workload("HPHD", 60, seed=3)
    r2 = offline_workload("HPHD", 60, seed=3)
    dis = simulate(cl, LLAMA2_70B, placement, r1)
    col = simulate_colocated(cl, LLAMA2_70B, placement.replicas, r2)
    assert dis.decode_throughput > col.decode_throughput * 0.95


def test_workload_classes_partition_lengths():
    for kind, (hp, hd) in {"HPLD": (True, False), "HPHD": (True, True),
                           "LPHD": (False, True), "LPLD": (False, False)
                           }.items():
        reqs = offline_workload(kind, 50, seed=4)
        assert all(r.is_heavy_prefill == hp for r in reqs), kind
        assert all(r.is_heavy_decode == hd for r in reqs), kind
