"""Serving telemetry tier (DESIGN.md §14): span derivation from
lifecycle stamps, TTFT attribution as an exact partition, rolling
window gauges, Chrome trace-event export + schema validation,
Prometheus text exposition, strict-JSON benchmark artifacts, and the
sim-vs-runtime span-stream parity contract on a seeded trace with a
mid-trace kill and an autoscale join."""
import json
import math
import os
import sys

import jax
import pytest

sys.path.insert(0, os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))     # benchmarks.* (namespace pkg)

from repro.configs import ARCHS
from repro.models import init_params
from repro.serving import (Coordinator, CoordinatorReplica, FleetController,
                           FleetSpec, Request, RequestState, Router,
                           StepClock, TTFT_BUCKETS, TraceRecorder,
                           WindowedGauges, chrome_trace,
                           mixed_priority_workload, prometheus_text,
                           request_spans, simulate_fleet, span_stream,
                           validate_chrome_trace)
from repro.serving.metrics import METRIC_FIELDS, ServeMetrics

KEY = jax.random.PRNGKey(5)


def _done_request(rid=0, *, arrival=0.0, ps=0.1, pe=0.3, te=0.4, de=0.9,
                  s_in=8, s_out=4, **kw) -> Request:
    req = Request(rid=rid, s_in=s_in, s_out=s_out, arrival=arrival, **kw)
    req.advance(RequestState.PREFILLING, ps)
    req.advance(RequestState.KV_TRANSFER, pe)
    req.advance(RequestState.DECODING, te)
    req.advance(RequestState.DONE, de)
    return req


# ---------------------------------------------------------------------------
# Span derivation (pure function of lifecycle stamps)
# ---------------------------------------------------------------------------


def test_request_spans_done_pipeline_order():
    req = _done_request()
    names = [sp.name for sp in request_spans(req)]
    assert names == ["queue", "prefill", "transfer", "decode"]
    spans = {sp.name: sp for sp in request_spans(req)}
    assert spans["queue"].start == 0.0 and spans["queue"].end == 0.1
    assert spans["prefill"].dur == pytest.approx(0.2)
    assert spans["decode"].end == 0.9
    # stages tile the lifetime: each starts where the previous ended
    assert spans["prefill"].start == spans["queue"].end
    assert spans["transfer"].start == spans["prefill"].end
    assert spans["decode"].start == spans["transfer"].end


def test_request_spans_kv_subspans_when_kv_shipped():
    req = _done_request()
    req.kv_serialized_s = 0.05
    req.kv_overlap_s = 0.03
    names = [sp.name for sp in request_spans(req)]
    assert names == ["queue", "prefill", "transfer", "transfer:wire",
                     "transfer:overlap", "decode"]
    wire = next(sp for sp in request_spans(req)
                if sp.name == "transfer:wire")
    assert wire.dur == pytest.approx(0.05)


def test_request_spans_terminal_markers():
    rej = Request(rid=1, s_in=4, s_out=2, arrival=0.5)
    rej.advance(RequestState.REJECTED, 0.5)
    assert [(s.name, s.start, s.dur) for s in request_spans(rej)] == \
        [("rejected", 0.5, 0.0)]
    # cancelled before any dispatch: instant marker at arrival
    can = Request(rid=2, s_in=4, s_out=2, arrival=0.2)
    can.advance(RequestState.CANCELLED, 0.7)
    assert [(s.name, s.start) for s in request_spans(can)] == \
        [("cancelled", 0.2)]
    # cancelled mid-pipeline: completed stages then the marker
    mid = Request(rid=3, s_in=4, s_out=2, arrival=0.0)
    mid.advance(RequestState.PREFILLING, 0.1)
    mid.advance(RequestState.KV_TRANSFER, 0.3)
    mid.advance(RequestState.CANCELLED, 0.6)
    assert [s.name for s in request_spans(mid)] == \
        ["queue", "prefill", "cancelled"]
    # still queued at trace end: no spans at all
    assert request_spans(Request(rid=4, s_in=4, s_out=2, arrival=0.0)) == []


def test_span_stream_orders_by_rid_then_pipeline_then_markers():
    reqs = [_done_request(rid=1, arrival=1.0, ps=1.1, pe=1.3, te=1.4,
                          de=1.9),
            _done_request(rid=0)]
    log = [{"rid": 1, "replica": 0, "dispatch_step": 22},
           {"rid": 0, "replica": 1, "dispatch_step": 2},
           {"rid": 1, "replica": 1, "dispatch_step": 25, "redispatch": 1}]
    stream = span_stream(reqs, log)
    rids = [t[0] for t in stream]
    assert rids == sorted(rids)
    r1 = [t for t in stream if t[0] == 1]
    assert [t[1] for t in r1] == ["queue", "prefill", "transfer", "decode",
                                  "dispatch", "redispatch"]
    assert r1[-2][2] == 22.0 and r1[-1][2] == 25.0    # step-ordered


# ---------------------------------------------------------------------------
# TTFT attribution: an exact partition of time-to-first-token
# ---------------------------------------------------------------------------


def test_ttft_attribution_partitions_exactly():
    req = _done_request()          # ttft = 0.3: queue 0.1 + prefill 0.2
    att = req.ttft_attribution()
    assert att == {"queue": pytest.approx(0.1),
                   "prefill": pytest.approx(0.2), "transfer": 0.0,
                   "warmup": 0.0, "decode_first": 0.0}
    assert sum(att.values()) == pytest.approx(req.ttft, abs=0)
    fr = req.ttft_fractions()
    assert sum(fr.values()) == pytest.approx(1.0, abs=1e-9)
    assert set(fr) == set(TTFT_BUCKETS)


def test_ttft_attribution_warmup_clamped_to_wait():
    req = _done_request()          # only 0.1s of non-prefill wait
    req.warmup_penalty_s = 5.0     # stamped penalty exceeds the wait
    att = req.ttft_attribution()
    assert att["warmup"] == pytest.approx(0.1)
    assert att["queue"] == 0.0
    assert sum(att.values()) == pytest.approx(req.ttft, abs=0)


def test_ttft_attribution_transfer_only_after_redo():
    base = dict(ps=0.5, pe=0.6)    # 0.5s queue-ish wait, 0.1 prefill
    clean = _done_request(**base)
    clean.kv_serialized_s = 0.2    # shipped KV but never re-did work
    assert clean.ttft_attribution()["transfer"] == 0.0
    redone = _done_request(**base)
    redone.kv_serialized_s = 0.2
    redone.kv_overlap_s = 0.05
    redone.preemptions = 1
    att = redone.ttft_attribution()
    assert att["transfer"] == pytest.approx(0.15)
    assert sum(att.values()) == pytest.approx(redone.ttft, abs=0)


def test_ttft_attribution_edge_cases():
    # unserved request: no attribution
    assert Request(rid=0, s_in=4, s_out=2, arrival=0.0) \
        .ttft_attribution() is None
    # zero-TTFT (same virtual step): all queue, fractions still sum to 1
    req = _done_request(arrival=0.1, ps=0.1, pe=0.1, te=0.1, de=0.1)
    assert req.ttft == 0.0
    fr = req.ttft_fractions()
    assert fr["queue"] == 1.0 and sum(fr.values()) == 1.0


# ---------------------------------------------------------------------------
# Metrics schema: p50s + ttft_breakdown (satellite of §14)
# ---------------------------------------------------------------------------


def test_p50_fields_in_schema_and_summary():
    assert "p50_ttft" in METRIC_FIELDS and "p50_latency" in METRIC_FIELDS
    assert "ttft_breakdown" in METRIC_FIELDS
    reqs = [_done_request(rid=i, de=0.9 + 0.1 * i) for i in range(5)]
    m = ServeMetrics(reqs, makespan=2.0, decode_tokens=20)
    s = m.summary()
    assert s["p50_ttft"] == pytest.approx(0.3)
    assert s["p50_latency"] == pytest.approx(1.1)   # median of .9..1.3
    assert s["p50_latency"] <= s["p99_latency"]
    # every summary value is a finite scalar; dict-valued fields
    # (ttft_breakdown et al.) stay OUT of the flat summary
    assert "ttft_breakdown" not in s
    assert all(isinstance(v, (int, float)) and math.isfinite(v)
               for v in s.values())


def test_ttft_breakdown_groups_by_priority_class():
    reqs = [_done_request(rid=0, priority=0),
            _done_request(rid=1, priority=0, ps=0.2),
            _done_request(rid=2, priority=2)]
    m = ServeMetrics(reqs, makespan=1.0, decode_tokens=12)
    bd = m.ttft_breakdown
    assert set(bd) == {0, 2}
    for cls, frac in bd.items():
        assert set(frac) == set(TTFT_BUCKETS)
        assert sum(frac.values()) == pytest.approx(1.0, abs=1e-9)
    # unserved-only class contributes nothing
    m2 = ServeMetrics([Request(rid=9, s_in=4, s_out=2, arrival=0.0,
                               priority=1)], makespan=1.0, decode_tokens=0)
    assert m2.ttft_breakdown == {}


# ---------------------------------------------------------------------------
# Benchmark artifacts are strict JSON (satellite: non-finite -> null)
# ---------------------------------------------------------------------------


def _reject_constants(name):
    raise AssertionError(f"non-standard JSON constant in artifact: {name}")


def test_artifact_json_never_emits_infinity(tmp_path, monkeypatch):
    from benchmarks.run import json_safe, write_artifact
    monkeypatch.delenv("REPRO_BENCH_SMOKE", raising=False)
    monkeypatch.chdir(tmp_path)
    rows = [("m.inf", float("inf"), "avg_ttft=inf"),
            ("m.nan", float("nan"), "ok"),
            ("m.fine", 12.5, "ok")]
    write_artifact("teltest", rows, elapsed_s=float("inf"))
    text = (tmp_path / "BENCH_teltest.json").read_text()
    # strict parse: Infinity/NaN literals are rejected outright
    art = json.loads(text, parse_constant=_reject_constants)
    assert art["rows"][0]["us_per_call"] is None
    assert art["rows"][1]["us_per_call"] is None
    assert art["rows"][2]["us_per_call"] == 12.5
    assert art["elapsed_s"] is None
    # the sanitizer itself recurses through containers
    assert json_safe({"a": [float("-inf"), (float("nan"), 1)]}) == \
        {"a": [None, [None, 1]]}


def test_artifact_dump_pins_allow_nan(monkeypatch, tmp_path):
    """If a non-finite value ever slips past the sanitizer, the dump
    must raise rather than emit an ``Infinity`` token."""
    import benchmarks.run as bench_run
    monkeypatch.delenv("REPRO_BENCH_SMOKE", raising=False)
    monkeypatch.chdir(tmp_path)
    monkeypatch.setattr(bench_run, "json_safe", lambda obj: obj)
    with pytest.raises(ValueError):
        bench_run.write_artifact("telraw", [("m", float("inf"), "x")], 0.0)


# ---------------------------------------------------------------------------
# Rolling-window gauges
# ---------------------------------------------------------------------------


def test_windowed_gauges_trim_and_snapshot():
    g = WindowedGauges(window_steps=10)
    early = _done_request(rid=0, slo_target_s=1.0)
    late = _done_request(rid=1, ps=0.2, slo_target_s=0.1)   # missed SLO
    late.cached_len = 4
    g.observe(early, 0)
    g.observe(late, 8)
    assert g.count() == 2
    assert g.slo_attainment() == pytest.approx(0.5)
    assert g.hit_rate() == pytest.approx(4 / 16)
    snap = g.snapshot()
    assert snap["window_completions"] == 2.0
    assert snap["window_ttft"] == pytest.approx(0.3)   # both ttft=0.3
    g.advance(11)          # step 0 falls out of the 10-step window
    assert g.count() == 1
    assert g.slo_attainment() == 0.0
    g.advance(40)
    assert g.count() == 0 and g.ttft() is None
    assert g.snapshot() == {"window_completions": 0.0}


def test_windowed_gauges_ignore_non_done():
    g = WindowedGauges()
    g.observe(Request(rid=0, s_in=4, s_out=2, arrival=0.0), 3)
    rej = Request(rid=1, s_in=4, s_out=2, arrival=0.0)
    rej.advance(RequestState.REJECTED, 0.0)
    g.observe(rej, 3)
    assert g.count() == 0 and g.slo_attainment() is None


# ---------------------------------------------------------------------------
# Chrome trace export + schema validator + Prometheus exposition
# ---------------------------------------------------------------------------


def _sim_with_recorder():
    rec = TraceRecorder()
    res = simulate_fleet(
        mixed_priority_workload(n=12, rate_rps=100.0, seed=7),
        num_replicas=2, slots_per_replica=2, max_prefill_batch=2,
        capacity=96, dt=0.05, queue_capacity=8, failures={3: 1},
        telemetry=rec)
    return res, rec


def test_chrome_trace_is_valid_and_flows_pair(tmp_path):
    res, rec = _sim_with_recorder()
    trace = chrome_trace(res.requests, dispatch_log=res.dispatch_log,
                         scale_events=res.scale_events, recorder=rec,
                         label="unit")
    assert validate_chrome_trace(trace) == []
    evs = trace["traceEvents"]
    # one track per replica: process metadata for router + replicas
    pnames = {e["args"]["name"] for e in evs if e["name"] == "process_name"}
    assert "router" in pnames and "replica:0" in pnames
    # φ→δ flow arrows pair start/finish per rid
    starts = {e["id"] for e in evs if e.get("ph") == "s"}
    finishes = {e["id"] for e in evs if e.get("ph") == "f"}
    assert starts and starts == finishes
    # the live bus contributed counter samples (queue depth etc.)
    assert any(e.get("ph") == "C" for e in evs)
    # round-trips through strict JSON
    from repro.serving.telemetry import dump_chrome_trace
    path = tmp_path / "trace.json"
    dump_chrome_trace(str(path), trace)
    assert validate_chrome_trace(json.loads(path.read_text())) == []


def test_validator_rejects_malformed_traces():
    assert validate_chrome_trace([]) == ["trace is empty"]
    assert validate_chrome_trace(42) == \
        ["trace must be a JSON object or array"]
    assert validate_chrome_trace({"foo": 1}) == \
        ["traceEvents: missing or not a list"]
    ok = {"name": "x", "ph": "X", "ts": 0.0, "dur": 1.0, "pid": 0}
    assert validate_chrome_trace([ok]) == []
    for bad in (dict(ok, ph="Z"),                  # unknown phase
                dict(ok, dur=-1.0),                # negative duration
                dict(ok, ts=float("inf")),         # non-finite ts
                {k: v for k, v in ok.items() if k != "pid"}):
        assert validate_chrome_trace([ok, bad]), bad
    # unmatched flow start
    flow = {"name": "f", "ph": "s", "ts": 0.0, "pid": 0, "id": 7}
    errs = validate_chrome_trace([ok, flow])
    assert any("unmatched" in e for e in errs)
    # metadata-only traces are not loadable timelines
    meta = {"name": "process_name", "ph": "M", "pid": 0,
            "args": {"name": "p"}}
    assert validate_chrome_trace([meta]) == \
        ["trace has only metadata events"]


def test_prometheus_text_exposition():
    res, _ = _sim_with_recorder()
    gauges = WindowedGauges()
    for req in res.requests:
        gauges.observe(req, 0)
    text = prometheus_text(res, gauges)
    assert "# HELP repro_p50_ttft" in text
    assert "# TYPE repro_p50_ttft gauge" in text
    for bucket in TTFT_BUCKETS:
        assert f'bucket="{bucket}"' in text
    assert 'repro_ttft_fraction{class="0",bucket="queue"}' in text
    assert "repro_window_completions" in text
    # non-finite aggregates render as exposition-format infinities
    class _Inf:
        def summary(self):
            return {"avg_ttft": float("inf")}
    assert "repro_avg_ttft +Inf" in prometheus_text(_Inf())


# ---------------------------------------------------------------------------
# Sim-vs-runtime span parity: kill + autoscale join on one seeded trace
# (the §14 parity contract; satellite 3)
# ---------------------------------------------------------------------------

PARITY_SPEC = FleetSpec(min_replicas=1, max_replicas=2, provision_steps=2,
                        warmup_steps=3, cold_window_steps=4, queue_high=0.5,
                        sustain_steps=2, cooldown_steps=4,
                        hysteresis_steps=8)
PARITY_KILL = {5: 0}


@pytest.fixture(scope="module")
def small_rt():
    cfg = ARCHS["qwen3-1.7b"].reduced()
    return cfg, init_params(KEY, cfg)


def test_sim_runtime_span_stream_parity(small_rt):
    """The same seeded mixed-priority trace — with a mid-trace replica
    kill AND an autoscale join — through the simulator and through real
    Coordinators: the derived span streams (event types, per-request
    ordering, step-quantized durations) must be EXACTLY equal."""
    cfg, params = small_rt

    def trace():
        return mixed_priority_workload(n=10, rate_rps=100.0, seed=7,
                                       vocab=min(cfg.vocab, 256),
                                       system_lens=(8, 6, 4),
                                       user_lens=(4, 6, 8),
                                       out_lens=(3, 5, 8))

    sim = simulate_fleet(trace(), num_replicas=1, slots_per_replica=2,
                         max_prefill_batch=2, capacity=96, dt=0.05,
                         queue_capacity=8, autoscale=PARITY_SPEC,
                         failures=PARITY_KILL)
    assert sim.scale_up_events >= 1          # the join must happen
    assert sim.counters["redispatched"] >= 1  # the kill must bite

    clock = StepClock()

    def factory(_slot):
        return CoordinatorReplica(
            Coordinator(cfg, params, num_decode_engines=1,
                        slots_per_engine=2, capacity=96,
                        num_prefill_engines=1,
                        prefix_cache_bytes=float("inf")),
            max_prefill_batch=2, clock=clock)

    router = Router([factory(0)], queue_capacity=8, clock=clock)
    ctrl = FleetController(router, factory, PARITY_SPEC, dt=0.05)
    rt = ctrl.run_trace(trace(), failures=PARITY_KILL)

    assert [(e.step, e.kind, e.replica) for e in ctrl.events] == \
        sim.scale_events
    assert router.counters == sim.counters
    sim_spans = span_stream(sim.requests, sim.dispatch_log)
    rt_spans = span_stream(rt.requests, router.dispatch_log)
    assert len(sim_spans) == len(rt_spans)
    assert sim_spans == rt_spans              # bitwise span parity
    # per-class attribution agrees too (same stamps, same arithmetic)
    assert rt.ttft_breakdown == sim.ttft_breakdown
    # and every served request's fractions partition to exactly 1
    for req in rt.requests:
        fr = req.ttft_fractions()
        if fr is not None:
            assert abs(sum(fr.values()) - 1.0) <= 1e-9


def test_router_gauges_feed_slo_floor_fallback():
    """With no WorkloadMonitor wired, the §13 ``slo_floor`` trigger
    reads the router's rolling-window SLO attainment — both domains
    feed it at the shared terminal sweep, keeping decisions in the
    parity surface."""
    res = simulate_fleet(
        mixed_priority_workload(n=12, rate_rps=100.0, seed=7,
                                slo_s=(0.01, 0.01, 0.01)),   # unmeetable
        num_replicas=1, slots_per_replica=2, max_prefill_batch=2,
        capacity=96, dt=0.05, queue_capacity=8,
        autoscale=FleetSpec(min_replicas=1, max_replicas=2,
                            provision_steps=2, warmup_steps=2,
                            cold_window_steps=2, queue_high=1e9,
                            slo_floor=0.99, sustain_steps=1,
                            cooldown_steps=4, hysteresis_steps=4))
    # the floor (not queue depth: queue_high is unreachable) triggered
    assert res.scale_up_events >= 1


# ---------------------------------------------------------------------------
# §15 satellites: bounded event bus, open-interval spans, decode_first
# ---------------------------------------------------------------------------


def test_trace_recorder_ring_bounds_and_counts_drops():
    rec = TraceRecorder(max_events=4)
    for i in range(7):
        rec.emit("tick", float(i))
    assert len(rec.events) == 4 and rec.dropped == 3
    # oldest evicted first: the retained window is the newest 4
    assert [e.ts for e in rec.events] == [3.0, 4.0, 5.0, 6.0]
    text = prometheus_text(ServeMetrics([], makespan=1.0, decode_tokens=0),
                           recorder=rec)
    assert "repro_trace_events_dropped 3" in text
    rec.clear()
    assert rec.dropped == 0 and len(rec.events) == 0
    # unbounded mode never drops
    rec2 = TraceRecorder(max_events=None)
    for i in range(10):
        rec2.emit("tick", float(i))
    assert rec2.dropped == 0 and len(rec2.events) == 10


def test_mid_decode_kill_yields_incomplete_open_span():
    """A request whose replica died mid-decode has no ``decode_end``;
    with ``trace_end`` the decode interval is closed there and flagged
    ``incomplete`` instead of silently truncating at transfer end."""
    req = Request(rid=3, s_in=8, s_out=4, arrival=0.0)
    req.advance(RequestState.PREFILLING, 0.1)
    req.advance(RequestState.KV_TRANSFER, 0.3)
    req.advance(RequestState.DECODING, 0.4)     # ... then the kill
    closed = request_spans(req)                  # parity default
    assert [s.name for s in closed] == ["queue", "prefill", "transfer"]
    spans = request_spans(req, trace_end=0.9)
    tail = spans[-1]
    assert tail.name == "decode" and tail.end == 0.9
    assert dict(tail.args)["incomplete"] is True
    # a never-dispatched request opens its queue interval the same way
    queued = Request(rid=4, s_in=8, s_out=4, arrival=0.2)
    [qs] = request_spans(queued, trace_end=0.9)
    assert qs.name == "queue" and (qs.start, qs.end) == (0.2, 0.9)
    assert dict(qs.args)["incomplete"] is True
    # and the rendered chrome trace stays schema-valid with open tails
    trace = chrome_trace([req, queued], trace_end=0.9)
    assert validate_chrome_trace(trace) == []
    assert any(ev.get("args", {}).get("incomplete")
               for ev in trace["traceEvents"])


def test_defer_first_token_populates_decode_first_bucket():
    """Async-handoff engines emit the first token a decode step after
    KV admission: the deferred-first-emission fixture must surface in
    the ``decode_first`` TTFT bucket, and the attribution must still
    partition to exactly 1."""
    def trace():
        return mixed_priority_workload(n=10, rate_rps=100.0, seed=7,
                                       out_lens=(3, 5, 8))

    deferred = simulate_fleet(trace(), num_replicas=1, slots_per_replica=2,
                              max_prefill_batch=2, capacity=96, dt=0.05,
                              queue_capacity=8, defer_first_token=True)
    served = [r for r in deferred.requests
              if r.phase is RequestState.DONE and r.tokens_out > 1]
    assert served and all(r.decode_first_s > 0.0 for r in served)
    bd = deferred.ttft_breakdown
    assert any(frac["decode_first"] > 0.0 for frac in bd.values())
    for frac in bd.values():
        assert sum(frac.values()) == pytest.approx(1.0, abs=1e-9)
    # the standard engine shape keeps the bucket at exactly zero
    sync = simulate_fleet(trace(), num_replicas=1, slots_per_replica=2,
                          max_prefill_batch=2, capacity=96, dt=0.05,
                          queue_capacity=8)
    assert all(r.decode_first_s == 0.0 for r in sync.requests)
    assert all(frac["decode_first"] == 0.0
               for frac in sync.ttft_breakdown.values())
