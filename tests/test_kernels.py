"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import gqa_decode_bhsd
from repro.kernels.flash_attention import flash_attention_bhsd

KEY = jax.random.PRNGKey(42)


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


FLASH_CASES = [
    # (b, hq, hkv, s, hd, causal, window)
    (1, 2, 2, 128, 64, True, 0),
    (2, 4, 2, 256, 64, True, 0),       # GQA group 2
    (1, 8, 1, 256, 128, True, 0),      # MQA
    (2, 4, 4, 384, 64, False, 0),      # non-causal (encoder)
    (1, 4, 2, 512, 64, True, 256),     # sliding window
    (1, 2, 2, 256, 96, True, 0),       # non-pow2 head dim
]


@pytest.mark.parametrize("b,hq,hkv,s,hd,causal,window", FLASH_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_matches_ref(b, hq, hkv, s, hd, causal, window,
                                     dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (b, hq, s, hd), dtype)
    k = _rand(k2, (b, hkv, s, hd), dtype)
    v = _rand(k3, (b, hkv, s, hd), dtype)
    out = flash_attention_bhsd(q, k, v, causal=causal, window=window,
                               interpret=True)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("block_q,block_k", [(64, 64), (128, 64), (64, 128)])
def test_flash_attention_block_shapes(block_q, block_k):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (1, 4, 256, 64), jnp.float32)
    k = _rand(k2, (1, 2, 256, 64), jnp.float32)
    v = _rand(k3, (1, 2, 256, 64), jnp.float32)
    out = flash_attention_bhsd(q, k, v, block_q=block_q, block_k=block_k,
                               interpret=True)
    expect = ref.flash_attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5, rtol=2e-5)


DECODE_CASES = [
    # (b, hq, hkv, s, hd)
    (1, 4, 4, 512, 64),
    (2, 8, 2, 1024, 64),
    (4, 4, 1, 512, 128),
    (1, 16, 2, 2048, 64),
    (3, 4, 2, 1536, 96),
]


@pytest.mark.parametrize("b,hq,hkv,s,hd", DECODE_CASES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_matches_ref(b, hq, hkv, s, hd, dtype):
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    q = _rand(k1, (b, hq, hd), dtype)
    kc = _rand(k2, (b, hkv, s, hd), dtype)
    vc = _rand(k3, (b, hkv, s, hd), dtype)
    vl = jax.random.randint(k4, (b,), 1, s + 1)
    out = gqa_decode_bhsd(q, kc, vc, vl, interpret=True)
    expect = ref.gqa_decode_ref(q, kc, vc, vl)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32),
                               atol=tol, rtol=tol)


def test_decode_attention_masks_invalid_slots():
    """Changing cache contents past valid_len must not change output."""
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = _rand(k1, (2, 4, 64), jnp.float32)
    kc = _rand(k2, (2, 2, 512, 64), jnp.float32)
    vc = _rand(k3, (2, 2, 512, 64), jnp.float32)
    vl = jnp.array([100, 200])
    out1 = gqa_decode_bhsd(q, kc, vc, vl, interpret=True)
    kc2 = kc.at[:, :, 300:].set(99.0)
    vc2 = vc.at[:, :, 300:].set(-99.0)
    out2 = gqa_decode_bhsd(q, kc2, vc2, vl, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2))


def test_ops_wrappers_model_layout():
    """ops.* accept [B,S,H,hd] model layout and match the attention refs."""
    import os
    os.environ["REPRO_FORCE_PALLAS"] = "interpret"
    try:
        from repro.kernels import ops
        from repro.models import attention as mattn
        k1, k2, k3 = jax.random.split(KEY, 3)
        q = _rand(k1, (2, 256, 4, 64), jnp.float32)
        k = _rand(k2, (2, 256, 2, 64), jnp.float32)
        v = _rand(k3, (2, 256, 2, 64), jnp.float32)
        out = ops.flash_attention(q, k, v, causal=True)
        expect = mattn.full_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   atol=2e-5, rtol=2e-5)
    finally:
        os.environ.pop("REPRO_FORCE_PALLAS", None)


@pytest.mark.parametrize("kernel", ["flash", "decode"])
def test_kernels_aot_lower_for_tpu_target(kernel):
    """The kernels must lower to real TPU Mosaic custom-calls via the AOT
    cross-lowering API (the container is CPU-only; this proves the TPU
    artifact is valid without hardware)."""
    import functools
    from repro.kernels.flash_attention import flash_attention_bhsd
    from repro.kernels.decode_attention import gqa_decode_bhsd
    if kernel == "flash":
        q = jax.ShapeDtypeStruct((1, 4, 512, 128), jnp.bfloat16)
        kv = jax.ShapeDtypeStruct((1, 2, 512, 128), jnp.bfloat16)
        tr = jax.jit(functools.partial(flash_attention_bhsd,
                                       causal=True)).trace(q, kv, kv)
    else:
        qd = jax.ShapeDtypeStruct((4, 16, 128), jnp.bfloat16)
        cache = jax.ShapeDtypeStruct((4, 2, 4096, 128), jnp.bfloat16)
        vl = jax.ShapeDtypeStruct((4,), jnp.int32)
        tr = jax.jit(gqa_decode_bhsd).trace(qd, cache, cache, vl)
    txt = tr.lower(lowering_platforms=("tpu",)).as_text()
    assert "tpu_custom_call" in txt
