"""Elastic fleet tier (DESIGN.md §13): replica lifecycle and warm-up
pricing, scale-to-demand with hysteresis, the last-replica
``FleetExhausted`` guard, auto-derived aging rate, the EWMA demand
estimator, capacity-drift max-flow re-solve, and exact sim-vs-runtime
parity of the controller's decisions."""
import jax
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.core import (LLAMA2_70B, WORKLOADS, WorkloadMonitor,
                        grow_cluster, reschedule_capacity, schedule,
                        warmup_steps, weight_load_time)
from repro.core.cluster import A100, A6000, H100, PAPER_SETTINGS
from repro.models import init_params
from repro.serving import (Coordinator, CoordinatorReplica, FleetController,
                           FleetExhausted, FleetSpec, ReplicaState, Request,
                           Router, SimReplica, StepClock,
                           mixed_priority_workload, simulate_fleet,
                           surge_workload)
from repro.serving.metrics import METRIC_FIELDS, ServeMetrics
from repro.serving.router import AdmissionQueue, _QEntry

KEY = jax.random.PRNGKey(5)

SPEC = FleetSpec(min_replicas=1, max_replicas=4, provision_steps=4,
                 warmup_steps=8, cold_window_steps=6, queue_high=1.0,
                 queue_low=0.25, sustain_steps=3, cooldown_steps=10,
                 hysteresis_steps=40)


def _surge(n=160, seed=3):
    return surge_workload(n, 3.0, seed=seed)


def _flat(n, s_out=4):
    return [Request(rid=i, s_in=4, s_out=s_out, arrival=0.0)
            for i in range(n)]


# ---------------------------------------------------------------------------
# Warm-up pricing (cost model) and cluster growth
# ---------------------------------------------------------------------------


def test_weight_load_time_orders_by_host_link():
    """The §13 warm-up price is bytes-of-params over the device's host
    link: faster links warm faster, sharding divides the load."""
    t = {g.name: weight_load_time(LLAMA2_70B, g)
         for g in (H100, A100, A6000)}
    assert t["H100"] < t["A100"] < t["A6000"]
    assert weight_load_time(LLAMA2_70B, A100, parallel=4) == \
        pytest.approx(t["A100"] / 4)
    # a mixed pod warms at its SLOWEST link (tensor shards rendezvous)
    assert weight_load_time(LLAMA2_70B, [H100, A6000]) == \
        pytest.approx(weight_load_time(LLAMA2_70B, [A6000, A6000]))


def test_warmup_steps_quantizes_up_and_never_zero():
    s = warmup_steps(LLAMA2_70B, A100, dt=0.05)
    assert s >= 1 and s * 0.05 >= weight_load_time(LLAMA2_70B, A100)
    # even an instant load costs one router step
    assert warmup_steps(LLAMA2_70B, H100, dt=1e9) == 1


def test_grow_cluster_preserves_existing_devices():
    cl = PAPER_SETTINGS["hetero1"]()
    grown, new = grow_cluster(cl, [("A100", 2)])
    assert grown.num_devices == cl.num_devices + 2
    assert new == [cl.num_devices, cl.num_devices + 1]
    for i in range(cl.num_devices):
        assert grown.devices[i].gpu.name == cl.devices[i].gpu.name
        for j in range(cl.num_devices):
            assert grown.bandwidth[i][j] == cl.bandwidth[i][j]
    for d in new:
        assert grown.devices[d].gpu.name == "A100"


def test_reschedule_capacity_resolves_and_shifts_routes():
    """A replica join re-solves max-flow: the joining devices get typed
    (prefill or decode) and the φ→δ route set genuinely shifts."""
    cl = PAPER_SETTINGS["hetero1"]()
    wl = WORKLOADS["LPHD"]
    base = schedule(cl, LLAMA2_70B, wl, max_refine_iters=2)
    grown, new = grow_cluster(cl, [("A100", 4)])
    cap = reschedule_capacity(grown, LLAMA2_70B, base, wl, new,
                              max_refine_iters=2)
    assert cap.placement.max_flow > 0
    assert len(cap.partition.groups) > len(base.partition.groups)
    covered = sorted(d for g in cap.partition.groups for d in g)
    assert covered == list(range(grown.num_devices))
    assert dict(cap.placement.kv_routes) != dict(base.placement.kv_routes)
    with pytest.raises(AssertionError):
        # joining devices must be NEW capacity, not already-placed ones
        reschedule_capacity(grown, LLAMA2_70B, base, wl, [0, 1],
                            max_refine_iters=2)


# ---------------------------------------------------------------------------
# Last-replica guard (Router.kill / Router.drain)
# ---------------------------------------------------------------------------


def _one_replica_router(**kw):
    clock = StepClock()
    rep = SimReplica(num_slots=2, max_prefill_batch=2, clock=clock)
    return Router([rep], queue_capacity=8, clock=clock, **kw), clock


def test_kill_last_live_replica_raises_fleet_exhausted():
    router, _ = _one_replica_router()
    for life in _flat(2):
        router.submit(life)
    with pytest.raises(FleetExhausted) as ei:
        router.kill(0)
    assert (ei.value.idx, ei.value.unfinished) == (0, 2)
    assert router.replicas[0].alive          # refused, nothing changed
    while router.unfinished:
        router.step()
    router.kill(0)                           # idle fleet: retirement is fine


def test_drain_last_live_replica_raises_fleet_exhausted():
    router, _ = _one_replica_router()
    router.submit(_flat(1)[0])
    with pytest.raises(FleetExhausted):
        router.drain(0)


def test_kill_last_replica_parks_when_capacity_joining():
    """With a join in flight (capacity_hook), killing the last replica
    parks the drained work in the queue; it completes once the new
    replica spawns — full conservation across the gap."""
    router, clock = _one_replica_router()
    router.capacity_hook = lambda: True
    for life in _flat(4):
        router.submit(life)
    router.step()
    moved = router.kill(0)
    assert moved                             # in-flight work was parked
    assert router.unfinished == 4
    router.spawn(SimReplica(num_slots=2, max_prefill_batch=2, clock=clock))
    while router.unfinished:
        router.step()
    assert router.counters["admitted"] == 4
    assert all(life.phase.value == "done"
               for _, _, life in router.results())


# ---------------------------------------------------------------------------
# Auto-derived aging rate (satellite of §13)
# ---------------------------------------------------------------------------


def test_auto_age_every_tracks_overtaking_rate():
    q = AdmissionQueue(capacity=64, age_every="auto")
    assert q.age_every == 8                  # default until observed
    # 2 interactive arrivals per step for 16 steps -> rate_hi = 2,
    # promotion every step
    for s in range(16):
        q.observe_arrival(0, s)
        q.observe_arrival(0, s)
        q.observe_arrival(2, s)
    assert q.age_every == 1
    # sparse urgent traffic: one interactive every 8 steps -> ~8
    q2 = AdmissionQueue(capacity=64, age_every="auto")
    for s in range(0, 128, 8):
        q2.observe_arrival(0, s)
        q2.observe_arrival(2, s + 1)
    assert 6 <= q2.age_every <= 10
    # nothing can overtake a single class: age as slowly as allowed
    q3 = AdmissionQueue(capacity=64, age_every="auto", auto_cap=64)
    for s in range(32):
        q3.observe_arrival(1, s)
    assert q3.age_every == 64


def test_auto_aging_preserves_starvation_bound():
    """The §12 provable bound, re-checked under a DERIVED rate: if a
    class-p entry pops while class-q (q < p) still waits, the popped
    one waited >= age_every * (p - q) with the rate in effect at pop
    time."""
    q = AdmissionQueue(capacity=512, age_every="auto")
    seq = 0
    q.observe_arrival(2, 0)
    q.push(_QEntry(Request(rid=0, s_in=1, s_out=1, arrival=0.0,
                           priority=2), seq, 0))
    seq += 1
    rid = 1
    for step in range(1, 40):
        q.observe_arrival(0, step)
        q.push(_QEntry(Request(rid=rid, s_in=1, s_out=1, arrival=0.0,
                               priority=0), seq, step))
        rid += 1
        seq += 1
        e = q.pop(step)
        if e.life.priority == 2:
            waited = step - e.enqueue_step
            assert waited >= q.age_every * 2
            break
    else:
        pytest.fail("aged batch entry never popped")


# ---------------------------------------------------------------------------
# EWMA completion-time estimator (satellite of §13)
# ---------------------------------------------------------------------------


def _req(rid, s_out, tokens_out=None, latency=None, slo=None):
    r = Request(rid=rid, s_in=8, s_out=s_out, arrival=0.0,
                priority=0, slo_target_s=slo)
    r.tokens_out = tokens_out
    if latency is not None:
        r.decode_end = latency
    return r


def test_ewma_estimator_learns_from_completions():
    mon = WorkloadMonitor(WORKLOADS["LPLD"], estimator="ewma",
                          ewma_alpha=0.5)
    assert mon.estimated_s_out == WORKLOADS["LPLD"].s_out
    mon.observe_completion(_req(0, s_out=40, tokens_out=40))
    assert mon.estimated_s_out == 40
    mon.observe_completion(_req(1, s_out=99, tokens_out=20))
    assert mon.estimated_s_out == pytest.approx(30.0)   # truncation counts
    # arrivals under "ewma" record the ESTIMATE, not the oracle length
    mon.observe(_req(2, s_out=10 ** 6))
    assert max(mon._s_out) < 100


def test_oracle_estimator_still_reads_arrival_lengths():
    mon = WorkloadMonitor(WORKLOADS["LPLD"])
    mon.observe(_req(0, s_out=123))
    assert 123 in mon._s_out


def test_monitor_demand_signals():
    mon = WorkloadMonitor(WORKLOADS["LPLD"], estimator="ewma")
    for s in range(32):
        mon.observe(_req(s, s_out=8), step=s)
        if s % 4 == 0:
            mon.observe(Request(rid=100 + s, s_in=4, s_out=4, arrival=0.0,
                                priority=2), step=s)
    assert mon.arrival_rate(31, window_steps=16) > 1.0
    rates = mon.rates_by_class(31, window_steps=16)
    assert rates[0] > rates[2] > 0
    assert mon.recent_slo_attainment() is None
    mon.observe_completion(_req(0, s_out=8, tokens_out=8, latency=1.0,
                                slo=2.0))
    mon.observe_completion(_req(1, s_out=8, tokens_out=8, latency=9.0,
                                slo=2.0))
    assert mon.recent_slo_attainment() == 0.5


# ---------------------------------------------------------------------------
# FleetController: lifecycle, scale-to-demand, hysteresis, accounting
# ---------------------------------------------------------------------------


def test_elastic_scales_up_through_lifecycle_and_back_down():
    res = simulate_fleet(_surge(), num_replicas=1, dt=0.05, autoscale=SPEC)
    assert res.scale_up_events >= 1 and res.scale_down_events >= 1
    by_kind = {}
    for step, kind, rep in res.scale_events:
        by_kind.setdefault((rep, kind), step)
    up = by_kind[(1, "scale_up")]
    live = by_kind[(1, "live")]
    # PROVISIONING then WARMING complete before the replica joins
    assert live - up >= SPEC.provision_steps + SPEC.warmup_steps
    assert by_kind[(1, "scale_down")] > live
    assert by_kind[(1, "dead")] > by_kind[(1, "scale_down")]
    st = res.replica_steps_by_state
    assert st["provisioning"] >= SPEC.provision_steps
    assert st["warming"] >= SPEC.warmup_steps
    assert st["live"] > st["warming"]
    assert all(r.phase.value == "done" for r in res.requests)


def test_elastic_beats_static_small_at_fraction_of_peak_cost():
    small = simulate_fleet(_surge(), num_replicas=1, dt=0.05)
    peak = simulate_fleet(_surge(), num_replicas=4, dt=0.05)
    el = simulate_fleet(_surge(), num_replicas=1, dt=0.05, autoscale=SPEC)
    assert el.slo_attainment_stated > small.slo_attainment_stated
    assert (sum(el.replica_steps_by_state.values())
            < sum(peak.replica_steps_by_state.values()))


def test_hysteresis_no_scale_up_shadowing_a_scale_down():
    res = simulate_fleet(_surge(), num_replicas=1, dt=0.05, autoscale=SPEC)
    downs = [s for s, k, _ in res.scale_events if k == "scale_down"]
    ups = [s for s, k, _ in res.scale_events if k == "scale_up"]
    for d in downs:
        assert not any(d < u < d + SPEC.hysteresis_steps for u in ups)


def test_cold_window_stamps_warmup_ttft_penalty():
    el = simulate_fleet(_surge(), num_replicas=1, dt=0.05, autoscale=SPEC)
    cold = [r for r in el.requests if r.warmup_penalty_s > 0]
    assert cold, "burst dispatches into the cold window must be stamped"
    assert el.warmup_ttft_penalty_s == pytest.approx(
        sum(r.warmup_penalty_s for r in el.requests))
    assert max(r.warmup_penalty_s for r in cold) <= \
        SPEC.cold_window_steps * 0.05 + 1e-9
    nocold = simulate_fleet(
        _surge(), num_replicas=1, dt=0.05,
        autoscale=FleetSpec(**{**SPEC.__dict__, "cold_window_steps": 0}))
    assert nocold.warmup_ttft_penalty_s == 0.0


def test_elastic_run_is_deterministic():
    a = simulate_fleet(_surge(), num_replicas=1, dt=0.05, autoscale=SPEC)
    b = simulate_fleet(_surge(), num_replicas=1, dt=0.05, autoscale=SPEC)
    assert a.scale_events == b.scale_events
    assert a.replica_steps_by_state == b.replica_steps_by_state
    assert a.summary() == b.summary()


def test_fleet_repairs_to_min_replicas_after_external_kill():
    """Failover meets elasticity: the seed replica dies mid-trace; the
    controller re-provisions to the min_replicas floor (bypassing
    dampers — healing is not flapping) and the trace completes."""
    spec = FleetSpec(min_replicas=1, max_replicas=2, provision_steps=2,
                     warmup_steps=3, sustain_steps=10 ** 6,
                     cooldown_steps=10 ** 6, hysteresis_steps=10 ** 6)
    trace = mixed_priority_workload(n=12, rate_rps=30.0, seed=2)
    res = simulate_fleet(trace, num_replicas=1, dt=0.05, autoscale=spec,
                         failures={3: 0})
    kinds = [k for _, k, _ in res.scale_events]
    assert "scale_up" in kinds and "live" in kinds and "dead" in kinds
    assert all(r.phase.value == "done" for r in res.requests)
    assert any(r.redispatches for r in res.requests)


def test_monitor_slo_floor_triggers_scale_up():
    """The WorkloadMonitor's attainment signal is a second up-trigger:
    even with queue_high unreachable, missed stated SLOs scale the
    fleet."""
    spec = FleetSpec(min_replicas=1, max_replicas=3, provision_steps=2,
                     warmup_steps=3, queue_high=10 ** 9, slo_floor=0.95,
                     sustain_steps=3, cooldown_steps=8,
                     hysteresis_steps=16)
    mon = WorkloadMonitor(WORKLOADS["LPLD"], estimator="ewma")
    res = simulate_fleet(_surge(), num_replicas=1, dt=0.05, autoscale=spec,
                         monitor=mon)
    assert res.scale_up_events >= 1
    assert mon.completions > 0


# ---------------------------------------------------------------------------
# Metrics schema (§8 contract extended by §13)
# ---------------------------------------------------------------------------


def test_metric_fields_cover_elastic_and_static_fleets():
    el = simulate_fleet(_surge(60, seed=5), num_replicas=1, dt=0.05,
                        autoscale=SPEC)
    static = simulate_fleet(_surge(60, seed=5), num_replicas=2, dt=0.05)
    for res in (el, static):
        for f in METRIC_FIELDS:
            assert hasattr(res, f), f
        assert all(np.isfinite(v) for v in res.summary().values())
    # static fleets still report their replica-step cost denominator
    assert static.replica_steps_by_state["live"] > 0
    # on a static fleet the elastic scalars are exactly the bare
    # ServeMetrics defaults — summary parity with the §8 schema
    bare = ServeMetrics(static.requests, static.makespan,
                        static.decode_tokens)
    assert static.summary() == bare.summary()


# ---------------------------------------------------------------------------
# Sim-vs-runtime parity of controller decisions (the §13 contract)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_rt():
    cfg = ARCHS["qwen3-1.7b"].reduced()
    return cfg, init_params(KEY, cfg)


def test_sim_runtime_elastic_parity(small_rt):
    """The same seeded burst through SimReplicas and through real
    Coordinators, both under FleetControllers with the same spec: scale
    events, per-state replica-step totals, and conservation counters
    must agree EXACTLY."""
    cfg, params = small_rt
    spec = FleetSpec(min_replicas=1, max_replicas=2, provision_steps=2,
                     warmup_steps=3, cold_window_steps=4, queue_high=0.5,
                     sustain_steps=2, cooldown_steps=4, hysteresis_steps=8)

    def trace():
        return mixed_priority_workload(n=10, rate_rps=100.0, seed=7,
                                       vocab=min(cfg.vocab, 256),
                                       system_lens=(8, 6, 4),
                                       user_lens=(4, 6, 8),
                                       out_lens=(3, 5, 8))

    sim = simulate_fleet(trace(), num_replicas=1, slots_per_replica=2,
                         max_prefill_batch=2, capacity=96, dt=0.05,
                         queue_capacity=8, autoscale=spec)
    assert sim.scale_up_events >= 1      # the burst must exercise §13

    clock = StepClock()

    def factory(_slot):
        return CoordinatorReplica(
            Coordinator(cfg, params, num_decode_engines=1,
                        slots_per_engine=2, capacity=96,
                        num_prefill_engines=1,
                        prefix_cache_bytes=float("inf")),
            max_prefill_batch=2, clock=clock)

    router = Router([factory(0)], queue_capacity=8, clock=clock)
    ctrl = FleetController(router, factory, spec, dt=0.05)
    rt = ctrl.run_trace(trace())

    assert [(e.step, e.kind, e.replica) for e in ctrl.events] == \
        sim.scale_events
    assert dict(ctrl.replica_steps_by_state) == sim.replica_steps_by_state
    assert router.counters == sim.counters
    assert rt.warmup_ttft_penalty_s == sim.warmup_ttft_penalty_s
    # both on the shared virtual clock: per-class timing agrees too
    # (kv_bytes are excluded — SimReplica doesn't model the runtime's
    # intra-replica handoff bytes, same as the §12 parity test)
    assert rt.avg_ttft_by_class == sim.avg_ttft_by_class
    assert rt.slo_attainment_by_class == sim.slo_attainment_by_class
    assert rt.makespan == sim.makespan
