"""End-to-end system behaviour: schedule a paper cluster → simulate the
four paper workloads → verify the paper's qualitative claims hold in our
reproduction (speedups over baselines, scheduler behaviours §5.2)."""
import numpy as np
import pytest

from repro.core import (LLAMA2_70B, OPT_30B, WORKLOADS, colocated_throughput,
                        schedule)
from repro.core.cluster import (heterogeneous_setting_1,
                                heterogeneous_setting_4,
                                homogeneous_setting)
from repro.serving import offline_workload, simulate, simulate_colocated


@pytest.fixture(scope="module")
def hetero1():
    return heterogeneous_setting_1()


@pytest.mark.parametrize("wl_name", ["HPLD", "HPHD", "LPHD", "LPLD"])
def test_hexgen2_serves_all_paper_workloads(hetero1, wl_name):
    res = schedule(hetero1, LLAMA2_70B, WORKLOADS[wl_name],
                   max_refine_iters=6)
    reqs = offline_workload(wl_name, 40, seed=0)
    sim = simulate(hetero1, LLAMA2_70B, res.placement, reqs)
    assert sim.decode_throughput > 0
    assert all(r.decode_end is not None for r in sim.requests)


def test_hexgen2_beats_colocated_average(hetero1):
    """Paper: HexGen-2 averages ~1.4x over colocated HexGen. We assert a
    conservative >1.1x average across workloads in simulation."""
    ratios = []
    for wl_name in ("HPLD", "HPHD", "LPHD", "LPLD"):
        res = schedule(hetero1, LLAMA2_70B, WORKLOADS[wl_name],
                       max_refine_iters=6)
        dis = simulate(hetero1, LLAMA2_70B, res.placement,
                       offline_workload(wl_name, 40, seed=1))
        col = simulate_colocated(hetero1, LLAMA2_70B, res.placement.replicas,
                                 offline_workload(wl_name, 40, seed=1))
        ratios.append(dis.decode_throughput / max(col.decode_throughput,
                                                  1e-9))
    assert np.mean(ratios) > 1.1, ratios


def test_scheduler_prefers_tp_for_prefill(hetero1):
    """Paper §5.2 finding (1): prefill replicas lean on TP (latency-
    optimal); decode replicas use hybrid/deeper-batch plans."""
    res = schedule(hetero1, LLAMA2_70B, WORKLOADS["HPHD"],
                   max_refine_iters=6)
    pref_tp = [max(r.plan.tp_degrees) for r in
               res.placement.prefill_replicas() if r.plan]
    assert pref_tp and max(pref_tp) >= 2


def test_smaller_model_gets_more_replicas(hetero1):
    r30 = schedule(hetero1, OPT_30B, WORKLOADS["HPHD"], max_refine_iters=4)
    r70 = schedule(hetero1, LLAMA2_70B, WORKLOADS["HPHD"],
                   max_refine_iters=4)
    assert len(r30.placement.replicas) >= len(r70.placement.replicas)


def test_homogeneous_setting_works_too():
    cl = homogeneous_setting()
    res = schedule(cl, OPT_30B, WORKLOADS["LPLD"], max_refine_iters=4)
    sim = simulate(cl, OPT_30B, res.placement,
                   offline_workload("LPLD", 30, seed=2))
    assert sim.decode_throughput > 0
