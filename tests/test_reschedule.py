"""Online rescheduling: drift monitor, warm-start refinement, mid-trace
placement swap in the simulator, coordinator rebalance."""
import numpy as np
import pytest

from repro.core import (LLAMA2_70B, WORKLOADS, WorkloadMonitor, reschedule,
                        schedule, solve_flow)
from repro.core.cluster import heterogeneous_setting_1
from repro.serving import (TracePhase, drifting_workload, observed_workload,
                           simulate, simulate_online)


@pytest.fixture(scope="module")
def hetero():
    return heterogeneous_setting_1()


@pytest.fixture(scope="module")
def sched_hpld(hetero):
    return schedule(hetero, LLAMA2_70B, WORKLOADS["HPLD"], max_refine_iters=6)


# -- WorkloadMonitor --------------------------------------------------------


def test_monitor_no_drift_on_baseline_mix():
    wl = WORKLOADS["HPLD"]
    mon = WorkloadMonitor(wl, window=32, threshold=0.3, min_observations=16)
    rng = np.random.default_rng(0)
    for _ in range(40):
        mon.observe(int(wl.s_in * rng.uniform(0.9, 1.1)),
                    int(wl.s_out * rng.uniform(0.9, 1.1)))
    assert not mon.drifted()
    assert mon.drift() < 0.3


def test_monitor_detects_drift_and_rebases():
    wl = WORKLOADS["HPLD"]   # s_in=1024, s_out=64
    mon = WorkloadMonitor(wl, window=32, threshold=0.3, min_observations=16)
    lphd = WORKLOADS["LPHD"]  # s_in=256, s_out=256
    for _ in range(32):
        mon.observe(lphd.s_in, lphd.s_out)
    assert mon.drifted()
    snap = mon.snapshot()
    assert snap.s_in == lphd.s_in and snap.s_out == lphd.s_out
    mon.rebase(snap)
    assert mon.n == 0 and not mon.drifted()


def test_monitor_needs_min_observations():
    mon = WorkloadMonitor(WORKLOADS["HPLD"], min_observations=16)
    for _ in range(8):
        mon.observe(64, 512)   # wildly drifted, but too few samples
    assert mon.drift() > 0.3 and not mon.drifted()


# -- warm-start reschedule --------------------------------------------------


def test_reschedule_warm_start_improves_on_stale_placement(hetero,
                                                           sched_hpld):
    new_wl = WORKLOADS["LPHD"]
    stale = solve_flow(hetero, LLAMA2_70B, sched_hpld.partition, new_wl)
    res = reschedule(hetero, LLAMA2_70B, sched_hpld, new_wl,
                     max_refine_iters=8)
    # refinement starts from the stale partition: never worse, and the
    # HPLD->LPHD shift leaves enough slack that it should strictly gain
    assert res.placement.max_flow >= stale.placement.max_flow - 1e-6
    res.partition.validate(hetero.num_devices)
    assert res.placement.prefill_replicas() and res.placement.decode_replicas()
    assert res.trace[0].action == "initial"


def test_reschedule_same_workload_is_stable(hetero, sched_hpld):
    res = reschedule(hetero, LLAMA2_70B, sched_hpld, WORKLOADS["HPLD"],
                     max_refine_iters=4)
    assert res.placement.max_flow >= sched_hpld.placement.max_flow * 0.99


# -- drifting traces --------------------------------------------------------


def test_drifting_workload_phases():
    phases = [TracePhase(100.0, 2.0, {"HPLD": 1.0}),
              TracePhase(100.0, 4.0, {"LPHD": 1.0})]
    reqs = drifting_workload(phases, seed=0)
    a = [r for r in reqs if r.arrival < 100.0]
    b = [r for r in reqs if r.arrival >= 100.0]
    assert a and b
    assert all(r.arrival < 200.0 for r in reqs)
    assert all(r.is_heavy_prefill and not r.is_heavy_decode for r in a)
    assert all(not r.is_heavy_prefill and r.is_heavy_decode for r in b)
    # rids are unique and ordered by arrival
    assert [r.rid for r in reqs] == list(range(len(reqs)))


def test_observed_workload_fits_means():
    reqs = drifting_workload([TracePhase(50.0, 4.0, {"LPHD": 1.0})], seed=1)
    wl = observed_workload(reqs)
    assert wl.s_in == int(np.mean([r.s_in for r in reqs]))
    assert wl.s_out == int(np.mean([r.s_out for r in reqs]))


# -- simulator swap ---------------------------------------------------------


@pytest.fixture(scope="module")
def drifted_trace():
    phases = [TracePhase(100.0, 2.0, {"HPLD": 1.0}),
              TracePhase(200.0, 6.0, {"LPHD": 1.0})]
    return phases


def test_simulate_online_no_monitor_matches_simulate(hetero, sched_hpld,
                                                     drifted_trace):
    r1 = drifting_workload(drifted_trace, seed=5)
    r2 = drifting_workload(drifted_trace, seed=5)
    base = simulate(hetero, LLAMA2_70B, sched_hpld.placement, r1)
    on = simulate_online(hetero, LLAMA2_70B, sched_hpld.placement, r2)
    assert on.reschedules == []
    assert on.decode_tokens == base.decode_tokens
    assert on.makespan == pytest.approx(base.makespan)


def test_simulate_online_swap_completes_every_request(hetero, sched_hpld,
                                                      drifted_trace):
    reqs = drifting_workload(drifted_trace, seed=5)
    mon = WorkloadMonitor(WORKLOADS["HPLD"], window=48, threshold=0.3,
                          min_observations=24)

    def rescheduler(wl):
        return reschedule(hetero, LLAMA2_70B, sched_hpld, wl,
                          max_refine_iters=6).placement

    on = simulate_online(hetero, LLAMA2_70B, sched_hpld.placement, reqs,
                         monitor=mon, rescheduler=rescheduler,
                         min_gap_s=60.0)
    assert on.reschedules, "drifted trace must trigger a reschedule"
    # no token lost or double-counted across the swap
    assert on.decode_tokens == sum(r.s_out for r in reqs)
    assert all(r.decode_end is not None for r in on.requests)
    for ev in on.reschedules:
        assert ev.drain_s >= 0.0 and ev.max_flow > 0


def test_simulate_online_beats_static_under_drift(hetero, sched_hpld,
                                                  drifted_trace):
    r1 = drifting_workload(drifted_trace, seed=5)
    r2 = drifting_workload(drifted_trace, seed=5)
    stat = simulate(hetero, LLAMA2_70B, sched_hpld.placement, r1)
    mon = WorkloadMonitor(WORKLOADS["HPLD"], window=48, threshold=0.3,
                          min_observations=24)

    def rescheduler(wl):
        return reschedule(hetero, LLAMA2_70B, sched_hpld, wl,
                          max_refine_iters=6).placement

    on = simulate_online(hetero, LLAMA2_70B, sched_hpld.placement, r2,
                         monitor=mon, rescheduler=rescheduler,
                         min_gap_s=60.0)
    assert on.decode_throughput >= stat.decode_throughput


# -- coordinator rebalance --------------------------------------------------


def test_coordinator_rebalance_from_flow_assignment(hetero, sched_hpld):
    import jax
    from repro.configs import ARCHS
    from repro.models import init_params
    from repro.serving import Coordinator

    cfg = ARCHS["qwen3-1.7b"].reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    n_dec = len(sched_hpld.placement.decode_replicas())
    coord = Coordinator(cfg, params, num_decode_engines=max(n_dec, 1),
                        slots_per_engine=2, capacity=32)
    w = coord.apply_flow_assignment(sched_hpld.placement)
    assert w.shape == (max(n_dec, 1),)
    assert w.sum() == pytest.approx(1.0)
    # weights follow the flow into each decode group
    per_group = {}
    for (_, did), f in sched_hpld.placement.kv_routes.items():
        per_group[did] = per_group.get(did, 0.0) + f
    flows = [per_group.get(g, 0.0) for g in
             sorted(r.group_id for r in sched_hpld.placement.decode_replicas())]
    expect = np.array(flows) / sum(flows)
    np.testing.assert_allclose(np.asarray(w), expect, atol=1e-6)


def test_coordinator_update_route_weights_validates():
    import jax
    from repro.configs import ARCHS
    from repro.models import init_params
    from repro.serving import Coordinator

    cfg = ARCHS["qwen3-1.7b"].reduced()
    params = init_params(jax.random.PRNGKey(0), cfg)
    coord = Coordinator(cfg, params, num_decode_engines=2,
                        slots_per_engine=2, capacity=32)
    coord._routed[:] = [5.0, 1.0]
    coord.update_route_weights([3.0, 1.0], reset_counts=True)
    np.testing.assert_allclose(coord._weights, [0.75, 0.25])
    assert coord._routed.sum() == 0.0
    with pytest.raises(AssertionError):
        coord.update_route_weights([1.0])   # wrong engine count
