"""Preflow-push max-flow vs the networkx oracle (+ hypothesis graphs)."""
import numpy as np
import pytest

nx = pytest.importorskip("networkx")  # oracle for flow comparisons
pytest.importorskip("hypothesis")  # property tests need the optional dep
from hypothesis import given, settings, strategies as st

from repro.core.maxflow import FlowNetwork


def _to_nx(net: FlowNetwork) -> nx.DiGraph:
    g = nx.DiGraph()
    for (u, v), c in net.capacity.items():
        if c > 0:
            g.add_edge(u, v, capacity=c)
    return g


def test_simple_diamond():
    net = FlowNetwork()
    net.add_edge("s", "a", 3.0)
    net.add_edge("s", "b", 2.0)
    net.add_edge("a", "t", 2.0)
    net.add_edge("b", "t", 3.0)
    net.add_edge("a", "b", 1.0)
    res = net.preflow_push("s", "t")
    assert res.max_flow == pytest.approx(5.0)


def test_bottleneck_path():
    net = FlowNetwork()
    net.add_edge("s", "a", 10.0)
    net.add_edge("a", "b", 1.5)
    net.add_edge("b", "t", 10.0)
    res = net.preflow_push("s", "t")
    assert res.max_flow == pytest.approx(1.5)
    assert res.edge_flow("a", "b") == pytest.approx(1.5)


def test_disconnected():
    net = FlowNetwork()
    net.add_edge("s", "a", 1.0)
    net.add_edge("b", "t", 1.0)
    assert net.preflow_push("s", "t").max_flow == 0.0


def test_flow_conservation_and_capacity():
    rng = np.random.default_rng(0)
    net = FlowNetwork()
    nodes = list(range(8))
    for _ in range(20):
        u, v = rng.choice(nodes, 2, replace=False)
        net.add_edge(int(u), int(v), float(rng.integers(1, 10)))
    net.add_edge("s", 0, 15.0)
    net.add_edge(7, "t", 15.0)
    res = net.preflow_push("s", "t")
    # capacity constraints
    for (u, v), f in res.flow.items():
        assert f <= net.capacity[(u, v)] + 1e-6
    # conservation at internal nodes
    for n in nodes:
        inflow = sum(f for (u, v), f in res.flow.items() if v == n)
        outflow = sum(f for (u, v), f in res.flow.items() if u == n)
        assert inflow == pytest.approx(outflow, abs=1e-6)


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 7), st.integers(1, 20), st.integers(0, 10_000))
def test_matches_networkx(n_nodes, n_edges, seed):
    rng = np.random.default_rng(seed)
    net = FlowNetwork()
    net.add_edge("s", 0, float(rng.integers(1, 20)))
    net.add_edge(n_nodes - 1, "t", float(rng.integers(1, 20)))
    for _ in range(n_edges):
        u = int(rng.integers(n_nodes))
        v = int(rng.integers(n_nodes))
        if u == v:
            continue
        net.add_edge(u, v, float(rng.integers(1, 20)))
    ours = net.preflow_push("s", "t").max_flow
    g = _to_nx(net)
    theirs = nx.maximum_flow_value(g, "s", "t",
                                   flow_func=nx.algorithms.flow.preflow_push)
    assert ours == pytest.approx(theirs, rel=1e-9, abs=1e-9)
